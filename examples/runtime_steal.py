"""SynergyRuntime demo: one workload, live engines, jobs that migrate.

Shows the paper's §4.3 thief protocol on real threads: a ThreadedPipeline
whose GEMM stage is *pinned* to F-PE runs under a runtime scope, so the pin
is only a queue-affinity hint — the idle S-PE steals row-panel tile jobs
from F-PE's deque and the merged result is unchanged.  Then an engine is
hot-plugged mid-run (register_engine -> live rebalance) and retired again.

    PYTHONPATH=src python examples/runtime_steal.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core.job import JobSet
from repro.core.pipeline import EngineStage, ThreadedPipeline
from repro.engines import registered
from repro.engines.sim import SIM_ENGINE_SPECS, SimPEEngine
from repro.soc import SimRuntime, SynergyRuntime


def main():
    w = jax.random.normal(jax.random.key(0), (64, 48))
    frames = [jax.random.normal(jax.random.key(i), (320, 64))
              for i in range(8)]
    stages = [EngineStage.gemm("mm", w, engine="F-PE", tile=(32, 32, 32)),
              ("post", lambda y: float(jnp.sum(y)))]

    # --- pinned vs runtime ------------------------------------------------
    _, pinned = ThreadedPipeline(stages).run(frames)
    print(f"pinned   : {pinned['fps']:6.1f} fps, all jobs on F-PE")

    with SynergyRuntime(["F-PE", "S-PE"], name="demo") as rt, rt.scope():
        _, st = ThreadedPipeline(stages).run(frames)
        stats = st["runtime"]
        print(f"runtime  : {st['fps']:6.1f} fps, "
              f"steals={stats['total_steals']}, "
              f"agg busy fraction={stats['aggregate_busy_fraction']:.2f}")
        for name, s in stats["engines"].items():
            print(f"  {name:<5s} jobs={s['jobs']:<3d} steals={s['steals']:<3d} "
                  f"busy={s['busy_fraction']:5.1%}")

        # --- hot-plug an engine mid-run (live rebalance) ------------------
        boosted = SimPEEngine("X-PE", SIM_ENGINE_SPECS["F-PE"].scaled(4.0))
        rt2 = SynergyRuntime(["F-PE", "S-PE"], follow_registry=True,
                             name="hotplug").start()
        with registered(boosted):            # register_engine -> pool grows
            print(f"\nhot-plug : pool={rt2.engine_names}")
        print(f"unplug   : pool={rt2.engine_names}")
        rt2.shutdown()

    # --- virtual-time conformance twin ------------------------------------
    js = JobSet.for_gemm(0, 320, 48, 64, 32, name="mm")
    sim = SimRuntime(["F-PE", "S-PE"]).run(js, affinity="F-PE")
    print(f"\nSimRuntime (virtual time, same steal policy as the DES): "
          f"jobs={sim.per_engine_jobs} steals={sim.per_engine_steals} "
          f"busy fraction={sim.aggregate_busy_fraction:.2f}")


if __name__ == "__main__":
    main()
