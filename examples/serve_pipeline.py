"""Synergy serving engine demo: the paper's inter-frame pipeline (C4) +
work-stealing-style balancing (C3) at REQUEST granularity.

Stages (threads + mailboxes, exactly the paper's producer/consumer layout):
  tokenize(stub) -> prefill (big GEMM jobs) -> decode xN (small jobs)
  -> detokenize(stub)

Prefill and decode are the heterogeneous job mix the Synergy scheduler
balances: prefill jobs are compute-heavy tiles, decode jobs are
memory-bound tiles.  A StragglerRebalancer shifts the request share between
two decode "clusters" (replica groups), emulating a degraded replica.

    PYTHONPATH=src python examples/serve_pipeline.py
"""

import sys, os, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.core.pipeline import ThreadedPipeline
from repro.models import decode_step, init_cache, init_model, prefill
from repro.runtime import StragglerRebalancer

DECODE_TOKENS = 8
PROMPT = 32


def main():
    cfg = reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=64)
    params = init_model(cfg, jax.random.key(0))

    prefill_fn = jax.jit(lambda p, t: prefill(cfg, p, tokens=t))
    decode_fn = jax.jit(
        lambda p, c, t, i: decode_step(cfg, p, c, t, i))

    def stage_tokenize(req_id):
        toks = jax.random.randint(jax.random.key(req_id), (1, PROMPT), 0,
                                  cfg.vocab_size)
        return req_id, toks

    def stage_prefill(item):
        req_id, toks = item
        logits = prefill_fn(params, toks)
        first = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        cache = init_cache(cfg, 1, PROMPT + DECODE_TOKENS + 1)
        return req_id, first, cache

    def stage_decode(item):
        req_id, tok, cache = item
        out = [int(tok[0, 0])]
        for i in range(DECODE_TOKENS):
            logits, cache = decode_fn(params, cache, tok,
                                      jnp.int32(PROMPT + i))
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            out.append(int(tok[0, 0]))
        return req_id, out

    def stage_detok(item):
        req_id, toks = item
        return req_id, " ".join(map(str, toks))

    pipe = ThreadedPipeline([
        ("tokenize", stage_tokenize),
        ("prefill", stage_prefill),
        ("decode", stage_decode),
        ("detok", stage_detok),
    ], mailbox_capacity=4)

    n_req = 12
    outs, stats = pipe.run(list(range(n_req)))
    print(f"served {len(outs)} requests at {stats['fps']:.1f} req/s "
          f"(wall {stats['wall_s']:.2f}s)")
    for name, u in stats["stage_utilization"].items():
        print(f"  stage {name:<9s} utilization {u:5.1%}")
    print("sample:", outs[0][1])

    # --- between-step work stealing across two decode replicas ------------
    print("\nstraggler rebalancing (replica B degraded 2x):")
    rb = StragglerRebalancer(2, ema=0.5)
    shares = rb.shares
    for step in range(12):
        t_a = shares[0] / 1.0
        t_b = shares[1] / 0.5          # replica B at half speed
        shares = rb.observe([t_a, t_b])
        if step % 3 == 2:
            counts = rb.split_jobs(n_req)
            print(f"  step {step}: shares A={shares[0]:.2f} "
                  f"B={shares[1]:.2f} -> jobs {counts}")


if __name__ == "__main__":
    main()
