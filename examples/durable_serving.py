"""Durable serving demo: SIGKILL a serving worker mid-wave, restore it.

A child process serves a batch of requests on a durable
:class:`~repro.core.serving.SynergyServer` — every accepted request and
every emitted token hits a write-ahead journal before it is visible, and
crash-consistent snapshots land through the seed Checkpointer on a step
cadence.  The parent SIGKILLs the child mid-generation (a real kill -9,
no cleanup handlers run), then calls ``SynergyServer.restore`` on the
same directory: the latest snapshot loads, the journal suffix replays
(every recomputed token verified bitwise against the record), and the
restored server finishes every request.  The printed streams match a
never-crashed reference exactly — served once, lost never.

The child also installs :func:`~repro.soc.install_sigterm_drain`, so a
polite ``SIGTERM`` (instead of the demo's ``SIGKILL``) would drain
gracefully: finish live generations, snapshot, close the journal.

    PYTHONPATH=src python examples/durable_serving.py
"""

import os
import subprocess
import sys
import tempfile
import textwrap

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                  # noqa: E402
import jax.numpy as jnp                                     # noqa: E402

from repro.configs import ARCHS, reduced                    # noqa: E402
from repro.core.serving import Request, SynergyServer       # noqa: E402
from repro.models import init_model                         # noqa: E402
from repro.soc import Durability, RequestJournal            # noqa: E402

N_REQ, NEW_TOKENS, PLEN = 6, 12, 4

#: the worker: a durable server that snapshots every 4 steps and prints
#: a heartbeat per step so the parent can kill it demonstrably mid-wave
_WORKER = textwrap.dedent("""
    import sys
    import jax, jax.numpy as jnp
    from repro.configs import ARCHS, reduced
    from repro.core.serving import Request, SynergyServer
    from repro.models import init_model
    from repro.soc import Durability, install_sigterm_drain

    cfg = reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=32,
                  n_heads=2, d_ff=64, vocab=128)
    params = init_model(cfg, jax.random.key(0))
    srv = SynergyServer(cfg, params, slots=3, max_len=32, prefill_len=4,
                        durable=Durability(sys.argv[1], snapshot_every=4))
    install_sigterm_drain(srv)        # SIGTERM would drain gracefully...
    for i in range(6):
        srv.submit(Request(i, jnp.arange(4, dtype=jnp.int32) + 3 * i,
                           max_new_tokens=12))
    while srv.step():                 # ...but SIGKILL gets no warning
        print("step", srv.stats.engine_steps, "tokens",
              srv.stats.tokens_out, flush=True)
""")


def reference(cfg, params):
    srv = SynergyServer(cfg, params, slots=3, max_len=32, prefill_len=4)
    reqs = [Request(i, jnp.arange(4, dtype=jnp.int32) + 3 * i,
                    max_new_tokens=NEW_TOKENS) for i in range(N_REQ)]
    for r in reqs:
        srv.submit(r)
    srv.run()
    return {r.rid: list(r.out) for r in reqs}


def main():
    cfg = reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=32,
                  n_heads=2, d_ff=64, vocab=128)
    params = init_model(cfg, jax.random.key(0))

    workdir = tempfile.mkdtemp(prefix="durable-serving-")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = (os.path.abspath(src) + os.pathsep
                         + env.get("PYTHONPATH", ""))

    print(f"== worker serving {N_REQ} requests into {workdir}")
    child = subprocess.Popen([sys.executable, "-c", _WORKER, workdir],
                             stdout=subprocess.PIPE, text=True, env=env)
    for line in child.stdout:          # kill -9 mid-generation
        print("  worker:", line.strip())
        if "tokens" in line and int(line.split()[-1]) >= 11:
            child.kill()
            break
    child.wait()
    print(f"== worker SIGKILLed (rc={child.returncode}) — no cleanup ran")

    records, _, torn = RequestJournal.scan(
        os.path.join(workdir, "journal.bin"))
    print(f"== journal: {len(records)} records"
          + (" + torn tail (truncated on restore)" if torn else ""))

    print("== restoring: latest snapshot + journal-suffix replay")
    srv = SynergyServer.restore(
        cfg, params, durable=Durability(workdir, snapshot_every=4),
        slots=3, max_len=32, prefill_len=4)
    print(f"   replayed {srv.stats.replayed_tokens} already-delivered "
          f"tokens (verified bitwise), resuming fresh serving")
    srv.run()

    ref = reference(cfg, params)
    print("== streams after crash + restore vs never-crashed reference:")
    ok = True
    for rid in sorted(srv.restored_requests):
        got = list(srv.restored_requests[rid].out)
        match = got == ref[rid]
        ok &= match
        print(f"   rid {rid}: {got} {'== reference' if match else '!= '}"
              + ("" if match else str(ref[rid])))
    stats = srv.close()
    print(f"== served exactly once: {ok};  fresh tokens "
          f"{stats.tokens_out}, replayed {stats.replayed_tokens}, "
          f"snapshots {stats.snapshots}, restores {stats.restores}")
    assert ok


if __name__ == "__main__":
    main()
