"""The paper end-to-end: CIFAR CNN inference through the Synergy stack —
im2col + tiled-MM jobs + layer-threaded pipeline — plus the DES
reproduction of Fig 9 / Fig 13 / Table 6 numbers.

    PYTHONPATH=src python examples/cnn_inference.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.paper_cnns import PAPER_CNNS
from repro.core.pipeline import EngineStage, ThreadedPipeline
from repro.core.scheduler import simulate, single_thread_latency, search_sc
from repro.core.synergy_mm import SynergyTrace
from repro.models.cnn import build_simnet, cnn_forward, init_cnn


def main():
    cfg = PAPER_CNNS["CIFAR_full"]
    params = init_cnn(cfg, jax.random.key(0))

    # --- job decomposition of one frame ------------------------------------
    x = jax.random.normal(jax.random.key(1),
                          (1, cfg.input_hw, cfg.input_hw, cfg.cin))
    tr = SynergyTrace()
    with tr.activate():
        logits = jax.jit(lambda p, xx: cnn_forward(cfg, p, xx))(params, x)
    print(f"{cfg.name}: logits {logits.shape}, "
          f"{len(tr.jobsets)} GEMMs -> {tr.num_jobs} tile jobs (TS=32)")
    for js in tr.jobsets:
        print(f"  {js.name:<22s} m={js.m:<5d} n={js.n:<4d} k={js.k:<5d} "
              f"jobs={js.num_jobs:<3d} pad_waste={js.padding_waste:5.1%}")
    for name, t in tr.engine_stats.items():
        print(f"  dispatched to {name}: {t.gemms} GEMMs / {t.jobs} jobs "
              f"(~{t.busy_s*1e3:.2f} ms est busy)")

    # --- inter-frame pipeline over engine-backed stages --------------------
    conv = jax.jit(lambda p, xx: cnn_forward(cfg, p, xx, engine="xla"))
    stages = [EngineStage("infer", lambda f: conv(params, f), engine="xla"),
              ("postproc", lambda lg: int(jnp.argmax(lg)))]
    frames = [jax.random.normal(jax.random.key(i),
                                (1, cfg.input_hw, cfg.input_hw, cfg.cin))
              for i in range(16)]
    pipe = ThreadedPipeline(stages)
    outs, stats = pipe.run(frames)
    print(f"\npipelined inference: {stats['fps']:.1f} frames/s on CPU, "
          f"stage util {stats['stage_utilization']}")

    # --- LIVE work stealing: one frame split across the PE pool -------------
    from repro.soc import SynergyRuntime
    with SynergyRuntime(["F-PE", "S-PE", "NEON"], name="cnn") as rt:
        logits_rt = cnn_forward(cfg, params, x, runtime=rt)
        st = rt.stats()
    drift = float(jnp.max(jnp.abs(logits_rt - logits)))
    print(f"\nruntime split across {list(st['engines'])}: "
          f"{st['total_jobs']} tile jobs, {st['total_steals']} stolen, "
          f"agg busy fraction {st['aggregate_busy_fraction']:.2f} "
          f"(|logits drift| {drift:.2e})")

    # --- the paper's runtime, reproduced ------------------------------------
    print("\nZynq runtime simulation (calibrated DES):")
    net = build_simnet(cfg)
    st = single_thread_latency(net)
    ws = simulate(net, policy="ws", frames=96)
    sf = simulate(net, policy="sf", frames=96)
    _, _, sc = search_sc(net, frames=64)
    print(f"  single-thread ARM: {st*1e3:7.1f} ms/frame")
    print(f"  Synergy (WS):      {ws.fps:7.1f} fps "
          f"(speedup {ws.fps*st:.1f}x, util {ws.utilization:.1%})")
    print(f"  static fixed (SF): {sf.fps:7.1f} fps (util {sf.utilization:.1%})")
    print(f"  static custom(SC): {sc.fps:7.1f} fps (util {sc.utilization:.1%})")
    print(f"  WS vs SF: +{100*(ws.fps/sf.fps-1):.0f}%   "
          f"WS vs SC: +{100*(ws.fps/sc.fps-1):.0f}%   (paper: +24% / +6%)")


if __name__ == "__main__":
    main()
