"""End-to-end training driver: data pipeline -> sharded train step ->
checkpointing -> fault-tolerant supervisor.

CPU demo (default):    PYTHONPATH=src python examples/train_lm.py
~100M model (TPU pod): PYTHONPATH=src python examples/train_lm.py \
                           --preset 100m --steps 300
Resume after crash:    re-run the same command — the supervisor restores
                       the latest atomic checkpoint automatically.
"""

import argparse
import dataclasses
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.checkpoint import Checkpointer
from repro.configs import ARCHS, reduced
from repro.configs.base import ArchConfig, ShapeCell
from repro.data import prefetch, synthetic_batches
from repro.launch.mesh import make_test_mesh
from repro.launch.train import build_train_step, make_train_state
from repro.runtime import run_with_recovery

PRESETS = {
    # ~2M params: CPU-friendly smoke run
    "tiny": dict(n_layers=4, d_model=128, n_heads=4, d_ff=512, vocab=2048),
    # ~25M params
    "small": dict(n_layers=8, d_model=384, n_heads=8, d_ff=1536, vocab=8192),
    # ~100M params: the end-to-end target (run on real accelerators)
    "100m": dict(n_layers=12, d_model=768, n_heads=12, d_ff=3072,
                 vocab=32000),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b",
                    choices=sorted(ARCHS))
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = reduced(ARCHS[args.arch], **p)
    cfg = dataclasses.replace(cfg, remat=False)
    cell = ShapeCell("train", args.seq, args.batch, "train")
    mesh = make_test_mesh(data=1, model=1)

    with mesh:
        jfn, (aval, sspecs), _ = build_train_step(cfg, cell, mesh,
                                                  donate=False)
        ck = Checkpointer(args.ckpt_dir, keep=2, async_write=True)
        batches = prefetch(synthetic_batches(cfg, cell, seed=0), depth=2)

        def run_steps(start, end, state):
            it = prefetch(synthetic_batches(cfg, cell, seed=0,
                                            start_step=start), depth=2)
            for s in range(start, end):
                state, metrics = jfn(state, next(it))
                if (s + 1) % 5 == 0 or s == start:
                    print(f"step {s+1:4d}  loss {float(metrics['loss']):.4f}"
                          f"  gnorm {float(metrics.get('grad_norm', 0)):.3f}")
                if (s + 1) % args.ckpt_every == 0:
                    ck.save(s + 1, state)
            ck.wait()
            return state

        resume = ck.latest_step()
        if resume:
            print(f"resuming from checkpoint step {resume}")
            state = ck.restore(aval)
        else:
            state = make_train_state(cfg, jax.random.key(0))

        state, failures = run_with_recovery(
            steps=args.steps, run_steps=run_steps, checkpointer=ck,
            state0=state)
        print(f"done at step {int(state['step'])}; "
              f"{len(failures)} recovered failures")


if __name__ == "__main__":
    main()
