"""Chaos demo: kill an engine mid-run and watch the pool recover.

A 3-engine pool serves waves of GEMM submissions.  A deterministic
:class:`~repro.soc.FaultPlan` (seed-reproducible — rerun the script and
the SAME faults hit at the SAME calls) injects two transient panel
exceptions on one engine and then KILLS another engine's worker thread
mid-wave.  The runtime's :class:`~repro.soc.RetryPolicy` absorbs all of
it: failed panels re-seed onto surviving engines, the heartbeat monitor
declares the dead worker and re-seeds its orphaned panels, and every
merged output stays bitwise identical to the fault-free answer — faults
cost retries, never ULPs.

    PYTHONPATH=src python examples/chaos_pool.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                  # noqa: E402
import jax.numpy as jnp                                     # noqa: E402
import numpy as np                                          # noqa: E402

from repro.core.job import JobSet                           # noqa: E402
from repro.engines import CAP_GEMM, CostModel, Engine       # noqa: E402
from repro.soc import (FaultPlan, FaultSpec, RetryPolicy,   # noqa: E402
                       SynergyRuntime, wrap_pool)

M, K, N, TILE = 256, 64, 48, (32, 32, 32)
WAVES = 12


class PacedEngine(Engine):
    """Identical fp32 math on every instance, paced by the cost model so
    the pool behaves like real heterogeneous silicon."""

    def __init__(self, name, macs_per_s):
        super().__init__(name, {CAP_GEMM, "epilogue"},
                         cost=CostModel(macs_per_s=macs_per_s))

    def execute(self, a, b, *, bias=None, activation=None, tile=None,
                out_dtype=None, precision=None):
        m, k = a.shape
        time.sleep(m * k * b.shape[1] / self.cost.macs_per_s)
        y = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
        return y.astype(out_dtype or a.dtype)


def pool():
    return [PacedEngine("cp-a", 4e7), PacedEngine("cp-b", 4e7),
            PacedEngine("cp-c", 2e7)]


def run_waves(rt, base):
    ka, kb = jax.random.split(jax.random.key(7))
    a = jax.random.normal(ka, (M, K))
    b = jax.random.normal(kb, (K, N))
    outs = []
    for i in range(WAVES):
        fut = rt.submit_gemm(
            a, b, jobset=JobSet.for_gemm(base + i, M, K, N, 32,
                                         name=f"wave{base + i}"),
            tile=TILE)
        outs.append(np.asarray(fut.result(120)))
    return outs


def main():
    retry = RetryPolicy(max_attempts=4, heartbeat_timeout_s=0.2,
                        monitor_interval_s=0.05)

    print("clean run (no faults)...")
    with SynergyRuntime(pool(), name="warm", retry=retry) as rt:
        run_waves(rt, 900)                # warmup: jit compiles, untimed
    t0 = time.perf_counter()
    with SynergyRuntime(pool(), name="clean", retry=retry) as rt:
        clean = run_waves(rt, 0)
    clean_s = time.perf_counter() - t0
    print(f"  {WAVES} waves in {clean_s:.2f}s\n")

    plan = FaultPlan((
        FaultSpec("cp-b", "raise", at_call=1, count=2),   # transient panics
        FaultSpec("cp-c", "die", at_call=4),              # worker crash
    ), seed=13)
    print("chaos run: 2 injected panel exceptions on cp-b, then cp-c's "
          "worker is killed mid-wave...")
    t0 = time.perf_counter()
    with SynergyRuntime(wrap_pool(pool(), plan), name="chaos",
                        retry=retry) as rt:
        chaos = run_waves(rt, 100)
        stats = rt.stats()
    chaos_s = time.perf_counter() - t0

    print(f"  {WAVES} waves in {chaos_s:.2f}s on the wounded pool")
    print(f"  injected        : "
          f"{[(e, k, c) for e, k, c in plan.injected]}")
    print(f"  panel retries   : {stats['retries']}")
    print(f"  worker deaths   : {stats['worker_deaths']}")
    print(f"  orphan re-seeds : {stats['orphan_reseeds']}")

    bitwise = all(np.array_equal(c, f) for c, f in zip(clean, chaos))
    print(f"  outputs bitwise identical to clean run: {bitwise}")
    assert bitwise, "fault recovery must never change the math"
    assert stats["worker_deaths"] == 1 and stats["retries"] >= 2
    print(f"\nrecovered throughput: {WAVES / chaos_s:.1f} waves/s vs "
          f"{WAVES / clean_s:.1f} clean "
          f"({(WAVES / chaos_s) / (WAVES / clean_s):.2f}x)")


if __name__ == "__main__":
    main()
