"""Trace a 3-engine work-stealing pool and export a Chrome trace.

A burst of GEMMs is submitted with affinity to ONE engine of a
heterogeneous 3-engine pool, so the other two engines must steal their
share — every seed, enqueue, dequeue, steal, and panel execution lands
on one :class:`repro.obs.Tracer`, which is then exported as Chrome
``trace_event`` JSON.  Open the file in ``chrome://tracing`` or
https://ui.perfetto.dev to see one timeline track per engine with panel
spans and steal markers.

    PYTHONPATH=src python examples/trace_steals.py [out.json]
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro.core.job import JobSet
from repro.obs import Tracer, render_prometheus, validate_events
from repro.soc import SynergyRuntime


def main(out_path: str = "results/trace_steals.json") -> None:
    tracer = Tracer(capacity=100_000)
    a = jnp.ones((128, 32))
    b = jnp.ones((32, 32))
    with SynergyRuntime(["F-PE", "S-PE", "NEON"], name="trace-demo",
                        tracer=tracer) as rt:
        # everything seeds onto F-PE; S-PE and NEON must steal to help
        futs = [rt.submit_gemm(
            a, b, jobset=JobSet.for_gemm(s, 128, 32, 32, 32,
                                         name=f"burst{s}"),
            tile=(32, 32, 32), affinity="F-PE") for s in range(12)]
        for f in futs:
            f.result(60)
        stats = rt.stats()
        prom = render_prometheus(runtime=rt)

    events = tracer.events()
    errors = validate_events(events, engines={"F-PE", "S-PE", "NEON"})
    assert not errors, errors

    counts = tracer.counts()
    print(f"recorded {len(events)} events: "
          + ", ".join(f"{k}={v}" for k, v in sorted(counts.items())))
    steals = [e for e in events if e.kind == "steal"]
    for ev in steals[:8]:
        print(f"  steal @ {ev.ts:.6f}s: {ev.track} <- "
              f"{ev.tags['victim']} ({ev.tags['jobset']})")
    if len(steals) > 8:
        print(f"  ... and {len(steals) - 8} more steals")
    for name, es in stats["engines"].items():
        print(f"  {name}: jobs={es['jobs']} steals={es['steals']} "
              f"busy={es['busy_fraction']:.2f}")

    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    n = tracer.export_chrome_trace(out_path)
    print(f"wrote {n} Chrome trace events -> {out_path}")
    print("open it in chrome://tracing or https://ui.perfetto.dev")
    print("\n--- Prometheus exposition (first 12 lines) ---")
    print("\n".join(prom.splitlines()[:12]))


if __name__ == "__main__":
    main(*sys.argv[1:])
