"""The heterogeneous precision zoo: fp32 + int8 + VPU engines on one chip.

Walks the whole quant subsystem end to end:

  1. calibrate + register an int8 engine over the XLA backend — the gate
     now measures the TRUE int8×int8 qmm path and swaps the nominal 4x
     cost guess for the measured kernel rate (and the registry still
     REFUSES an engine that misses tolerance);
  2. the online activation-calibration lifecycle: a fresh engine starts
     on the weight-only fp32-cast dot, the first live batch publishes a
     per-shape ActScale, and from then on the contraction consumes int8
     operands (jaxpr-visible);
  3. precision routing: decode-class GEMMs land on the int8 engine,
     prefill/train stay on grad-safe full-precision paths, and plain
     auto-dispatch never silently quantizes;
  4. serving: a SynergyServer whose decode steps run quantized AND feed
     the calibrator, with per-precision job counts in ServeStats;
  5. the throughput claim: a mixed fp32+int8+VPU pool beats the best
     homogeneous pool on busy-fraction-weighted simulated fps, while the
     int8 outputs stay inside the calibrated tolerance of the fp32 oracle.

    PYTHONPATH=src python examples/quant_zoo.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core.job import JobSet
from repro.engines import Dispatcher, get_engine, unregister_engine
from repro.engines.sim import SIM_ENGINE_SPECS, SimPEEngine
from repro.engines.vpu import NeonVpuEngine
from repro.quant import (CalibrationError, QuantizedEngine, calibrate,
                         register_quantized, rel_err)
from repro.soc import SimRuntime


def banner(title):
    print(f"\n=== {title} " + "=" * max(0, 60 - len(title)))


def main():
    # --- 1. calibrated registration --------------------------------------
    banner("calibrate + register (gated on the int8x8 path)")
    eng = register_quantized("xla", tol=0.05)
    print(f"registered {eng.name!r}: {eng.calibration}")
    print(f"  cost model: measured "
          f"{eng.cost.macs_per_s / 1e9:.2f} GMAC/s on the real qmm kernel "
          f"(drops the nominal {eng.speedup:g}x guess)")
    try:
        register_quantized("xla", name="impossible-int8", tol=1e-9)
    except CalibrationError as e:
        print(f"refused past tolerance: {type(e).__name__}: "
              f"{str(e).split(':')[0]} ...")

    # --- 1b. the online activation-calibration lifecycle -----------------
    banner("activation calibration: weight-only -> int8x8")
    fresh = QuantizedEngine(get_engine("xla"), name="lifecycle-int8")
    ka, kb = jax.random.split(jax.random.key(3))
    a = jax.random.normal(ka, (4, 64))
    w = jax.random.normal(kb, (64, 128)) * 0.05
    print(f"  before any live batch: act scale = "
          f"{fresh.act_scale_for(64, 128)} (weight-only fp32-cast dot)")
    y = fresh.execute(a, w)                  # first decode batch observes
    s = fresh.act_scale_for(64, 128)
    print(f"  after one decode batch: act scale = {s:.5f} "
          f"-> int8 operands into the contraction")
    rel = rel_err(y, get_engine("reference").execute(a, w))
    print(f"  int8x8 rel err vs oracle: {rel:.2e}")

    # --- 2. precision routing --------------------------------------------
    banner("job-class routing")
    js = JobSet.for_gemm(0, 8, 256, 64, 32, name="decode-step")
    d = Dispatcher()
    for cls in (None, "decode", "prefill", "train"):
        picked = d.select(js, job_class=cls)
        print(f"  job_class={str(cls):<8} -> {picked.name}")

    # --- 3. serving with quantized decode --------------------------------
    banner("SynergyServer: quantized decode steps")
    from repro.configs import ARCHS, reduced
    from repro.core.serving import Request, SynergyServer
    from repro.models import init_model
    cfg = reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=32,
                  n_heads=2, d_ff=64, vocab=128)
    params = init_model(cfg, jax.random.key(0))
    srv = SynergyServer(cfg, params, slots=2, max_len=32, prefill_len=4)
    for i in range(3):
        srv.submit(Request(i, jax.random.randint(jax.random.key(i), (4,),
                                                 0, 128), max_new_tokens=6))
    stats = srv.run()
    print(f"  routed: {stats.job_engine}")
    print(f"  per-precision tile jobs: {stats.precision_jobs}")
    unregister_engine(eng.name)

    # --- 4. mixed pool vs best homogeneous pool --------------------------
    banner("mixed fp32+int8+VPU pool (virtual time)")
    fp32 = SimPEEngine("zoo-fp32", SIM_ENGINE_SPECS["F-PE"])
    int8 = QuantizedEngine(fp32, name="zoo-int8")
    vpu = NeonVpuEngine("zoo-vpu", interpret=True,
                        cost=SIM_ENGINE_SPECS["NEON"])
    report = calibrate(int8, tol=0.05)
    frames = [JobSet.for_gemm(i, 128, 256, 64, 32, name=f"decode{i}")
              for i in range(16)]

    def run_pool(engines):
        makespan, fracs = 0.0, 0.0
        for js in frames:
            res = SimRuntime(engines).run(js)
            makespan += res.makespan_s
            fracs += res.aggregate_busy_fraction
        fps = len(frames) / makespan
        return fps, fps * fracs / len(frames)

    results = {}
    for name, pool in [("fp32-only", [fp32]), ("int8-only", [int8]),
                       ("vpu-only", [vpu]), ("mixed", [fp32, int8, vpu])]:
        fps, wfps = run_pool(pool)
        results[name] = wfps
        print(f"  {name:<10} {fps:7.1f} fps  "
              f"{wfps:7.1f} busy-fraction-weighted fps")
    best_homog = max(v for k, v in results.items() if k != "mixed")
    gain = results["mixed"] / best_homog
    print(f"  mixed pool vs best homogeneous: {gain:.2f}x "
          f"({'WINS' if gain > 1 else 'loses'})")

    # the accuracy side of the trade: int8 decode output vs fp32 oracle,
    # measured with the same formula the calibration gate uses
    ka, kb = jax.random.split(jax.random.key(1))
    a = jax.random.normal(ka, (4, 64))
    w = jax.random.normal(kb, (64, 256)) * 0.05
    rel = rel_err(int8.execute(a, w), fp32.execute(a, w))
    print(f"  int8 decode rel err vs fp32 oracle: {rel:.2e} "
          f"(calibrated tol {report.tol:g}) -> "
          f"{'within tolerance' if rel <= report.tol else 'OUT OF TOLERANCE'}")

    # --- 5. the VPU kernel is real compute --------------------------------
    banner("NeonVpuEngine: MXU-free Pallas kernel (interpret off-TPU)")
    y = get_engine("neon-vpu").execute(a, w, tile=(16, 16, 16))
    ref = get_engine("reference").execute(a, w)
    print(f"  vpu_mm matches oracle: "
          f"{bool(jnp.allclose(y, ref, rtol=1e-4, atol=1e-4))}")

    assert gain > 1.0 and rel <= report.tol


if __name__ == "__main__":
    main()
