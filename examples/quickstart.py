"""Quickstart: build a tiny LM, inspect its Synergy tile-job decomposition,
train a few steps, decode a few tokens.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeCell
from repro.core.synergy_mm import SynergyTrace
from repro.models import decode_step, init_cache, init_model, lm_loss
from repro.optim import AdamWConfig, adamw_init, adamw_update


def main():
    cfg = reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=64)
    key = jax.random.key(0)
    params = init_model(cfg, key)
    print(f"arch={cfg.name} (reduced) params="
          f"{sum(p.size for p in jax.tree.leaves(params)):,}")

    # --- the Synergy view: every GEMM is a tile-job set -------------------
    batch = {
        "tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
    }
    tr = SynergyTrace()
    with tr.activate():
        jax.eval_shape(lambda p: lm_loss(cfg, p, batch), params)
    print(f"traced {len(tr.jobsets)} GEMMs -> {tr.num_jobs} tile jobs, "
          f"{tr.total_flops/1e9:.2f} GFLOP per step")
    for js in tr.jobsets[:4]:
        print(f"  layer {js.layer_id:<2d} {js.name:<22s} "
              f"m={js.m:<6d} n={js.n:<6d} k={js.k:<5d} jobs={js.num_jobs}")
    # where the dispatcher routed the work (the unified engine registry)
    for name, t in tr.engine_stats.items():
        print(f"  engine {name:<10s} gemms={t.gemms:<3d} jobs={t.jobs:<5d} "
              f"busy~{t.busy_s*1e3:.2f}ms bytes={t.bytes_moved/1e6:.1f}MB")

    # --- a few train steps -------------------------------------------------
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100)
    opt = adamw_init(params)
    step = jax.jit(lambda p, o, b: _train(cfg, opt_cfg, p, o, b))
    for i in range(5):
        params, opt, loss = step(params, opt, batch)
        print(f"step {i}: loss {float(loss):.4f}")

    # --- decode -------------------------------------------------------------
    cache = init_cache(cfg, 1, 16)
    tok = jnp.zeros((1, 1), jnp.int32)
    dec = jax.jit(lambda p, c, t, i: decode_step(cfg, p, c, t, i))
    out = []
    for i in range(8):
        logits, cache = dec(params, cache, tok, jnp.int32(i))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print("greedy decode:", out)


def _train(cfg, opt_cfg, params, opt, batch):
    loss, grads = jax.value_and_grad(lambda p: lm_loss(cfg, p, batch))(params)
    params, opt, _ = adamw_update(opt_cfg, grads, opt, params)
    return params, opt, loss


if __name__ == "__main__":
    main()
