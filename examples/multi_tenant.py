"""Multi-tenant QoS demo: the same overloaded 2-tenant request mix served
(a) by the untenanted FIFO server and (b) by the QoS server, printing
each tenant's SLO deadline attainment side by side.

A BULK flood (12 sheddable, undeadlined requests) is submitted AHEAD of
a small GOLD stream (4 interactive requests with a deadline).  FIFO
admits in arrival order, so every gold request waits behind the whole
flood and misses; QoS admission picks gold first (priority 10, weight
4) and its prefill/decode panels carry priority tags through the
work-stealing runtime, so gold meets its deadline while bulk absorbs
the queueing delay.

    PYTHONPATH=src python examples/multi_tenant.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.configs import ARCHS, reduced                    # noqa: E402
from repro.core.serving import Request, SynergyServer       # noqa: E402
from repro.models import init_model                         # noqa: E402
from repro.soc import SynergyRuntime, Tenant                # noqa: E402
from repro.soc.qos import QosClass                          # noqa: E402

N_GOLD, N_BULK, SLOTS, PLEN = 4, 12, 2, 8
GOLD = QosClass("gold", priority=10, weight=4.0)
BULK = QosClass("bulk", priority=-10, sheddable=True)


def requests(base, n, tenant, max_new, deadline_s=None):
    return [Request(base + i,
                    jax.random.randint(jax.random.key(base + i), (PLEN,),
                                       0, 128),
                    max_new_tokens=max_new, tenant=tenant,
                    deadline_s=deadline_s) for i in range(n)]


def make_server(cfg, params, tenants):
    rt = SynergyRuntime(["F-PE", "S-PE"],
                        name="qos-demo" if tenants else "fifo-demo")
    srv = SynergyServer(cfg, params, slots=SLOTS, max_len=32,
                        prefill_len=PLEN, runtime=rt, tenants=tenants)
    warm = "gold" if tenants else None
    for r in requests(900_000, SLOTS, warm, 2):    # warmup: jit compiles
        srv.submit(r)
    srv.run()
    srv.reset_stats()
    return srv, rt


def attainment(gold_reqs):
    hits = sum(1 for r in gold_reqs
               if r.done_at is not None and r.done_at <= r.deadline_at)
    return hits / len(gold_reqs)


def main():
    cfg = reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=32,
                  n_heads=2, d_ff=64, vocab=128)
    params = init_model(cfg, jax.random.key(0))

    # self-calibrate the gold deadline: 1.5x the solo gold makespan
    srv_q, rt_q = make_server(cfg, params,
                              [Tenant("gold", GOLD), Tenant("bulk", BULK)])
    t0 = time.perf_counter()
    for r in requests(800_000, N_GOLD, "gold", 4):
        srv_q.submit(r)
    srv_q.run()
    deadline_s = 1.5 * (time.perf_counter() - t0) + 0.25
    srv_q.reset_stats()
    print(f"gold SLO deadline (self-calibrated): {deadline_s:.2f}s\n")

    results = {}
    # FIFO baseline: no tenancy, arrival order wins
    srv_f, rt_f = make_server(cfg, params, None)
    bulk = requests(0, N_BULK, None, 8)
    gold = requests(5000, N_GOLD, None, 4, deadline_s=deadline_s)
    for r in bulk + gold:
        srv_f.submit(r)
    srv_f.run()
    results["fifo"] = attainment(gold)
    rt_f.shutdown()

    # QoS: same arrival order, priority admission + tagged panels
    bulk = requests(0, N_BULK, "bulk", 8)
    gold = requests(5000, N_GOLD, "gold", 4, deadline_s=deadline_s)
    for r in bulk + gold:
        srv_q.submit(r)
    stats = srv_q.run()
    results["qos"] = attainment(gold)
    rt_q.shutdown()

    print(f"{'server':<8s} {'gold SLO attainment':>20s}   (bulk has no SLO)")
    for mode, att in results.items():
        print(f"{mode:<8s} {att:>20.0%}")
    print("\nper-tenant stats (QoS server):")
    for name, ts in sorted(stats.tenants.items()):
        print(f"  {name:<6s} admitted={ts.admitted:<3d} "
              f"tokens={ts.tokens_out:<4d} "
              f"queue_wait={ts.queue_wait_s:6.2f}s "
              f"deadline {ts.deadline_hits}/{ts.deadline_hits + ts.deadline_misses} hit")


if __name__ == "__main__":
    main()
