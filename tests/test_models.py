"""Per-arch smoke tests (reduced configs): forward/train step shapes, no
NaNs, decode; plus the strong incremental-decode == full-forward check."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import (decode_step, init_cache, init_model, lm_forward,
                          lm_loss, model_flops, prefill)
from repro.configs.base import SHAPES


def _batch(cfg, key, b=2, s=16):
    batch = {"labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.takes_embeddings:
        batch["embeds"] = jax.random.normal(key, (b, s, cfg.d_model))
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.family == "audio":
        batch["enc_embeds"] = jax.random.normal(
            key, (b, cfg.encoder_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_reduced_train_step(name):
    cfg = reduced(ARCHS[name])
    key = jax.random.key(0)
    params = init_model(cfg, key)
    batch = _batch(cfg, key)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: lm_loss(cfg, p, batch)))(params)
    assert jnp.isfinite(loss), name
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.isfinite(g).all()), (name, path)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_reduced_forward_shapes(name):
    cfg = reduced(ARCHS[name])
    params = init_model(cfg, jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1), b=2, s=16)
    logits = jax.jit(lambda p: lm_forward(
        cfg, p, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
        enc_embeds=batch.get("enc_embeds")))(params)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_reduced_decode_step(name):
    cfg = reduced(ARCHS[name])
    params = init_model(cfg, jax.random.key(0))
    cache = init_cache(cfg, 2, 24)
    tok = (jax.random.normal(jax.random.key(2), (2, 1, cfg.d_model))
           if cfg.takes_embeddings
           else jnp.zeros((2, 1), jnp.int32))
    logits, cache2 = jax.jit(
        lambda p, c, t: decode_step(cfg, p, c, t, jnp.int32(0)))(
            params, cache, tok)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("name", ["granite-3-2b", "mamba2-130m"])
def test_incremental_decode_matches_forward(name):
    """Token-by-token decode must reproduce the full-sequence forward
    logits (dense attention via KV cache; SSM via state recurrence)."""
    cfg = reduced(ARCHS[name])
    params = init_model(cfg, jax.random.key(0))
    b, s = 2, 12
    tokens = jax.random.randint(jax.random.key(3), (b, s), 0, cfg.vocab_size)
    full = lm_forward(cfg, params, tokens=tokens)      # (b, s, V)

    cache = init_cache(cfg, b, s, dtype=jnp.float32)
    step = jax.jit(lambda p, c, t, i: decode_step(cfg, p, c, t, i))
    outs = []
    for i in range(s):
        logits, cache = step(params, cache, tokens[:, i:i + 1], jnp.int32(i))
        outs.append(logits[:, 0])
    inc = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_hybrid_decode_matches_forward():
    """zamba2: mamba states + shared-attn caches together must reproduce
    the full forward."""
    cfg = reduced(ARCHS["zamba2-2.7b"])
    params = init_model(cfg, jax.random.key(0))
    b, s = 1, 8
    tokens = jax.random.randint(jax.random.key(4), (b, s), 0, cfg.vocab_size)
    full = lm_forward(cfg, params, tokens=tokens)
    cache = init_cache(cfg, b, s, dtype=jnp.float32)
    outs = []
    for i in range(s):
        logits, cache = decode_step(cfg, params, cache, tokens[:, i:i + 1],
                                    jnp.int32(i))
        outs.append(logits[:, 0])
    inc = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_prefill_equals_forward_last_token():
    cfg = reduced(ARCHS["granite-3-2b"])
    params = init_model(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(5), (2, 16), 0,
                                cfg.vocab_size)
    full = lm_forward(cfg, params, tokens=tokens)
    pre = prefill(cfg, params, tokens=tokens)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, -1:]),
                               rtol=1e-5, atol=1e-5)


def test_model_flops_moe_active_vs_total():
    cfg = ARCHS["kimi-k2-1t-a32b"]
    assert cfg.n_params() > 0.9e12            # ~1T total
    assert cfg.n_active_params() < 0.05 * cfg.n_params()  # a32b-ish
    mf = model_flops(cfg, SHAPES["train_4k"])
    assert mf > 0


def test_vocab_padding():
    for name in ("internvl2-1b", "whisper-small", "granite-3-2b",
                 "mamba2-130m"):
        cfg = ARCHS[name]
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
