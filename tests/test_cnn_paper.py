"""The paper's 7 CNN benchmarks: JAX forward correctness + DES reproduction
of the headline claims (Fig 9, Table 6)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnns import PAPER_CNNS
from repro.core.im2col import conv2d_gemm, im2col
from repro.core.synergy_mm import SynergyTrace
from repro.models.cnn import (build_simnet, cnn_flops_per_frame, cnn_forward,
                              init_cnn)


@pytest.mark.parametrize("name", sorted(PAPER_CNNS))
def test_cnn_forward(name):
    cfg = PAPER_CNNS[name]
    params = init_cnn(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1),
                          (2, cfg.input_hw, cfg.input_hw, cfg.cin))
    tr = SynergyTrace()
    with tr.activate():
        logits = jax.jit(lambda p, xx: cnn_forward(cfg, p, xx))(params, x)
    assert logits.shape == (2, cfg.num_classes)
    assert bool(jnp.isfinite(logits).all())
    n_conv = sum(1 for s in cfg.layers if s[0] == "conv")
    n_fc = sum(1 for s in cfg.layers if s[0] == "fc")
    assert len(tr.jobsets) == n_conv + n_fc        # every GEMM traced


def test_im2col_matches_lax_conv():
    x = jax.random.normal(jax.random.key(2), (2, 12, 12, 3))
    w = jax.random.normal(jax.random.key(3), (5, 5, 3, 7))
    out = conv2d_gemm(x, w, stride=1, padding=2)
    ref = jax.lax.conv_general_dilated(
        x, w, (1, 1), [(2, 2), (2, 2)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_im2col_shapes():
    x = jnp.zeros((1, 8, 8, 2))
    patches = im2col(x, 3, 3, stride=1, padding=1)
    assert patches.shape == (1, 64, 18)


def test_flops_match_paper_gops_scale():
    """Per-frame op counts should sit in the paper's GOPS-at-fps range
    (Table 4): MNIST ~22 MOP, CIFAR_full ~26 MOP."""
    assert 15e6 < cnn_flops_per_frame(PAPER_CNNS["MNIST"]) < 35e6
    assert 15e6 < cnn_flops_per_frame(PAPER_CNNS["CIFAR_full"]) < 40e6


def test_simnet_structure():
    net = build_simnet(PAPER_CNNS["CIFAR_Darknet"])
    convs = [l for l in net.layers if l.kind == "conv"]
    assert len(convs) == 4                       # Table 2: 4 CONV layers
    assert all(l.jobset.num_jobs > 0 for l in convs)
