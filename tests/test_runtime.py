"""Fault-tolerance runtime: straggler rebalancer, elastic mesh planning,
cross-pod compressed sync."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import (HeartbeatMonitor, StragglerRebalancer,
                           crosspod_traffic_bytes, plan_elastic_mesh,
                           sync_pods_compressed)


def test_heartbeat_detects_silent_host():
    hb = HeartbeatMonitor(4, timeout_steps=2)
    for step in range(5):
        for h in range(4):
            if h != 2:
                hb.beat(h, step)
    assert hb.failed_hosts(5) == [2]


def test_elastic_mesh_drops_dp_replicas():
    assert plan_elastic_mesh(256, 16) == (16, 16)
    assert plan_elastic_mesh(240, 16) == (15, 16)     # lost one replica
    assert plan_elastic_mesh(512, 16, pods=2) == (2, 16, 16)
    with pytest.raises(RuntimeError):
        plan_elastic_mesh(8, 16)


def test_straggler_rebalancer_shifts_work():
    """Cluster 1 runs at half speed; its share should fall toward 1/3."""
    rb = StragglerRebalancer(2, ema=0.5)
    shares = rb.shares
    for _ in range(40):
        times = [shares[0] / 1.0, shares[1] / 0.5]
        shares = rb.observe(times)
    assert abs(shares[0] - 2 / 3) < 0.05
    counts = rb.split_jobs(90)
    assert sum(counts) == 90
    assert counts[0] > counts[1]


def test_split_jobs_exact():
    rb = StragglerRebalancer(3)
    assert sum(rb.split_jobs(100)) == 100
    assert sum(rb.split_jobs(7)) == 7


def test_crosspod_sync_compressed_matches_mean():
    """On a (pod=2, data=2) mesh: compressed delta averaging approximates
    plain parameter averaging within int8 quantization error."""
    devices = jax.devices()
    if len(devices) < 4:
        pytest.skip("needs 4 devices (run via subprocess harness)")
    mesh = jax.make_mesh((2, 2), ("pod", "data"))
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    key = jax.random.key(0)
    anchor = {"w": jax.random.normal(key, (2, 64))}   # per-pod leading dim
    drift = {"w": jnp.stack([jnp.ones((64,)) * 0.1,
                             -jnp.ones((64,)) * 0.3])}
    params = {"w": anchor["w"] + drift["w"]}
    err = {"w": jnp.zeros((2, 64))}

    def body(p, a, e):
        p = jax.tree.map(lambda x: x[0], p)
        a = jax.tree.map(lambda x: x[0], a)
        e = jax.tree.map(lambda x: x[0], e)
        new_p, _, new_e = sync_pods_compressed(p, a, e, axis_name="pod")
        return (jax.tree.map(lambda x: x[None], new_p),
                jax.tree.map(lambda x: x[None], new_e))

    f = shard_map(body, mesh=mesh,
                  in_specs=(P("pod"), P("pod"), P("pod")),
                  out_specs=(P("pod"), P("pod")))
    new_p, _ = f(params, anchor, err)
    expected = anchor["w"] + jnp.mean(drift["w"], axis=0, keepdims=True)
    np.testing.assert_allclose(np.asarray(new_p["w"][0]),
                               np.asarray(expected[0]), atol=2e-2)


def test_compression_traffic_ratio():
    params = {"w": jnp.zeros((100_000,))}
    c = crosspod_traffic_bytes(params, compressed=True)
    u = crosspod_traffic_bytes(params, compressed=False)
    assert c < 0.3 * u
