"""Hypothesis property tests for durable serving (ISSUE 10 keystone).

For RANDOM crash points, admission modes (blocking wave vs chunked
prefill), and a torn-or-clean journal tail, over a 2-tenant server:

  * every token stream after ``SynergyServer.restore`` is BITWISE
    identical to the uninterrupted run's;
  * every accepted request is served exactly once — restored
    ``tokens_out`` + ``replayed_tokens`` equals the uninterrupted run's
    ``tokens_out``, and no request finishes short or long;
  * FairShare virtual times converge to the uninterrupted run's.

The fixed-point sweeps in ``test_durable.py`` cover the same invariants
when the hypothesis dev-dependency is absent.
"""

import shutil
import struct
import tempfile

import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev deps
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import ARCHS, reduced                  # noqa: E402
from repro.core.serving import Request, SynergyServer     # noqa: E402
from repro.models import init_model                       # noqa: E402
from repro.soc import (CrashPlan, Durability, QosClass,   # noqa: E402
                       SimulatedCrash, Tenant)

_HDR = struct.Struct("<II")

_MODEL = None
_REF = {}          # chunked -> (streams, tokens_out, fair_vt)


def _model():
    global _MODEL
    if _MODEL is None:
        cfg = reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=32,
                      n_heads=2, d_ff=64, vocab=128)
        _MODEL = cfg, init_model(cfg, jax.random.key(0))
    return _MODEL


def _tenants():
    return [Tenant("acme", QosClass("interactive", priority=1,
                                    weight=2.0)),
            Tenant("bulk", QosClass("bulk", priority=0, weight=1.0))]


def _kw(chunked):
    kw = dict(slots=2, max_len=32, prefill_len=4)
    if chunked:
        kw["prefill_chunk_macs"] = 2_000
    return kw


def _reqs():
    return [Request(i, jnp.arange(4, dtype=jnp.int32) + i,
                    max_new_tokens=5,
                    tenant="acme" if i % 2 == 0 else "bulk")
            for i in range(5)]


def _reference(chunked):
    """The uninterrupted run for one admission mode (computed once)."""
    if chunked not in _REF:
        cfg, params = _model()
        srv = SynergyServer(cfg, params, tenants=_tenants(),
                            **_kw(chunked))
        rr = _reqs()
        for r in rr:
            srv.submit(r)
        srv.run()
        _REF[chunked] = ({r.rid: list(r.out) for r in rr},
                         srv.stats.tokens_out, srv._fair.snapshot())
    return _REF[chunked]


@settings(max_examples=10, deadline=None)
@given(crash_at=st.integers(1, 16), chunked=st.booleans(),
       snapshot_every=st.sampled_from([0, 2, 4]),
       torn_tail=st.booleans())
def test_crash_restore_is_exactly_once_and_bitwise(
        crash_at, chunked, snapshot_every, torn_tail):
    cfg, params = _model()
    ref, ref_tokens, ref_vt = _reference(chunked)
    work = tempfile.mkdtemp(prefix="durprop-")
    try:
        d = Durability(work, snapshot_every=snapshot_every)
        srv = SynergyServer(cfg, params, tenants=_tenants(), durable=d,
                            crash_plan=CrashPlan(at_step=crash_at),
                            **_kw(chunked))
        rr = _reqs()
        try:
            for r in rr:
                srv.submit(r)
            srv.run()
            return        # finished before the crash point: nothing to do
        except SimulatedCrash:
            pass
        if torn_tail:     # the dying process half-wrote one more record
            with open(d.journal_path, "ab") as f:
                f.write(_HDR.pack(77, 0) + b"half-a-record")
        srv2 = SynergyServer.restore(cfg, params, durable=d,
                                     tenants=_tenants(), **_kw(chunked))
        if torn_tail:
            assert srv2._journal.truncated_bytes > 0
        srv2.run()
        got = {rid: list(r.out)
               for rid, r in srv2.restored_requests.items()}
        for r in rr:
            assert got.get(r.rid, list(r.out)) == ref[r.rid], \
                (crash_at, chunked, r.rid)
        assert (srv2.stats.tokens_out + srv2.stats.replayed_tokens
                == ref_tokens), (crash_at, chunked)
        assert srv2._fair.snapshot() == ref_vt, (crash_at, chunked)
        for r in srv2.restored_requests.values():
            assert len(r.out) == r.max_new_tokens     # exactly once
    finally:
        shutil.rmtree(work, ignore_errors=True)
