"""Continuous-batching serving engine invariants."""

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.core.serving import Request, SynergyServer
from repro.models import init_model


def _server(slots=2):
    cfg = reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=32,
                  n_heads=2, d_ff=64, vocab=128)
    params = init_model(cfg, jax.random.key(0))
    return SynergyServer(cfg, params, slots=slots, max_len=32,
                         prefill_len=4)


def test_all_requests_complete():
    srv = _server(slots=2)
    reqs = [Request(i, jax.random.randint(jax.random.key(i), (4,), 0, 128),
                    max_new_tokens=5) for i in range(5)]
    for r in reqs:
        srv.submit(r)
    stats = srv.run()
    assert all(len(r.out) >= 5 for r in reqs), [len(r.out) for r in reqs]
    assert stats.prefills == 5
    assert not srv.pending
    assert all(s is None for s in srv.slot_req)


def test_continuous_batching_overlaps_requests():
    """With more requests than slots, decode steps must serve multiple
    requests per step on average (slot_efficiency > 1)."""
    srv = _server(slots=2)
    for i in range(4):
        srv.submit(Request(i, jnp.arange(4, dtype=jnp.int32) + i,
                           max_new_tokens=6))
    stats = srv.run()
    assert stats.slot_efficiency > 1.0, stats


def test_engine_idle_returns_false():
    srv = _server()
    assert srv.step() is False
