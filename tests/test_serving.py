"""Continuous-batching serving engine invariants."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core.serving import (PrefillJob, Request, ServeTimeoutError,
                                SynergyServer)
from repro.engines import CAP_EPILOGUE, CAP_GEMM, CAP_GRAD, CostModel, Engine
from repro.models import init_model
from repro.models.cnn import CNNConfig

#: a tiny conv front-end (MNIST topology at a fraction of the MACs) for
#: tests that run the REAL conv-as-GEMM prefill chain on slow sim engines
TINY_CNN = CNNConfig(
    name="tiny", input_hw=8, cin=1, layers=(
        ("conv", 4, 3, 1, 1), ("pool", 2),
        ("conv", 8, 3, 1, 1), ("fc", 10),
    ))


def _cfg():
    return reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=32,
                   n_heads=2, d_ff=64, vocab=128)


def _server(slots=2, **kw):
    cfg = _cfg()
    params = init_model(cfg, jax.random.key(0))
    return SynergyServer(cfg, params, slots=slots, max_len=32,
                         prefill_len=4, **kw)


def test_all_requests_complete():
    srv = _server(slots=2)
    reqs = [Request(i, jax.random.randint(jax.random.key(i), (4,), 0, 128),
                    max_new_tokens=5) for i in range(5)]
    for r in reqs:
        srv.submit(r)
    stats = srv.run()
    assert all(len(r.out) >= 5 for r in reqs), [len(r.out) for r in reqs]
    assert stats.prefills == 5
    assert not srv.pending
    assert all(s is None for s in srv.slot_req)


def test_continuous_batching_overlaps_requests():
    """With more requests than slots, decode steps must serve multiple
    requests per step on average (slot_efficiency > 1)."""
    srv = _server(slots=2)
    for i in range(4):
        srv.submit(Request(i, jnp.arange(4, dtype=jnp.int32) + i,
                           max_new_tokens=6))
    stats = srv.run()
    assert stats.slot_efficiency > 1.0, stats


def test_engine_idle_returns_false():
    srv = _server()
    assert srv.step() is False


def test_prefill_does_not_corrupt_live_requests():
    """Regression: prefill used to broadcast the new prompt's tokens into
    EVERY slot's KV cache; a live request's generation changed whenever
    another request was admitted.  Prefill must write only the target
    slot, so a request's output is identical with or without a
    mid-generation admission."""
    prompt = jax.random.randint(jax.random.key(7), (4,), 0, 128)

    solo = _server(slots=2)
    ra = Request(0, prompt, max_new_tokens=8)
    solo.submit(ra)
    solo.run()

    staggered = _server(slots=2)
    rb = Request(0, prompt, max_new_tokens=8)
    staggered.submit(rb)
    staggered.step()                       # prefill A
    staggered.step(); staggered.step()     # 2 decode steps
    other = Request(1, jax.random.randint(jax.random.key(9), (4,), 0, 128),
                    max_new_tokens=8)
    staggered.submit(other)                # admitted mid-generation
    staggered.run()

    assert rb.out == ra.out, "another request's prefill changed A's tokens"
    assert len(other.out) >= 8


def test_decode_uses_per_slot_positions():
    """Slots prefilled at different times decode at their own positions:
    a request's output must not depend on its slot's admission order."""
    prompt = jnp.arange(4, dtype=jnp.int32)
    first = _server(slots=2)
    r1 = Request(0, prompt, max_new_tokens=6)
    first.submit(r1)
    first.run()

    late = _server(slots=2)
    filler = Request(7, jnp.arange(4, dtype=jnp.int32) + 3,
                     max_new_tokens=3)
    late.submit(filler)
    late.step()                  # filler occupies slot 0, advances its pos
    late.step(); late.step()
    r2 = Request(0, prompt, max_new_tokens=6)
    late.submit(r2)              # prefills into a DIFFERENT slot state
    late.run()
    assert r2.out == r1.out


def test_prefill_does_not_corrupt_live_ssm_state():
    """Same isolation guarantee for recurrent (Mamba) caches: bystander
    slots' SSM state is masked during prefill, and a reused slot's state
    is reset (attention masks stale K/V, but a recurrence would otherwise
    continue from the previous request)."""
    cfg = reduced(ARCHS["mamba2-130m"], n_layers=2, d_model=32, vocab=128)
    params = init_model(cfg, jax.random.key(0))

    def mk():
        return SynergyServer(cfg, params, slots=2, max_len=32,
                             prefill_len=4)

    prompt = jnp.arange(4, dtype=jnp.int32)
    solo = mk()
    ra = Request(0, prompt, max_new_tokens=6)
    solo.submit(ra)
    solo.run()

    staggered = mk()
    rb = Request(0, prompt, max_new_tokens=6)
    staggered.submit(rb)
    staggered.step(); staggered.step(); staggered.step()
    staggered.submit(Request(1, jnp.arange(4, dtype=jnp.int32) + 7,
                             max_new_tokens=6))
    staggered.run()
    assert rb.out == ra.out

    # slot reuse: 3 identical prompts through 2 slots; the third (reused
    # slot) must decode the same tokens as the first
    reuse = mk()
    reqs = [Request(i, prompt, max_new_tokens=5) for i in range(3)]
    for r in reqs:
        reuse.submit(r)
    reuse.run()
    assert reqs[2].out == reqs[0].out


def test_serving_jobs_route_through_dispatcher():
    srv = _server(slots=2)
    for i in range(3):
        srv.submit(Request(i, jnp.arange(4, dtype=jnp.int32) + i,
                           max_new_tokens=4))
    stats = srv.run()
    assert stats.job_engine.keys() == {"prefill", "decode"}
    assert stats.job_busy_s["prefill"] > 0
    assert stats.job_busy_s["decode"] > 0


# ------------------------------------------------------- admission waves

def test_wave_admission_admits_min_pending_free():
    """N pending requests + M free slots admit min(N, M) in ONE step."""
    srv = _server(slots=3)
    for i in range(5):
        srv.submit(Request(i, jnp.arange(4, dtype=jnp.int32) + i,
                           max_new_tokens=4))
    assert srv.step() is True
    assert srv.stats.prefills == 3          # min(5 pending, 3 free)
    assert srv.stats.prefill_waves == 1
    assert len(srv.pending) == 2
    assert all(r is not None for r in srv.slot_req)
    # no free slot -> the next step decodes instead of admitting
    srv.step()
    assert srv.stats.prefills == 3
    assert srv.stats.decode_steps == 1
    stats = srv.run()
    assert stats.prefills == 5
    # 5 requests through 3 slots took at most 3 waves
    assert stats.prefill_waves <= 3


def test_single_admission_mode_admits_one_per_step():
    srv = _server(slots=3, admission="single")
    for i in range(3):
        srv.submit(Request(i, jnp.arange(4, dtype=jnp.int32) + i,
                           max_new_tokens=4))
    srv.step()
    assert srv.stats.prefills == 1
    stats = srv.run()
    assert stats.prefills == 3
    assert stats.prefill_waves == 3


def test_wave_admission_outputs_match_single_admission():
    """Batching the admission wave must not change any request's tokens:
    per-slot masked positions keep the batched LM replay equal to the
    one-request-at-a-time replay."""
    reqs = lambda: [Request(i, jnp.arange(4, dtype=jnp.int32) * (i + 1) % 128,
                            max_new_tokens=6) for i in range(4)]
    wave, single = _server(slots=2), _server(slots=2, admission="single")
    ra, rb = reqs(), reqs()
    for r in ra:
        wave.submit(r)
    for r in rb:
        single.submit(r)
    wave.run()
    single.run()
    assert [r.out for r in ra] == [r.out for r in rb]


def test_wave_slot_reuse_stays_corruption_free():
    """The PR 1 masked-KV regression, extended to the batched admission
    path: 3 identical prompts through 2 slots (the third rides a REUSED
    slot admitted in a second wave) decode identical tokens."""
    from repro.soc import SynergyRuntime
    prompt = jnp.arange(4, dtype=jnp.int32)
    with SynergyRuntime(["F-PE", "S-PE"], name="reuse") as rt:
        srv = _server(slots=2, runtime=rt, prefill_cnn=TINY_CNN)
        reqs = [Request(i, prompt, max_new_tokens=5) for i in range(3)]
        for r in reqs:
            srv.submit(r)
        srv.run()
    assert reqs[2].out == reqs[0].out
    assert reqs[1].out == reqs[0].out


# ------------------------------------------------- real conv-as-GEMM prefill

def test_prefill_jobsets_are_real_conv_shapes():
    """No proxy GEMM left: the wave's JobSets are the conv-as-GEMM shapes
    of the paper CNN (k = kh*kw*cin, n = cout, m = frames*oh*ow), exactly
    what build_simnet exports to the DES."""
    from repro.models.cnn import conv_jobsets
    cfg = _cfg()
    job = PrefillJob(wave=1, rids=(0, 1), slots=(0, 1), n_frames=8,
                     cnn=TINY_CNN)
    jss = job.jobsets()
    expected = [js for _, js in conv_jobsets(TINY_CNN, 8)]
    assert [(js.m, js.n, js.k) for js in jss] \
        == [(js.m, js.n, js.k) for js in expected]
    # conv0: 8 frames x 8x8 spatial, 3x3x1 patch, 4 filters
    assert (jss[0].m, jss[0].n, jss[0].k) == (8 * 8 * 8, 4, 9)
    # the old proxy (m = tokens*layers, k = d_model) is gone
    assert all(js.k != cfg.d_model for js in jss)
    assert all("conv" in js.name for js in jss)


def test_prefill_busy_seconds_match_conv_cost_model():
    """ServeStats prefill busy-seconds == the conv cost model's estimate
    of the wave's jobsets, on BOTH dispatch paths (single-engine runtime
    split and dispatcher-routed accounting)."""
    from repro.engines import get_engine
    from repro.models.cnn import conv_jobsets
    from repro.soc import SynergyRuntime

    def expected_busy(eng, n_frames):
        return sum(eng.estimate(js)
                   for _, js in conv_jobsets(TINY_CNN, n_frames))

    # runtime path: single F-PE pool -> every panel booked at F-PE rates
    with SynergyRuntime(["F-PE"], name="busy") as rt:
        srv = _server(slots=2, runtime=rt, prefill_cnn=TINY_CNN)
        for i in range(2):
            srv.submit(Request(i, jnp.arange(4, dtype=jnp.int32) + i,
                               max_new_tokens=2))
        stats = srv.run()
    exp = expected_busy(get_engine("F-PE"), n_frames=8)   # 2 reqs x 4 toks
    assert stats.job_busy_s["prefill"] == pytest.approx(exp, rel=1e-6)

    # dispatcher path books the selected engine's estimate of the same sets
    srv2 = _server(slots=2, prefill_cnn=TINY_CNN)
    srv2.submit(Request(0, jnp.arange(4, dtype=jnp.int32),
                        max_new_tokens=2))
    stats2 = srv2.run()
    eng = srv2.dispatcher.select(
        PrefillJob(1, (0,), (0,), 4, TINY_CNN).jobsets()[0],
        job_class="prefill")
    exp2 = expected_busy(eng, n_frames=4)
    assert stats2.job_busy_s["prefill"] == pytest.approx(exp2, rel=1e-6)


def test_wave_prefill_gathers_im2col_once_per_layer(monkeypatch):
    """Satellite: ONE im2col gather per conv layer covers the whole
    admission wave — not one gather per request."""
    import repro.core.serving as serving_mod
    calls = []
    real = serving_mod.im2col_wave

    def counting(x, *a, **kw):
        calls.append(int(x.shape[0]))
        return real(x, *a, **kw)

    monkeypatch.setattr(serving_mod, "im2col_wave", counting)
    from repro.soc import SynergyRuntime
    with SynergyRuntime(["F-PE", "S-PE"], name="gather") as rt:
        srv = _server(slots=3, runtime=rt, prefill_cnn=TINY_CNN)
        for i in range(3):
            srv.submit(Request(i, jnp.arange(4, dtype=jnp.int32) + i,
                               max_new_tokens=2))
        assert srv.step() is True      # one wave admits all 3
        srv.drain()
    n_conv = sum(1 for spec in TINY_CNN.layers if spec[0] == "conv")
    assert len(calls) == n_conv        # NOT 3 * n_conv
    assert calls[0] == 12              # 3 requests x 4 frames, one batch


# ------------------------------------------- coalesced decode: bitwise

def _run_decode_mode(mode, engines, n_req=3, cnn=TINY_CNN, **server_kw):
    from repro.soc import SynergyRuntime
    cfg = _cfg()
    params = init_model(cfg, jax.random.key(0))
    with SynergyRuntime(engines, name=f"bitwise-{mode}") as rt:
        srv = SynergyServer(cfg, params, slots=2, max_len=32, prefill_len=4,
                            runtime=rt, prefill_cnn=cnn, decode_mode=mode,
                            keep_decode_outputs=True, max_inflight=1,
                            **server_kw)
        reqs = [Request(i, jnp.arange(4, dtype=jnp.int32) + i,
                        max_new_tokens=5) for i in range(n_req)]
        for r in reqs:
            srv.submit(r)
        stats = srv.run()
    return reqs, stats, srv.decode_gemm_outputs


def test_batched_decode_bitwise_identical_fp32():
    """The coalesced (live*n_layers, d) @ (d, 4d) decode submission is
    BITWISE identical to the sequential per-slot loop on the fp32 path
    (row reductions are row-independent)."""
    ra, sa, outs_a = _run_decode_mode("batched", ["F-PE", "S-PE"])
    rb, sb, outs_b = _run_decode_mode("per-slot", ["F-PE", "S-PE"])
    assert [r.out for r in ra] == [r.out for r in rb]
    assert sa.decode_steps == sb.decode_steps
    assert len(outs_a) == sa.decode_steps and len(outs_b) == sb.decode_steps
    for ya, yb in zip(outs_a, outs_b):
        assert ya.shape == yb.shape    # (live, n_layers, 4*d_model)
        assert np.array_equal(np.asarray(ya), np.asarray(yb))
    # batched mode coalesces: one submission per step, fewer padded tiles
    assert sa.runtime_jobs < sb.runtime_jobs


def test_batched_decode_bitwise_identical_int8_calibrated():
    """Same bitwise identity on the int8-calibrated path: panels carry
    exact int32 partials and both modes feed the calibrator ONCE per step
    at reap (batch-shape keyed), so scale trajectories — and therefore
    quantized outputs — are identical."""
    from repro.engines import get_engine
    from repro.quant import QuantizedEngine

    def mk_engine(tag):
        return QuantizedEngine(get_engine("xla"), name=f"bw-int8-{tag}")

    qa = mk_engine("batched")
    ra, sa, outs_a = _run_decode_mode("batched", [qa])
    qb = mk_engine("per-slot")
    rb, sb, outs_b = _run_decode_mode("per-slot", [qb])
    assert [r.out for r in ra] == [r.out for r in rb]
    # the calibrator saw one observation per decode step in BOTH modes;
    # the key is the real n-stacked FFN GEMM shape (d, n_layers·2·d_ff)
    cfg = _cfg()
    key = (cfg.d_model, cfg.n_layers * 2 * cfg.d_ff)
    assert qa.calibrator.state()[key].updates == sa.decode_steps
    assert qb.calibrator.state()[key].updates == sb.decode_steps
    assert qa.calibrator.state()[key].amax \
        == qb.calibrator.state()[key].amax
    assert qa.act_scale_for(*key) is not None
    assert len(outs_a) == len(outs_b) > 1
    for ya, yb in zip(outs_a, outs_b):
        assert np.array_equal(np.asarray(ya), np.asarray(yb))
    # decode really ran on the quantized engine
    assert sa.precision_jobs["int8"] > 0


# --------------------------------------------------- async in-flight window

def test_inflight_window_overlaps_and_orders_completions():
    from repro.soc import SynergyRuntime
    with SynergyRuntime(["F-PE", "S-PE"], name="window") as rt:
        srv = _server(slots=2, runtime=rt, prefill_cnn=TINY_CNN,
                      max_inflight=4)
        for i in range(4):
            srv.submit(Request(i, jnp.arange(4, dtype=jnp.int32) + i,
                               max_new_tokens=4))
        stats = srv.run()
    assert stats.inflight_peak > 1         # submissions overlapped steps
    assert not srv._inflight               # run() drained the window
    assert stats.runtime_jobs > 0
    assert rt.stats()["total_jobs"] == stats.runtime_jobs


class _SleepyEngine(Engine):
    """Deterministically slow engine: every panel sleeps, so a tiny
    submit_timeout trips mid-prefill."""

    def __init__(self, name="sleepy", delay_s=0.2):
        super().__init__(name, {CAP_GEMM, CAP_EPILOGUE, CAP_GRAD},
                         cost=CostModel(macs_per_s=1e9))
        self.delay_s = delay_s

    def execute(self, a, b, *, bias=None, activation=None,
                tile=(256, 256, 256), out_dtype=None, precision=None):
        time.sleep(self.delay_s)
        y = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
        if bias is not None:
            y = y + bias
        if activation is not None:
            y = activation(y)
        return y.astype(out_dtype or a.dtype)


def test_submit_timeout_surfaces_serve_timeout_error():
    """Satellite: the hard-coded 60s futures wait is gone — the timeout is
    a constructor arg and tripping it raises ServeTimeoutError naming the
    jobset (not a bare TimeoutError)."""
    from repro.soc import SynergyRuntime
    with SynergyRuntime([_SleepyEngine()], name="slowpool") as rt:
        srv = _server(slots=1, runtime=rt, prefill_cnn=TINY_CNN,
                      submit_timeout=0.01)
        srv.submit(Request(0, jnp.arange(4, dtype=jnp.int32),
                           max_new_tokens=2))
        with pytest.raises(ServeTimeoutError) as ei:
            srv.run()
    assert "prefill/w1" in str(ei.value)
    assert ei.value.timeout == 0.01


def test_timeout_cancels_graph_and_drains_queues():
    """Satellite 1: tripping submit_timeout on a prefill graph CANCELS
    it — not-yet-started downstream nodes never launch, queued panels are
    drained — and the pool immediately serves fresh work instead of
    grinding through the dead wave's backlog."""
    from repro.core.job import JobSet
    from repro.soc import GraphCancelled, SynergyRuntime
    eng = _SleepyEngine(delay_s=0.2)
    with SynergyRuntime([eng], name="slowpool2") as rt:
        srv = _server(slots=1, runtime=rt, prefill_cnn=TINY_CNN,
                      submit_timeout=0.01)
        captured = {}
        orig = rt.submit_graph

        def capture(*a, **kw):
            gf = orig(*a, **kw)
            captured["gf"] = gf
            return gf

        rt.submit_graph = capture
        srv.submit(Request(0, jnp.arange(4, dtype=jnp.int32),
                           max_new_tokens=2))
        with pytest.raises(ServeTimeoutError):
            srv.run()
        gf = captured["gf"]
        with pytest.raises((GraphCancelled, RuntimeError)):
            gf.result(10)
        states = gf.node_states()
        assert "cancelled" in states       # downstream never started
        assert "done" not in states[-1:] or states[-1] == "cancelled"
        # queues drained: fresh work completes in ~one panel delay, far
        # less than the cancelled wave's remaining serial backlog
        a = jnp.ones((16, 32), jnp.float32)
        b = jnp.ones((32, 16), jnp.float32)
        t0 = time.monotonic()
        rt.submit_gemm(a, b, jobset=JobSet.for_gemm(9, 16, 16, 32, 16,
                                                    name="fresh"),
                       tile=(16, 16, 16)).result(30)
        assert time.monotonic() - t0 < 1.0


# ------------------------------------------------------- chunked prefill

def test_chunked_prefill_interleaves_decode_and_matches_blocking():
    """Tentpole: with ``prefill_chunk_macs`` set, admission work is split
    into bounded chunks interleaved with decode — live decoders never
    stall behind a wave (decode_stall_steps == 0) — and every request's
    token stream is IDENTICAL to the legacy blocking admission (replay
    quanta touch only the wave's slots, decode only live slots)."""
    def run(**kw):
        srv = _server(slots=2, **kw)
        reqs = [Request(i, jnp.arange(4, dtype=jnp.int32) + i,
                        max_new_tokens=3 + i) for i in range(4)]
        for r in reqs:
            srv.submit(r)
        stats = srv.run()
        return [list(r.out) for r in reqs], stats

    outs_blk, st_blk = run()
    outs_chk, st_chk = run(prefill_chunk_macs=20_000)
    assert outs_chk == outs_blk                     # bitwise token parity
    assert st_chk.prefill_chunks > 0
    assert st_chk.decode_stall_steps == 0           # decode ran every step
    assert st_blk.prefill_chunks == 0               # legacy mode untouched
    # staggered completions force an admission while a decoder is live:
    # the blocking server stalls it, the chunked one never does
    assert st_blk.decode_stall_steps > 0
    assert st_chk.prefills == st_blk.prefills == 4


def test_chunked_conv_graph_chunks_through_runtime():
    """The wave's conv front-end splits into multiple bounded-MAC graph
    chunks chained by their carry, still producing the same tokens as one
    unchunked graph, with all conv jobs booked."""
    from repro.soc import SynergyRuntime

    def run(chunk):
        with SynergyRuntime(["F-PE", "S-PE"], name=f"chunk{chunk}") as rt:
            srv = _server(slots=2, runtime=rt, prefill_cnn=TINY_CNN,
                          prefill_chunk_macs=chunk)
            reqs = [Request(i, jnp.arange(4, dtype=jnp.int32) + i,
                            max_new_tokens=3 + i) for i in range(4)]
            for r in reqs:
                srv.submit(r)
            stats = srv.run()
        return [list(r.out) for r in reqs], stats

    outs_one, st_one = run(None)
    # ~147k MACs per TINY_CNN conv layer at 8 frames: one layer per chunk
    outs_many, st_many = run(150_000)
    assert outs_many == outs_one
    assert st_many.prefill_chunks >= 4     # >= 2 conv chunks x 2 waves
    assert st_many.decode_stall_steps == 0
    assert st_many.prefills == st_one.prefills == 4
    # chunking never drops conv work (busy-SECONDS are steal-placement
    # dependent across F-PE/S-PE, so compare booked work, not seconds)
    assert st_many.runtime_jobs > 0
    assert st_many.job_busy_s["prefill"] > 0


# ------------------------------------------------- real FFN decode weights

def test_decode_weight_stacks_real_ffn_layers():
    """Satellite 2: dense-family params expose blocks.mlp.wi of shape
    (n_layers, d_model, 2·d_ff) — the decode GEMM weight is the REAL
    per-layer wi stacked along n, not the seeded proxy."""
    cfg = _cfg()
    params = init_model(cfg, jax.random.key(0))
    srv = SynergyServer(cfg, params, slots=2, max_len=32, prefill_len=4)
    assert srv._decode_ffn_cols == 2 * cfg.d_ff
    assert srv._decode_w.shape == (cfg.d_model,
                                   cfg.n_layers * 2 * cfg.d_ff)
    wi = params["blocks"]["mlp"]["wi"]
    ref = jnp.transpose(wi, (1, 0, 2)).reshape(cfg.d_model, -1)
    assert np.array_equal(np.asarray(srv._decode_w),
                          np.asarray(ref.astype(jnp.float32)))


def test_decode_weight_proxy_fallback_for_ssm():
    """Families without a dense FFN stack (mamba blocks) fall back to the
    (d_model, 4·d_model) proxy — and still serve end to end."""
    cfg = reduced(ARCHS["mamba2-130m"])
    params = init_model(cfg, jax.random.key(0))
    srv = SynergyServer(cfg, params, slots=1, max_len=16, prefill_len=2)
    assert srv._decode_ffn_cols is None
    assert srv._decode_w.shape == (cfg.d_model, 4 * cfg.d_model)
    req = Request(0, jnp.arange(2, dtype=jnp.int32) % cfg.vocab_size,
                  max_new_tokens=2)
    srv.submit(req)
    stats = srv.run()
    assert stats.decode_steps >= 1 and len(req.out) >= 2


def test_empty_prompt_mid_wave_drops_nothing():
    """A bad request mid-wave must fail BEFORE any wave member is popped:
    the earlier requests stay pending and get served on retry."""
    srv = _server(slots=2)
    good = Request(0, jnp.arange(4, dtype=jnp.int32), max_new_tokens=3)
    bad = Request(1, jnp.zeros((0,), jnp.int32), max_new_tokens=3)
    srv.submit(good)
    srv.submit(bad)
    with pytest.raises(ValueError, match="empty prompt"):
        srv.step()
    assert srv.pending and srv.pending[0] is good   # nothing was dropped
    assert all(r is None for r in srv.slot_req)
    srv.pending.remove(bad)
    stats = srv.run()
    assert stats.prefills == 1 and len(good.out) >= 3
