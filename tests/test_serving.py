"""Continuous-batching serving engine invariants."""

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.core.serving import Request, SynergyServer
from repro.models import init_model


def _server(slots=2):
    cfg = reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=32,
                  n_heads=2, d_ff=64, vocab=128)
    params = init_model(cfg, jax.random.key(0))
    return SynergyServer(cfg, params, slots=slots, max_len=32,
                         prefill_len=4)


def test_all_requests_complete():
    srv = _server(slots=2)
    reqs = [Request(i, jax.random.randint(jax.random.key(i), (4,), 0, 128),
                    max_new_tokens=5) for i in range(5)]
    for r in reqs:
        srv.submit(r)
    stats = srv.run()
    assert all(len(r.out) >= 5 for r in reqs), [len(r.out) for r in reqs]
    assert stats.prefills == 5
    assert not srv.pending
    assert all(s is None for s in srv.slot_req)


def test_continuous_batching_overlaps_requests():
    """With more requests than slots, decode steps must serve multiple
    requests per step on average (slot_efficiency > 1)."""
    srv = _server(slots=2)
    for i in range(4):
        srv.submit(Request(i, jnp.arange(4, dtype=jnp.int32) + i,
                           max_new_tokens=6))
    stats = srv.run()
    assert stats.slot_efficiency > 1.0, stats


def test_engine_idle_returns_false():
    srv = _server()
    assert srv.step() is False


def test_prefill_does_not_corrupt_live_requests():
    """Regression: prefill used to broadcast the new prompt's tokens into
    EVERY slot's KV cache; a live request's generation changed whenever
    another request was admitted.  Prefill must write only the target
    slot, so a request's output is identical with or without a
    mid-generation admission."""
    prompt = jax.random.randint(jax.random.key(7), (4,), 0, 128)

    solo = _server(slots=2)
    ra = Request(0, prompt, max_new_tokens=8)
    solo.submit(ra)
    solo.run()

    staggered = _server(slots=2)
    rb = Request(0, prompt, max_new_tokens=8)
    staggered.submit(rb)
    staggered.step()                       # prefill A
    staggered.step(); staggered.step()     # 2 decode steps
    other = Request(1, jax.random.randint(jax.random.key(9), (4,), 0, 128),
                    max_new_tokens=8)
    staggered.submit(other)                # admitted mid-generation
    staggered.run()

    assert rb.out == ra.out, "another request's prefill changed A's tokens"
    assert len(other.out) >= 8


def test_decode_uses_per_slot_positions():
    """Slots prefilled at different times decode at their own positions:
    a request's output must not depend on its slot's admission order."""
    prompt = jnp.arange(4, dtype=jnp.int32)
    first = _server(slots=2)
    r1 = Request(0, prompt, max_new_tokens=6)
    first.submit(r1)
    first.run()

    late = _server(slots=2)
    filler = Request(7, jnp.arange(4, dtype=jnp.int32) + 3,
                     max_new_tokens=3)
    late.submit(filler)
    late.step()                  # filler occupies slot 0, advances its pos
    late.step(); late.step()
    r2 = Request(0, prompt, max_new_tokens=6)
    late.submit(r2)              # prefills into a DIFFERENT slot state
    late.run()
    assert r2.out == r1.out


def test_prefill_does_not_corrupt_live_ssm_state():
    """Same isolation guarantee for recurrent (Mamba) caches: bystander
    slots' SSM state is masked during prefill, and a reused slot's state
    is reset (attention masks stale K/V, but a recurrence would otherwise
    continue from the previous request)."""
    cfg = reduced(ARCHS["mamba2-130m"], n_layers=2, d_model=32, vocab=128)
    params = init_model(cfg, jax.random.key(0))

    def mk():
        return SynergyServer(cfg, params, slots=2, max_len=32,
                             prefill_len=4)

    prompt = jnp.arange(4, dtype=jnp.int32)
    solo = mk()
    ra = Request(0, prompt, max_new_tokens=6)
    solo.submit(ra)
    solo.run()

    staggered = mk()
    rb = Request(0, prompt, max_new_tokens=6)
    staggered.submit(rb)
    staggered.step(); staggered.step(); staggered.step()
    staggered.submit(Request(1, jnp.arange(4, dtype=jnp.int32) + 7,
                             max_new_tokens=6))
    staggered.run()
    assert rb.out == ra.out

    # slot reuse: 3 identical prompts through 2 slots; the third (reused
    # slot) must decode the same tokens as the first
    reuse = mk()
    reqs = [Request(i, prompt, max_new_tokens=5) for i in range(3)]
    for r in reqs:
        reuse.submit(r)
    reuse.run()
    assert reqs[2].out == reqs[0].out


def test_serving_jobs_route_through_dispatcher():
    srv = _server(slots=2)
    for i in range(3):
        srv.submit(Request(i, jnp.arange(4, dtype=jnp.int32) + i,
                           max_new_tokens=4))
    stats = srv.run()
    assert stats.job_engine.keys() == {"prefill", "decode"}
    assert stats.job_busy_s["prefill"] > 0
    assert stats.job_busy_s["decode"] > 0
