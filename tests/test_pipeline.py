"""Inter-frame pipeline: threaded mailbox pipeline + GPipe reference."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pipeline import (PipelineStageError, ThreadedPipeline,
                                 gpipe_reference)


def test_threaded_pipeline_order_and_outputs():
    stages = [("a", lambda x: x + 1), ("b", lambda x: x * 2),
              ("c", lambda x: x - 3)]
    pipe = ThreadedPipeline(stages, mailbox_capacity=2)
    outs, stats = pipe.run(list(range(20)))
    assert outs == [(i + 1) * 2 - 3 for i in range(20)]
    assert stats["fps"] > 0
    assert set(stats["stage_utilization"]) == {"a", "b", "c"}


def test_threaded_pipeline_overlaps_stages():
    """With two equal slow stages, pipelined wall time ~ 1x stage time
    per frame (not 2x) once the pipe is full."""
    dt = 0.01

    def slow(x):
        time.sleep(dt)
        return x

    pipe = ThreadedPipeline([("s1", slow), ("s2", slow)])
    n = 20
    t0 = time.perf_counter()
    outs, _ = pipe.run(list(range(n)))
    wall = time.perf_counter() - t0
    assert len(outs) == n
    assert wall < n * 2 * dt * 0.8   # clearly better than serial


def test_raising_stage_does_not_deadlock():
    """Regression: a stage exception used to kill the worker thread and
    leave run() blocked forever on the final mailbox.  Now the failure
    drains the pipe and re-raises, well before any deadlock timeout."""
    def boom(x):
        if x == 5:
            raise ValueError("frame 5 is cursed")
        return x

    pipe = ThreadedPipeline([("pre", lambda x: x), ("boom", boom),
                             ("post", lambda x: x * 2)],
                            mailbox_capacity=2)
    t0 = time.perf_counter()
    with pytest.raises(PipelineStageError, match="boom") as ei:
        pipe.run(list(range(20)))
    assert isinstance(ei.value.__cause__, ValueError)
    assert time.perf_counter() - t0 < 10.0
    # the pipeline object is not poisoned: a fresh run still works
    pipe2 = ThreadedPipeline([("ok", lambda x: x + 1)])
    outs, _ = pipe2.run([1, 2, 3])
    assert outs == [2, 3, 4]


def test_raising_first_frame_and_multiple_failures():
    """Even frame 0 failing (nothing ever reaches the sink) and repeated
    failures must drain cleanly; the FIRST failure is reported."""
    pipe = ThreadedPipeline([("always", lambda x: 1 / 0)])
    with pytest.raises(PipelineStageError, match="always"):
        pipe.run(list(range(8)))


def test_gpipe_reference_matches_sequential():
    stage_params = [jnp.float32(p) for p in (1.5, -0.5, 2.0)]

    def stage_fn(p, x):
        return jnp.tanh(x * p)

    mb = jax.random.normal(jax.random.key(0), (4, 8))
    out = gpipe_reference(stage_fn, stage_params, mb)
    expected = mb
    for p in stage_params:
        expected = jnp.tanh(expected * p)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=1e-6, atol=1e-6)
