"""Weight-only int8 quantization: roundtrip + matmul drift bounds."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.quant import (dequantize_weight, quant_matmul,
                               quantize_params, quantize_weight)


def test_roundtrip_error_bound():
    w = jax.random.normal(jax.random.key(0), (64, 32)) * 0.1
    q, s = quantize_weight(w)
    deq = dequantize_weight(q, s, dtype=jnp.float32)
    # symmetric per-channel int8: |err| <= scale/2 per element
    assert float(jnp.abs(deq - w).max()) <= float(s.max()) / 2 + 1e-6


def test_quant_matmul_close_to_fp():
    x = jax.random.normal(jax.random.key(1), (8, 64)).astype(jnp.bfloat16)
    w = jax.random.normal(jax.random.key(2), (64, 32)) * 0.05
    q, s = quantize_weight(w)
    y_q = quant_matmul(x, q, s)
    y_f = (x.astype(jnp.float32) @ w).astype(jnp.bfloat16)
    rel = float(jnp.abs(y_q.astype(jnp.float32) - y_f.astype(jnp.float32)).max()
                / (jnp.abs(y_f.astype(jnp.float32)).max() + 1e-6))
    assert rel < 0.05, rel


def test_quantize_params_walks_model():
    from repro.configs import ARCHS, reduced
    from repro.models import init_model
    cfg = reduced(ARCHS["granite-3-2b"])
    params = init_model(cfg, jax.random.key(0))
    qp = quantize_params(params)
    # attention weights quantized; norms untouched
    blk = qp["blocks"]
    assert isinstance(blk["attn"]["wq"], dict) and blk["attn"]["wq"]["q"].dtype == jnp.int8
    assert blk["ln1"].dtype != jnp.int8
    # int8 payload ~4x smaller than fp32 for the quantized leaves
    orig = params["blocks"]["attn"]["wq"].nbytes
    quant = blk["attn"]["wq"]["q"].nbytes + blk["attn"]["wq"]["scale"].nbytes
    assert quant < 0.3 * orig
