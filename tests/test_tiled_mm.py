"""tiled_mm Pallas kernel vs pure-jnp oracle: shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev deps
from hypothesis import given, settings, strategies as st

from repro.kernels.tiled_mm import tiled_matmul, tiled_mm_ref


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.key(key), shape, jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(32, 32, 32), (64, 128, 96),
                                   (70, 45, 33), (1, 257, 129),
                                   (130, 1, 31)])
def test_matches_ref(shape, dtype):
    m, n, k = shape
    a = _rand(0, (m, k), dtype)
    b = _rand(1, (k, n), dtype)
    y = tiled_matmul(a, b, tile=32)
    r = tiled_mm_ref(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(r, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("act", [None, jax.nn.relu, jax.nn.silu])
def test_fused_epilogue(act):
    a = _rand(2, (48, 40), jnp.float32)
    b = _rand(3, (40, 56), jnp.float32)
    bias = _rand(4, (56,), jnp.float32)
    y = tiled_matmul(a, b, bias=bias, activation=act, tile=(16, 32, 16))
    r = tiled_mm_ref(a, b, bias=bias, activation=act)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                               rtol=1e-5, atol=1e-5)


def test_paper_tile_size_32():
    """The paper's TS=32 PE configuration is exactly expressible."""
    a = _rand(5, (100, 75), jnp.float32)   # CIFAR conv1-like GEMM panel
    b = _rand(6, (75, 32), jnp.float32)
    y = tiled_matmul(a, b, tile=32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(tiled_mm_ref(a, b)),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(m=st.integers(1, 70), n=st.integers(1, 70), k=st.integers(1, 70),
       tm=st.sampled_from([8, 16, 32]), tn=st.sampled_from([8, 16, 32]),
       tk=st.sampled_from([8, 16, 32]))
def test_property_any_shape_any_tile(m, n, k, tm, tn, tk):
    """Border zero-padding (paper §3.2.1) makes every (shape, tile) pair
    correct — the fixed-size PE serves every layer."""
    a = _rand(m * 7919 + n, (m, k), jnp.float32)
    b = _rand(k * 31 + 1, (k, n), jnp.float32)
    y = tiled_matmul(a, b, tile=(tm, tn, tk))
    np.testing.assert_allclose(np.asarray(y), np.asarray(tiled_mm_ref(a, b)),
                               rtol=2e-5, atol=2e-5)


def test_out_dtype():
    a = _rand(7, (33, 65), jnp.bfloat16)
    b = _rand(8, (65, 17), jnp.bfloat16)
    y = tiled_matmul(a, b, tile=32, out_dtype=jnp.float32)
    assert y.dtype == jnp.float32
