"""SynergyRuntime: work-stealing execution over live engine pools.

Covers the acceptance criteria of the runtime PR: split-and-merge GEMMs
match the oracle, work conservation under randomized steal timing, nonzero
steals + strictly higher aggregate busy fraction vs single-engine pinning
for a steady-frame ThreadedPipeline, live add/remove rebalance (including
registry-driven), serving submissions, and DES <-> SimRuntime conformance.
"""

import random
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.clusters import Accelerator, Cluster
from repro.core.job import JobSet
from repro.core.pipeline import EngineStage, ThreadedPipeline
from repro.core.scheduler import SimLayer, SimNet, simulate
from repro.core.synergy_mm import SynergyTrace, synergy_matmul
from repro.engines import (CAP_GEMM, CostModel, Engine, get_engine,
                           registered)
from repro.soc import (SimRuntime, SynergyRuntime, current_runtime,
                       runtime_scope, should_steal)


def _ab(m, k, n, seed=0):
    ka, kb = jax.random.split(jax.random.key(seed))
    return (jax.random.normal(ka, (m, k)), jax.random.normal(kb, (k, n)))


class _DelayEngine(Engine):
    """Deterministic-output engine with seeded random per-job delays —
    randomized steal timing without randomized results."""

    def __init__(self, name, macs_per_s=1e9, seed=0, max_delay_s=0.004):
        super().__init__(name, {CAP_GEMM, "epilogue"},
                         cost=CostModel(macs_per_s=macs_per_s))
        self._rng = random.Random(seed)
        self._max_delay_s = max_delay_s
        self.executed = 0

    def execute(self, a, b, *, bias=None, activation=None, tile=None,
                out_dtype=None, precision=None):
        time.sleep(self._rng.random() * self._max_delay_s)
        self.executed += 1
        y = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
        if bias is not None:
            y = y + bias
        if activation is not None:
            y = activation(y)
        return y.astype(out_dtype or a.dtype)


# ------------------------------------------------------------ split + merge

def test_runtime_scope_splits_and_matches_dot():
    a, b = _ab(300, 64, 48)
    with SynergyRuntime(["F-PE", "S-PE"]) as rt, rt.scope():
        tr = SynergyTrace()
        with tr.activate():
            y = synergy_matmul(a, b, tile=32, name="split")
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.dot(a, b)),
                               rtol=1e-4, atol=1e-4)
    # all 10x2 tile jobs booked, across however many engines executed
    assert sum(t.jobs for t in tr.engine_stats.values()) == 20
    stats = rt.stats()
    assert stats["total_jobs"] == 20
    assert stats["submissions"] == 1


def test_runtime_scope_epilogue_and_border_tiles():
    a, b = _ab(70, 33, 45, seed=3)       # border tiles in every direction
    bias = jax.random.normal(jax.random.key(9), (45,))
    with SynergyRuntime(["F-PE", "S-PE"]) as rt, rt.scope():
        y = synergy_matmul(a, b, bias=bias, activation=jax.nn.relu, tile=32)
    ref = get_engine("reference").execute(a, b, bias=bias,
                                          activation=jax.nn.relu)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_runtime_scope_is_inert_under_jit():
    """Traced arrays cannot cross worker threads: under jit the call falls
    back to single-engine dispatch and stays correct."""
    a, b = _ab(64, 32, 32, seed=4)
    f = jax.jit(lambda a, b: synergy_matmul(a, b, tile=32))
    with SynergyRuntime(["F-PE", "S-PE"]) as rt, rt.scope():
        y = f(a, b)
        assert rt.stats()["total_jobs"] == 0
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.dot(a, b)),
                               rtol=1e-4, atol=1e-4)


def test_current_runtime_scope_nesting():
    rt1 = SynergyRuntime(["F-PE"], name="outer")
    rt2 = SynergyRuntime(["S-PE"], name="inner")
    assert current_runtime() is None
    try:
        with runtime_scope(rt1):
            assert current_runtime() is rt1
            with runtime_scope(rt2):
                assert current_runtime() is rt2
            assert current_runtime() is rt1
        assert current_runtime() is None
    finally:
        rt1.shutdown()
        rt2.shutdown()


# ------------------------------------------------------- work conservation

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_work_conservation_under_randomized_stealing(seed):
    """Every tile job executes exactly once no matter how steals interleave,
    and the merged result is bit-exact vs the same split executed serially
    on one engine of the same family."""
    engines = [_DelayEngine(f"d{i}", macs_per_s=(i + 1) * 1e9,
                            seed=seed * 10 + i) for i in range(3)]
    a, b = _ab(17 * 16, 40, 24, seed=seed)
    js = JobSet.for_gemm(0, a.shape[0], 24, 40, 16)
    with SynergyRuntime(engines) as rt:
        fut = rt.submit_gemm(a, b, jobset=js, tile=(16, 16, 16))
        y = fut.result(60)
    assert fut.execution_counts == [1] * 17          # exactly once, per panel
    acct_jobs = sum(x["jobs"] for x in fut.accounting.values())
    assert acct_jobs == js.num_jobs == 17 * 2
    assert sum(e.executed for e in engines) == 17
    # bit-exact oracle: same row panels on a single same-family engine
    solo = _DelayEngine("solo", seed=99, max_delay_s=0.0)
    parts = [solo.execute(a[r:r + 16], b) for r in range(0, a.shape[0], 16)]
    assert np.array_equal(np.asarray(y), np.asarray(jnp.concatenate(parts)))


def test_accounting_submission_conserves_jobs():
    js = JobSet.for_gemm(0, 320, 128, 64, 32)
    with SynergyRuntime(["F-PE", "S-PE", "NEON"]) as rt:
        futs = [rt.submit(js, affinity="F-PE") for _ in range(4)]
        for fut in futs:
            fut.result(30)
            assert sum(x["jobs"] for x in fut.accounting.values()) \
                == js.num_jobs
    assert rt.stats()["total_jobs"] == 4 * js.num_jobs


# ------------------------------------- acceptance: steals + busy fraction

def _agg_busy_fraction(before, after):
    """Table-6 analog over a fixed pool: total cost-model busy seconds over
    pool-size x the busiest engine's busy seconds."""
    deltas = [a.busy_s - b.busy_s for b, a in zip(before, after)]
    top = max(deltas)
    return sum(deltas) / (len(deltas) * top) if top > 0 else 0.0


def test_pipeline_runtime_steals_and_beats_pinned_busy_fraction():
    """ISSUE acceptance: with >=2 engines, a steady-frame ThreadedPipeline
    run through runtime_scope() reports nonzero steal count and strictly
    higher aggregate busy fraction than the same workload pinned to a
    single engine (simulated-PE pool)."""
    pool = ["F-PE", "S-PE"]
    engines = [get_engine(n) for n in pool]
    w = jax.random.normal(jax.random.key(0), (64, 48))
    frames = [jax.random.normal(jax.random.key(i), (320, 64))
              for i in range(6)]

    def snap():
        return [e.telemetry.snapshot() for e in engines]

    # pinned: every GEMM hard-routed to F-PE (PR-1 single-engine dispatch);
    # TS=32 gives 10 row-panel jobs per frame, deep enough for the tail
    # guard to let the 0.5x S-PE steal
    stages = [EngineStage.gemm("mm", w, engine="F-PE", tile=(32, 32, 32)),
              ("post", lambda y: float(jnp.sum(y)))]
    b0 = snap()
    outs, _ = ThreadedPipeline(stages).run(frames)
    pinned_frac = _agg_busy_fraction(b0, snap())
    assert len(outs) == len(frames)
    assert pinned_frac == pytest.approx(1.0 / len(pool))

    # runtime: same stages, same pin — now a queue-affinity hint; the idle
    # S-PE steals tile jobs from F-PE's deque
    with SynergyRuntime(pool, name="accept") as rt, rt.scope():
        b1 = snap()
        outs, stats = ThreadedPipeline(stages).run(frames)
        rt_frac = _agg_busy_fraction(b1, snap())
    assert len(outs) == len(frames)
    rstats = stats["runtime"]
    assert rstats is not None and rstats["total_steals"] > 0
    assert rt_frac > pinned_frac
    assert rstats["aggregate_busy_fraction"] > 1.0 / len(pool)


# --------------------------------------------------- live pool add/remove

def test_add_engine_mid_run_rebalances():
    slow = _DelayEngine("slow-only", macs_per_s=1e9, seed=1,
                        max_delay_s=0.01)
    helper = _DelayEngine("helper", macs_per_s=1e9, seed=2, max_delay_s=0.0)
    a, b = _ab(24 * 16, 32, 16, seed=7)
    js = JobSet.for_gemm(0, a.shape[0], 16, 32, 16)
    with SynergyRuntime([slow]) as rt:
        fut = rt.submit_gemm(a, b, jobset=js, tile=(16, 16, 16))
        rt.add_engine(helper)
        y = fut.result(120)
        assert rt.stats()["rebalances"] >= 1
    assert helper.executed > 0, "added engine never picked up queued work"
    assert slow.executed + helper.executed == 24
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.dot(a, b)),
                               rtol=1e-4, atol=1e-4)


def test_remove_engine_mid_run_work_still_completes():
    doomed = _DelayEngine("doomed", macs_per_s=1e9, seed=3,
                          max_delay_s=0.01)
    survivor = _DelayEngine("survivor", macs_per_s=1e9, seed=4,
                            max_delay_s=0.0)
    a, b = _ab(24 * 16, 32, 16, seed=8)
    js = JobSet.for_gemm(0, a.shape[0], 16, 32, 16)
    with SynergyRuntime([doomed, survivor]) as rt:
        fut = rt.submit_gemm(a, b, jobset=js, tile=(16, 16, 16),
                             affinity="doomed")
        rt.remove_engine("doomed")
        y = fut.result(120)
        assert "doomed" not in rt.engine_names
    assert fut.execution_counts == [1] * 24
    assert survivor.executed > 0
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.dot(a, b)),
                               rtol=1e-4, atol=1e-4)


def test_trace_counts_split_gemm_once():
    """A split GEMM is still ONE gemm: trace gemms sum to len(jobsets)
    on the runtime path exactly as on the dispatcher path."""
    a, b = _ab(320, 64, 48, seed=13)
    tr = SynergyTrace()
    with SynergyRuntime(["F-PE", "S-PE"]) as rt, rt.scope():
        with tr.activate():
            synergy_matmul(a, b, tile=32, name="g0")
            synergy_matmul(a, b, tile=32, name="g1")
    assert sum(t.gemms for t in tr.engine_stats.values()) == 2
    assert sum(t.jobs for t in tr.engine_stats.values()) == tr.num_jobs


def test_runtime_scope_is_thread_local():
    """A scope in one thread must not hijack GEMMs in unrelated threads
    (explicit engine= pins there keep routing through the dispatcher)."""
    import threading
    seen = {}

    def other_thread():
        seen["runtime"] = current_runtime()

    with SynergyRuntime(["F-PE"]) as rt, rt.scope():
        t = threading.Thread(target=other_thread)
        t.start()
        t.join()
        assert current_runtime() is rt
    assert seen["runtime"] is None


def test_stats_totals_survive_engine_removal():
    """Hot-unplug folds the retired worker's counters into the totals —
    monitoring never sees total_jobs/total_steals go backwards."""
    e1 = _DelayEngine("r1", seed=21, max_delay_s=0.002)
    e2 = _DelayEngine("r2", seed=22, max_delay_s=0.0)
    a, b = _ab(12 * 16, 32, 16, seed=23)
    js = JobSet.for_gemm(0, a.shape[0], 16, 32, 16)
    with SynergyRuntime([e1, e2]) as rt:
        rt.submit_gemm(a, b, jobset=js, tile=(16, 16, 16)).result(60)
        before = rt.stats()
        assert before["total_jobs"] == 12
        rt.remove_engine("r1")
        after = rt.stats()
    assert after["total_jobs"] == before["total_jobs"]
    assert after["total_steals"] == before["total_steals"]
    assert "r1" not in after["engines"]


def test_reregister_single_engine_pool_keeps_queued_work():
    """Swapping the ONLY engine of a follow_registry pool (the registered()
    shadow pattern) must hand queued jobs to the replacement, not fail
    them with 'no engines left'."""
    slow = _DelayEngine("solo-pe", seed=31, max_delay_s=0.01)
    swap = _DelayEngine("solo-pe", seed=32, max_delay_s=0.0)
    a, b = _ab(16 * 16, 32, 16, seed=33)
    js = JobSet.for_gemm(0, a.shape[0], 16, 32, 16)
    with registered(slow):
        with SynergyRuntime(["solo-pe"], follow_registry=True) as rt:
            fut = rt.submit_gemm(a, b, jobset=js, tile=(16, 16, 16))
            with registered(swap):           # atomic same-name swap
                y = fut.result(120)
            assert fut.execution_counts == [1] * 16
    assert slow.executed + swap.executed == 16
    assert swap.executed > 0, "replacement engine never ran queued work"
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.dot(a, b)),
                               rtol=1e-4, atol=1e-4)


def test_follow_registry_tracks_register_unregister():
    """register_engine/unregister_engine mid-run adapt the live pool — the
    paper's runtime reconfigurability as an API property."""
    ext = _DelayEngine("hotplug", macs_per_s=5e9, seed=5, max_delay_s=0.0)
    with SynergyRuntime(["F-PE"], follow_registry=True) as rt:
        assert rt.engine_names == ["F-PE"]
        with registered(ext):
            assert "hotplug" in rt.engine_names
            a, b = _ab(10 * 32, 48, 32, seed=9)
            js = JobSet.for_gemm(0, a.shape[0], 32, 48, 32)
            y = rt.submit_gemm(a, b, jobset=js).result(60)
            np.testing.assert_allclose(np.asarray(y),
                                       np.asarray(jnp.dot(a, b)),
                                       rtol=1e-4, atol=1e-4)
        assert "hotplug" not in rt.engine_names


# ---------------------------------------------------------- submit_many

def test_submit_many_matches_individual_submissions():
    """The batched accounting path (ONE lock/LPT-seed/wakeup per wave)
    completes every jobset as its own submission with the same totals as
    N individual submits; empty jobsets come back already finished."""
    jobsets = [JobSet.for_gemm(i, 64 * (i + 1), 32, 48, 32, name=f"js{i}")
               for i in range(4)]
    empty = JobSet.for_gemm(9, 0, 32, 48, 32, name="empty")
    with SynergyRuntime(["F-PE", "S-PE"], name="many") as rt:
        futs = rt.submit_many(jobsets + [empty])
        assert futs[-1].done()            # empty: finished in place
        for fut, js in zip(futs, jobsets):
            fut.result(60)
            assert sum(a["jobs"] for a in fut.accounting.values()) \
                == js.num_jobs
            assert sum(a["est_s"] for a in fut.accounting.values()) > 0
        stats = rt.stats()
    # one submission per non-empty jobset, all jobs conserved
    assert stats["submissions"] == len(jobsets)
    assert stats["total_jobs"] == sum(js.num_jobs for js in jobsets)


def test_submit_many_requires_started_runtime():
    rt = SynergyRuntime(["F-PE"], name="cold")
    js = JobSet.for_gemm(0, 64, 32, 48, 32)
    with pytest.raises(RuntimeError, match="not started"):
        rt.submit_many([js])


# -------------------------------------------------------------- serving

def test_server_routes_jobs_through_runtime():
    from repro.configs import ARCHS, reduced
    from repro.core.serving import Request, SynergyServer
    from repro.models import init_model
    cfg = reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=32,
                  n_heads=2, d_ff=64, vocab=128)
    params = init_model(cfg, jax.random.key(0))
    with SynergyRuntime(["F-PE", "S-PE"]) as rt:
        srv = SynergyServer(cfg, params, slots=2, max_len=32,
                            prefill_len=4, runtime=rt)
        for i in range(3):
            srv.submit(Request(i, jax.random.randint(jax.random.key(i),
                                                     (4,), 0, 128),
                               max_new_tokens=4))
        stats = srv.run()
    assert stats.prefills == 3
    assert stats.runtime_jobs > 0
    assert stats.job_busy_s["prefill"] > 0
    assert stats.job_busy_s["decode"] > 0
    assert set(stats.job_engine.values()) <= {"F-PE", "S-PE"}
    assert rt.stats()["total_jobs"] == stats.runtime_jobs


# ------------------------------------------------------ DES conformance

def test_simruntime_conforms_to_des_work_stealing():
    """The virtual-time runtime and simulate(policy='ws') make IDENTICAL
    steal decisions for identical cost models: per-engine busy seconds
    (hence job counts) and utilization agree exactly."""
    js = JobSet.for_gemm(0, 320, 128, 96, 32, name="conv0")
    net = SimNet("one", (SimLayer("conv0", "conv", jobset=js,
                                  im2col_bytes=0),))
    clusters = [Cluster("A", (Accelerator("F-PE0", "F-PE"),)),
                Cluster("B", (Accelerator("S-PE0", "S-PE"),))]
    des = simulate(net, clusters, policy="ws", mapping={"conv0": 0},
                   frames=1, inflight=1, warmup_frames=0)
    sim = SimRuntime(["F-PE", "S-PE"]).run(js, affinity="F-PE")
    des_busy = {"F-PE": des.per_cluster_busy["A"] * des.makespan_s,
                "S-PE": des.per_cluster_busy["B"] * des.makespan_s}
    for kind in ("F-PE", "S-PE"):
        assert sim.per_engine_busy[kind] == pytest.approx(des_busy[kind],
                                                          rel=1e-12)
    assert sim.makespan_s == pytest.approx(des.makespan_s, rel=1e-12)
    assert sim.aggregate_busy_fraction == pytest.approx(des.utilization,
                                                        rel=1e-12)
    assert sim.total_steals > 0       # the slow engine stole real work


def test_steal_policy_is_shared_object():
    """One policy, three executors: the simulator, the live runtime and
    SimRuntime must all call the SAME function."""
    import repro.core.scheduler as sched
    import repro.soc.policy as policy
    import repro.soc.runtime as runtime
    import repro.soc.simrt as simrt
    assert sched.should_steal is policy.should_steal
    assert runtime.should_steal is policy.should_steal
    assert simrt.should_steal is policy.should_steal
    assert should_steal is policy.should_steal
    # the tail guard itself
    assert should_steal(1.0, 1) and should_steal(0.5, 3)
    assert not should_steal(0.5, 2) and not should_steal(1.0, 0)


def test_simruntime_no_affinity_and_empty_jobset():
    js = JobSet.for_gemm(0, 64, 64, 32, 32)
    res = SimRuntime(["F-PE", "S-PE"]).run(js)
    assert sum(res.per_engine_jobs.values()) == js.num_jobs
    empty = JobSet.for_gemm(0, 0, 0, 0, 32)
    res0 = SimRuntime(["F-PE"]).run(empty)
    assert res0.makespan_s == 0.0 and res0.total_steals == 0
