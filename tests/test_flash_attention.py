"""flash_attention Pallas kernel + flash_xla scan path vs naive oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev deps
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import (attention_ref, flash_attention,
                                           flash_attention_pallas)
from repro.models.attention import flash_attention_xla


def _qkv(b, hq, hkv, s, sk, d, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, sk, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, sk, d), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal", [True, False])
def test_pallas_matches_ref(hq, hkv, causal):
    q, k, v = _qkv(2, hq, hkv, 128, 128, 64)
    o = flash_attention_pallas(q, k, v, causal=causal, blk_q=64, blk_k=64,
                               interpret=True)
    r = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("s,sk", [(128, 128), (100, 1500), (257, 64),
                                  (64, 256)])
def test_xla_flash_matches_ref(s, sk):
    q, k, v = _qkv(2, 4, 4, s, sk, 32, seed=1)
    o = flash_attention_xla(q, k, v, causal=False, blk_q=64, blk_k=128)
    r = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               rtol=2e-5, atol=2e-5)


def test_causal_first_token_ignores_future():
    q, k, v = _qkv(1, 2, 2, 64, 64, 32, seed=2)
    o = flash_attention(q, k, v, causal=True, impl="pallas",
                        blk_q=32, blk_k=32)
    # token 0 attends only to kv[0]
    expected = v[:, :, 0, :]
    np.testing.assert_allclose(np.asarray(o[:, :, 0, :]),
                               np.asarray(expected), rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(b=st.integers(1, 2), hkv=st.sampled_from([1, 2]),
       g=st.sampled_from([1, 2, 4]),
       s=st.sampled_from([64, 128]), d=st.sampled_from([32, 64]),
       causal=st.booleans())
def test_property_gqa_blocks(b, hkv, g, s, d, causal):
    q, k, v = _qkv(b, hkv * g, hkv, s, s, d, seed=b * 100 + s)
    o = flash_attention_pallas(q, k, v, causal=causal, blk_q=32, blk_k=32,
                               interpret=True)
    r = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               rtol=3e-5, atol=3e-5)


def test_bf16():
    q, k, v = _qkv(1, 4, 2, 128, 128, 64, dtype=jnp.bfloat16, seed=3)
    o = flash_attention_pallas(q, k, v, causal=True, blk_q=64, blk_k=64,
                               interpret=True)
    r = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               rtol=5e-2, atol=5e-2)
