"""Property tests for the Synergy core: job decomposition invariants and
scheduler behavior (paper §3.1, §4.3)."""

import pytest
pytest.importorskip("hypothesis")  # property tests need the dev deps
from hypothesis import given, settings, strategies as st

from repro.configs.paper_cnns import PAPER_CNNS
from repro.core.clusters import (Cluster, F_PE, NEON, S_PE,
                                 default_synergy_clusters)
from repro.core.job import JobSet, ceil_div
from repro.core.scheduler import (lpt_plan, rebalance, sf_layer_map,
                                  simulate, single_thread_latency)
from repro.models.cnn import build_simnet


# --------------------------------------------------------------- job algebra

@settings(max_examples=40, deadline=None)
@given(m=st.integers(1, 500), n=st.integers(1, 500), k=st.integers(1, 500),
       ts=st.sampled_from([8, 16, 32, 128]))
def test_jobs_tile_output_exactly_once(m, n, k, ts):
    js = JobSet.for_gemm(0, m, n, k, ts)
    cover = {}
    for job in js.jobs():
        for i in range(job.t1 * ts, job.t1 * ts + job.rows):
            for jx in {job.t2 * ts, job.t2 * ts + job.cols - 1}:
                key = (i, jx)
                assert key not in cover, "output element owned by two jobs"
                cover[key] = True
    # corners cover every row index of every valid column edge
    assert js.num_jobs == ceil_div(m, ts) * ceil_div(n, ts)


@settings(max_examples=30, deadline=None)
@given(m=st.integers(1, 300), n=st.integers(1, 300), k=st.integers(1, 300))
def test_padding_waste_bounds(m, n, k):
    js = JobSet.for_gemm(0, m, n, k, 32)
    assert 0.0 <= js.padding_waste < 1.0
    assert js.total_macs >= js.useful_macs


def test_arithmetic_intensity_grows_with_tile():
    from repro.core.job import arithmetic_intensity
    small = arithmetic_intensity(JobSet.for_gemm(0, 1024, 1024, 1024, 32))
    big = arithmetic_intensity(JobSet.for_gemm(0, 1024, 1024, 1024, 256))
    assert big > small  # the TS=32 -> MXU-tile hillclimb rationale


# ----------------------------------------------------------------- simulator

@pytest.mark.parametrize("net_name", ["MNIST", "CIFAR_full", "CIFAR_Alex+"])
def test_ws_beats_or_matches_sf(net_name):
    net = build_simnet(PAPER_CNNS[net_name])
    ws = simulate(net, policy="ws", frames=48)
    sf = simulate(net, policy="sf", frames=48)
    assert ws.fps >= sf.fps * 0.99
    assert ws.utilization > 0.97          # paper: 99.8% mean
    assert 0 < sf.utilization <= 1.0


def test_paper_speedup_band():
    """Fig 9: 7.3x mean speedup over single-threaded ARM Darknet."""
    speedups = []
    for cfg in PAPER_CNNS.values():
        net = build_simnet(cfg)
        st_lat = single_thread_latency(net)
        ws = simulate(net, policy="ws", frames=48)
        speedups.append(ws.fps * st_lat)
    mean = sum(speedups) / len(speedups)
    assert 6.0 <= mean <= 9.0, f"mean speedup {mean:.2f} outside paper band"


def test_nonpipelined_utilization_low():
    """Table 6: non-pipelined designs leave accelerators idle (~56%)."""
    net = build_simnet(PAPER_CNNS["CIFAR_Alex"])
    np_res = simulate(net, policy="ws", frames=16, pipelined=False)
    pi_res = simulate(net, policy="ws", frames=48, pipelined=True)
    assert np_res.utilization < 0.75
    assert pi_res.utilization > np_res.utilization + 0.2


def test_all_frames_complete():
    net = build_simnet(PAPER_CNNS["SVHN"])
    res = simulate(net, policy="ws", frames=20)
    assert res.fps > 0 and res.makespan_s > 0
    assert all(0 <= u <= 1.0 + 1e-9 for u in res.per_cluster_busy.values())


# ------------------------------------------------------------------ planners

def test_lpt_plan_assigns_each_jobset_once():
    jobsets = [JobSet.for_gemm(i, 100 * (i + 1), 64, 64, 32)
               for i in range(7)]
    clusters = default_synergy_clusters()
    plan = lpt_plan(jobsets, clusters)
    seen = sorted(i for part in plan for i in part)
    assert seen == list(range(7))


def test_lpt_balance_bound():
    jobsets = [JobSet.for_gemm(i, 256, 256, 256, 32) for i in range(16)]
    clusters = [Cluster("a", tuple(F_PE(i) for i in range(4))),
                Cluster("b", tuple(F_PE(i) for i in range(4)))]
    plan = lpt_plan(jobsets, clusters)
    loads = [sum(jobsets[i].total_macs for i in part) for part in plan]
    assert max(loads) <= 2 * min(loads)   # LPT guarantee for equal clusters


def test_rebalance_converges_to_rates():
    """Slow cluster (2x slower) ends up with ~1/3 of the work."""
    shares = [0.5, 0.5]
    for _ in range(30):
        times = [shares[0] / 1.0, shares[1] / 0.5]   # rates 1.0 vs 0.5
        shares = rebalance(shares, times, ema=0.5)
    assert abs(shares[0] - 2 / 3) < 0.02
    assert abs(sum(shares) - 1.0) < 1e-9


# ------------------------------------------------------- DES property sweep

@settings(max_examples=10, deadline=None)
@given(n_convs=st.integers(1, 4),
       widths=st.lists(st.sampled_from([16, 32, 64]), min_size=4,
                       max_size=4),
       seed=st.integers(0, 100))
def test_simulator_physics_on_random_nets(n_convs, widths, seed):
    """For ANY random CNN: throughput never exceeds the accelerator pool's
    physical MAC rate, utilization stays in [0,1], and WS >= SF."""
    from repro.core.scheduler import SimLayer, SimNet
    from repro.core.clusters import F_PE_MACS_PER_S

    layers = [SimLayer("norm", "cpu", cpu_ops=1000)]
    m = 32 * 32
    for i in range(n_convs):
        k = 9 * widths[i]
        js = JobSet.for_gemm(i, m, widths[(i + 1) % 4], k, 32,
                             name=f"c{i}")
        layers.append(SimLayer(f"c{i}", "conv", jobset=js,
                               im2col_bytes=4 * m * k))
        m = max(64, m // 4)
    net = SimNet("rand", tuple(layers))
    clusters = default_synergy_clusters()
    pool_rate = sum(a.macs_per_s for c in clusters for a in c.accelerators)
    macs = sum(l.jobset.total_macs for l in net.layers if l.kind == "conv")

    ws = simulate(net, clusters, policy="ws", frames=48)
    sf = simulate(net, clusters, policy="sf", frames=48)
    ceiling = pool_rate / macs
    # work conservation: completed work / wall time can never exceed the
    # pool's MAC rate (the windowed fps estimator is steady-state-biased by
    # design, so the physics bound is asserted on makespan throughput)
    assert 48 / ws.makespan_s <= ceiling * 1.001, (48 / ws.makespan_s,
                                                   ceiling)
    assert 0.0 <= ws.utilization <= 1.0 + 1e-9
    assert ws.fps >= sf.fps * 0.95
