"""Online activation quantization and its wiring (ISSUE 4).

Covers the ActCalibrator EMA (determinism, warmup gating, tracer
safety), the QuantizedEngine fast path (int8×int8 once a scale is
published, weight-only before, forced weight-only for pinned splits),
the calibration gate measuring the int8×int8 path and replacing the
simulated 4x with a measured kernel rate, the runtime's int32-partial
split/merge (deterministic, steal-friendly, one dequant), serving's
decode-feeds-the-calibrator loop, the auto-recalibration cadence with
JSON rate persistence, and the grad(jit(f)) pjit-jvp guard.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.job import JobSet
from repro.core.synergy_mm import synergy_matmul
from repro.engines import (CAP_GEMM, CostModel, Engine, get_engine,
                           registered)
from repro.engines.sim import SIM_ENGINE_SPECS, SimPEEngine
from repro.quant import (ActCalibrator, QuantizedEngine, calibrate,
                         quant_gemm, quantize_activations, quantize_weights,
                         register_quantized)
from repro.soc import SynergyRuntime


def _ab(m, k, n, seed=0, wscale=0.05):
    ka, kb = jax.random.split(jax.random.key(seed))
    return (jax.random.normal(ka, (m, k)),
            jax.random.normal(kb, (k, n)) * wscale)


# ----------------------------------------------------------- calibrator

def test_act_calibrator_ema_and_gating():
    cal = ActCalibrator(momentum=0.5, min_updates=2)
    a1 = jnp.full((4, 8), 2.0)
    a2 = jnp.full((4, 8), 4.0)
    assert cal.scale_for(("x",)) is None
    cal.observe(a1, ("x",))
    assert cal.scale_for(("x",)) is None          # still warming up
    cal.observe(a2, ("x",))
    s = cal.scale_for(("x",))
    # EMA: 0.5*2 + 0.5*4 = 3 -> scale 3/127
    assert s == pytest.approx(3.0 / 127.0)
    assert len(cal) == 1


def test_act_calibration_is_deterministic_across_runs():
    """Seeded batches in the same order -> bit-identical scales, and two
    engines calibrated that way produce bit-identical outputs."""
    def run():
        cal = ActCalibrator()
        key = jax.random.key(7)
        for i in range(5):
            key, k = jax.random.split(key)
            cal.observe(jax.random.normal(k, (4, 32)) * (1 + i / 5), (32, 16))
        return cal.scale_for((32, 16))
    s1, s2 = run(), run()
    assert s1 == s2
    a, w = _ab(8, 32, 16, seed=1)
    qw = quantize_weights(w)
    y1 = quant_gemm(a, qw, act_scale=s1)
    y2 = quant_gemm(a, qw, act_scale=s2)
    assert np.array_equal(np.asarray(y1), np.asarray(y2))


def test_calibrator_ignores_tracers():
    cal = ActCalibrator()
    jax.jit(lambda x: (cal.observe(x, ("t",)), x)[1])(jnp.ones((2, 2)))
    assert cal.scale_for(("t",)) is None


def test_quantize_activations_saturates():
    q = quantize_activations(jnp.array([[-10.0, 0.0, 10.0]]), 0.05)
    assert q.dtype == jnp.int8
    assert q.tolist() == [[-127, 0, 127]]


# ------------------------------------------------------- engine routing

def test_engine_flips_to_int8_path_after_observation():
    """Online lifecycle: before any concrete batch the engine runs the
    weight-only fp32 dot; the first live batch publishes a scale and
    later calls consume int8 operands."""
    q = QuantizedEngine(get_engine("xla"), name="flip-int8")
    a, w = _ab(8, 48, 16, seed=2)
    assert q.act_scale_for(48, 16) is None
    y = q.execute(a, w, tile=(16, 16, 16))
    assert q.act_scale_for(48, 16) is not None    # decode batch calibrated
    y2 = q.execute(a, w, tile=(16, 16, 16))
    ref = a @ w
    for out in (y, y2):
        rel = float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))
        assert rel < 0.05, rel


def test_engine_without_calibrator_stays_weight_only():
    q = QuantizedEngine(get_engine("xla"), name="wo-int8", calibrator=None)
    a, w = _ab(8, 48, 16, seed=3)
    q.execute(a, w, tile=(16, 16, 16))
    assert q.act_scale_for(48, 16) is None


def test_execute_weight_only_never_observes():
    q = QuantizedEngine(get_engine("xla"), name="pin-wo-int8")
    a, w = _ab(8, 48, 16, seed=4)
    y = q.execute_weight_only(a, w, tile=(16, 16, 16))
    assert q.act_scale_for(48, 16) is None
    ref = a @ w
    assert float(jnp.max(jnp.abs(y - ref)) / jnp.max(jnp.abs(ref))) < 0.05


def test_calibration_gate_measures_int8_path_and_rate():
    q = QuantizedEngine(get_engine("xla"), name="gate-int8")
    report = calibrate(q, tol=0.05)
    assert report.passed
    assert report.int8_path                      # gated on the REAL path
    assert report.measured_macs_per_s and report.measured_macs_per_s > 0
    assert "int8x8" in str(report)


def test_calibration_gate_warms_slow_publishing_calibrators():
    """Regression: with min_updates=2 the int8 path first runs (and jit-
    compiles) on the SECOND pass — the gate must keep that compile out of
    the timed window, or the measured rate poisons the cost model."""
    fast = calibrate(QuantizedEngine(get_engine("xla"), name="mu1-int8"),
                     tol=0.05)
    slow = calibrate(
        QuantizedEngine(get_engine("xla"), name="mu2-int8",
                        calibrator=ActCalibrator(min_updates=2)),
        tol=0.05)
    assert slow.int8_path                 # the published path was timed
    # compile-free timing: same order of magnitude as the default engine
    assert slow.measured_macs_per_s > fast.measured_macs_per_s / 20


def test_register_quantized_drops_simulated_4x_for_measured_rate():
    from repro.engines import unregister_engine
    base = get_engine("xla")
    eng = register_quantized("xla", name="rate-int8", tol=0.05)
    try:
        nominal = base.cost.macs_per_s * eng.speedup
        assert eng.cost.macs_per_s == pytest.approx(
            eng.calibration.measured_macs_per_s)
        assert eng.cost.macs_per_s != pytest.approx(nominal)
    finally:
        unregister_engine("rate-int8")


def test_register_quantized_keeps_sim_base_constants():
    """A CAP_SIM base's scaled paper constants must never absorb a host
    rate — virtual time would be corrupted."""
    from repro.engines import unregister_engine
    fpe = get_engine("F-PE")
    eng = register_quantized(fpe, name="sim-int8", tol=0.05)
    try:
        assert eng.cost.macs_per_s == pytest.approx(
            fpe.cost.macs_per_s * eng.speedup)
    finally:
        unregister_engine("sim-int8")


# --------------------------------------------- runtime int32-partial split

def _mixed_pool(seed=0):
    fp32 = SimPEEngine(f"aq-fp32-{seed}", SIM_ENGINE_SPECS["F-PE"])
    int8 = QuantizedEngine(fp32, name=f"aq-int8-{seed}")
    return fp32, int8


def test_runtime_decode_split_uses_int32_partials_and_steals():
    """An opted-in GEMM with a published scale splits into raw int32
    panels that ANY engine may run (exact integer partials), so the
    split stays stealable even on a mixed pool — and both engines
    execute panels."""
    fp32, int8 = _mixed_pool(seed=1)
    a, w = _ab(24 * 16, 40, 24, seed=5)
    js = JobSet.for_gemm(0, a.shape[0], 24, 40, 16)
    with SynergyRuntime([fp32, int8], name="i32") as rt:
        seen = {}
        orig = rt._submit_jobs

        def spy(jobset, units, merge, affinity, stealable=True, **kw):
            seen["stealable"] = stealable
            return orig(jobset, units, merge, affinity, stealable, **kw)

        rt._submit_jobs = spy
        fut = rt.submit_gemm(a, w, jobset=js, tile=(16, 16, 16),
                             job_class="decode")
        y = fut.result(60)
    assert seen["stealable"] is True              # int32 partials steal
    assert set(fut.accounting) == {fp32.name, int8.name}
    ref = np.asarray(a @ w)
    rel = float(np.max(np.abs(np.asarray(y) - ref)) / np.max(np.abs(ref)))
    assert rel < 0.05, rel


def test_runtime_decode_split_deterministic_despite_stealing():
    fp32, int8 = _mixed_pool(seed=2)
    a, w = _ab(12 * 16, 32, 16, seed=6)
    js = JobSet.for_gemm(0, a.shape[0], 16, 32, 16)
    outs = []
    for trial in range(3):
        with SynergyRuntime([fp32, int8], name=f"det{trial}") as rt:
            y = rt.submit_gemm(a, w, jobset=js, tile=(16, 16, 16),
                               job_class="decode").result(60)
            outs.append(np.asarray(y))
    assert all(np.array_equal(outs[0], o) for o in outs[1:])


def test_runtime_plain_split_still_full_precision():
    fp32, int8 = _mixed_pool(seed=3)
    a, w = _ab(8 * 16, 32, 16, seed=7)
    js = JobSet.for_gemm(0, a.shape[0], 16, 32, 16)
    ref = fp32.execute(a, w)
    with SynergyRuntime([fp32, int8], name="plain") as rt:
        y = rt.submit_gemm(a, w, jobset=js, tile=(16, 16, 16)).result(60)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------- serving

def test_server_decode_feeds_calibrator():
    from repro.configs import ARCHS, reduced
    from repro.core.serving import Request, SynergyServer
    from repro.models import init_model
    cfg = reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=32,
                  n_heads=2, d_ff=64, vocab=128)
    params = init_model(cfg, jax.random.key(0))
    q = QuantizedEngine(get_engine("xla"), name="feed-int8")
    # real n-stacked FFN decode GEMM: key is (d_model, n_layers·2·d_ff)
    key = (cfg.d_model, cfg.n_layers * 2 * cfg.d_ff)
    with registered(q):
        srv = SynergyServer(cfg, params, slots=2, max_len=32, prefill_len=4)
        for i in range(2):
            srv.submit(Request(i, jax.random.randint(jax.random.key(i),
                                                     (4,), 0, 128),
                               max_new_tokens=4))
        stats = srv.run()
    assert stats.decode_steps > 0
    scales = q.calibrator.state()
    assert key in scales
    # every decode step observed one embedding batch
    assert scales[key].updates == stats.decode_steps
    assert q.act_scale_for(*key) is not None


# --------------------------------------- recalibration cadence + sidecar

class _Claiming(Engine):
    """Deterministic engine claiming ``claimed`` MAC/s, delivering the
    rate its per-job sleep implies."""

    def __init__(self, name, claimed, actual):
        super().__init__(name, {CAP_GEMM, "epilogue"},
                         cost=CostModel(macs_per_s=claimed))
        self.actual = actual

    def execute(self, a, b, *, bias=None, activation=None, tile=None,
                out_dtype=None, precision=None):
        import time
        macs = a.shape[0] * a.shape[1] * b.shape[1]
        time.sleep(macs / self.actual)
        return jnp.dot(a, b).astype(out_dtype or a.dtype)


def test_auto_recalibration_cadence_and_persistence(tmp_path):
    """recalibrate_every=N triggers without any caller involvement, and
    the learned rate survives a 'restart' via the JSON sidecar."""
    sidecar = tmp_path / "rates.json"
    true_rate = 2e8
    eng = _Claiming("cadence", claimed=100 * true_rate, actual=true_rate)
    a, w = _ab(8 * 16, 32, 16, seed=8)
    js = JobSet.for_gemm(0, a.shape[0], 16, 32, 16)
    with SynergyRuntime([eng], name="auto", recalibrate_every=2,
                        rates_path=sidecar) as rt:
        before = eng.cost.macs_per_s
        rt.submit_gemm(a, w, jobset=js, tile=(16, 16, 16)).result(60)
        assert eng.cost.macs_per_s == before      # cadence not due yet
        rt.submit_gemm(a, w, jobset=js, tile=(16, 16, 16)).result(60)
        deadline = 50
        while eng.cost.macs_per_s == before and deadline:
            import time
            time.sleep(0.05)
            deadline -= 1
        after = eng.cost.macs_per_s
    assert after < before                         # over-claim EMA'd down
    data = json.loads(sidecar.read_text())
    assert data["macs_per_s"]["cadence"] == pytest.approx(after)
    # 'restart': a fresh runtime over a fresh engine re-applies the rate
    eng2 = _Claiming("cadence", claimed=100 * true_rate, actual=true_rate)
    SynergyRuntime([eng2], name="restart", rates_path=sidecar)
    assert eng2.cost.macs_per_s == pytest.approx(after)


def test_sim_engines_never_load_persisted_rates(tmp_path):
    sidecar = tmp_path / "rates.json"
    sidecar.write_text(json.dumps({"macs_per_s": {"F-PE": 1.0}}))
    fpe = get_engine("F-PE")
    before = fpe.cost.macs_per_s
    SynergyRuntime(["F-PE"], name="simload", rates_path=sidecar)
    assert fpe.cost.macs_per_s == before


def test_corrupt_sidecar_is_a_fresh_start(tmp_path):
    sidecar = tmp_path / "rates.json"
    sidecar.write_text("{not json")
    eng = _Claiming("fresh", claimed=1e9, actual=1e9)
    SynergyRuntime([eng], name="fresh", rates_path=sidecar)
    assert eng.cost.macs_per_s == 1e9


# -------------------------------------------------- grad(jit(f)) guard

class _GradFreeMock(Engine):
    def __init__(self, name="pjit-mock"):
        super().__init__(name, {CAP_GEMM, "epilogue"},
                         cost=CostModel(macs_per_s=1e18))
        self.calls = 0

    def execute(self, a, b, *, bias=None, activation=None, tile=None,
                out_dtype=None, precision=None):
        self.calls += 1
        return jnp.zeros((a.shape[0], b.shape[1]), a.dtype)   # poisoned


def test_grad_of_jit_never_selects_grad_free_engine():
    """ISSUE 4 satellite: grad(jit(f)) differentiates f's jaxpr outside
    the JVP trace; the stack-walk guard must still require CAP_GRAD —
    no manual job_class='train' at the call site."""
    a, w = _ab(8, 16, 12, seed=9, wscale=1.0)
    mock = _GradFreeMock()
    with registered(mock):
        g = jax.grad(jax.jit(
            lambda b: jnp.sum(synergy_matmul(a, b, tile=8))))(w)
        assert mock.calls == 0
        assert bool(jnp.any(g != 0))              # real gradient
        # contrast: a PLAIN jit trace still routes to the cheap mock
        y = jax.jit(lambda b: synergy_matmul(a, b, tile=8))(w)
        assert mock.calls > 0
        assert not bool(jnp.any(y != 0))          # the poisoned output


def test_jit_of_grad_still_guarded():
    a, w = _ab(8, 16, 12, seed=10, wscale=1.0)
    mock = _GradFreeMock(name="pjit-mock-2")
    with registered(mock):
        g = jax.jit(jax.grad(
            lambda b: jnp.sum(synergy_matmul(a, b, tile=8))))(w)
        assert mock.calls == 0
        assert bool(jnp.any(g != 0))
