"""Multi-tenant QoS: priorities, SLO deadlines, preemption at panel
granularity, admission/fairness/shedding, and self-healing engine pools.

Covers the PR's acceptance surface without the hypothesis dev-dependency
(see ``test_qos_props.py`` for the property sweeps):

  * pure policy units (:mod:`repro.soc.qos_policy`) — queue insertion,
    victim choice, effective deadlines, stride fair share;
  * :class:`repro.soc.qos.EngineHealth` lifecycle state machine;
  * live runtime placement: priority-sorted deques, deadline-aware seed
    order, QoS victim choice in ``_try_steal_locked``, and end-to-end
    priority completion ordering behind a gated worker;
  * live quarantine/readmission of a rate-degraded engine;
  * :meth:`repro.soc.SimRuntime.run_qos` — deadline verdicts, quarantine
    exclusion, and seed-map conformance against the live
    ``_seed_locked`` (shared-function identity asserted too);
  * serving tenancy: bounded queues + ``AdmissionRejected`` retry-after,
    weighted fair admission, the shed ladder's int8 degradation,
    per-tenant stats, and bitwise token parity of a tenanted server
    against the untenanted FIFO path on an unloaded pool.
"""

import math
import random
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core.job import JobSet
from repro.core.serving import (Request, ServeTimeoutError, SynergyServer,
                                TenantStats)
from repro.engines import CAP_GEMM, CostModel, Engine, get_engine
from repro.models import init_model
from repro.soc import (AdmissionRejected, EngineHealth, FairShare,
                       HealthPolicy, QosClass, QosTag, SimRuntime,
                       SynergyRuntime, Tenant, effective_deadline,
                       qos_victim, queue_insert_index)
from repro.soc.qos import BULK, DEFAULT_CLASS
from repro.soc.runtime import _RuntimeJob, _Submission


def _cfg():
    return reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=32,
                   n_heads=2, d_ff=64, vocab=128)


def _server(slots=2, **kw):
    cfg = _cfg()
    params = init_model(cfg, jax.random.key(0))
    return SynergyServer(cfg, params, slots=slots, max_len=32,
                         prefill_len=4, **kw)


# ------------------------------------------------------------ policy units

def test_queue_insert_index():
    # all-neutral queue: plain append (the pre-QoS behavior)
    assert queue_insert_index([0, 0, 0], 0) == 3
    assert queue_insert_index([], 5) == 0
    # ahead of strictly lower priority, behind peers (FIFO within class)
    assert queue_insert_index([10, 10, 0, -5], 10) == 2
    assert queue_insert_index([10, 0], 5) == 1
    assert queue_insert_index([10, 5, 0], -1) == 3


def test_qos_victim_prefers_lowest_tail_priority():
    # bulk tail (-10) wins over a busier neutral queue
    assert qos_victim([0, -10, 0], [5, 3, 4]) == 1
    # ties on tail priority fall back to the busiest (pick_victim)
    assert qos_victim([0, 0, 0], [2, 7, 4]) == 1
    assert qos_victim([3], [1]) == 0


def test_effective_deadline():
    assert effective_deadline(10.0, 2.5) == 7.5
    assert effective_deadline(math.inf, 1.0) == math.inf


def test_fair_share_weighted_picks():
    fs = FairShare()
    counts = {"a": 0, "b": 0}
    cands = [("a", 0, math.inf, 4.0), ("b", 0, math.inf, 1.0)]
    for _ in range(10):
        name = fs.pick(cands)
        counts[name] += 1
        fs.charge(name, 4.0 if name == "a" else 1.0)
    # stride scheduling: 4x weight -> 4x the admissions
    assert counts == {"a": 8, "b": 2}


def test_fair_share_priority_trumps_virtual_time():
    fs = FairShare()
    fs.charge("hi", 1.0)          # hi has spent credit already
    picked = fs.pick([("hi", 10, math.inf, 1.0),
                      ("lo", 0, math.inf, 1.0)])
    assert picked == "hi"


def test_fair_share_idle_tenant_rejoins_at_floor():
    fs = FairShare()
    for _ in range(5):
        fs.charge("busy", 1.0)
    # a late joiner enters at the current minimum, not at 0 credit-hoard
    fs.pick([("busy", 0, math.inf, 1.0), ("late", 0, math.inf, 1.0)])
    assert fs.snapshot()["late"] == pytest.approx(
        min(5.0, fs.snapshot()["busy"]))


# ----------------------------------------------------- EngineHealth units

def test_engine_health_lifecycle():
    pol = HealthPolicy(alpha=0.5, quarantine_below=0.5, readmit_above=0.8,
                       min_samples=3, probe_interval_s=0.25,
                       min_probe_samples=2)
    h = EngineHealth()
    assert h.health == 1.0                  # no data: presumed healthy
    h.observe(100.0, pol)                   # first sample seeds the EMA
    assert h.ema_rate == 100.0 and h.baseline == 100.0
    h.observe(100.0, pol)
    assert not h.should_quarantine(pol)     # min_samples gate (2 < 3)
    h.observe(10.0, pol)                    # ema -> 55: above threshold
    assert not h.should_quarantine(pol)
    h.observe(10.0, pol)                    # ema -> 32.5 < 50
    assert h.should_quarantine(pol)
    h.enter_quarantine(now=100.0)
    assert h.quarantined and h.quarantines == 1
    assert not h.probe_due(100.1, pol)      # probe cadence
    assert h.probe_due(100.3, pol)
    h.observe(100.0, pol)                   # probe 1: ema -> 66.25
    assert not h.recovered(pol)             # min_probe_samples gate
    h.observe(100.0, pol)                   # probe 2: ema -> 83.1 >= 80
    assert h.recovered(pol)
    h.exit_quarantine()
    assert not h.quarantined and h.probe_samples == 0
    # baseline was NOT raised by quarantine probes
    assert h.baseline == 100.0


# ------------------------------------------------- runtime placement units

class _Plain(Engine):
    def __init__(self, name, macs_per_s=1e9):
        super().__init__(name, {CAP_GEMM, "epilogue"},
                         cost=CostModel(macs_per_s=macs_per_s))

    def execute(self, a, b, *, bias=None, activation=None, tile=None,
                out_dtype=None, precision=None):
        y = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
        return y.astype(out_dtype or a.dtype)


def _job(sub, index, priority=0, deadline_at=math.inf, macs=1000):
    return _RuntimeJob(sub, index, None, 1, macs, 0, priority=priority,
                       deadline_at=deadline_at)


def test_enqueue_keeps_queue_priority_sorted():
    from collections import deque
    js = JobSet.for_gemm(0, 32, 32, 32, 32)
    sub = _Submission(js, 6, None)
    q: deque = deque()
    for i, prio in enumerate([0, 0, 10, -5, 10, 3]):
        SynergyRuntime._enqueue(q, _job(sub, i, prio))
    prios = [j.priority for j in q]
    assert prios == sorted(prios, reverse=True)
    # FIFO within class: the two priority-10 jobs keep submission order
    tens = [j.index for j in q if j.priority == 10]
    assert tens == [2, 4]


def test_seed_order_neutral_is_identity_else_edf():
    js = JobSet.for_gemm(0, 32, 32, 32, 32)
    sub = _Submission(js, 4, None)
    neutral = [_job(sub, i) for i in range(4)]
    # all-neutral: the SAME sequence comes back (bitwise pre-QoS parity)
    assert SynergyRuntime._seed_order(neutral, 1e9) is neutral
    jobs = [_job(sub, 0, priority=0, deadline_at=math.inf),
            _job(sub, 1, priority=10, deadline_at=5.0, macs=2000),
            _job(sub, 2, priority=10, deadline_at=4.0, macs=1000),
            _job(sub, 3, priority=-10)]
    got = [j.index for j in SynergyRuntime._seed_order(jobs, 1e9)]
    # priority desc; within the 10s, earliest EFFECTIVE deadline first
    assert got == [2, 1, 0, 3]


def test_try_steal_picks_lowest_priority_tail_victim():
    e = [_Plain(f"v{i}") for i in range(3)]
    rt = SynergyRuntime(e)          # never started: queues are ours
    js = JobSet.for_gemm(0, 32, 32, 32, 32)
    sub = _Submission(js, 8, None)
    ws = list(rt._workers.values())
    # thief = ws[0] (empty); ws[1] busier but neutral; ws[2] holds bulk
    for i in range(3):
        ws[1].queue.append(_job(sub, i, priority=0))
    for i in range(2):
        ws[2].queue.append(_job(sub, 3 + i, priority=-10))
    stolen = rt._try_steal_locked(ws[0])
    assert stolen is not None and stolen.priority == -10
    assert stolen.index == 4        # the TAIL of the bulk victim
    assert len(ws[2].queue) == 1 and len(ws[1].queue) == 3


def test_priority_completion_order_behind_gated_worker():
    """With one worker blocked mid-panel, an interactive submission that
    arrives AFTER a bulk one still finishes first: its panels enter the
    queue ahead of the queued bulk panels (preemption at panel
    granularity — the in-flight panel itself is never killed)."""
    gate = threading.Event()
    seen: list[int] = []

    class _GateEngine(_Plain):
        def execute(self, a, b, **kw):
            if a.shape[1] == 4:          # the gate GEMM: k == 4
                gate.wait(30)
            seen.append(a.shape[1])
            return super().execute(a, b, **kw)

    eng = _GateEngine("gated")
    k_bulk, k_inter = 8, 12
    with SynergyRuntime([eng], name="gate") as rt:
        a_gate = jnp.ones((16, 4)); b_gate = jnp.ones((4, 8))
        f0 = rt.submit_gemm(a_gate, b_gate,
                            jobset=JobSet.for_gemm(0, 16, 8, 4, 16),
                            tile=(16, 16, 16))
        time.sleep(0.2)                  # worker is inside the gate panel
        a_b = jnp.ones((48, k_bulk)); b_b = jnp.ones((k_bulk, 8))
        fb = rt.submit_gemm(a_b, b_b,
                            jobset=JobSet.for_gemm(1, 48, 8, k_bulk, 16),
                            tile=(16, 16, 16), qos=QosTag(-10))
        a_i = jnp.ones((48, k_inter)); b_i = jnp.ones((k_inter, 8))
        fi = rt.submit_gemm(a_i, b_i,
                            jobset=JobSet.for_gemm(2, 48, 8, k_inter, 16),
                            tile=(16, 16, 16), qos=QosTag(10))
        gate.set()
        for f in (f0, fb, fi):
            f.result(60)
    assert seen[0] == 4
    # every interactive panel ran before every bulk panel
    assert seen[1:4] == [k_inter] * 3 and seen[4:] == [k_bulk] * 3


# ------------------------------------------------- live self-healing pool

class _SickEngine(_Plain):
    """Wall-clock paced engine with a MUTABLE per-panel delay — flip
    ``delay_s`` to simulate a thermal-throttled / failing accelerator."""

    def __init__(self, name, delay_s):
        super().__init__(name, macs_per_s=1e9)
        self.delay_s = delay_s

    def execute(self, a, b, **kw):
        time.sleep(self.delay_s)
        return super().execute(a, b, **kw)


def _gemm(rt, step, m=16, affinity=None):
    a = jnp.ones((m, 32)); b = jnp.ones((32, 16))
    return rt.submit_gemm(a, b,
                          jobset=JobSet.for_gemm(step, m, 16, 32, 16),
                          tile=(16, 16, 16), affinity=affinity)


def test_quarantine_and_readmission_lifecycle():
    pol = HealthPolicy(alpha=0.5, quarantine_below=0.5, readmit_above=0.6,
                       min_samples=3, probe_interval_s=0.05,
                       min_probe_samples=2)
    sick = _SickEngine("sick", delay_s=0.008)
    buddy = _SickEngine("buddy", delay_s=0.008)
    with SynergyRuntime([sick, buddy], name="heal", health=pol) as rt:
        # phase 1: establish a healthy baseline on both workers
        for s in range(8):
            _gemm(rt, s, affinity="sick").result(30)
        assert not rt.stats()["engines"]["sick"]["quarantined"]

        # phase 2: the sick engine degrades 15x -> quarantine
        sick.delay_s = 0.12
        deadline = time.monotonic() + 30
        step = 100
        while not rt.stats()["engines"]["sick"]["quarantined"]:
            assert time.monotonic() < deadline, "never quarantined"
            _gemm(rt, step, affinity="sick").result(30)
            step += 1
        st = rt.stats()
        assert st["quarantines"] >= 1
        assert st["engines"]["sick"]["health"] < 1.0
        assert sick.telemetry.snapshot().quarantines >= 1
        rebalances_at_quarantine = st["rebalances"]
        assert rebalances_at_quarantine >= 1    # deque drained to buddy

        # quarantined worker takes no seeds: fresh work lands on buddy
        before = rt.stats()["engines"]["buddy"]["jobs"]
        _gemm(rt, step, affinity="sick").result(30)
        step += 1
        assert rt.stats()["engines"]["buddy"]["jobs"] > before

        # phase 3: engine recovers; probation probes re-admit it
        sick.delay_s = 0.008
        deadline = time.monotonic() + 60
        while rt.stats()["engines"]["sick"]["quarantined"]:
            assert time.monotonic() < deadline, "never re-admitted"
            # deep buddy queue so the probe steal passes the tail guard
            _gemm(rt, step, m=64, affinity="buddy").result(60)
            step += 1
        assert rt.stats()["rebalances"] > rebalances_at_quarantine


def test_health_none_keeps_stats_shape():
    with SynergyRuntime([_Plain("nh")], name="nohealth") as rt:
        _gemm(rt, 0).result(30)
        st = rt.stats()
    assert st["quarantines"] == 0
    assert st["engines"]["nh"]["health"] is None
    assert st["engines"]["nh"]["quarantined"] is False


# --------------------------------------------------- SimRuntime.run_qos

def test_qos_functions_are_shared_objects():
    import repro.soc.qos_policy as qp
    import repro.soc.runtime as runtime
    import repro.soc.simrt as simrt
    for mod in (runtime, simrt):
        assert mod.qos_victim is qp.qos_victim
        assert mod.queue_insert_index is qp.queue_insert_index
        assert mod.effective_deadline is qp.effective_deadline
    import repro.soc.policy as policy
    assert simrt.lpt_pick is policy.lpt_pick
    assert runtime.lpt_pick is policy.lpt_pick


def test_run_qos_priority_and_deadlines_single_engine():
    """On one engine the schedule is strictly priority-ordered, so the
    interactive submission finishes after exactly its own service time —
    a deadline with any slack over that is met no matter how much bulk
    work was admitted alongside."""
    eng = get_engine("F-PE")
    bulk = JobSet.for_gemm(0, 320, 128, 96, 32, name="bulk")
    inter = JobSet.for_gemm(1, 64, 128, 96, 32, name="inter")
    j = next(inter.jobs())
    solo_s = inter.num_jobs * eng.cost.job_time(j.macs, j.bytes_moved)
    res = SimRuntime(["F-PE"]).run_qos(
        [(bulk, QosTag(-10)), (inter, QosTag(10, solo_s * 1.01))])
    assert res.deadline_met == (True, True)      # bulk has no deadline
    assert res.submission_finish_s[1] == pytest.approx(solo_s, rel=1e-9)
    assert res.submission_finish_s[1] < res.submission_finish_s[0]
    assert sum(res.per_engine_jobs.values()) == \
        bulk.num_jobs + inter.num_jobs


def test_run_qos_quarantine_exclusion():
    js = JobSet.for_gemm(0, 320, 128, 96, 32)
    res = SimRuntime(["F-PE", "S-PE"]).run_qos([(js, None)],
                                               quarantined=["S-PE"])
    assert res.per_engine_jobs["S-PE"] == 0
    assert res.per_engine_jobs["F-PE"] == js.num_jobs
    assert set(res.seed_map[0]) == {"F-PE"}
    with pytest.raises(ValueError, match="every engine quarantined"):
        SimRuntime(["F-PE"]).run_qos([(js, None)], quarantined=["F-PE"])


def test_run_qos_seed_map_conforms_to_live_seeding():
    """The sim's seed map and the live runtime's ``_seed_locked`` make
    IDENTICAL placement decisions for identical cost models — deadline
    sort, LPT pick, and priority insertion are the same shared
    functions, applied in the same order."""
    subs = [
        (JobSet.for_gemm(0, 128, 64, 32, 32, name="bulk"), QosTag(-10)),
        (JobSet.for_gemm(1, 64, 64, 32, 32, name="hot"), QosTag(10, 0.5)),
        (JobSet.for_gemm(2, 96, 64, 32, 32, name="mid"), None),
    ]
    sim = SimRuntime(["F-PE", "S-PE"]).run_qos(subs)

    rt = SynergyRuntime(["F-PE", "S-PE"])      # never started
    jobs, sids = [], []
    from repro.soc.qos_policy import NEUTRAL_TAG
    for sid, (js, tag) in enumerate(subs):
        tag = tag or NEUTRAL_TAG
        units = rt._accounting_units(js, "job")
        sub = _Submission(js, len(units), None)
        for i, (fn, n_jobs, macs, nbytes) in enumerate(units):
            jobs.append(_RuntimeJob(sub, i, fn, n_jobs, macs, nbytes,
                                    priority=tag.priority,
                                    deadline_at=tag.deadline_at))
            sids.append(sid)
        sub._sid = sid
    rt._seed_locked(jobs, affinity=None)
    live = [[None] * len(sim.seed_map[s]) for s in range(len(subs))]
    for name, w in rt._workers.items():
        for job in w.queue:
            live[job.sub._sid][job.index] = name
    assert tuple(tuple(m) for m in live) == sim.seed_map


# -------------------------------------------------------- serving tenancy

GOLD = QosClass("gold", priority=10, deadline_s=120.0, weight=4.0)


def _reqs(n, tenant=None, base=0, max_new=3):
    return [Request(base + i, jnp.arange(4, dtype=jnp.int32) + i,
                    max_new_tokens=max_new, tenant=tenant)
            for i in range(n)]


def test_tenanted_server_end_to_end_stats():
    srv = _server(slots=2, tenants=[Tenant("gold", GOLD),
                                    Tenant("bulk", BULK)])
    reqs = _reqs(2, "gold") + _reqs(3, "bulk", base=10)
    for r in reqs:
        srv.submit(r)
    stats = srv.run()
    assert all(len(r.out) >= 3 for r in reqs)
    assert all(r.done_at is not None for r in reqs)
    g, b = stats.tenants["gold"], stats.tenants["bulk"]
    assert g.admitted == 2 and b.admitted == 3
    assert g.prefills == 2 and b.prefills == 3
    assert g.tokens_out + b.tokens_out == stats.tokens_out
    assert g.queue_wait_s >= 0 and g.max_queue_wait_s >= 0
    # gold's 120 s deadline: every completion is accounted, all hits
    assert g.deadline_hits + g.deadline_misses == 2
    assert g.deadline_attainment == 1.0
    # bulk has no deadline: vacuous attainment
    assert b.deadline_hits == b.deadline_misses == 0
    assert b.deadline_attainment == 1.0


def test_unknown_tenant_and_constructor_validation():
    srv = _server(slots=2, tenants=[Tenant("a")])
    with pytest.raises(KeyError, match="unknown tenant"):
        srv.submit(Request(0, jnp.arange(4, dtype=jnp.int32), 2,
                           tenant="nope"))
    with pytest.raises(ValueError, match="duplicate tenant"):
        _server(tenants=[Tenant("a"), Tenant("a")])
    with pytest.raises(ValueError, match="tenants"):
        _server(tenants=[])


def test_bounded_queue_rejects_with_retry_after():
    srv = _server(slots=2, tenants=[Tenant("t", DEFAULT_CLASS,
                                           max_pending=2)])
    for r in _reqs(2, "t"):
        srv.submit(r)
    with pytest.raises(AdmissionRejected) as ei:
        srv.submit(Request(9, jnp.arange(4, dtype=jnp.int32), 2,
                           tenant="t"))
    assert ei.value.tenant == "t"
    assert ei.value.retry_after_s > 0
    assert "retry after" in str(ei.value)
    assert srv.stats.admission_rejects == 1
    assert srv.stats.tenants["t"].rejected == 1


def test_untenanted_global_max_pending_bound():
    srv = _server(slots=2, max_pending=1)
    srv.submit(Request(0, jnp.arange(4, dtype=jnp.int32), 2))
    with pytest.raises(AdmissionRejected):
        srv.submit(Request(1, jnp.arange(4, dtype=jnp.int32), 2))
    assert srv.stats.admission_rejects == 1
    # the real mutable legacy list is still exposed
    srv.pending.clear()
    srv.submit(Request(2, jnp.arange(4, dtype=jnp.int32), 2))
    assert len(srv.pending) == 1


def test_pending_property_tenanted_snapshot():
    srv = _server(slots=2, tenants=[Tenant("a"), Tenant("b")])
    for r in _reqs(2, "a") + _reqs(1, "b", base=10):
        srv.submit(r)
    assert len(srv.pending) == 3
    assert {r.tenant for r in srv.pending} == {"a", "b"}


def test_weighted_fair_admission_order():
    srv = _server(slots=2, tenants=[Tenant("gold", GOLD),
                                    Tenant("bulk", BULK)])
    for r in _reqs(8, "gold") + _reqs(8, "bulk", base=100):
        srv.submit(r)
    picked = srv._pick_requests(10)
    # peek only: nothing popped
    assert len(srv.pending) == 16
    names = [n for n, _ in picked]
    # gold outranks bulk by priority: admitted first while it has work
    assert names[:8] == ["gold"] * 8
    assert names[8:] == ["bulk"] * 2


def test_shed_ladder_engages_and_degrades_decode():
    """Under queue pressure the ladder degrades SHEDDABLE tenants' decode
    to the int8-only job class BEFORE anything is rejected."""
    from repro.quant import QuantizedEngine
    pool = [get_engine("F-PE"),
            QuantizedEngine(get_engine("xla"), name="int8-shed")]
    with SynergyRuntime(pool, name="shed") as rt:
        srv = _server(slots=2, runtime=rt,
                      tenants=[Tenant("bulk", BULK, max_pending=4)])
        for r in _reqs(4, "bulk", max_new=3):
            srv.submit(r)
        with pytest.raises(AdmissionRejected):
            srv.submit(Request(99, jnp.arange(4, dtype=jnp.int32), 3,
                               tenant="bulk"))
        assert srv.stats.shed_engagements == 1     # 80% watermark crossed
        stats = srv.run()
    assert stats.shed_degraded_steps > 0
    assert stats.tenants["bulk"].degraded_steps > 0


def test_serve_timeout_error_carries_identity():
    err = ServeTimeoutError("decode/s3", 1.5, {"F-PE": {"jobs": 2}},
                            rids=(7, 8), tenants=("gold", "", "bulk"))
    assert err.rids == (7, 8)
    assert err.tenants == ("gold", "bulk")
    msg = str(err)
    assert "rids=[7, 8]" in msg and "'bulk'" in msg and "'gold'" in msg
    bare = ServeTimeoutError("x", 1.0, {})
    assert "rids" not in str(bare)


def test_tenanted_matches_untenanted_tokens_bitwise():
    """QoS must be a SCHEDULING layer only: on an unloaded pool a
    default-class tenanted server produces bitwise-identical token
    streams (and decode GEMM outputs) to the untenanted FIFO server."""
    def run(tenants):
        with SynergyRuntime(["F-PE", "S-PE"], name="parity") as rt:
            srv = _server(slots=2, runtime=rt, tenants=tenants,
                          keep_decode_outputs=True)
            tname = tenants[0].name if tenants else None
            reqs = [Request(i, jnp.arange(4, dtype=jnp.int32) + i,
                            max_new_tokens=4, tenant=tname)
                    for i in range(4)]
            for r in reqs:
                srv.submit(r)
            srv.run()
            return [list(r.out) for r in reqs], srv.decode_gemm_outputs

    toks_fifo, outs_fifo = run(None)
    toks_qos, outs_qos = run([Tenant("default")])
    assert toks_qos == toks_fifo
    assert len(outs_qos) == len(outs_fifo)
    for a, b in zip(outs_fifo, outs_qos):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_deadline_misses_are_counted():
    srv = _server(slots=2,
                  tenants=[Tenant("t", QosClass("t", deadline_s=0.0))])
    for r in _reqs(2, "t"):
        srv.submit(r)
    stats = srv.run()
    ts = stats.tenants["t"]
    assert ts.deadline_misses == 2 and ts.deadline_hits == 0
    assert ts.deadline_attainment == 0.0


def test_tenant_stats_attainment_empty():
    assert TenantStats().deadline_attainment == 1.0


# --------------------------------------- seeded deterministic QoS sweep

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_random_qos_tags_conserve_work(seed):
    """Accounting waves under random priorities/deadlines: every future
    completes and every tile job is booked exactly once (the no-
    hypothesis twin of the property sweep in test_qos_props.py)."""
    rng = random.Random(seed)
    with SynergyRuntime(["F-PE", "S-PE", "NEON"], name=f"sweep{seed}") \
            as rt:
        futs, total = [], 0
        for w in range(4):
            jobsets = [JobSet.for_gemm(w * 10 + i, 32 * rng.randint(1, 4),
                                       64, 32, 32, name=f"w{w}j{i}")
                       for i in range(3)]
            tag = QosTag(rng.choice([-10, 0, 10]),
                         rng.choice([math.inf, 5.0]))
            futs.extend(rt.submit_many(jobsets, qos=tag))
            total += sum(js.num_jobs for js in jobsets)
        for f in futs:
            f.result(60)
            assert sum(a["jobs"] for a in f.accounting.values()) \
                == f.jobset.num_jobs
        assert rt.stats()["total_jobs"] == total
