"""qmm: the int8×int8 Pallas kernel and its contract.

Covers the structural claim of the whole PR — the contraction consumes
int8 operands with int32 accumulation, NO fp32 upcast before the dot
(jaxpr-proved on both the Pallas kernel and the off-TPU fallback) — plus
numeric agreement between kernel, oracle and the fp32 reference, the
fused dequant epilogue, border shapes, the raw int32 partial mode the
runtime merges, and the exactness property that makes stolen panels
bitwise-safe.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.qmm import qmm_matmul, qmm_ref
from repro.quant import quantize_weights
from repro.quant.act import one_shot_act_scale, quantize_activations


def _quantized_operands(m, k, n, seed=0, wscale=0.05):
    ka, kb = jax.random.split(jax.random.key(seed))
    a = jax.random.normal(ka, (m, k))
    w = jax.random.normal(kb, (k, n)) * wscale
    qw = quantize_weights(w)
    act_scale = one_shot_act_scale(a)
    a_q = quantize_activations(a, act_scale)
    return a, w, a_q, qw, act_scale


def _all_dot_eqns(jaxpr):
    """Every dot_general equation anywhere in a (possibly nested) jaxpr —
    pallas_call, pjit and custom-call params are all descended into."""
    found = []
    stack = [jaxpr]
    while stack:
        jx = stack.pop()
        for eqn in jx.eqns:
            if eqn.primitive.name == "dot_general":
                found.append(eqn)
            for v in eqn.params.values():
                for sub in jax.tree_util.tree_leaves(
                        v, is_leaf=lambda x: hasattr(x, "eqns")):
                    if hasattr(sub, "eqns"):
                        stack.append(sub)
                    elif hasattr(sub, "jaxpr"):
                        stack.append(sub.jaxpr)
        if hasattr(jx, "jaxpr"):
            stack.append(jx.jaxpr)
    return found


# ------------------------------------------------------------ the proof

@pytest.mark.parametrize("interpret", [False, True])
def test_qmm_dot_consumes_int8_operands(interpret):
    """THE acceptance claim: every contraction in the lowered qmm — the
    Pallas kernel (interpret=True) and the off-TPU exact fallback alike —
    takes int8 operands into an int32 accumulation.  No fp32 upcast
    before the dot."""
    _, _, a_q, qw, act_scale = _quantized_operands(16, 32, 24)
    jaxpr = jax.make_jaxpr(
        lambda a_q, q, s: qmm_matmul(a_q, q, s, act_scale=act_scale,
                                     tile=(8, 8, 8),
                                     interpret=interpret))(a_q, qw.q, qw.scale)
    dots = _all_dot_eqns(jaxpr.jaxpr)
    assert dots, "qmm lowered without any contraction"
    for eqn in dots:
        in_dtypes = [v.aval.dtype for v in eqn.invars]
        assert all(d == jnp.int8 for d in in_dtypes), (
            f"fp32-cast dot snuck back in: operands {in_dtypes}")
        assert eqn.outvars[0].aval.dtype == jnp.int32
        assert eqn.params.get("preferred_element_type") == jnp.int32


def test_weight_only_path_is_the_fp32_cast_dot():
    """Contrast check: the weight-only quant_gemm really is the upcast
    dot the qmm path ends — same introspection, opposite verdict."""
    from repro.quant import quant_gemm
    a, w, _, qw, _ = _quantized_operands(16, 32, 24)
    jaxpr = jax.make_jaxpr(lambda a: quant_gemm(a, qw))(a)
    dots = _all_dot_eqns(jaxpr.jaxpr)
    assert dots
    assert all(v.aval.dtype == jnp.float32
               for eqn in dots for v in eqn.invars)


# ------------------------------------------------------------- numerics

@pytest.mark.parametrize("shape", [(16, 32, 24),    # tile-aligned
                                   (33, 70, 45),    # borders everywhere
                                   (1, 129, 17)])   # single-token decode
def test_kernel_matches_oracle(shape):
    """Integer accumulation is exact, so kernel (interpret mode) and
    oracle agree BITWISE on the accumulator; the fused fp32 epilogue may
    differ by compiler FMA contraction only (ulp-level)."""
    m, k, n = shape
    _, _, a_q, qw, act_scale = _quantized_operands(m, k, n, seed=1)
    acc_kernel = qmm_matmul(a_q, qw.q, qw.scale, fuse_dequant=False,
                            tile=(16, 16, 16), interpret=True)
    acc_ref = qmm_ref(a_q, qw.q, qw.scale, fuse_dequant=False)
    np.testing.assert_array_equal(np.asarray(acc_kernel), np.asarray(acc_ref))
    bias = jax.random.normal(jax.random.key(9), (n,))
    y_kernel = qmm_matmul(a_q, qw.q, qw.scale, act_scale=act_scale,
                          bias=bias, activation=jax.nn.relu,
                          tile=(16, 16, 16), interpret=True)
    y_ref = qmm_ref(a_q, qw.q, qw.scale, act_scale=act_scale, bias=bias,
                    activation=jax.nn.relu)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-6)


def test_qmm_close_to_fp32_reference():
    a, w, a_q, qw, act_scale = _quantized_operands(32, 64, 48, seed=2)
    y = qmm_matmul(a_q, qw.q, qw.scale, act_scale=act_scale,
                   tile=(16, 16, 16), interpret=True)
    ref = a @ w
    rel = float(jnp.max(jnp.abs(y - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 0.05, rel


def test_raw_int32_partials_merge_to_fused_output():
    """The runtime's split mode: raw per-panel int32 accumulators,
    concatenated, then ONE dequant_finish.  The panel accumulators stack
    to the exact whole-GEMM accumulator (so the split never rounds
    twice), and the merged output matches the fused single-call kernel
    to epilogue-FMA precision."""
    from repro.quant import dequant_finish
    _, _, a_q, qw, act_scale = _quantized_operands(32, 24, 16, seed=3)
    bias = jax.random.normal(jax.random.key(4), (16,))
    parts = [qmm_matmul(a_q[r0:r0 + 8], qw.q, qw.scale,
                        fuse_dequant=False, tile=(8, 8, 8), interpret=True)
             for r0 in range(0, 32, 8)]
    assert all(p.dtype == jnp.int32 for p in parts)
    whole = qmm_matmul(a_q, qw.q, qw.scale, fuse_dequant=False,
                       tile=(8, 8, 8), interpret=True)
    np.testing.assert_array_equal(np.asarray(jnp.concatenate(parts, 0)),
                                  np.asarray(whole))
    fused = qmm_matmul(a_q, qw.q, qw.scale, act_scale=act_scale,
                       bias=bias, activation=jax.nn.relu, tile=(8, 8, 8),
                       interpret=True)
    merged = dequant_finish(jnp.concatenate(parts, 0), qw,
                            act_scale=act_scale, bias=bias,
                            activation=jax.nn.relu, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(merged),
                               rtol=1e-5, atol=1e-6)


def test_partials_are_engine_order_independent():
    """Why stolen int8 panels are safe: the int32 accumulator of any
    panel is a pure integer function of its inputs — fallback oracle and
    interpreted kernel produce the identical array."""
    _, _, a_q, qw, _ = _quantized_operands(8, 40, 12, seed=5)
    via_ref = qmm_matmul(a_q, qw.q, qw.scale, fuse_dequant=False,
                         tile=(8, 8, 8))           # off-TPU -> exact oracle
    via_kernel = qmm_matmul(a_q, qw.q, qw.scale, fuse_dequant=False,
                            tile=(8, 8, 8), interpret=True)
    np.testing.assert_array_equal(np.asarray(via_ref), np.asarray(via_kernel))


def test_fresh_act_scales_do_not_retrace():
    """Regression: the online EMA republises a new float scale per live
    batch; act_scale folds into the TRACED (1, n) scale operand, so a
    decode loop reuses one compiled kernel instead of recompiling per
    step."""
    _, _, _, qw, _ = _quantized_operands(4, 32, 16, seed=6)
    a = jax.random.normal(jax.random.key(7), (4, 32))
    before = qmm_matmul._cache_size()
    for s in (0.011, 0.012, 0.013, 0.014):
        qmm_matmul(quantize_activations(a, s), qw.q, qw.scale,
                   act_scale=s, tile=(8, 8, 8))
    assert qmm_matmul._cache_size() - before <= 1


def test_quant_gemm_fast_path_accepts_batched_activations():
    """Regression: the weight-only fallback contracts over a_q.ndim - 1,
    so 3-D activations must not start crashing the moment a shape's
    scale publishes and flips it onto the kernel path."""
    from repro.quant import quant_gemm
    _, w, _, qw, _ = _quantized_operands(4, 32, 16, seed=8)
    a3 = jax.random.normal(jax.random.key(9), (2, 4, 32))
    s = one_shot_act_scale(a3)
    y = quant_gemm(a3, qw, act_scale=s)
    assert y.shape == (2, 4, 16)
    ref = jnp.einsum("bmk,kn->bmn", a3, w)
    rel = float(jnp.max(jnp.abs(y - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 0.05, rel


def test_out_dtype_and_saturation():
    y = qmm_matmul(jnp.full((4, 8), 127, jnp.int8),
                   jnp.full((8, 4), 127, jnp.int8),
                   jnp.ones((1, 4)), act_scale=1.0, tile=(4, 4, 4),
                   interpret=True, out_dtype=jnp.bfloat16)
    assert y.dtype == jnp.bfloat16
    # 8 * 127 * 127 accumulates exactly in int32 (no int8 overflow)
    assert float(y[0, 0]) == pytest.approx(8 * 127 * 127, rel=1e-2)
