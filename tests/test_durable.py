"""Durable serving (PR 10): the write-ahead request journal, crash-
consistent snapshots through the seed Checkpointer, deterministic
``CrashPlan`` crash/restore sweeps, journal-suffix replay, graceful
drain/close, and SIGTERM wiring.

The keystone property — token streams after restore are BITWISE identical
to the uninterrupted run and every accepted request is served exactly
once — is asserted here over fixed crash points (blocking admission,
chunked prefill with tenants, and a real runtime pool); the randomized
hypothesis sweep lives in test_durable_props.py."""

import json
import os
import signal
import struct
import subprocess
import sys
import textwrap
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core.serving import Request, ServeStats, SynergyServer
from repro.models import init_model
from repro.models.cnn import CNNConfig
from repro.soc import (CrashPlan, Durability, HealthPolicy, QosClass,
                       RequestJournal, RestoreMismatch, SimulatedCrash,
                       SynergyRuntime, Tenant)
from repro.soc.durable import array_to_meta, meta_to_array

TINY_CNN = CNNConfig(
    name="tiny", input_hw=8, cin=1, layers=(
        ("conv", 4, 3, 1, 1), ("pool", 2),
        ("conv", 8, 3, 1, 1), ("fc", 10),
    ))

_HDR = struct.Struct("<II")


def _cfg():
    return reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=32,
                   n_heads=2, d_ff=64, vocab=128)


@pytest.fixture(scope="module")
def model():
    cfg = _cfg()
    return cfg, init_model(cfg, jax.random.key(0))


def _reqs(n=4, new=5, tenant=None):
    out = []
    for i in range(n):
        t = tenant(i) if callable(tenant) else tenant
        out.append(Request(i, jnp.arange(4, dtype=jnp.int32) + i,
                           max_new_tokens=new, tenant=t))
    return out


def _streams(reqs):
    return {r.rid: list(r.out) for r in reqs}


# ------------------------------------------------------------- journal

def test_journal_roundtrip_and_offsets(tmp_path):
    p = tmp_path / "j.bin"
    j = RequestJournal(p)
    recs = [{"t": "submit", "rid": 1, "tok": [1, 2, 3]},
            {"t": "admit", "wave": [[1, 0]]},
            {"t": "tok", "e": [[1, 0, 42]]}]
    offs = [j.append(r) for r in recs]
    assert offs == sorted(offs) and j.offset() == offs[-1]
    j.close()
    j.close()                                    # idempotent
    got, end, torn = RequestJournal.scan(p)
    assert got == recs and end == offs[-1] and not torn
    # suffix scan from a stored boundary picks up exactly the tail
    tail, _, _ = RequestJournal.scan(p, start=offs[0])
    assert tail == recs[1:]


def test_journal_truncates_torn_tail(tmp_path):
    p = tmp_path / "j.bin"
    j = RequestJournal(p)
    j.append({"t": "submit", "rid": 7, "tok": [9]})
    good = j.offset()
    j.close()
    with open(p, "ab") as f:                     # crash mid-append
        f.write(_HDR.pack(100, 0) + b"only-part-of-the-payload")
    recs, end, torn = RequestJournal.scan(p)
    assert torn and end == good and len(recs) == 1
    j2 = RequestJournal(p)                       # reopen truncates
    assert j2.truncated_bytes > 0
    assert os.path.getsize(p) == good
    j2.append({"t": "tok", "e": [[7, 0, 1]]})    # appends land cleanly
    j2.close()
    recs, _, torn = RequestJournal.scan(p)
    assert not torn and [r["t"] for r in recs] == ["submit", "tok"]


def test_journal_rejects_corrupt_crc(tmp_path):
    p = tmp_path / "j.bin"
    j = RequestJournal(p)
    j.append({"t": "submit", "rid": 1, "tok": [1]})
    j.append({"t": "tok", "e": [[1, 0, 5]]})
    j.close()
    raw = bytearray(p.read_bytes())
    raw[-1] ^= 0xFF                              # flip a payload byte
    p.write_bytes(bytes(raw))
    recs, _, torn = RequestJournal.scan(p)
    assert torn and len(recs) == 1               # stops AT the bad record


def test_meta_array_roundtrip():
    meta = {"a": 1, "b": [1.5, None, "x"], "c": {"d": True}}
    assert array_to_meta(meta_to_array(meta)) == meta


def test_crash_plan_due():
    plan = CrashPlan(at_step=3)
    assert not plan.due(2) and plan.due(3) and plan.due(7)


# ------------------------------------------- keystone: crash → restore

def _crash_restore(cfg, params, workdir, crash_at, *, reqs, ref,
                   snapshot_every=3, tenants=None, **kw):
    """Run to a deterministic crash, restore, finish, and assert the
    keystone: bitwise streams + exactly-once accounting."""
    d = Durability(str(workdir), snapshot_every=snapshot_every)
    srv = SynergyServer(cfg, params, tenants=tenants, durable=d,
                        crash_plan=CrashPlan(at_step=crash_at), **kw)
    rr = reqs()
    with pytest.raises(SimulatedCrash):
        for r in rr:
            srv.submit(r)
        srv.run()
    srv2 = SynergyServer.restore(cfg, params, durable=d,
                                 tenants=tenants, **kw)
    srv2.run()
    got = {rid: list(r.out) for rid, r in srv2.restored_requests.items()}
    for r in rr:
        assert got.get(r.rid, list(r.out)) == ref[r.rid], \
            f"crash_at={crash_at} rid={r.rid}"
    # exactly once: fresh + replayed tokens == the uninterrupted total
    assert (srv2.stats.tokens_out + srv2.stats.replayed_tokens
            == sum(max(0, len(v) - 1) for v in ref.values()))
    assert srv2.stats.restores == 1
    return srv, srv2


def test_crash_restore_blocking_sweep(model, tmp_path):
    cfg, params = model
    kw = dict(slots=2, max_len=32, prefill_len=4, admission="wave")
    ref_srv = SynergyServer(cfg, params, **kw)
    rr = _reqs()
    for r in rr:
        ref_srv.submit(r)
    ref_srv.run()
    ref = _streams(rr)
    for crash_at in (1, 2, 5, 9):
        _crash_restore(cfg, params, tmp_path / f"at{crash_at}", crash_at,
                       reqs=_reqs, ref=ref, **kw)


def test_crash_restore_chunked_tenants_sweep(model, tmp_path):
    """Chunked prefill + 2 tenants: streams stay bitwise, the replayed
    admissions charge FairShare identically (restored virtual times ==
    the uninterrupted run's), and nothing double-books."""
    cfg, params = model
    tenants = [Tenant("acme", QosClass("interactive", priority=1,
                                       weight=2.0)),
               Tenant("bulk", QosClass("bulk", priority=0, weight=1.0))]
    kw = dict(slots=2, max_len=32, prefill_len=4,
              prefill_chunk_macs=2_000)
    mk = lambda: _reqs(5, tenant=lambda i: "acme" if i % 2 == 0
                       else "bulk")
    ref_srv = SynergyServer(cfg, params, tenants=tenants, **kw)
    rr = mk()
    for r in rr:
        ref_srv.submit(r)
    ref_srv.run()
    ref, ref_vt = _streams(rr), ref_srv._fair.snapshot()
    for crash_at in (1, 5, 8, 13):
        _, srv2 = _crash_restore(
            cfg, params, tmp_path / f"at{crash_at}", crash_at,
            reqs=mk, ref=ref, snapshot_every=4, tenants=tenants, **kw)
        assert srv2._fair.snapshot() == ref_vt
        # replay recomputes state, it does not re-serve: per-tenant
        # tokens stay <= the uninterrupted totals
        for name, ts in srv2.stats.tenants.items():
            assert ts.tokens_out <= ref_srv.stats.tenants[name].tokens_out


def test_restore_survives_torn_journal_tail(model, tmp_path):
    cfg, params = model
    kw = dict(slots=2, max_len=32, prefill_len=4)
    ref_srv = SynergyServer(cfg, params, **kw)
    rr = _reqs()
    for r in rr:
        ref_srv.submit(r)
    ref_srv.run()
    ref = _streams(rr)
    d = Durability(str(tmp_path), snapshot_every=3)
    srv = SynergyServer(cfg, params, durable=d,
                        crash_plan=CrashPlan(at_step=5), **kw)
    with pytest.raises(SimulatedCrash):
        for r in _reqs():
            srv.submit(r)
        srv.run()
    with open(d.journal_path, "ab") as f:        # die mid-append
        f.write(_HDR.pack(64, 123456) + b"torn")
    srv2 = SynergyServer.restore(cfg, params, durable=d, **kw)
    assert srv2._journal.truncated_bytes > 0
    srv2.run()
    for rid, r in srv2.restored_requests.items():
        assert list(r.out) == ref[rid]


def test_restore_mismatch_on_forged_journal(model, tmp_path):
    """A journal whose recorded token disagrees with the recomputation
    must raise RestoreMismatch (and flight-dump) — serving must not
    resume from state that is not the crashed process's state."""
    from repro.obs import FlightRecorder, Tracer
    cfg, params = model
    kw = dict(slots=2, max_len=32, prefill_len=4)
    d = Durability(str(tmp_path / "w"), snapshot_every=0)
    srv = SynergyServer(cfg, params, durable=d,
                        crash_plan=CrashPlan(at_step=6), **kw)
    with pytest.raises(SimulatedCrash):
        for r in _reqs():
            srv.submit(r)
        srv.run()
    recs, _, _ = RequestJournal.scan(d.journal_path)
    forged, done = [], False
    for rec in recs:
        if not done and rec["t"] == "tok":
            rec = dict(rec, e=[[rid, slot, (tok + 1) % 128]
                               for rid, slot, tok in rec["e"]])
            done = True
        forged.append(rec)
    assert done
    with open(d.journal_path, "wb") as f:
        for rec in forged:
            payload = json.dumps(rec, separators=(",", ":")).encode()
            f.write(_HDR.pack(len(payload), zlib.crc32(payload)))
            f.write(payload)
    tr = Tracer(capacity=256)
    fr = FlightRecorder(tr, dir=str(tmp_path / "dumps"))
    with pytest.raises(RestoreMismatch):
        SynergyServer.restore(cfg, params, durable=d, tracer=tr,
                              flight_recorder=fr, **kw)
    assert len(fr.dumps) == 1
    dump = json.loads(open(fr.dumps[0]).read())
    assert dump["reason"] == "restore_mismatch"


# ------------------------------------ snapshot state: field round-trips

def test_pool_state_round_trips_field_by_field(model, tmp_path):
    """Calibrator EMA, learned engine rates, and health baselines ride
    the snapshot: a restore into a FRESH pool starts with the crashed
    pool's state, field by field (the seed Checkpointer is no longer
    orphaned — it carries live serving state)."""
    from repro.engines import get_engine
    from repro.quant import QuantizedEngine
    cfg, params = model
    pol = HealthPolicy(alpha=0.5, quarantine_below=0.0,
                       readmit_above=0.0)
    kw = dict(slots=2, max_len=32, prefill_len=4, max_inflight=0)

    def pool():
        return [QuantizedEngine(get_engine("xla"), name="dur-int8"),
                "F-PE"]

    d = Durability(str(tmp_path), snapshot_every=0,
                   async_snapshots=False)
    with SynergyRuntime(pool(), name="dur-a",
                        rates_path=str(tmp_path / "r1.json"),
                        health=pol) as rt:
        srv = SynergyServer(cfg, params, runtime=rt,
                            prefill_cnn=TINY_CNN, durable=d, **kw)
        for r in _reqs(3):
            srv.submit(r)
        for _ in range(4):
            srv.step()
        srv.snapshot()
        want_rt = rt.state_snapshot()
        cal = srv._calibration_engine().calibrator.export_state()
        assert cal and want_rt["macs_per_s"]
    with SynergyRuntime(pool(), name="dur-b",
                        rates_path=str(tmp_path / "r2.json"),
                        health=pol) as rt2:
        srv2 = SynergyServer(cfg, params, runtime=rt2,
                             prefill_cnn=TINY_CNN, **kw)
        from repro.soc.durable import load_snapshot
        from repro.checkpoint import Checkpointer
        _, flat = load_snapshot(Checkpointer(d.snapshot_dir))
        srv2._apply_snapshot(flat)
        got_rt = rt2.state_snapshot()
        assert got_rt["macs_per_s"] == want_rt["macs_per_s"]
        for name, h in want_rt["health"].items():
            assert got_rt["health"][name] == h
        assert (srv2._calibration_engine().calibrator.export_state()
                == cal)


def test_crash_restore_with_runtime_pool(model, tmp_path):
    """End-to-end over a real pool (int8 + F-PE, health, sidecar): the
    restored server finishes every request with the reference streams and
    replay books runtime work into replayed_jobs, not runtime_jobs."""
    from repro.engines import get_engine
    from repro.quant import QuantizedEngine
    cfg, params = model
    kw = dict(slots=2, max_len=32, prefill_len=4, max_inflight=1)

    def pool(tag):
        return [QuantizedEngine(get_engine("xla"), name=f"ci8-{tag}"),
                "F-PE"]

    with SynergyRuntime(pool("ref"), name="dur-ref") as rt:
        ref_srv = SynergyServer(cfg, params, runtime=rt,
                                prefill_cnn=TINY_CNN, **kw)
        rr = _reqs(3)
        for r in rr:
            ref_srv.submit(r)
        ref_srv.run()
    ref = _streams(rr)
    d = Durability(str(tmp_path), snapshot_every=3)
    with SynergyRuntime(pool("a"), name="dur-x") as rt:
        srv = SynergyServer(cfg, params, runtime=rt,
                            prefill_cnn=TINY_CNN, durable=d,
                            crash_plan=CrashPlan(at_step=4), **kw)
        with pytest.raises(SimulatedCrash):
            for r in _reqs(3):
                srv.submit(r)
            srv.run()
        rt.shutdown()
    with SynergyRuntime(pool("a"), name="dur-y") as rt2:
        srv2 = SynergyServer.restore(cfg, params, durable=d,
                                     runtime=rt2,
                                     prefill_cnn=TINY_CNN, **kw)
        if srv2.stats.replayed_tokens:
            assert srv2.stats.replayed_jobs > 0
        srv2.run()
        for rid, r in srv2.restored_requests.items():
            assert list(r.out) == ref[rid]
        assert (srv2.stats.tokens_out + srv2.stats.replayed_tokens
                == sum(max(0, len(v) - 1) for v in ref.values()))


# --------------------------------------------------- no double counting

def test_replay_does_not_double_count(model, tmp_path):
    """Restored counters seed from the snapshot and replay books ONLY
    replayed_tokens — the sum of fresh tokens over (crashed run, restored
    run) equals one uninterrupted run exactly."""
    cfg, params = model
    kw = dict(slots=2, max_len=32, prefill_len=4)
    ref_srv = SynergyServer(cfg, params, **kw)
    rr = _reqs()
    for r in rr:
        ref_srv.submit(r)
    ref_srv.run()
    d = Durability(str(tmp_path), snapshot_every=2)
    srv = SynergyServer(cfg, params, durable=d,
                        crash_plan=CrashPlan(at_step=7), **kw)
    with pytest.raises(SimulatedCrash):
        for r in _reqs():
            srv.submit(r)
        srv.run()
    srv2 = SynergyServer.restore(cfg, params, durable=d, **kw)
    srv2.run()
    assert (srv2.stats.tokens_out + srv2.stats.replayed_tokens
            == ref_srv.stats.tokens_out)
    for r in srv2.restored_requests.values():
        assert len(r.out) == r.max_new_tokens and r.done_at is not None
    assert srv2.stats.snapshots >= 1 and srv2.stats.restores == 1


# -------------------------------------------------------- drain / close

def test_close_drains_snapshots_and_rejects(model, tmp_path):
    from repro.soc import AdmissionRejected
    cfg, params = model
    kw = dict(slots=2, max_len=32, prefill_len=4)
    d = Durability(str(tmp_path), snapshot_every=0)
    srv = SynergyServer(cfg, params, durable=d, **kw)
    rr = _reqs(2)
    for r in rr:
        srv.submit(r)
    srv.step()                                   # admit the wave
    srv.close()
    # LIVE generations ran to completion (close stops admission only)
    assert all(len(r.out) == r.max_new_tokens for r in rr)
    with pytest.raises(AdmissionRejected):
        srv.submit(Request(99, jnp.arange(4, dtype=jnp.int32),
                           max_new_tokens=2))
    from repro.checkpoint import Checkpointer
    assert Checkpointer(d.snapshot_dir).latest_step() is not None
    assert srv._journal._f.closed


def test_close_snapshot_preserves_pending_for_restore(model, tmp_path):
    """Requests still queued when the deadline cuts close() short are in
    the final snapshot: restore picks them up and serves them with the
    reference streams (graceful handoff, not loss)."""
    cfg, params = model
    kw = dict(slots=1, max_len=32, prefill_len=4)
    ref_srv = SynergyServer(cfg, params, **kw)
    rr = _reqs(3)
    for r in rr:
        ref_srv.submit(r)
    ref_srv.run()
    ref = _streams(rr)
    d = Durability(str(tmp_path), snapshot_every=0)
    srv = SynergyServer(cfg, params, durable=d, **kw)
    for r in _reqs(3):
        srv.submit(r)
    srv.step()                                   # admit only the first
    srv.close(deadline_s=0.0)                    # deadline: stop NOW
    srv2 = SynergyServer.restore(cfg, params, durable=d, **kw)
    srv2.run()
    for rid, r in srv2.restored_requests.items():
        assert list(r.out) == ref[rid]
    assert len(srv2.restored_requests) == 3


def test_request_drain_stops_run_loop(model, tmp_path):
    cfg, params = model
    d = Durability(str(tmp_path), snapshot_every=0)
    srv = SynergyServer(cfg, params, slots=2, max_len=32, prefill_len=4,
                        durable=d)
    rr = _reqs(2)
    for r in rr:
        srv.submit(r)
    srv.step()                                   # admit the wave
    srv.request_drain()
    srv.run()
    assert all(len(r.out) == r.max_new_tokens for r in rr)
    assert srv._journal._f.closed                # close() ran


_SIGTERM_CHILD = textwrap.dedent("""
    import os, signal, sys, threading
    import jax, jax.numpy as jnp
    from repro.configs import ARCHS, reduced
    from repro.core.serving import Request, SynergyServer
    from repro.models import init_model
    from repro.soc import Durability, install_sigterm_drain

    cfg = reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=32,
                  n_heads=2, d_ff=64, vocab=128)
    params = init_model(cfg, jax.random.key(0))
    srv = SynergyServer(cfg, params, slots=2, max_len=32, prefill_len=4,
                        durable=Durability(sys.argv[1], snapshot_every=0))
    install_sigterm_drain(srv)
    for i in range(60):
        srv.submit(Request(i, jnp.arange(4, dtype=jnp.int32) + i,
                           max_new_tokens=40))
    threading.Timer(0.2, os.kill,
                    (os.getpid(), signal.SIGTERM)).start()
    stats = srv.run(max_steps=100_000)
    print("DONE", stats.tokens_out, flush=True)
""")


def test_sigterm_drains_to_clean_snapshot(tmp_path):
    """SIGTERM mid-run must end in a clean snapshot + closed journal, not
    a dead process — and a restore from that directory serves whatever
    the drain left pending."""
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", _SIGTERM_CHILD, str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=240)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "DONE" in out.stdout
    from repro.checkpoint import Checkpointer
    assert Checkpointer(str(tmp_path / "snapshots")).latest_step() \
        is not None
    # the journal tail is intact (clean close, no torn record)
    _, _, torn = RequestJournal.scan(str(tmp_path / "journal.bin"))
    assert not torn


# --------------------------------------------------------- observability

def test_trace_and_metrics_cover_durability(model, tmp_path):
    from repro.obs import MetricsRegistry, Tracer, render_prometheus
    cfg, params = model
    tr = Tracer(capacity=512)
    d = Durability(str(tmp_path), snapshot_every=2,
                   async_snapshots=False)
    srv = SynergyServer(cfg, params, slots=2, max_len=32, prefill_len=4,
                        durable=d, tracer=tr)
    for r in _reqs(2):
        srv.submit(r)
    srv.run()
    srv.close()
    kinds = {e.kind for e in tr.events()}
    assert {"snapshot", "drain"} <= kinds
    text = render_prometheus(server=srv,
                             registry=MetricsRegistry())
    assert "repro_serve_snapshots_total" in text
    assert "repro_serve_replayed_tokens_total" in text
