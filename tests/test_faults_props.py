"""Hypothesis property tests for deterministic fault injection (ISSUE 9
satellite c).

For RANDOM retryable fault plans (raise / corrupt / slowdown at seeded
call indices) layered over a mixed fp32/int8-capable pool with seeded
random steal timing:

  * every tile panel completes exactly once — failed attempts retry,
    but never double-merge into the output or the accounting;
  * every GEMM's merged output is bitwise identical to the fault-free
    answer (the keystone invariant: faults cost retries, not ULPs);
  * no :class:`RuntimeFuture` hangs — every submission resolves within
    the timeout and reports done.

The seeded chaos sweep in ``test_faults.py`` covers the same invariants
when the hypothesis dev-dependency is absent.
"""

import random
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev deps
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.job import JobSet                         # noqa: E402
from repro.engines import (CAP_GEMM, CAP_INT8, CostModel,  # noqa: E402
                           Engine)
from repro.soc import (FaultPlan, RetryPolicy,            # noqa: E402
                       SynergyRuntime, wrap_pool)


class _ChaosEngine(Engine):
    """Identical fp32 math on every instance (placement-independent,
    bitwise-comparable outputs) plus a seeded random per-panel delay so
    steal timing varies between hypothesis examples."""

    def __init__(self, name, macs_per_s=5e8, *, seed=0, int8=False,
                 max_delay_s=0.002):
        caps = {CAP_GEMM, "epilogue"} | ({CAP_INT8} if int8 else set())
        super().__init__(name, caps, cost=CostModel(macs_per_s=macs_per_s))
        self._rng = random.Random(seed)
        self._max_delay_s = max_delay_s

    def execute(self, a, b, *, bias=None, activation=None, tile=None,
                out_dtype=None, precision=None):
        time.sleep(self._rng.random() * self._max_delay_s)
        y = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
        return y.astype(out_dtype or a.dtype)


@settings(max_examples=10, deadline=None)
@given(plan_seed=st.integers(0, 2**16), steal_seed=st.integers(0, 2**16),
       wl_seed=st.integers(0, 2**16))
def test_random_fault_plans_exactly_once_bitwise_no_hangs(plan_seed,
                                                          steal_seed,
                                                          wl_seed):
    rng = random.Random(wl_seed)
    names = ["pf0", "pf1", "pf2"]
    # mixed pool: pf2 advertises int8 so the steal-eligibility filter
    # (int8 thieves only take int8-ok panels) is exercised under faults
    pool = [_ChaosEngine(names[0], seed=steal_seed),
            _ChaosEngine(names[1], 3e8, seed=steal_seed + 1),
            _ChaosEngine(names[2], 4e8, seed=steal_seed + 2, int8=True)]
    plan = FaultPlan.random(plan_seed, names)  # retryable kinds only
    retry = RetryPolicy(max_attempts=6, backoff_s=0.0,
                        avoid_failed_engine=True, check_outputs=True)

    d = 64
    w = jax.random.normal(jax.random.key(3), (d, 48))
    mats = [jax.random.normal(jax.random.key(200 + wl_seed + i),
                              (32 * rng.randint(1, 4), d))
            for i in range(rng.randint(2, 4))]

    with SynergyRuntime(wrap_pool(pool, plan), name="fprop",
                        retry=retry) as rt:
        futs = [rt.submit_gemm(
            a, w, jobset=JobSet.for_gemm(i, a.shape[0], 48, d, 32,
                                         name=f"fp{i}"),
            tile=(32, 32, 32)) for i, a in enumerate(mats)]
        for f, a in zip(futs, mats):
            got = f.result(120)            # no hung futures
            assert f.done()
            # exactly-once: each panel merged once, accounting books
            # every tile job once, retries never double-count
            assert f.execution_counts == [1] * len(f.execution_counts)
            assert sum(x["jobs"] for x in f.accounting.values()) \
                == f.jobset.num_jobs
            ref = jnp.dot(a, w, preferred_element_type=jnp.float32)
            assert np.array_equal(np.asarray(got), np.asarray(ref))
        stats = rt.stats()
    # every injected fault that raised/corrupted was absorbed as a retry
    assert stats["retries"] == sum(
        1 for (_, kind, _) in plan.injected if kind in ("raise", "corrupt"))
    # per-engine counters track BURNED work (failed attempts included),
    # so they bound the exactly-once submission accounting from above
    assert stats["total_jobs"] >= sum(f.jobset.num_jobs for f in futs)
