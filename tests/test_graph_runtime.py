"""repro.soc.graph: dataflow-graph submissions over the live runtime.

Covers the ISSUE 6 tentpole invariants: successors' panels enter the
deques the moment their predecessors' tail panels land (finish_order
respects every edge), host gather nodes overlap GEMM nodes, adopted
``submit_gemm`` futures complete their node bitwise-identically to a
serial reference, failures cancel descendants, ``GraphFuture.cancel``
drains queued-but-unstarted panels (satellite 1), and the virtual-time
``SimRuntime.run_graph`` replays chain graphs unit-for-unit identically
to back-to-back ``run()`` calls (the DES-conformance bridge).
"""

import random
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.job import JobSet
from repro.engines import CAP_GEMM, CostModel, Engine, get_engine
from repro.soc import (GraphCancelled, GraphNode, SimRuntime, SynergyRuntime)
from repro.soc.graph import validate_dag


def _ab(m, k, n, seed=0):
    ka, kb = jax.random.split(jax.random.key(seed))
    return (jax.random.normal(ka, (m, k)), jax.random.normal(kb, (k, n)))


class _DelayEngine(Engine):
    """Deterministic-output engine with seeded random per-job delays —
    randomized steal timing without randomized results."""

    def __init__(self, name, macs_per_s=1e9, seed=0, max_delay_s=0.003):
        super().__init__(name, {CAP_GEMM, "epilogue"},
                         cost=CostModel(macs_per_s=macs_per_s))
        self._rng = random.Random(seed)
        self._max_delay_s = max_delay_s
        self.executed = 0

    def execute(self, a, b, *, bias=None, activation=None, tile=None,
                out_dtype=None, precision=None):
        time.sleep(self._rng.random() * self._max_delay_s)
        self.executed += 1
        y = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
        if bias is not None:
            y = y + bias
        if activation is not None:
            y = activation(y)
        return y.astype(out_dtype or a.dtype)


class _SleepyEngine(Engine):
    """Every panel sleeps: keeps queues populated so cancellation can
    observe queued-but-unstarted panels."""

    def __init__(self, name="sleepy", delay_s=0.15):
        super().__init__(name, {CAP_GEMM, "epilogue"},
                         cost=CostModel(macs_per_s=1e9))
        self._delay_s = delay_s
        self.executed = 0

    def execute(self, a, b, *, bias=None, activation=None, tile=None,
                out_dtype=None, precision=None):
        time.sleep(self._delay_s)
        self.executed += 1
        return jnp.dot(a.astype(jnp.float32),
                       b.astype(jnp.float32)).astype(out_dtype or a.dtype)


# ----------------------------------------------------------- validate_dag

def test_validate_dag_rejects_cycles_and_bad_edges():
    with pytest.raises(ValueError, match="cycle"):
        validate_dag(3, [(0, 1), (1, 2), (2, 0)])
    with pytest.raises(ValueError, match="self-edge"):
        validate_dag(2, [(0, 0)])
    with pytest.raises(ValueError, match="out of range"):
        validate_dag(2, [(0, 5)])
    succs, preds = validate_dag(3, [(0, 2), (1, 2)])
    assert succs == [[2], [2], []]
    assert preds == [[], [], [0, 1]]     # edge order preserved


# ----------------------------------------------- accounting-only DAG nodes

def test_graph_accounting_diamond_orders_and_books_all_jobs():
    """Bare JobSets as nodes: every tile job is scheduled and booked, and
    the completion order respects every dependency edge (the reap-order
    audit trail of the per-node dependency counters)."""
    jss = [JobSet.for_gemm(i, 96, 64, 32, 32, name=f"n{i}")
           for i in range(4)]
    edges = [(0, 1), (0, 2), (1, 3), (2, 3)]
    with SynergyRuntime(["F-PE", "S-PE"], name="diamond") as rt:
        gf = rt.submit_graph(jss, edges, name="diamond")
        vals = gf.result(60)
    assert vals == [None] * 4            # accounting nodes carry no value
    pos = {nid: i for i, nid in enumerate(gf.finish_order)}
    for u, v in edges:
        assert pos[u] < pos[v], (gf.finish_order, (u, v))
    assert gf.node_states() == ["done"] * 4
    total = sum(a["jobs"] for a in gf.accounting.values())
    assert total == sum(js.num_jobs for js in jss)
    assert rt.stats()["total_jobs"] == total


def test_graph_empty_jobset_node_cascades():
    """A zero-job node completes instantly and releases its successors."""
    empty = JobSet.for_gemm(0, 0, 32, 32, 32, name="empty")
    real = JobSet.for_gemm(1, 64, 32, 32, 32, name="real")
    with SynergyRuntime(["F-PE"], name="empty") as rt:
        gf = rt.submit_graph([empty, real], [(0, 1)])
        gf.result(60)
    assert gf.node_states() == ["done", "done"]


# ------------------------------------------------- value-flow (run nodes)

def test_graph_value_flow_adopted_gemm_bitwise():
    """Host nodes flow values along edges; a run node returning a
    RuntimeFuture (nested submit_gemm) is ADOPTED — the node completes at
    the submission's tail panel, and the chained numerics are bitwise
    identical to the serial reference."""
    a, w1 = _ab(48, 32, 32, seed=1)
    _, w2 = _ab(48, 32, 24, seed=2)
    js1 = JobSet.for_gemm(0, 48, 32, 32, 16, name="g1")
    js2 = JobSet.for_gemm(1, 48, 24, 32, 16, name="g2")
    nodes = [
        GraphNode(name="scale", run=lambda rt: a * 2.0),
        GraphNode(name="g1", run=lambda rt, x: rt.submit_gemm(
            x, w1, jobset=js1, tile=(16, 16, 16))),
        GraphNode(name="relu", run=lambda rt, y: jax.nn.relu(y)),
        GraphNode(name="g2", run=lambda rt, y: rt.submit_gemm(
            y, w2, jobset=js2, tile=(16, 16, 16))),
    ]
    with SynergyRuntime(["F-PE", "S-PE"], name="flow") as rt:
        gf = rt.submit_graph(nodes, [(0, 1), (1, 2), (2, 3)], name="flow")
        vals = gf.result(60)
    ref = jnp.dot(jax.nn.relu(jnp.dot(a * 2.0, w1)), w2)
    assert np.array_equal(np.asarray(vals[3]), np.asarray(ref))
    assert gf.node_future(1) is not None      # adopted submission futures
    assert gf.node_future(0) is None          # pure host node: no future


def test_graph_parallel_branches_share_the_pool():
    """Two independent GEMM branches fan out over the pool and a join
    node sees both predecessor values in edge order."""
    a, w = _ab(64, 32, 32, seed=3)
    jss = [JobSet.for_gemm(i, 64, 32, 32, 16, name=f"br{i}")
           for i in range(2)]
    nodes = [
        GraphNode(name="b0", run=lambda rt: rt.submit_gemm(
            a, w, jobset=jss[0], tile=(16, 16, 16))),
        GraphNode(name="b1", run=lambda rt: rt.submit_gemm(
            a * 3.0, w, jobset=jss[1], tile=(16, 16, 16))),
        GraphNode(name="join", run=lambda rt, y0, y1: y0 + y1),
    ]
    with SynergyRuntime(["F-PE", "S-PE"], name="fan") as rt:
        gf = rt.submit_graph(nodes, [(0, 2), (1, 2)], name="fan")
        vals = gf.result(60)
    ref = jnp.dot(a, w) + jnp.dot(a * 3.0, w)
    assert np.array_equal(np.asarray(vals[2]), np.asarray(ref))


# ------------------------------------------------- failure / cancellation

def test_graph_failure_cancels_descendants():
    boom = RuntimeError("boom")

    def fail(rt, x):
        raise boom

    nodes = [
        GraphNode(name="ok", run=lambda rt: 1),
        GraphNode(name="bad", run=fail),
        GraphNode(name="downstream", run=lambda rt, x: x),
    ]
    with SynergyRuntime(["F-PE"], name="fail") as rt:
        gf = rt.submit_graph(nodes, [(0, 1), (1, 2)], name="fail")
        with pytest.raises(RuntimeError, match="boom"):
            gf.result(60)
    assert gf.node_states() == ["done", "failed", "cancelled"]


def test_graph_cancel_drains_queued_panels_and_downstream():
    """Satellite 1: cancel() marks every not-yet-started node cancelled
    AND drains the running submissions' queued panels from the worker
    deques — the sleepy engine never executes the drained tail, and the
    runtime keeps serving fresh work afterwards."""
    eng = _SleepyEngine(delay_s=0.15)
    a, w = _ab(4 * 16, 32, 16, seed=5)
    js0 = JobSet.for_gemm(0, a.shape[0], 16, 32, 16, name="head")
    js1 = JobSet.for_gemm(1, a.shape[0], 16, 32, 16, name="tail")
    nodes = [
        GraphNode(name="head", run=lambda rt: rt.submit_gemm(
            a, w, jobset=js0, tile=(16, 16, 16))),
        GraphNode(name="tail", run=lambda rt, y: rt.submit_gemm(
            y, w[:16, :].T @ w, jobset=js1, tile=(16, 16, 16))),
    ]
    with SynergyRuntime([eng], name="cancel") as rt:
        gf = rt.submit_graph(nodes, [(0, 1)], name="cancel")
        time.sleep(0.05)                 # first panel in flight, rest queued
        gf.cancel("test cancel")
        with pytest.raises(GraphCancelled):
            gf.result(60)
        # the 4-panel head never ran to completion: queued panels drained
        assert eng.executed < 4
        assert gf.node_states()[1] == "cancelled"
        # the pool is healthy: fresh work still completes
        y = rt.submit_gemm(a[:16], w, jobset=JobSet.for_gemm(
            2, 16, 16, 32, 16, name="after"), tile=(16, 16, 16)).result(60)
        assert np.array_equal(np.asarray(y),
                              np.asarray(jnp.dot(a[:16], w)))


def test_runtime_shutdown_cancels_active_graphs():
    eng = _SleepyEngine(delay_s=0.2)
    a, w = _ab(4 * 16, 32, 16, seed=6)
    js = JobSet.for_gemm(0, a.shape[0], 16, 32, 16, name="shut")
    rt = SynergyRuntime([eng], name="shut")
    rt.start()
    gf = rt.submit_graph(
        [GraphNode(name="g", run=lambda r: r.submit_gemm(
            a, w, jobset=js, tile=(16, 16, 16))),
         GraphNode(name="down", run=lambda r, y: y)],
        [(0, 1)], name="shut")
    time.sleep(0.05)
    rt.shutdown()
    with pytest.raises((GraphCancelled, RuntimeError)):
        gf.result(10)


# ------------------------------------------ randomized DAG property sweep

def _random_dag_case(seed: int):
    """One seeded random case: topology, node kinds, mixed pool."""
    rng = random.Random(seed)
    n = rng.randint(3, 6)
    edges = [(u, v) for u in range(n) for v in range(u + 1, n)
             if rng.random() < 0.45]
    kinds = [rng.choice(["gemm", "acct"]) for _ in range(n)]
    return n, edges, kinds


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_random_dag_exactly_once_ordered_and_bitwise(seed):
    """Property (satellite 3, seeded sweep): random DAGs over a mixed
    fp32/int8 pool with randomized steal timing execute every node
    exactly once, complete predecessors strictly before successors, and
    produce gemm values bitwise equal to the serial reference."""
    from repro.quant import QuantizedEngine
    n, edges, kinds = _random_dag_case(seed)
    _, preds = validate_dag(n, edges)
    d = 32
    base = [jax.random.normal(jax.random.key(100 + i), (48, d))
            for i in range(n)]
    w = jax.random.normal(jax.random.key(7), (d, d))
    ran: list[int] = []

    def make_node(i):
        if kinds[i] == "acct":
            return GraphNode(name=f"acct{i}",
                             jobset=JobSet.for_gemm(i, 96, 64, 32, 32,
                                                    name=f"acct{i}"))

        def run(rt, *pvals, _i=i):
            ran.append(_i)
            x = base[_i]
            for pv in pvals:
                if pv is not None:       # accounting preds carry no value
                    x = x + pv
            return rt.submit_gemm(x, w, jobset=JobSet.for_gemm(
                _i, 48, d, d, 16, name=f"gemm{_i}"), tile=(16, 16, 16))
        return GraphNode(name=f"gemm{i}", run=run)

    pool = [_DelayEngine("dly-a", seed=seed), _DelayEngine("dly-b", seed=seed + 9),
            QuantizedEngine(get_engine("xla"), name=f"int8-{seed}")]
    with SynergyRuntime(pool, name=f"rand{seed}") as rt:
        gf = rt.submit_graph([make_node(i) for i in range(n)], edges,
                             name=f"rand{seed}")
        vals = gf.result(120)
    # every run node executed exactly once
    assert sorted(ran) == [i for i in range(n) if kinds[i] == "gemm"]
    # reap order respects every edge
    pos = {nid: i for i, nid in enumerate(gf.finish_order)}
    for u, v in edges:
        assert pos[u] < pos[v]
    # serial reference, same pred-value accumulation order (edge order)
    ref: list = [None] * n
    for i in range(n):
        if kinds[i] == "acct":
            continue
        x = base[i]
        for p in preds[i]:
            if ref[p] is not None:
                x = x + ref[p]
        ref[i] = jnp.dot(x, w)
    for i in range(n):
        if kinds[i] == "gemm":
            assert np.array_equal(np.asarray(vals[i]), np.asarray(ref[i])), i
        else:
            assert vals[i] is None


# --------------------------------------------- SimRuntime virtual-time twin

def test_sim_run_graph_chain_matches_back_to_back_runs():
    """DES conformance bridge: a chain graph replays unit-for-unit like
    back-to-back run() calls (which are themselves DES-conformant) — at a
    chain boundary every engine is free, so the release+kick reproduces a
    fresh run's initial state exactly."""
    sim = SimRuntime(["F-PE", "S-PE", "NEON"])
    jss = [JobSet.for_gemm(i, 512, 256, 128, 32, name=f"l{i}")
           for i in range(3)]
    g = sim.run_graph(jss, [(0, 1), (1, 2)])
    t = 0.0
    busy = {e.name: 0.0 for e in sim.engines}
    jobs = {e.name: 0 for e in sim.engines}
    steals = {e.name: 0 for e in sim.engines}
    for js in jss:
        r = sim.run(js)
        t += r.makespan_s
        for k in busy:
            busy[k] += r.per_engine_busy[k]
            jobs[k] += r.per_engine_jobs[k]
            steals[k] += r.per_engine_steals[k]
    assert g.makespan_s == pytest.approx(t, rel=1e-12)
    for k in busy:
        assert g.per_engine_busy[k] == pytest.approx(busy[k], rel=1e-12)
        assert g.per_engine_jobs[k] == jobs[k]
        assert g.per_engine_steals[k] == steals[k]
    # node stamps are the chain's running makespans
    assert g.node_finish_s[-1] == pytest.approx(g.makespan_s, rel=1e-12)
    assert list(g.node_finish_s) == sorted(g.node_finish_s)


def test_sim_run_graph_diamond_topo_order_and_conservation():
    sim = SimRuntime(["F-PE", "S-PE"])
    jss = [JobSet.for_gemm(i, 256, 128, 64, 32, name=f"n{i}")
           for i in range(4)]
    edges = [(0, 1), (0, 2), (1, 3), (2, 3)]
    g = sim.run_graph(jss, edges)
    for u, v in edges:
        assert g.node_finish_s[u] < g.node_finish_s[v]
    assert sum(g.per_engine_jobs.values()) == sum(js.num_jobs for js in jss)
    # parallel branches overlap: strictly faster than the serial chain
    serial = sum(sim.run(js).makespan_s for js in jss)
    assert g.makespan_s < serial
