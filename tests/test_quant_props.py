"""Property tests for the quant subsystem (ISSUE 3 + ISSUE 4 satellites).

Hypothesis-driven invariants:

  * the int8 quantize -> dequant reconstruction error stays within the
    calibrated per-channel bound (scale/2 per element) across random GEMM
    shapes and weight scales;
  * the int8×int8 ``quant_gemm`` error obeys the COMPOSED bound — the
    activation-scale and weight-scale error terms add (plus their cross
    term), each capped by its own scale/2;
  * seeded activation-scale calibration is deterministic across runs,
    and so are the int8×int8 outputs it parameterizes;
  * runtime split/merge over a MIXED-precision pool is deterministic
    given a seed — the merged output is a pure function of (inputs,
    pool), never of thread timing.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev deps
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.job import JobSet                         # noqa: E402
from repro.engines.sim import SIM_ENGINE_SPECS, SimPEEngine  # noqa: E402
from repro.quant import (ActCalibrator, QuantizedEngine,  # noqa: E402
                         dequantize_weights, one_shot_act_scale,
                         quant_gemm, quantize_activations,
                         quantize_weights)
from repro.soc import SynergyRuntime                      # noqa: E402


@settings(max_examples=25, deadline=None)
@given(k=st.integers(1, 96), n=st.integers(1, 96),
       wscale=st.floats(1e-3, 10.0), seed=st.integers(0, 2**16))
def test_quantize_error_within_calibrated_bound(k, n, wscale, seed):
    w = jax.random.normal(jax.random.key(seed), (k, n)) * wscale
    qw = quantize_weights(w)
    err = jnp.abs(dequantize_weights(qw) - w)
    # per-channel: each column's error bounded by ITS scale / 2
    assert bool(jnp.all(err <= qw.scale / 2 + 1e-6 * wscale))
    assert float(jnp.max(err)) <= qw.error_bound + 1e-6 * wscale


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 48), k=st.integers(1, 48), n=st.integers(1, 48),
       seed=st.integers(0, 2**16))
def test_quant_gemm_error_tracks_weight_scale(m, k, n, seed):
    """GEMM-level consequence of the bound: |y_q - y_f| <= sum_k |a_ik| *
    scale_j/2, evaluated per output element (tight shapes included)."""
    ka, kb = jax.random.split(jax.random.key(seed))
    a = jax.random.normal(ka, (m, k))
    w = jax.random.normal(kb, (k, n)) * 0.1
    qw = quantize_weights(w)
    y_q = quant_gemm(a, qw)
    y_f = jnp.dot(a, w)
    bound = jnp.dot(jnp.abs(a), jnp.ones((k, 1))) * (qw.scale / 2)
    assert bool(jnp.all(jnp.abs(y_q - y_f) <= bound + 1e-5))


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 32), k=st.integers(1, 64), n=st.integers(1, 64),
       wscale=st.floats(1e-3, 2.0), seed=st.integers(0, 2**16))
def test_int8x8_error_within_composed_scale_bound(m, k, n, wscale, seed):
    """ISSUE 4 satellite: the int8×int8 path's error decomposes as
    ``da @ w + a @ dw + da @ dw`` with |da| <= act_scale/2 per element
    and |dw_kj| <= w_scale_j/2, so per output element

        |y_q - y_f| <= (s_a/2) * sum_k|w_kj| + sum_k|a_ik| * (s_wj/2)
                       + k * (s_a/2) * (s_wj/2).
    """
    ka, kb = jax.random.split(jax.random.key(seed))
    a = jax.random.normal(ka, (m, k))
    w = jax.random.normal(kb, (k, n)) * wscale
    qw = quantize_weights(w)
    s_a = one_shot_act_scale(a)
    y_q = quant_gemm(a, qw, act_scale=s_a)
    y_f = jnp.dot(a, w)
    half_sa, half_sw = s_a / 2.0, qw.scale / 2.0        # (1, n)
    bound = (half_sa * jnp.sum(jnp.abs(w), axis=0, keepdims=True)
             + jnp.sum(jnp.abs(a), axis=1, keepdims=True) * half_sw
             + k * half_sa * half_sw)
    slack = 1e-5 * (1.0 + float(jnp.max(jnp.abs(y_f))))
    assert bool(jnp.all(jnp.abs(y_q - y_f) <= bound + slack))


@settings(max_examples=10, deadline=None)
@given(batches=st.integers(1, 6), k=st.integers(1, 48),
       n=st.integers(1, 48), seed=st.integers(0, 2**16))
def test_seeded_act_calibration_deterministic_across_runs(batches, k, n,
                                                          seed):
    """ISSUE 4 satellite: feeding the same seeded batch sequence into two
    fresh calibrators yields bit-identical scales, quantizations and
    int8×int8 outputs — online calibration is a pure fold."""
    def calibrated_scale():
        cal = ActCalibrator()
        key = jax.random.key(seed)
        for i in range(batches):
            key, kk = jax.random.split(key)
            cal.observe(jax.random.normal(kk, (4, k)) * (1 + i), (k, n))
        return cal.scale_for((k, n))

    s1, s2 = calibrated_scale(), calibrated_scale()
    assert s1 == s2 and s1 is not None
    ka, kb = jax.random.split(jax.random.key(seed + 1))
    a = jax.random.normal(ka, (3, k))
    qw = quantize_weights(jax.random.normal(kb, (k, n)) * 0.1)
    assert np.array_equal(np.asarray(quantize_activations(a, s1)),
                          np.asarray(quantize_activations(a, s2)))
    assert np.array_equal(np.asarray(quant_gemm(a, qw, act_scale=s1)),
                          np.asarray(quant_gemm(a, qw, act_scale=s2)))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**16), panels=st.integers(2, 12))
def test_mixed_pool_split_merge_deterministic_given_seed(seed, panels):
    """Same seed -> same inputs -> bitwise-identical merged output, every
    run, despite two engines of different precision racing for work.
    (Since ISSUE 4 the decode-class split quantizes once at submit and
    panels compute EXACT int32 partials — determinism now survives even
    cross-precision stealing, instead of relying on the LPT pin.)"""
    fp32 = SimPEEngine(f"prop-fp32-{seed}", SIM_ENGINE_SPECS["F-PE"])
    int8 = QuantizedEngine(fp32, name=f"prop-int8-{seed}")
    ka, kb = jax.random.split(jax.random.key(seed))
    a = jax.random.normal(ka, (panels * 16, 32))
    w = jax.random.normal(kb, (32, 24)) * 0.05
    js = JobSet.for_gemm(0, a.shape[0], 24, 32, 16, name=f"prop{seed}")
    outs = []
    for trial in range(2):
        with SynergyRuntime([fp32, int8], name=f"prop-{seed}-{trial}") as rt:
            y = rt.submit_gemm(a, w, jobset=js, tile=(16, 16, 16),
                               job_class="decode").result(60)
            outs.append(np.asarray(y))
    assert np.array_equal(outs[0], outs[1])
    rel = float(np.max(np.abs(outs[0] - np.asarray(jnp.dot(a, w))))
                / (np.max(np.abs(np.asarray(jnp.dot(a, w)))) + 1e-9))
    assert rel < 0.05, rel
