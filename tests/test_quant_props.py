"""Property tests for the quant subsystem (ISSUE 3 satellite).

Two invariants, hypothesis-driven:

  * the int8 quantize -> dequant reconstruction error stays within the
    calibrated per-channel bound (scale/2 per element) across random GEMM
    shapes and weight scales;
  * runtime split/merge over a MIXED-precision pool is deterministic
    given a seed — the precision-pinned LPT seed makes the merged output
    a pure function of (inputs, pool), never of thread timing.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev deps
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.job import JobSet                         # noqa: E402
from repro.engines.sim import SIM_ENGINE_SPECS, SimPEEngine  # noqa: E402
from repro.quant import (QuantizedEngine, dequantize_weights,  # noqa: E402
                         quant_gemm, quantize_weights)
from repro.soc import SynergyRuntime                      # noqa: E402


@settings(max_examples=25, deadline=None)
@given(k=st.integers(1, 96), n=st.integers(1, 96),
       wscale=st.floats(1e-3, 10.0), seed=st.integers(0, 2**16))
def test_quantize_error_within_calibrated_bound(k, n, wscale, seed):
    w = jax.random.normal(jax.random.key(seed), (k, n)) * wscale
    qw = quantize_weights(w)
    err = jnp.abs(dequantize_weights(qw) - w)
    # per-channel: each column's error bounded by ITS scale / 2
    assert bool(jnp.all(err <= qw.scale / 2 + 1e-6 * wscale))
    assert float(jnp.max(err)) <= qw.error_bound + 1e-6 * wscale


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 48), k=st.integers(1, 48), n=st.integers(1, 48),
       seed=st.integers(0, 2**16))
def test_quant_gemm_error_tracks_weight_scale(m, k, n, seed):
    """GEMM-level consequence of the bound: |y_q - y_f| <= sum_k |a_ik| *
    scale_j/2, evaluated per output element (tight shapes included)."""
    ka, kb = jax.random.split(jax.random.key(seed))
    a = jax.random.normal(ka, (m, k))
    w = jax.random.normal(kb, (k, n)) * 0.1
    qw = quantize_weights(w)
    y_q = quant_gemm(a, qw)
    y_f = jnp.dot(a, w)
    bound = jnp.dot(jnp.abs(a), jnp.ones((k, 1))) * (qw.scale / 2)
    assert bool(jnp.all(jnp.abs(y_q - y_f) <= bound + 1e-5))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**16), panels=st.integers(2, 12))
def test_mixed_pool_split_merge_deterministic_given_seed(seed, panels):
    """Same seed -> same inputs -> bitwise-identical merged output, every
    run, despite two engines of different precision racing for work."""
    fp32 = SimPEEngine(f"prop-fp32-{seed}", SIM_ENGINE_SPECS["F-PE"])
    int8 = QuantizedEngine(fp32, name=f"prop-int8-{seed}")
    ka, kb = jax.random.split(jax.random.key(seed))
    a = jax.random.normal(ka, (panels * 16, 32))
    w = jax.random.normal(kb, (32, 24)) * 0.05
    js = JobSet.for_gemm(0, a.shape[0], 24, 32, 16, name=f"prop{seed}")
    outs = []
    for trial in range(2):
        with SynergyRuntime([fp32, int8], name=f"prop-{seed}-{trial}") as rt:
            y = rt.submit_gemm(a, w, jobset=js, tile=(16, 16, 16),
                               job_class="decode").result(60)
            outs.append(np.asarray(y))
    assert np.array_equal(outs[0], outs[1])
    rel = float(np.max(np.abs(outs[0] - np.asarray(jnp.dot(a, w))))
                / (np.max(np.abs(np.asarray(jnp.dot(a, w)))) + 1e-9))
    assert rel < 0.05, rel
