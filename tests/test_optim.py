"""Optimizers + gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (AdamWConfig, AdafactorConfig, adamw_init,
                         adamw_update, adafactor_init, adafactor_update,
                         compress_tree, init_error_feedback, quantize_int8,
                         dequantize_int8, global_norm)


def _quadratic_losses(update_fn, init_fn, cfg, steps=60):
    params = {"w": jnp.array([[2.0, -3.0], [1.0, 4.0]] * 32).reshape(64, 2)}
    target = jnp.zeros_like(params["w"])
    state = init_fn(params)
    losses = []
    for _ in range(steps):
        loss, grads = jax.value_and_grad(
            lambda p: jnp.mean((p["w"] - target) ** 2))(params)
        params, state, _ = update_fn(cfg, grads, state, params)
        losses.append(float(loss))
    return losses


def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=1,
                      total_steps=1000)
    losses = _quadratic_losses(adamw_update, adamw_init, cfg, steps=180)
    assert losses[-1] < 0.05 * losses[0]


def test_adafactor_decreases_quadratic():
    cfg = AdafactorConfig(lr=0.05)
    losses = _quadratic_losses(adafactor_update, adafactor_init, cfg)
    assert losses[-1] < 0.2 * losses[0]


def test_adafactor_factored_memory():
    params = {"w": jnp.zeros((64, 128))}
    state = adafactor_init(params)
    stats = state["stats"]["w"]
    assert stats["vr"].shape == (64,) and stats["vc"].shape == (128,)
    n_stat = stats["vr"].size + stats["vc"].size
    assert n_stat < params["w"].size // 10


def test_int8_roundtrip_error_small():
    x = jax.random.normal(jax.random.key(0), (1000,)) * 3.0
    q, s, pad = quantize_int8(x)
    deq = dequantize_int8(q, s, pad, x.shape)
    err = jnp.abs(deq - x)
    assert float(err.max()) < float(jnp.abs(x).max()) / 64


def test_error_feedback_accumulates_to_truth():
    """Repeatedly syncing the same gradient with error feedback converges
    to the uncompressed sum (bias vanishes)."""
    g = {"w": jax.random.normal(jax.random.key(1), (512,)) * 0.1}
    err = init_error_feedback(g)
    total = jnp.zeros((512,))
    for _ in range(50):
        q, err = compress_tree(g, err)
        deq = dequantize_int8(q["w"][0], q["w"][1],
                              (-512) % 256, (512,))
        total = total + deq
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g["w"]),
                               atol=1e-3)


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.ones((4,)) * 2}
    assert abs(float(global_norm(t)) - np.sqrt(3 + 16)) < 1e-5
