"""The unified engine registry: numeric agreement across backends,
capability-filtered dispatch, telemetry/trace consistency, legacy-impl
shim, and zero-call-site-edit rerouting via a mock engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnns import PAPER_CNNS
from repro.core.clusters import F_PE
from repro.core.job import JobSet
from repro.core.synergy_mm import SynergyTrace, synergy_matmul
from repro.engines import (CAP_GEMM, CostModel, Dispatcher, Engine,
                           SimPEEngine, get_engine, list_engines,
                           registered, resolve_op)
from repro.models.cnn import cnn_forward, init_cnn


def _ab(m, k, n, seed=0):
    ka, kb = jax.random.split(jax.random.key(seed))
    return (jax.random.normal(ka, (m, k)), jax.random.normal(kb, (k, n)))


# ------------------------------------------------------------------ registry

def test_builtin_engines_registered():
    names = {e.name for e in list_engines()}
    assert {"xla", "pallas", "reference", "F-PE", "S-PE", "NEON",
            "ARM"} <= names


@pytest.mark.parametrize("shape", [(64, 64, 64),      # tile-aligned
                                   (70, 45, 33),      # border tiles
                                   (1, 257, 129)])
def test_engines_agree_numerically(shape):
    """XLA, Pallas (interpret off-TPU), and the reference oracle compute
    the same GEMM, bias and activation included."""
    m, k, n = shape
    a, b = _ab(m, k, n)
    bias = jax.random.normal(jax.random.key(2), (n,))
    kw = dict(bias=bias, activation=jax.nn.relu, tile=(32, 32, 32))
    ref = get_engine("reference").execute(a, b, **kw)
    for name in ("xla", "pallas"):
        got = get_engine(name).execute(a, b, **kw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4, err_msg=name)


def test_sim_engines_are_executable():
    a, b = _ab(16, 8, 8)
    ref = get_engine("reference").execute(a, b)
    got = get_engine("F-PE").execute(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------- dispatcher

class _FastMock(Engine):
    """Implausibly fast mock engine: auto-dispatch must pick it."""

    def __init__(self, name="mock", caps=(CAP_GEMM, "epilogue")):
        super().__init__(name, set(caps), cost=CostModel(macs_per_s=1e18))
        self.calls = 0

    def execute(self, a, b, *, bias=None, activation=None, tile=None,
                out_dtype=None, precision=None):
        self.calls += 1
        y = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
        if bias is not None:
            y = y + bias
        if activation is not None:
            y = activation(y)
        return y.astype(out_dtype or a.dtype)


def test_dispatcher_ranks_by_cost_model():
    js = JobSet.for_gemm(0, 64, 64, 64, 32)
    with registered(_FastMock()) as (mock,):
        assert Dispatcher().select(js) is mock
    # once unregistered the default choice returns
    assert Dispatcher().select(js).name != "mock"


def test_dispatcher_respects_capabilities():
    js = JobSet.for_gemm(0, 64, 64, 64, 32)
    no_gemm = _FastMock(name="mock-nogemm", caps=("epilogue",))
    with registered(no_gemm):
        eng = Dispatcher().select(js)
        assert eng.name != "mock-nogemm"     # lacks CAP_GEMM, never picked
    with pytest.raises(ValueError):
        Dispatcher().select(js, engine=no_gemm)   # explicit is still checked
    # sim engines are excluded from AUTO selection but usable explicitly
    assert Dispatcher().select(js).name not in ("F-PE", "S-PE", "NEON")
    assert Dispatcher().select(js, engine="F-PE").name == "F-PE"


def test_mock_engine_reroutes_with_zero_callsite_edits():
    """Registering an engine reroutes a whole model's GEMMs — no edits to
    cnn_forward or any call site."""
    cfg = PAPER_CNNS["MNIST"]
    params = init_cnn(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1),
                          (1, cfg.input_hw, cfg.input_hw, cfg.cin))
    baseline = cnn_forward(cfg, params, x)
    mock = _FastMock()
    with registered(mock):
        rerouted = cnn_forward(cfg, params, x)
    assert mock.calls > 0, "mock engine never selected"
    assert mock.telemetry.gemms == mock.calls
    np.testing.assert_allclose(np.asarray(rerouted), np.asarray(baseline),
                               rtol=1e-3, atol=1e-3)


# ----------------------------------------------------------------- telemetry

def test_trace_aggregates_per_engine_telemetry():
    a, b = _ab(70, 45, 33)
    tr = SynergyTrace()
    with tr.activate():
        synergy_matmul(a, b, tile=32, name="g0")
        synergy_matmul(a, b, tile=32, name="g1", engine="reference")
    assert sum(t.jobs for t in tr.engine_stats.values()) == tr.num_jobs
    assert sum(t.gemms for t in tr.engine_stats.values()) == len(tr.jobsets)
    assert "reference" in tr.engine_stats
    for t in tr.engine_stats.values():
        assert t.busy_s > 0 and t.bytes_moved > 0


def test_engine_global_telemetry_advances():
    eng = get_engine("reference")
    before = eng.telemetry.snapshot()
    a, b = _ab(32, 32, 32, seed=3)
    synergy_matmul(a, b, tile=32, engine="reference")
    assert eng.telemetry.gemms == before.gemms + 1
    assert eng.telemetry.jobs == before.jobs + 1


# ------------------------------------------------------- legacy shim + ops

def test_impl_string_shim_warns_and_works():
    a, b = _ab(16, 8, 8)
    with pytest.warns(DeprecationWarning):
        y = synergy_matmul(a, b, impl="xla")
    ref = get_engine("reference").execute(a, b)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    with pytest.warns(DeprecationWarning):
        synergy_matmul(a, b, impl="auto")   # auto -> dispatcher


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_impl_shim_routes_to_same_engine_as_new_api(impl):
    """The legacy string and the new engine= spelling must land on the
    SAME registered engine (trace-visible routing identity)."""
    a, b = _ab(16, 8, 8, seed=11)
    tr_old, tr_new = SynergyTrace(), SynergyTrace()
    with tr_old.activate(), pytest.warns(DeprecationWarning):
        synergy_matmul(a, b, tile=8, impl=impl)
    with tr_new.activate():
        synergy_matmul(a, b, tile=8, engine=impl)
    assert set(tr_old.engine_stats) == set(tr_new.engine_stats) == {impl}
    # explicit engine= wins over a conflicting legacy string
    tr = SynergyTrace()
    with tr.activate(), pytest.warns(DeprecationWarning):
        synergy_matmul(a, b, tile=8, impl=impl, engine="reference")
    assert set(tr.engine_stats) == {"reference"}


def test_engine_scope_nesting_restores_outer_pin():
    from repro.engines import current_scope_engine, engine_scope
    a, b = _ab(16, 8, 8, seed=12)
    assert current_scope_engine() is None
    with engine_scope("reference"):
        with engine_scope("xla"):
            assert current_scope_engine() == "xla"
            tr = SynergyTrace()
            with tr.activate():
                synergy_matmul(a, b, tile=8)
            assert set(tr.engine_stats) == {"xla"}
        assert current_scope_engine() == "reference"
        tr = SynergyTrace()
        with tr.activate():
            synergy_matmul(a, b, tile=8)
        assert set(tr.engine_stats) == {"reference"}
        # engine_scope(None) re-enables dispatcher auto-selection inside
        # an outer pin
        with engine_scope(None):
            assert current_scope_engine() is None
    assert current_scope_engine() is None


def test_resolve_op_variants():
    # auto resolves to an available variant; explicit names resolve even
    # when unavailable for auto (Pallas interpret off-TPU)
    assert resolve_op("flash_attention") is resolve_op(
        "flash_attention",
        "pallas" if jax.default_backend() == "tpu" else "xla")
    with pytest.raises(KeyError):
        resolve_op("flash_attention", "nope")
    with pytest.raises(KeyError):
        resolve_op("no_such_op")


# ------------------------------------------------- scheduler/registry view

def test_accelerators_are_registry_views():
    """Re-registering a kind's engine re-rates every Accelerator view —
    including accelerators built BEFORE the re-registration, and kinds
    other than the F-PE base."""
    from repro.core.clusters import S_PE, default_synergy_clusters
    base = F_PE(0).macs_per_s
    boosted = SimPEEngine("F-PE", CostModel(macs_per_s=2 * base,
                                            dispatch_s=30e-6))
    with registered(boosted):
        assert F_PE(0).macs_per_s == pytest.approx(2 * base)
    assert F_PE(0).macs_per_s == pytest.approx(base)

    spe = S_PE(0).macs_per_s
    clusters = default_synergy_clusters()      # built with the old rate
    with registered(SimPEEngine("S-PE", CostModel(macs_per_s=2 * spe,
                                                  dispatch_s=30e-6))):
        assert S_PE(0).macs_per_s == pytest.approx(2 * spe)
        pre_built = clusters[0].accelerators[2]   # an S-PE view
        assert pre_built.macs_per_s == pytest.approx(2 * spe)


def test_engine_scope_pins_auto_dispatch():
    from repro.engines import engine_scope
    a, b = _ab(16, 8, 8, seed=5)
    tr = SynergyTrace()
    with tr.activate(), engine_scope("reference"):
        synergy_matmul(a, b, tile=8)
        # explicit engine still beats the scope
        synergy_matmul(a, b, tile=8, engine="xla")
    assert set(tr.engine_stats) == {"reference", "xla"}
