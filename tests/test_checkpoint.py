"""Checkpointing: roundtrip equality, atomicity, retention, recovery loop,
and data-pipeline determinism (the fault-tolerance invariants)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeCell
from repro.data import make_batch, prefetch, synthetic_batches
from repro.runtime import run_with_recovery


def _state(seed=0):
    k = jax.random.key(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros((8,))},
            "opt": {"m": jnp.ones((8, 8)), "step": jnp.int32(7)},
            "step": jnp.int32(7)}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=False)
    state = _state()
    ck.save(7, state)
    restored = ck.restore(jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_write_and_wait(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=True)
    ck.save(1, _state())
    ck.wait()
    assert ck.latest_step() == 1


def test_retention_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        ck.save(s, _state())
    assert ck.all_steps() == [3, 4]


def test_no_tmp_left_behind(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=False)
    ck.save(5, _state())
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_stale_tmp_gc_on_open(tmp_path):
    """A process killed mid-save leaves ``step_N.tmp`` behind; the next
    Checkpointer on the directory must sweep it (and ``all_steps`` must
    never report it), or the orphan blocks a later save of the same
    step and leaks disk forever on an embedded target."""
    stale = tmp_path / "step_00000099.tmp"
    stale.mkdir()
    (stale / "half_written.npy").write_bytes(b"\x93NUMPY garbage")
    # a *file* named like a snapshot dir must not crash the scan either
    (tmp_path / "step_00000001").write_bytes(b"not a dir")
    ck = Checkpointer(str(tmp_path), async_write=False)
    assert not stale.exists()
    assert ck.all_steps() == []
    ck.save(99, _state())                   # the once-blocked step saves
    assert ck.latest_step() == 99
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_run_with_recovery_resumes(tmp_path):
    """Inject a failure at step 6; supervisor must restore step 5 and
    complete all 10 steps with the arithmetic intact."""
    ck = Checkpointer(str(tmp_path), async_write=False)
    state0 = {"x": jnp.float32(0.0), "step": jnp.int32(0)}
    fail_once = {"armed": True}

    def run_steps(start, end, state):
        for s in range(start, end):
            if s == 6 and fail_once["armed"]:
                fail_once["armed"] = False
                raise RuntimeError("simulated node failure")
            state = {"x": state["x"] + 1.0, "step": jnp.int32(s + 1)}
            if (s + 1) % 5 == 0:
                ck.save(s + 1, state)
        return state

    final, failures = run_with_recovery(
        steps=10, run_steps=run_steps, checkpointer=ck, state0=state0)
    assert len(failures) == 1
    assert int(final["step"]) == 10
    assert float(final["x"]) == 10.0


def test_data_determinism_across_restart():
    cfg = reduced(ARCHS["granite-3-2b"])
    cell = ShapeCell("t", 16, 4, "train")
    a = make_batch(cfg, cell, seed=42, step=3)
    b = make_batch(cfg, cell, seed=42, step=3)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = make_batch(cfg, cell, seed=42, step=4)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))


def test_prefetch_preserves_order():
    cfg = reduced(ARCHS["granite-3-2b"])
    cell = ShapeCell("t", 8, 2, "train")
    it = synthetic_batches(cfg, cell, seed=1)
    direct = [next(it) for _ in range(4)]
    it2 = prefetch(synthetic_batches(cfg, cell, seed=1), depth=2)
    fetched = [next(it2) for _ in range(4)]
    for d, f in zip(direct, fetched):
        np.testing.assert_array_equal(np.asarray(d["tokens"]),
                                      np.asarray(f["tokens"]))
