"""repro.obs: tracer, metrics, flight recorder, and instrumentation
invariants (ISSUE 8)."""

import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core.job import JobSet
from repro.core.serving import Request, ServeTimeoutError, SynergyServer
from repro.engines import CAP_GEMM, CostModel, Engine, Telemetry
from repro.models import init_model
from repro.obs import (EVENT_KINDS, FlightRecorder, MetricsRegistry, Tracer,
                       load_chrome_trace, parse_prometheus,
                       render_prometheus, validate_events)
from repro.obs.metrics import Histogram
from repro.obs.trace import (TraceEvent, get_default_tracer,
                             set_default_tracer, trace_scope)
from repro.soc import HealthPolicy, SynergyRuntime, Tenant
from repro.soc.qos import QosClass
from repro.soc.simrt import SimRuntime


# ------------------------------------------------------------ tracer core

def test_tracer_ring_keeps_newest_and_counts_drops():
    tr = Tracer(capacity=10, flush_every=1)
    for i in range(25):
        tr.emit("seed", "manager", ts=float(i), n=i)
    evs = tr.events()
    assert len(evs) == 10
    assert [e.tags["n"] for e in evs] == list(range(15, 25))
    assert tr.dropped == 15


def test_tracer_thread_local_cells_all_flush():
    tr = Tracer(capacity=100_000)

    def worker(tid):
        for i in range(500):
            tr.emit("enqueue", f"eng{tid}", ts=float(i), i=i)

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    evs = tr.events()     # flushes every cell, including partial chunks
    assert len(evs) == 2000
    # stable order: (ts, seq) — same-ts events keep emission order
    assert [e.ts for e in evs] == sorted(e.ts for e in evs)


def test_tracer_span_and_validate():
    tr = Tracer()
    tr.span("panel", "e0", 1.0, 0.5, jobset="j0", n_jobs=2)
    evs = tr.events()
    assert [e.kind for e in evs] == ["panel_start", "panel_end"]
    assert evs[0].dur == 0.5 and evs[1].ts == 1.5
    assert validate_events(evs) == []


def test_validate_catches_broken_invariants():
    bad = [TraceEvent(0.0, "panel_end", "e0"),
           TraceEvent(1.0, "panel_start", "e0"),
           TraceEvent(2.0, "steal", "e0", tags={"victim": "e0"}),
           TraceEvent(3.0, "steal", "e1", tags={"victim": "ghost"}),
           TraceEvent(4.0, "nonsense", "e0")]
    errs = validate_events(bad, engines={"e0", "e1"})
    assert len(errs) == 5
    assert any("without panel_start" in e for e in errs)
    assert any("unmatched panel_start" in e for e in errs)
    assert any("steal from self" in e for e in errs)
    assert any("ghost" in e for e in errs)
    assert any("unknown event kind" in e for e in errs)


def test_default_tracer_scope():
    assert get_default_tracer() is None
    tr = Tracer()
    with trace_scope(tr):
        assert get_default_tracer() is tr
    assert get_default_tracer() is None


# ------------------------------------------------- runtime event round-trip

@pytest.fixture
def traced_burst(tmp_path):
    """A 3-engine pool with everything seeded on one engine (forced
    steals), exported to a Chrome trace and parsed back."""
    tr = Tracer(capacity=100_000)
    a, b = jnp.ones((128, 32)), jnp.ones((32, 32))
    with SynergyRuntime(["F-PE", "S-PE", "NEON"], name="obs-rt",
                        tracer=tr) as rt:
        futs = [rt.submit_gemm(
            a, b, jobset=JobSet.for_gemm(s, 128, 32, 32, 32,
                                         name=f"burst{s}"),
            tile=(32, 32, 32), affinity="F-PE") for s in range(10)]
        for f in futs:
            f.result(60)
        stats = rt.stats()
    path = tmp_path / "trace.json"
    n = tr.export_chrome_trace(str(path))
    assert n > 0
    return tr, stats, path


def test_runtime_trace_round_trip_and_replay_invariants(traced_burst):
    tr, stats, path = traced_burst
    engines = {"F-PE", "S-PE", "NEON"}
    live = tr.events()
    assert validate_events(live, engines=engines) == []
    counts = tr.counts()
    # every panel executed exactly once: starts == ends == dequeues+steals
    assert counts["panel_start"] == counts["panel_end"]
    assert counts["panel_start"] == counts["dequeue"] + counts["steal"]
    assert counts["steal"] > 0          # affinity burst forces stealing
    # steal events agree with the runtime's own accounting
    assert counts["steal"] == sum(
        es["steals"] for es in stats["engines"].values())

    # export -> parse -> same invariants hold on the parsed stream
    parsed = load_chrome_trace(str(path))
    assert validate_events(parsed, engines=engines) == []
    assert (sum(1 for e in parsed if e.kind == "steal")
            == counts["steal"])
    # panel spans survive with durations and jobset tags
    spans = [e for e in parsed if e.kind == "panel_start"]
    assert spans and all(e.dur is not None and e.dur >= 0 for e in spans)
    assert all(e.tags.get("jobset", "").startswith("burst") for e in spans)


def test_chrome_trace_structure(traced_burst):
    _, _, path = traced_burst
    with open(path) as f:
        data = json.load(f)
    evs = data["traceEvents"]
    # metadata names one row per track, engines included
    names = {d["args"]["name"] for d in evs
             if d.get("ph") == "M" and d.get("name") == "thread_name"}
    assert {"F-PE", "S-PE", "NEON", "manager"} <= names
    phases = {d["ph"] for d in evs}
    assert "X" in phases and "i" in phases and "M" in phases
    assert all(d["ts"] >= 0 for d in evs if d["ph"] != "M")


# ------------------------------------------------------- sim conformance

def test_sim_trace_same_schema_as_live():
    """The virtual-time twin emits the live schema: same kinds, same tag
    keys on panel/steal events, virtual stamps from 0."""
    js = JobSet.for_gemm(0, 256, 64, 64, 32, name="simjob")
    sim = SimRuntime(["F-PE", "S-PE"], tracer=Tracer(capacity=10_000))
    res = sim.run(js, affinity="F-PE")
    evs = sim.tracer.events()
    assert evs and {e.kind for e in evs} <= EVENT_KINDS
    assert validate_events(evs, engines={"F-PE", "S-PE"}) == []
    assert min(e.ts for e in evs) == 0.0
    assert max(e.ts for e in evs) == pytest.approx(res.makespan_s)
    panel = next(e for e in evs if e.kind == "panel_start")
    assert {"jobset", "n_jobs", "stolen", "priority"} <= set(panel.tags)
    steals = [e for e in evs if e.kind == "steal"]
    assert len(steals) == res.total_steals
    for s in steals:
        assert {"victim", "jobset", "priority", "probe"} <= set(s.tags)

    # live trace of the same workload: kind vocabulary is identical and
    # per-kind tag keys match, so the two traces are diffable
    lt = Tracer(capacity=10_000)
    with SynergyRuntime(["F-PE", "S-PE"], name="conf", tracer=lt) as rt:
        rt.submit_gemm(jnp.ones((256, 64)), jnp.ones((64, 64)),
                       jobset=js, tile=(32, 32, 32),
                       affinity="F-PE").result(60)
    live = lt.events()

    def tag_keys(events):
        out = {}
        for e in events:
            out.setdefault(e.kind, set()).update(e.tags)
        return out

    sim_keys, live_keys = tag_keys(evs), tag_keys(live)
    for kind in set(sim_keys) & set(live_keys):
        assert sim_keys[kind] <= live_keys[kind] | {"runtime"}, kind


def test_sim_graph_trace_has_node_events():
    mk = lambda i: JobSet.for_gemm(i, 64, 32, 32, 32, name=f"n{i}")
    sim = SimRuntime(["F-PE", "S-PE"], tracer=Tracer())
    res = sim.run_graph([mk(0), mk(1), mk(2)], [(0, 1), (0, 2)])
    counts = sim.tracer.counts()
    assert counts["graph_node_ready"] == 3
    assert counts["graph_node_done"] == 3
    evs = sim.tracer.events()
    done_ts = {e.tags["node"]: e.ts for e in evs
               if e.kind == "graph_node_done"}
    assert done_ts[0] <= done_ts[1] and done_ts[0] <= done_ts[2]
    assert max(done_ts.values()) == pytest.approx(res.makespan_s)


# ----------------------------------------------------------- metrics

def test_metrics_render_and_parse_round_trip():
    reg = MetricsRegistry()
    reg.counter("obs_test_total", "a counter").inc(3)
    reg.gauge("obs_test_depth", "a gauge", ("engine",)).labels("e0").set(2.5)
    h = reg.histogram("obs_test_wait_seconds", "a histogram",
                      buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    text = reg.render()
    parsed = parse_prometheus(text)
    assert parsed["obs_test_total"] == [({}, 3.0)]
    assert parsed["obs_test_depth"] == [({"engine": "e0"}, 2.5)]
    buckets = {lb["le"]: v for lb, v in parsed["obs_test_wait_seconds_bucket"]}
    assert buckets == {"0.1": 1.0, "1": 2.0, "+Inf": 3.0}   # cumulative
    assert parsed["obs_test_wait_seconds_count"] == [({}, 3.0)]
    assert parsed["obs_test_wait_seconds_sum"][0][1] == pytest.approx(5.55)


def test_metrics_type_conflict_rejected():
    reg = MetricsRegistry()
    reg.counter("obs_conflict")
    with pytest.raises(ValueError, match="re-registered"):
        reg.gauge("obs_conflict")
    with pytest.raises(ValueError, match="invalid metric name"):
        reg.counter("bad name!")


def test_histogram_observe_is_allocation_free():
    h = Histogram(buckets=(1.0, 2.0))
    h.observe(0.5)
    import tracemalloc
    tracemalloc.start()
    for _ in range(100):
        h.observe(1.5)
    current, _ = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert current < 512        # bookkeeping noise only, no per-obs allocs
    assert h.count == 101


def test_render_prometheus_covers_runtime_views():
    reg = MetricsRegistry()
    with SynergyRuntime(["F-PE", "S-PE"], name="obs-m") as rt:
        rt.submit_gemm(jnp.ones((64, 32)), jnp.ones((32, 32)),
                       jobset=JobSet.for_gemm(0, 64, 32, 32, 32),
                       tile=(32, 32, 32)).result(30)
        text = render_prometheus(runtime=rt, registry=reg)
    parsed = parse_prometheus(text)
    for name in ("repro_engine_queue_depth", "repro_engine_jobs_total",
                 "repro_engine_steals_total", "repro_engine_busy_fraction",
                 "repro_runtime_steal_rate",
                 "repro_runtime_submissions_total"):
        assert name in parsed, name
    engines = {lb["engine"] for lb, _ in parsed["repro_engine_jobs_total"]}
    assert engines == {"F-PE", "S-PE"}
    total = sum(v for _, v in parsed["repro_engine_jobs_total"])
    assert total == rt.stats()["total_jobs"]


# ------------------------------------------ serving: parity + flight rec

def _cfg():
    return reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=32,
                   n_heads=2, d_ff=64, vocab=128)


def _serve_tokens(tracer, metrics=None):
    from repro.models.cnn import CNNConfig
    tiny = CNNConfig(name="tiny", input_hw=8, cin=1, layers=(
        ("conv", 4, 3, 1, 1), ("pool", 2), ("fc", 10)))
    cfg = _cfg()
    params = init_model(cfg, jax.random.key(0))
    with SynergyRuntime(["F-PE", "S-PE"], name="obs-parity",
                        tracer=tracer) as rt:
        srv = SynergyServer(cfg, params, slots=2, max_len=32,
                            prefill_len=4, runtime=rt, prefill_cnn=tiny,
                            keep_decode_outputs=True, max_inflight=1,
                            metrics=metrics)
        reqs = [Request(i, jnp.arange(4, dtype=jnp.int32) + i,
                        max_new_tokens=5) for i in range(4)]
        for r in reqs:
            srv.submit(r)
        srv.run()
    return [list(r.out) for r in reqs], srv.decode_gemm_outputs


def test_disabled_tracer_bitwise_parity_on_token_streams():
    """Tracing is observation only: tokens AND raw decode GEMM outputs
    are bitwise identical with a tracer attached and with none."""
    toks_off, outs_off = _serve_tokens(None)
    toks_on, outs_on = _serve_tokens(Tracer(capacity=200_000))
    assert toks_off == toks_on
    assert len(outs_off) == len(outs_on) > 0
    for ya, yb in zip(outs_off, outs_on):
        assert np.array_equal(np.asarray(ya), np.asarray(yb))


class _StuckEngine(Engine):
    """Sleeps far past the server's submit_timeout."""

    def __init__(self, name="stuck"):
        super().__init__(name, {CAP_GEMM, "epilogue"},
                         cost=CostModel(macs_per_s=1e9))

    def execute(self, a, b, **kw):
        time.sleep(2.0)
        return jnp.zeros((a.shape[0], b.shape[1]), a.dtype)


def test_flight_recorder_dumps_on_forced_timeout(tmp_path):
    from repro.models.cnn import CNNConfig
    tiny = CNNConfig(name="tiny", input_hw=8, cin=1, layers=(
        ("conv", 4, 3, 1, 1), ("fc", 10)))
    cfg = _cfg()
    params = init_model(cfg, jax.random.key(0))
    tr = Tracer(capacity=10_000)
    rec = FlightRecorder(tr, dir=str(tmp_path), last_n=64)
    with SynergyRuntime([_StuckEngine()], name="obs-stuck",
                        tracer=tr, flight_recorder=rec) as rt:
        srv = SynergyServer(cfg, params, slots=1, max_len=16,
                            prefill_len=4, runtime=rt, prefill_cnn=tiny,
                            submit_timeout=0.1)
        srv.submit(Request(0, jnp.arange(4, dtype=jnp.int32),
                           max_new_tokens=2))
        with pytest.raises(ServeTimeoutError):
            srv.run()
        rt.shutdown(drain=False, timeout=5.0)
    assert srv._flight is rec          # server inherited the recorder
    assert len(rec.dumps) == 1
    with open(rec.dumps[0]) as f:
        dump = json.load(f)
    assert dump["reason"] == "serve_timeout"
    assert dump["context"]["timeout_s"] == 0.1
    assert "stuck" in dump["stats"]["runtime"]["engines"]
    assert len(dump["events"]) <= 64
    kinds = {e["kind"] for e in dump["events"]}
    assert kinds <= EVENT_KINDS


def test_flight_recorder_cap_and_bad_dir(tmp_path):
    blocker = tmp_path / "not-a-dir"
    blocker.write_text("")
    rec = FlightRecorder(None, dir=str(blocker / "sub"), max_dumps=2)
    assert rec.dump("x") is None       # unwritable dir: never raises
    rec2 = FlightRecorder(None, dir=str(tmp_path), max_dumps=0)
    assert rec2.dump("x") is None and rec2.suppressed == 1


# --------------------------- tenants + quarantine acceptance integration

class _SickEngine(Engine):
    def __init__(self, name, macs_per_s=1e9):
        super().__init__(name, {CAP_GEMM, "epilogue"},
                         cost=CostModel(macs_per_s=macs_per_s))
        self.delay_s = 0.008

    def execute(self, a, b, **kw):
        time.sleep(self.delay_s)
        y = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
        return y.astype(a.dtype)


def test_tenanted_quarantine_run_produces_full_trace(tmp_path):
    """ISSUE 8 acceptance: a serving run with tenants + a quarantine
    yields a Chrome trace with per-engine tracks and steal / quarantine /
    deadline / admission events, and the flight recorder captured the
    quarantine."""
    pol = HealthPolicy(alpha=0.5, quarantine_below=0.5, readmit_above=0.6,
                       min_samples=3, probe_interval_s=1e9,
                       min_probe_samples=2)
    tr = Tracer(capacity=200_000)
    rec = FlightRecorder(tr, dir=str(tmp_path))
    sick, buddy = _SickEngine("sick"), _SickEngine("buddy")
    a, b = jnp.ones((16, 32)), jnp.ones((32, 16))

    def gemm(rt, step, affinity=None):
        return rt.submit_gemm(
            a, b, jobset=JobSet.for_gemm(step, 16, 16, 32, 16),
            tile=(16, 16, 16), affinity=affinity)

    with SynergyRuntime([sick, buddy], name="obs-heal", health=pol,
                        tracer=tr, flight_recorder=rec) as rt:
        for s in range(6):
            gemm(rt, s, affinity="sick").result(30)
        sick.delay_s = 0.12
        deadline = time.monotonic() + 30
        step = 100
        while not rt.stats()["engines"]["sick"]["quarantined"]:
            assert time.monotonic() < deadline, "never quarantined"
            gemm(rt, step, affinity="sick").result(30)
            step += 1

        # a tenanted serving run on the SAME tracer (serving tracks)
        from repro.models.cnn import CNNConfig
        tiny = CNNConfig(name="tiny", input_hw=8, cin=1, layers=(
            ("conv", 4, 3, 1, 1), ("fc", 10)))
        cfg = _cfg()
        params = init_model(cfg, jax.random.key(0))
        srv = SynergyServer(
            cfg, params, slots=2, max_len=32, prefill_len=4, runtime=rt,
            prefill_cnn=tiny,
            tenants=[Tenant("gold", QosClass(priority=10, deadline_s=60.0)),
                     Tenant("bulk")])
        for i in range(3):
            srv.submit(Request(i, jnp.arange(4, dtype=jnp.int32) + i,
                               max_new_tokens=3,
                               tenant="gold" if i == 0 else "bulk"))
        srv.run()

    evs = tr.events()
    assert validate_events(evs, engines={"sick", "buddy"}) == []
    kinds = {e.kind for e in evs}
    assert {"quarantine", "steal", "admission", "deadline_hit",
            "panel_start", "panel_end"} <= kinds
    assert rec.dumps, "quarantine must flight-record"
    with open(rec.dumps[0]) as f:
        assert json.load(f)["reason"] == "quarantine"

    path = tmp_path / "accept.json"
    tr.export_chrome_trace(str(path))
    with open(path) as f:
        data = json.load(f)
    names = {d["args"]["name"] for d in data["traceEvents"]
             if d.get("ph") == "M" and d.get("name") == "thread_name"}
    assert {"sick", "buddy", "serving", "admission"} <= names
    # metrics exposition over the same run parses and shows the tenants
    text = render_prometheus(runtime=rt, server=srv,
                             registry=MetricsRegistry())
    parsed = parse_prometheus(text)
    tenants = {lb["tenant"] for lb, _ in parsed["repro_tenant_tokens_total"]}
    assert tenants == {"gold", "bulk"}
    assert parsed["repro_runtime_quarantines_total"][0][1] >= 1


# ------------------------------------- Telemetry view regression (bugfix)

def test_busy_fraction_reads_consistently_under_concurrent_merge():
    """busy_fraction must read busy+idle under the lock: hammering
    record_runtime/merge from threads can never produce a fraction
    outside [0, 1] (the torn-read symptom) and totals stay exact."""
    t = Telemetry()
    stop = threading.Event()
    bad = []

    def writer():
        while not stop.is_set():
            t.record_runtime(wall_busy_s=0.001, idle_s=0.001)

    def reader():
        while not stop.is_set():
            f = t.busy_fraction
            if not (0.0 <= f <= 1.0):
                bad.append(f)

    threads = [threading.Thread(target=writer) for _ in range(2)] + \
              [threading.Thread(target=reader) for _ in range(2)]
    for th in threads:
        th.start()
    time.sleep(0.3)
    stop.set()
    for th in threads:
        th.join()
    assert not bad
    snap = t.snapshot()
    assert snap.wall_busy_s == pytest.approx(snap.idle_s)
    assert t.busy_fraction == pytest.approx(0.5)


def test_merge_mid_window_never_double_counts_idle():
    """The worker books an idle window only AFTER cond.wait returns, so
    a snapshot taken mid-window UNDERCOUNTS idle; merging a mid-window
    snapshot with the final state must never exceed the true totals."""
    src = Telemetry()
    src.record_runtime(idle_s=0.5)         # window 1 fully booked
    mid = src.snapshot()                   # snapshot while window 2 open
    src.record_runtime(idle_s=0.25)        # window 2 lands afterwards
    assert mid.idle_s == 0.5               # open window invisible: no double
    merged = Telemetry()
    merged.merge(mid)
    assert merged.idle_s == 0.5
    final = Telemetry()
    final.merge(src.snapshot())
    assert final.idle_s == pytest.approx(0.75)
    # merging two engines' snapshots sums exactly once each
    other = Telemetry()
    other.record_runtime(wall_busy_s=0.75, idle_s=0.25)
    final.merge(other.snapshot())
    assert final.idle_s == pytest.approx(1.0)
    assert final.wall_busy_s == pytest.approx(0.75)
    assert final.busy_fraction == pytest.approx(0.75 / 1.75)
