"""Hypothesis property tests for the multi-tenant QoS scheduler (ISSUE 7
satellite c).

For RANDOM priority/deadline mixes, workload sizes, and steal-timing
seeds:

  * every tile panel executes exactly once and every GEMM's value is
    bitwise equal to the plain XLA dot, whatever QoS tags are attached —
    QoS reorders work, it never changes or drops it;
  * LOW-priority submissions still complete (and book the right number
    of jobs) when capacity allows — priority queueing starves nobody;
  * on a single simulated engine the schedule is strictly
    priority-ordered, so the unique highest-priority submission finishes
    after exactly its own service time — any deadline with slack over
    that is met regardless of how much lower-priority work was admitted
    alongside, and the sim's ``deadline_met`` verdicts agree with its
    own finish stamps.

The seeded deterministic sweep in ``test_qos.py`` covers the core
invariants when the hypothesis dev-dependency is absent.
"""

import math
import random
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev deps
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.job import JobSet                         # noqa: E402
from repro.engines import (CAP_GEMM, CostModel, Engine,   # noqa: E402
                           get_engine)
from repro.soc import QosTag, SimRuntime, SynergyRuntime  # noqa: E402


class _DelayEngine(Engine):
    """Deterministic-output engine with seeded random per-job delays."""

    def __init__(self, name, macs_per_s=1e9, seed=0, max_delay_s=0.002):
        super().__init__(name, {CAP_GEMM, "epilogue"},
                         cost=CostModel(macs_per_s=macs_per_s))
        self._rng = random.Random(seed)
        self._max_delay_s = max_delay_s

    def execute(self, a, b, *, bias=None, activation=None, tile=None,
                out_dtype=None, precision=None):
        time.sleep(self._rng.random() * self._max_delay_s)
        y = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
        return y.astype(out_dtype or a.dtype)


@settings(max_examples=12, deadline=None)
@given(wl_seed=st.integers(0, 2**16), steal_seed=st.integers(0, 2**16))
def test_random_tags_exactly_once_bitwise(wl_seed, steal_seed):
    rng = random.Random(wl_seed)
    d = 32
    w = jax.random.normal(jax.random.key(5), (d, 16))
    subs = []
    for i in range(rng.randint(2, 5)):
        m = 16 * rng.randint(1, 4)
        a = jax.random.normal(jax.random.key(100 + wl_seed + i), (m, d))
        tag = QosTag(rng.choice([-10, -1, 0, 10]),
                     rng.choice([math.inf, 0.5, 5.0]))
        subs.append((a, tag))

    pool = [_DelayEngine("qp-a", seed=steal_seed),
            _DelayEngine("qp-b", macs_per_s=4e8, seed=steal_seed + 1)]
    with SynergyRuntime(pool, name="qosprop") as rt:
        futs = [rt.submit_gemm(a, w,
                               jobset=JobSet.for_gemm(i, a.shape[0], 16,
                                                      d, 16, name=f"p{i}"),
                               tile=(16, 16, 16), qos=tag)
                for i, (a, tag) in enumerate(subs)]
        for f, (a, _) in zip(futs, subs):
            got = f.result(120)
            # exactly-once panels: the runtime booked every tile job
            assert sum(x["jobs"] for x in f.accounting.values()) \
                == f.jobset.num_jobs
            ref = jnp.dot(a, w, preferred_element_type=jnp.float32)
            assert np.array_equal(np.asarray(got), np.asarray(ref))
        st_ = rt.stats()
    assert st_["total_jobs"] == sum(f.jobset.num_jobs for f in futs)


@settings(max_examples=12, deadline=None)
@given(wl_seed=st.integers(0, 2**16), steal_seed=st.integers(0, 2**16))
def test_low_priority_never_starves_with_capacity(wl_seed, steal_seed):
    """A best-effort submission behind a stream of interactive work still
    finishes — the runtime drains queues in priority order but never
    parks low-priority panels forever while workers have capacity."""
    rng = random.Random(wl_seed)
    pool = [_DelayEngine("st-a", seed=steal_seed, max_delay_s=0.001),
            _DelayEngine("st-b", seed=steal_seed + 1, max_delay_s=0.001)]
    d = 32
    w = jax.random.normal(jax.random.key(7), (d, 16))
    a_lo = jax.random.normal(jax.random.key(wl_seed), (32, d))
    with SynergyRuntime(pool, name="starve") as rt:
        lo = rt.submit_gemm(a_lo, w,
                            jobset=JobSet.for_gemm(0, 32, 16, d, 16,
                                                   name="lo"),
                            tile=(16, 16, 16), qos=QosTag(-20))
        his = []
        for i in range(rng.randint(3, 6)):
            a = jax.random.normal(jax.random.key(1000 + i), (32, d))
            his.append(rt.submit_gemm(
                a, w, jobset=JobSet.for_gemm(1 + i, 32, 16, d, 16,
                                             name=f"hi{i}"),
                tile=(16, 16, 16), qos=QosTag(10, 5.0)))
        got = lo.result(60)          # completes: no starvation
        assert np.array_equal(
            np.asarray(got),
            np.asarray(jnp.dot(a_lo, w,
                               preferred_element_type=jnp.float32)))
        assert sum(x["jobs"] for x in lo.accounting.values()) \
            == lo.jobset.num_jobs
        for f in his:
            f.result(60)


@settings(max_examples=25, deadline=None)
@given(wl_seed=st.integers(0, 2**16),
       n_bulk=st.integers(1, 5),
       slack=st.floats(1.01, 3.0))
def test_sim_highest_priority_deadline_with_slack_is_met(wl_seed, n_bulk,
                                                         slack):
    rng = random.Random(wl_seed)
    eng = get_engine("F-PE")
    inter = JobSet.for_gemm(0, 32 * rng.randint(1, 4), 128, 96, 32,
                            name="inter")
    j = next(inter.jobs())
    solo_s = inter.num_jobs * eng.cost.job_time(j.macs, j.bytes_moved)
    subs = [(inter, QosTag(10, solo_s * slack))]
    for i in range(n_bulk):
        bulk = JobSet.for_gemm(1 + i, 32 * rng.randint(1, 8), 128, 96, 32,
                               name=f"bulk{i}")
        subs.append((bulk, QosTag(rng.choice([-10, 0]),
                                  rng.choice([math.inf, solo_s]))))
    res = SimRuntime(["F-PE"]).run_qos(subs)
    # strict priority order on one engine: the unique top-priority
    # submission is served first, so its finish is exactly its own work
    assert res.submission_finish_s[0] == pytest.approx(solo_s, rel=1e-9)
    assert res.deadline_met[0] is True
    # verdicts agree with the finish stamps for every submission
    for sid, (_, tag) in enumerate(subs):
        expect = res.submission_finish_s[sid] <= tag.deadline_at
        assert res.deadline_met[sid] is expect
    # work conservation
    assert sum(res.per_engine_jobs.values()) == \
        sum(js.num_jobs for js, _ in subs)
