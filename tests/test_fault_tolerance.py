"""Unit tests for the elastic-training fault-tolerance seed modules
(ISSUE 9 satellite b): ``repro.runtime.fault_tolerance`` (heartbeat
monitor, elastic re-meshing, checkpoint/restart supervisor) and
``repro.runtime.straggler`` (between-step work-share rebalancing) —
plus the contract that the SoC runtime's worker-death detector reuses
the SAME HeartbeatMonitor definition (one timeout semantic, not two).
"""

import pytest

import repro.soc.runtime as soc_runtime
from repro.runtime.fault_tolerance import (FailureEvent, HeartbeatMonitor,
                                           plan_elastic_mesh,
                                           run_with_recovery)
from repro.runtime.straggler import StragglerRebalancer
from repro.soc import RetryPolicy


# ------------------------------------------------------------ heartbeat

def test_heartbeat_monitor_flags_silent_hosts():
    hb = HeartbeatMonitor(n_hosts=4, timeout_steps=3)
    for step in range(1, 6):
        for h in (0, 1, 3):               # host 2 goes silent after step 0
            hb.beat(h, step)
    assert hb.failed_hosts(step=5) == [2]
    # a late beat clears the verdict — detection is state, not history
    hb.beat(2, 5)
    assert hb.failed_hosts(step=5) == []


def test_heartbeat_monitor_timeout_boundary():
    hb = HeartbeatMonitor(n_hosts=1, timeout_steps=3)
    hb.beat(0, 10)
    assert hb.failed_hosts(13) == []      # exactly timeout_steps late: alive
    assert hb.failed_hosts(14) == [0]     # one step beyond: failed


def test_soc_runtime_reuses_heartbeat_monitor_definition():
    """The SoC worker-death detector must be the SAME class, and
    RetryPolicy.timeout_steps converts its wall-clock knobs into the
    step-granularity timeout the monitor speaks."""
    import repro.runtime.fault_tolerance as ft
    assert soc_runtime.HeartbeatMonitor is ft.HeartbeatMonitor
    retry = RetryPolicy(heartbeat_timeout_s=0.5, monitor_interval_s=0.1)
    assert retry.timeout_steps == 5
    hb = HeartbeatMonitor(n_hosts=2, timeout_steps=retry.timeout_steps)
    hb.beat(0, 5)
    assert hb.failed_hosts(7) == [1]      # never beat past construction


# ------------------------------------------------------- elastic re-mesh

def test_plan_elastic_mesh_drops_data_replicas():
    assert plan_elastic_mesh(64, model_parallel=16) == (4, 16)
    assert plan_elastic_mesh(63, model_parallel=16) == (3, 16)  # lost one


def test_plan_elastic_mesh_pods_axis():
    assert plan_elastic_mesh(64, model_parallel=16, pods=2) == (2, 2, 16)
    assert plan_elastic_mesh(32, model_parallel=16, pods=2) == (2, 1, 16)


def test_plan_elastic_mesh_too_few_survivors():
    with pytest.raises(RuntimeError, match="cannot re-mesh"):
        plan_elastic_mesh(15, model_parallel=16)


# ------------------------------------------------- checkpoint supervisor

class _Ckpt:
    """Duck-typed checkpointer: remembers the last saved (step, state)."""

    def __init__(self):
        self.step = None
        self.state = None
        self.restores = 0

    def save(self, step, state):
        self.step, self.state = step, state

    def latest_step(self):
        return self.step

    def restore(self, _state):
        self.restores += 1
        return self.state


def test_run_with_recovery_restores_and_resumes():
    ckpt = _Ckpt()
    crashed = []

    def run_steps(start, end, state):
        for step in range(start, end):
            if step == 5 and not crashed:
                crashed.append(step)
                raise RuntimeError("host 3 lost")
            state += 1
            ckpt.save(step + 1, state)
        return state

    events = []
    final, failures = run_with_recovery(
        steps=10, run_steps=run_steps, checkpointer=ckpt, state0=0,
        on_failure=events.append)
    # resumed from the step-5 checkpoint: exactly 10 increments total
    assert final == 10
    assert ckpt.restores == 1
    assert [f.kind for f in failures] == ["step-exception"]
    assert events == failures and isinstance(events[0], FailureEvent)


def test_run_with_recovery_cold_restart_without_checkpoint():
    calls = []

    def run_steps(start, end, state):
        calls.append(start)
        if len(calls) == 1:
            raise RuntimeError("early fault")
        return state + (end - start)

    final, failures = run_with_recovery(
        steps=4, run_steps=run_steps, checkpointer=_Ckpt(), state0=0)
    assert final == 4 and calls == [0, 0]   # no checkpoint: restart at 0
    assert len(failures) == 1


def test_run_with_recovery_exceeds_max_restarts():
    def run_steps(start, end, state):
        raise RuntimeError("always down")

    with pytest.raises(RuntimeError, match="exceeded 2 restarts"):
        run_with_recovery(steps=3, run_steps=run_steps,
                          checkpointer=_Ckpt(), state0=0, max_restarts=2)


# ---------------------------------------------------- straggler shares

def test_straggler_rebalancer_shrinks_slow_cluster_share():
    rb = StragglerRebalancer(n_clusters=3)
    for _ in range(8):
        shares = rb.observe([1.0, 1.0, 2.0])   # cluster 2 runs 2x slow
    assert shares[2] < shares[0]
    assert shares[0] == pytest.approx(shares[1], rel=1e-6)
    assert sum(shares) == pytest.approx(1.0)
    assert all(s >= rb.min_share for s in shares)
    assert len(rb.history) == 8


def test_straggler_split_jobs_conserves_and_matches_shares():
    rb = StragglerRebalancer(n_clusters=3)
    for _ in range(8):
        rb.observe([1.0, 1.0, 3.0])
    for n in (1, 7, 32, 97):
        counts = rb.split_jobs(n)
        assert sum(counts) == n             # every tile job owned once
        assert len(counts) == 3
        assert all(c >= 0 for c in counts)
    counts = rb.split_jobs(100)
    assert counts[2] < counts[0]            # slow cluster owns less
