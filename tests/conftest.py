"""Test session config.

NOTE: we deliberately do NOT set --xla_force_host_platform_device_count
here (per the dry-run contract, only launch/dryrun.py forces fake devices).
Tests that need a multi-device mesh run themselves in a subprocess — see
tests/test_sharding_dryrun.py."""

import jax

jax.config.update("jax_enable_x64", False)
