"""repro.soc.faults: deterministic fault injection + runtime recovery.

Covers the ISSUE 9 acceptance criteria: seed-reproducible FaultPlans,
panel retry with bitwise-identical merged outputs (exactly-once merge —
a retried panel's failed attempt never double-merges), worker-death
detection re-seeding queued + in-flight panels, the stall sweep's
idempotent duplicate re-execution, the opt-in NaN/Inf integrity guard,
faults feeding the HealthPolicy quarantine EMA, graph node retry before
descendant-cancel, the per-job drain-error fix, flight-recorder dumps on
retry exhaustion, serving surviving a mid-prefill engine crash, and the
live <-> SimRuntime fault-trace conformance.
"""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.job import JobSet
from repro.engines import CAP_GEMM, CostModel, Engine
from repro.obs.flightrec import FlightRecorder
from repro.obs.trace import EVENT_KINDS, Tracer, validate_events
from repro.soc import (FaultPlan, FaultSpec, FaultyEngine, GraphNode,
                       HealthPolicy, InjectedFault, PanelRetryExhausted,
                       RetryPolicy, SimRuntime, SynergyRuntime, wrap_pool)


class _MathEngine(Engine):
    """All instances compute the IDENTICAL fp32 jnp.dot, so merged
    results are placement-independent and bitwise comparable across
    fault-free and faulted runs."""

    def __init__(self, name, macs_per_s=5e8):
        super().__init__(name, {CAP_GEMM, "epilogue"},
                         cost=CostModel(macs_per_s=macs_per_s))
        self.executed = 0

    def execute(self, a, b, *, bias=None, activation=None, tile=None,
                out_dtype=None, precision=None):
        self.executed += 1
        y = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
        if bias is not None:
            y = y + bias
        if activation is not None:
            y = activation(y)
        return y.astype(out_dtype or a.dtype)


def _pool(n=3, macs_per_s=5e8):
    return [_MathEngine(f"fe{i}", macs_per_s) for i in range(n)]


def _ab(m, k, n, seed=0):
    ka, kb = jax.random.split(jax.random.key(seed))
    return (jax.random.normal(ka, (m, k)), jax.random.normal(kb, (k, n)))


def _run_gemm(engines, *, retry=None, tracer=None, name="faults",
              m=256, k=64, n=48, seed=0, **rt_kw):
    a, b = _ab(m, k, n, seed)
    with SynergyRuntime(engines, name=name, retry=retry, tracer=tracer,
                        **rt_kw) as rt:
        fut = rt.submit_gemm(
            a, b, jobset=JobSet.for_gemm(0, m, k, n, 32, name="g0"),
            tile=(32, 32, 32), affinity="fe0")
        y = fut.result(60)
        stats = rt.stats()
    return np.asarray(y), fut, stats


# -------------------------------------------------------------- the plan

def test_fault_plan_is_seed_reproducible():
    engines = ["a", "b", "c"]
    p1 = FaultPlan.random(42, engines)
    p2 = FaultPlan.random(42, engines)
    assert p1.specs == p2.specs
    assert p1.specs != FaultPlan.random(43, engines).specs
    # the default draw is retryable-only: the chaos-sweep contract
    assert all(s.kind in ("raise", "corrupt", "slowdown")
               for s in p1.specs)


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("e", "meltdown")
    with pytest.raises(ValueError, match="count"):
        FaultSpec("e", "raise", count=0)
    with pytest.raises(ValueError, match="at_call"):
        FaultSpec("e", "raise", at_call=-1)
    s = FaultSpec("e", "raise", at_call=2, count=3)
    assert [s.hits(c) for c in range(6)] == [False, False, True, True,
                                             True, False]


def test_retry_policy_validation():
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match="monitor_interval_s"):
        RetryPolicy(monitor_interval_s=0)
    assert RetryPolicy(heartbeat_timeout_s=0.5,
                       monitor_interval_s=0.05).timeout_steps == 10
    assert RetryPolicy(heartbeat_timeout_s=0.01,
                       monitor_interval_s=1.0).timeout_steps == 1


def test_wrap_pool_only_wraps_targeted_engines():
    pool = _pool(3)
    plan = FaultPlan((FaultSpec("fe1", "raise"),), seed=0)
    wrapped = wrap_pool(pool, plan)
    assert isinstance(wrapped[1], FaultyEngine)
    assert wrapped[0] is pool[0] and wrapped[2] is pool[2]
    # delegation is attribute-faithful: no phantom int8 entry points
    assert not hasattr(wrapped[1], "execute_int8")
    assert wrapped[1].telemetry is pool[1].telemetry
    assert wrapped[1].cost.macs_per_s == pool[1].cost.macs_per_s


def test_heartbeat_monitor_is_shared_definition():
    """One heartbeat-timeout definition, not two: the runtime's
    worker-death detector must BE the elastic-training monitor."""
    import repro.runtime.fault_tolerance as ft
    import repro.soc.runtime as rt_mod
    assert rt_mod.HeartbeatMonitor is ft.HeartbeatMonitor


# --------------------------------------------------- retry, bitwise merge

def test_injected_raise_retries_bitwise_and_exactly_once():
    """The keystone invariant: two injected panel exceptions cost two
    retries and NOTHING else — merged output bitwise-identical to the
    fault-free run, every panel merged exactly once."""
    ref, _, _ = _run_gemm(_pool())
    plan = FaultPlan((FaultSpec("fe1", "raise", at_call=0, count=2),),
                     seed=3)
    tracer = Tracer()
    y, fut, stats = _run_gemm(wrap_pool(_pool(), plan, tracer=tracer),
                              retry=RetryPolicy(max_attempts=3),
                              tracer=tracer)
    assert np.array_equal(y, ref)
    assert plan.injected == [("fe1", "raise", 0), ("fe1", "raise", 1)]
    assert stats["retries"] == 2 and fut.retries == 2
    # exactly-once: failed attempts never reached the merge
    assert fut.execution_counts == [1] * len(fut.execution_counts)
    assert sum(a["jobs"] for a in fut.accounting.values()) == 8 * 2
    kinds = {e.kind for e in tracer.events()}
    assert {"fault_injected", "panel_retry"} <= kinds
    validate_events(tracer.events())


def test_retry_avoids_failed_engine():
    """fe0 ALWAYS raises; the submission can only succeed if retries
    re-seed onto the other engines."""
    plan = FaultPlan((FaultSpec("fe0", "raise", at_call=0, count=10_000),),
                     seed=0)
    ref, _, _ = _run_gemm(_pool())
    y, fut, stats = _run_gemm(wrap_pool(_pool(), plan),
                              retry=RetryPolicy(max_attempts=3))
    assert np.array_equal(y, ref)
    assert stats["retries"] >= 1
    # every injection the audit log shows happened on fe0, and each
    # faulted panel's retry succeeded elsewhere on the FIRST try
    assert {e for e, _, _ in plan.injected} == {"fe0"}
    assert stats["retries"] == len(plan.injected)


def test_retry_exhaustion_raises_and_dumps_flight(tmp_path):
    """A panel that fails everywhere surfaces PanelRetryExhausted with
    its audit trail, and the flight recorder dumps the post-mortem."""
    plan = FaultPlan(
        tuple(FaultSpec(f"fe{i}", "raise", at_call=0, count=10_000)
              for i in range(2)), seed=0)
    tracer = Tracer()
    flight = FlightRecorder(tracer, dir=str(tmp_path))
    a, b = _ab(64, 32, 32)
    with SynergyRuntime(wrap_pool(_pool(2), plan, tracer=tracer),
                        name="exhaust", retry=RetryPolicy(max_attempts=2),
                        tracer=tracer, flight_recorder=flight) as rt:
        fut = rt.submit_gemm(
            a, b, jobset=JobSet.for_gemm(0, 64, 32, 32, 32, name="doom"),
            tile=(32, 32, 32))
        with pytest.raises(PanelRetryExhausted) as ei:
            fut.result(60)
    assert ei.value.jobset_name == "doom"
    assert ei.value.attempts == 2
    assert isinstance(ei.value.last, InjectedFault)
    dumps = list(tmp_path.glob("flightrec-*retry_exhausted*.json"))
    assert dumps, "retry exhaustion must flight-record a post-mortem"


def test_backoff_delays_reseed():
    plan = FaultPlan((FaultSpec("fe0", "raise", at_call=0, count=1),),
                     seed=0)
    ref, _, _ = _run_gemm(_pool(2))
    t0 = time.perf_counter()
    y, fut, stats = _run_gemm(
        wrap_pool(_pool(2), plan),
        retry=RetryPolicy(max_attempts=3, backoff_s=0.15))
    assert np.array_equal(y, ref)
    assert stats["retries"] == 1
    assert time.perf_counter() - t0 >= 0.15


# ------------------------------------------------------------ worker death

def test_worker_death_reseeds_orphans_bitwise():
    """A worker killed mid-panel: the heartbeat monitor detects the dead
    thread, retires the engine, and the orphaned panels (queued AND the
    one it died holding) re-seed onto the survivors."""
    ref, _, _ = _run_gemm(_pool())
    plan = FaultPlan((FaultSpec("fe1", "die", at_call=0),), seed=0)
    tracer = Tracer()
    retry = RetryPolicy(heartbeat_timeout_s=0.1, monitor_interval_s=0.02)
    a, b = _ab(256, 64, 48)
    with SynergyRuntime(wrap_pool(_pool(), plan, tracer=tracer),
                        name="death", retry=retry, tracer=tracer) as rt:
        # seed onto the doomed engine: it dies holding its FIRST panel,
        # leaving both an in-flight orphan and queued orphans to re-seed
        fut = rt.submit_gemm(
            a, b, jobset=JobSet.for_gemm(0, 256, 64, 48, 32, name="g0"),
            tile=(32, 32, 32), affinity="fe1")
        y = fut.result(60)
        stats = rt.stats()
        assert "fe1" not in rt.engine_names     # retired, not respawned
    assert np.array_equal(np.asarray(y), ref)
    assert stats["worker_deaths"] == 1
    assert stats["orphan_reseeds"] >= 1
    assert fut.execution_counts == [1] * len(fut.execution_counts)
    kinds = {e.kind for e in tracer.events()}
    assert {"worker_death", "orphan_reseed", "fault_injected"} <= kinds


def test_dropped_completion_recovered_by_stall_sweep():
    """A dropped completion leaves the panel in flight forever; only the
    stall sweep's DUPLICATE re-execution recovers it — and the
    idempotent per-index merge keeps the duplicate safe."""
    ref, _, _ = _run_gemm(_pool())
    plan = FaultPlan((FaultSpec("fe2", "drop", at_call=0),), seed=0)
    retry = RetryPolicy(stall_timeout_s=0.15, monitor_interval_s=0.02)
    y, fut, stats = _run_gemm(wrap_pool(_pool(), plan), retry=retry)
    assert np.array_equal(y, ref)
    assert stats["retries"] >= 1
    # exactly-once MERGE even when execution happened twice
    assert fut.execution_counts == [1] * len(fut.execution_counts)


# --------------------------------------------------------- integrity guard

def test_corrupt_output_guard_opt_in():
    """check_outputs=True turns NaN corruption into a retryable fault;
    without the guard the corruption merges silently (documented)."""
    ref, _, _ = _run_gemm(_pool())
    plan = FaultPlan((FaultSpec("fe1", "corrupt", at_call=0),), seed=0)
    y, fut, stats = _run_gemm(
        wrap_pool(_pool(), plan),
        retry=RetryPolicy(max_attempts=3, check_outputs=True))
    assert np.array_equal(y, ref)
    assert np.isfinite(y).all()
    assert stats["retries"] >= 1
    # the guard is opt-in: check_outputs=False lets the NaN through
    plan2 = FaultPlan((FaultSpec("fe1", "corrupt", at_call=0),), seed=0)
    y2, _, _ = _run_gemm(wrap_pool(_pool(), plan2),
                         retry=RetryPolicy(max_attempts=3))
    assert np.isnan(y2).any()


# ----------------------------------------------------- health integration

def test_repeated_faults_quarantine_engine():
    """Faults drive the health EMA toward zero, tripping the SAME
    quarantine machinery a thermal collapse would.  fe1 never completes
    a healthy panel, so its quarantine rides the zero-baseline path
    (min_samples straight faults); quarantine_below is kept low so noisy
    wall-clock rates can't also condemn the honest engines."""
    plan = FaultPlan((FaultSpec("fe1", "raise", at_call=0, count=10_000),),
                     seed=0)
    health = HealthPolicy(alpha=0.5, quarantine_below=0.2,
                          min_samples=3, probe_interval_s=1e9)
    a, b = _ab(512, 64, 48)
    with SynergyRuntime(wrap_pool(_pool(), plan), name="sick",
                        retry=RetryPolicy(max_attempts=4),
                        health=health) as rt:
        for i in range(6):
            rt.submit_gemm(
                a, b, jobset=JobSet.for_gemm(i, 512, 64, 48, 32,
                                             name=f"g{i}"),
                tile=(32, 32, 32)).result(60)
        stats = rt.stats()
    assert stats["engines"]["fe1"]["faults"] >= 3
    assert stats["quarantines"] >= 1
    assert stats["engines"]["fe1"]["quarantined"]


# ------------------------------------------------------- drain-error fix

def test_drained_jobsets_get_distinct_exception_instances():
    """Regression: _drain_jobs_locked used to complete EVERY drained job
    with the SAME exception instance — concurrent waiters re-raising one
    object cross-contaminate tracebacks.  Each jobset must get its own
    copy, naming the jobset it drained."""
    slow = _MathEngine("slow", 5e8)
    orig = slow.execute

    def gated(a, b, **kw):
        time.sleep(0.3)
        return orig(a, b, **kw)
    slow.execute = gated
    a, b = _ab(64, 32, 32)
    caught = {}
    with SynergyRuntime([slow], name="drain") as rt:
        futs = [rt.submit_gemm(
            a, b, jobset=JobSet.for_gemm(i, 64, 32, 32, 32,
                                         name=f"js{i}"),
            tile=(32, 32, 32)) for i in range(2)]

        def waiter(i):
            try:
                futs[i].result(30)
            except BaseException as e:  # noqa: BLE001 - capturing for assert
                caught[i] = e
        threads = [threading.Thread(target=waiter, args=(i,))
                   for i in range(2)]
        for t in threads:
            t.start()
        time.sleep(0.05)               # waiters parked, panels queued
        with rt._cond:
            rt._drain_jobs_locked(lambda j: True,
                                  RuntimeError("upstream failed"))
        for t in threads:
            t.join(30)
    assert set(caught) == {0, 1}
    assert caught[0] is not caught[1]
    for i in (0, 1):
        assert f"js{i}" in str(caught[i])
        assert "upstream failed" in str(caught[i])


# ------------------------------------------------------ graph node retry

def test_graph_node_retries_before_cancel():
    """A failing graph node re-launches up to node_retries times BEFORE
    the failure cancels descendants."""
    attempts = {"n": 0}

    def flaky(rt):
        attempts["n"] += 1
        if attempts["n"] == 1:
            raise InjectedFault("first launch fails")
        return 41

    tracer = Tracer()
    with SynergyRuntime(_pool(2), name="gretry", tracer=tracer) as rt:
        gf = rt.submit_graph(
            [GraphNode(name="flaky", run=flaky),
             GraphNode(name="after", run=lambda rt, v: v + 1)],
            [(0, 1)], name="retrygraph", node_retries=1)
        vals = gf.result(60)
    assert vals == [41, 42]
    assert attempts["n"] == 2
    assert gf.retries >= 1
    assert "graph_node_retry" in {e.kind for e in tracer.events()}


def test_graph_node_retry_exhaustion_still_cancels():
    def doomed(rt):
        raise InjectedFault("always fails")

    with SynergyRuntime(_pool(2), name="gdoom") as rt:
        gf = rt.submit_graph(
            [GraphNode(name="doomed", run=doomed),
             GraphNode(name="after", run=lambda rt, v: v + 1)],
            [(0, 1)], name="doomgraph", node_retries=2)
        with pytest.raises(InjectedFault):
            gf.result(60)
    assert gf.retries >= 2


# ------------------------------------------------------------ observability

def test_fault_event_kinds_are_registered():
    assert {"fault_injected", "panel_retry", "worker_death",
            "orphan_reseed", "graph_node_retry"} <= EVENT_KINDS


def test_metrics_export_fault_counters():
    from repro.obs.metrics import MetricsRegistry, collect_runtime
    plan = FaultPlan((FaultSpec("fe1", "raise", at_call=0),), seed=0)
    with SynergyRuntime(wrap_pool(_pool(), plan), name="metrics",
                        retry=RetryPolicy(max_attempts=3)) as rt:
        a, b = _ab(128, 32, 32)
        rt.submit_gemm(
            a, b, jobset=JobSet.for_gemm(0, 128, 32, 32, 32, name="m0"),
            tile=(32, 32, 32)).result(60)
        reg = MetricsRegistry()
        collect_runtime(rt, reg)
    assert reg.counter("repro_runtime_retries_total").value == 1
    assert reg.counter("repro_runtime_worker_deaths_total").value == 0
    assert reg.counter("repro_runtime_orphan_reseeds_total").value == 0
    assert "repro_runtime_retries_total" in reg.render()


def test_stats_reset_zeroes_fault_counters():
    plan = FaultPlan((FaultSpec("fe0", "raise", at_call=0),), seed=0)
    with SynergyRuntime(wrap_pool(_pool(2), plan), name="rst",
                        retry=RetryPolicy(max_attempts=3)) as rt:
        a, b = _ab(64, 32, 32)
        rt.submit_gemm(
            a, b, jobset=JobSet.for_gemm(0, 64, 32, 32, 32, name="r0"),
            tile=(32, 32, 32)).result(60)
        assert rt.stats()["retries"] == 1
        rt.reset_stats()
        st = rt.stats()
    assert st["retries"] == 0 and st["worker_deaths"] == 0
    assert st["orphan_reseeds"] == 0


# --------------------------------------------------------- sim conformance

def test_sim_fault_trace_conforms_to_live_schema():
    """SimRuntime.run_faults emits the SAME event kinds and tag keys the
    live runtime emits for an equivalent plan, with exactly-once virtual
    accounting."""
    js = JobSet.for_gemm(0, 320, 128, 96, 32, name="conv0")
    plan_live = FaultPlan((FaultSpec("fe1", "raise", at_call=0, count=2),),
                          seed=5)
    live_tr = Tracer()
    _run_gemm(wrap_pool(_pool(), plan_live, tracer=live_tr),
              retry=RetryPolicy(max_attempts=3), tracer=live_tr)
    plan_sim = FaultPlan((FaultSpec("S-PE", "raise", at_call=0, count=2),),
                         seed=5)
    sim_tr = Tracer()
    res = SimRuntime(["F-PE", "S-PE"], tracer=sim_tr).run_faults(
        js, plan_sim, RetryPolicy(max_attempts=3), affinity="F-PE")
    assert res.completed_jobs == js.num_jobs       # exactly-once
    assert res.retries == 2 and res.exhausted == 0
    validate_events(sim_tr.events())

    def tag_keys(events, kind):
        return {frozenset(e.tags) for e in events if e.kind == kind}
    for kind in ("fault_injected", "panel_retry"):
        live_keys = tag_keys(live_tr.events(), kind)
        sim_keys = tag_keys(sim_tr.events(), kind)
        assert live_keys and sim_keys
        assert live_keys == sim_keys, kind


def test_sim_worker_death_reseeds_in_virtual_time():
    js = JobSet.for_gemm(0, 320, 128, 96, 32, name="conv0")
    plan = FaultPlan((FaultSpec("S-PE", "die", at_call=1),), seed=0)
    res = SimRuntime(["F-PE", "S-PE"]).run_faults(
        js, plan, RetryPolicy(), affinity="F-PE")
    assert res.completed_jobs == js.num_jobs
    assert res.worker_deaths == 1 and res.orphan_reseeds >= 1
    # determinism: same plan, same virtual outcome
    plan2 = FaultPlan((FaultSpec("S-PE", "die", at_call=1),), seed=0)
    res2 = SimRuntime(["F-PE", "S-PE"]).run_faults(
        js, plan2, RetryPolicy(), affinity="F-PE")
    assert res2.makespan_s == res.makespan_s
    assert res2.per_engine_jobs == res.per_engine_jobs


def test_sim_rejects_wall_clock_kinds():
    js = JobSet.for_gemm(0, 64, 64, 32, 32)
    for kind in ("stall", "drop"):
        plan = FaultPlan((FaultSpec("F-PE", kind),), seed=0)
        with pytest.raises(ValueError, match="wall-clock"):
            SimRuntime(["F-PE"]).run_faults(js, plan, RetryPolicy())


# -------------------------------------------------- serving survives faults

def test_serving_wave_survives_engine_crash():
    """A serving wave with a worker killed mid-prefill completes every
    request with token streams BITWISE identical to the fault-free run,
    and the retries surface in ServeStats.runtime_retries."""
    from repro.configs import ARCHS, reduced
    from repro.core.serving import Request, SynergyServer
    from repro.models import init_model
    cfg = reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=32,
                  n_heads=2, d_ff=64, vocab=128)
    params = init_model(cfg, jax.random.key(0))

    def requests():
        return [Request(i, jax.random.randint(jax.random.key(i),
                                              (4,), 0, 128),
                        max_new_tokens=4) for i in range(3)]

    def serve(engines, retry=None):
        with SynergyRuntime(engines, name="srv", retry=retry) as rt:
            srv = SynergyServer(cfg, params, slots=2, max_len=32,
                                prefill_len=4, runtime=rt)
            reqs = requests()
            for r in reqs:
                srv.submit(r)
            stats = srv.run()
        return [list(r.out) for r in reqs], stats

    clean_tokens, clean_stats = serve(_pool())
    assert clean_stats.runtime_retries == 0
    plan = FaultPlan((FaultSpec("fe1", "die", at_call=0),
                      FaultSpec("fe0", "raise", at_call=0, count=2)),
                     seed=11)
    retry = RetryPolicy(max_attempts=4, heartbeat_timeout_s=0.1,
                        monitor_interval_s=0.02)
    fault_tokens, fault_stats = serve(wrap_pool(_pool(), plan), retry)
    assert fault_tokens == clean_tokens     # bitwise token streams
    assert fault_stats.runtime_retries >= 1
    assert len(plan.injected) >= 2


# ------------------------------------------------------- chaos acceptance

def test_chaos_acceptance_crash_plus_exceptions_bitwise():
    """The ISSUE 9 acceptance scenario: a 3-engine pool with a worker
    crash mid-submission plus two injected panel exceptions completes
    every submission with results bitwise-identical to the fault-free
    run, the trace shows the retries and orphan re-seeds, and no
    RuntimeFuture hangs."""
    a, b = _ab(384, 64, 48, seed=7)
    jobsets = [JobSet.for_gemm(i, 384, 64, 48, 32, name=f"chaos{i}")
               for i in range(4)]

    def run(engines, retry=None, tracer=None):
        outs = []
        with SynergyRuntime(engines, name="chaos", retry=retry,
                            tracer=tracer) as rt:
            futs = [rt.submit_gemm(a, b, jobset=js, tile=(32, 32, 32),
                                   affinity="fe0") for js in jobsets]
            for f in futs:
                outs.append(np.asarray(f.result(60)))
            stats = rt.stats()
        return outs, stats, futs

    ref, _, _ = run(_pool())
    plan = FaultPlan((FaultSpec("fe2", "die", at_call=1),
                      FaultSpec("fe1", "raise", at_call=0, count=2)),
                     seed=23)
    tracer = Tracer()
    retry = RetryPolicy(max_attempts=4, heartbeat_timeout_s=0.1,
                        monitor_interval_s=0.02)
    outs, stats, futs = run(wrap_pool(_pool(), plan, tracer=tracer),
                            retry, tracer)
    for y, r in zip(outs, ref):
        assert np.array_equal(y, r)
    assert stats["worker_deaths"] == 1
    assert stats["retries"] >= 2
    assert stats["orphan_reseeds"] >= 1
    for f in futs:
        assert f.done()
        assert f.execution_counts == [1] * len(f.execution_counts)
    kinds = {e.kind for e in tracer.events()}
    assert {"fault_injected", "panel_retry", "worker_death",
            "orphan_reseed"} <= kinds
    validate_events(tracer.events())


def test_fault_free_pool_has_no_monitor_thread():
    """retry=None keeps the hot path untouched: no monitor thread, no
    live-panel registry entries."""
    with SynergyRuntime(_pool(2), name="clean") as rt:
        a, b = _ab(64, 32, 32)
        rt.submit_gemm(
            a, b, jobset=JobSet.for_gemm(0, 64, 32, 32, 32, name="c0"),
            tile=(32, 32, 32)).result(60)
        assert rt._monitor is None
        assert not rt._live_panels
        st = rt.stats()
    assert st["retries"] == 0 and st["worker_deaths"] == 0
