"""SSD (Mamba2) kernel: Pallas + chunked-XLA vs direct-recurrence oracle,
plus single-step decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev deps
from hypothesis import given, settings, strategies as st

from repro.kernels.ssd import ssd, ssd_ref
from repro.kernels.ssd.ops import _prescale, ssd_chunked_xla


def _inputs(b, l, h, p, n, seed=0):
    ks = jax.random.split(jax.random.key(seed), 5)
    x = jax.random.normal(ks[0], (b, l, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)) - 1.0)
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = jax.random.normal(ks[3], (b, l, n)) * 0.3
    cm = jax.random.normal(ks[4], (b, l, n)) * 0.3
    return x, dt, a, bm, cm


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_pallas_matches_scan(chunk):
    x, dt, a, bm, cm = _inputs(2, 128, 3, 16, 8)
    y, s = ssd(x, dt, a, bm, cm, chunk=chunk, impl="pallas")
    xdt, dta = _prescale(x, dt, a)
    y_ref, s_ref = ssd_ref(xdt, dta, bm, cm)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(jnp.swapaxes(y_ref, 1, 2)),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=2e-5, atol=2e-5)


def test_xla_chunked_matches_scan():
    x, dt, a, bm, cm = _inputs(1, 96, 2, 8, 4, seed=1)
    xdt, dta = _prescale(x, dt, a)
    y, s = ssd_chunked_xla(xdt, dta, bm, cm, chunk=32)
    y_ref, s_ref = ssd_ref(xdt, dta, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(b=st.integers(1, 2), h=st.integers(1, 3),
       p=st.sampled_from([8, 16]), n=st.sampled_from([4, 8]),
       nc=st.integers(1, 4))
def test_property_chunk_invariance(b, h, p, n, nc):
    """Chunked evaluation must be exactly chunk-size invariant."""
    l = 32 * nc
    x, dt, a, bm, cm = _inputs(b, l, h, p, n, seed=b * 7 + nc)
    y1, s1 = ssd(x, dt, a, bm, cm, chunk=32, impl="xla")
    y2, s2 = ssd(x, dt, a, bm, cm, chunk=16, impl="xla")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=3e-5, atol=3e-5)


def test_gradients_finite():
    x, dt, a, bm, cm = _inputs(1, 64, 2, 8, 4, seed=2)

    def loss(x):
        y, _ = ssd(x, dt, a, bm, cm, chunk=16, impl="xla")
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(x)
    assert bool(jnp.isfinite(g).all())
