"""MoE dispatch mechanics: EC gather/scatter vs explicit loop; TC oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import ec_capacity, init_moe, moe_ffn, moe_ffn_tc


def _setup(g=2, t=16, d=8, e=4, seed=0):
    params = init_moe(jax.random.key(seed), d, 16, e)
    x = jax.random.normal(jax.random.key(seed + 1), (g, t, d))
    return params, x


def _moe_ec_loop(params, x, top_k, capacity_factor, act="silu"):
    """Explicit per-expert loop implementing the same EC semantics."""
    g, t, d = x.shape
    e = params["router"].shape[1]
    c = ec_capacity(t, e, top_k, capacity_factor)
    out = np.zeros((g, t, d), np.float32)
    for gi in range(g):
        logits = np.asarray(x[gi] @ params["router"])
        probs = np.asarray(jax.nn.softmax(jnp.asarray(logits), axis=-1))
        for ei in range(e):
            order = np.argsort(-probs[:, ei], kind="stable")[:c]
            xe = np.asarray(x[gi])[order]                      # (C, d)
            h = xe @ np.asarray(params["w1"][ei])
            gate, up = np.split(h, 2, axis=-1)
            h = np.asarray(jax.nn.silu(jnp.asarray(gate))) * up
            o = h @ np.asarray(params["w2"][ei])
            for ci, ti in enumerate(order):
                out[gi, ti] += o[ci] * probs[ti, ei]
    return out


def test_ec_matches_loop():
    params, x = _setup()
    y = moe_ffn(params, x, top_k=2, capacity_factor=1.0)
    ref = _moe_ec_loop(params, x, 2, 1.0)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-4, atol=1e-4)


def test_tc_oracle_weights_normalized():
    params, x = _setup(seed=3)
    y = moe_ffn_tc(params, x, top_k=2)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())


def test_capacity_bounds():
    assert ec_capacity(1, 384, 8, 1.25) == 1
    assert ec_capacity(4096, 384, 8, 1.25) >= 4096 * 8 // 384
    assert ec_capacity(10, 4, 2, 1.0) <= 10


def test_ec_grad_finite():
    params, x = _setup(seed=5)
    loss = lambda p: jnp.sum(moe_ffn(p, x, top_k=2) ** 2)
    g = jax.grad(loss)(params)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.isfinite(leaf).all())


def test_decode_single_group():
    """Decode path: one group over the batch (T == B tokens)."""
    params, _ = _setup()
    xb = jax.random.normal(jax.random.key(9), (1, 8, 8))   # (1, B, d)
    y = moe_ffn(params, xb, top_k=2, capacity_factor=1.25)
    assert y.shape == xb.shape
    assert bool(jnp.isfinite(y).all())
