"""vpu_mm: the VPU-only (MXU-free) Pallas kernel and its engine.

Covers numeric agreement with the oracle (interpret mode off-TPU,
border shapes and epilogue included), the structural no-MXU guarantee
(no ``dot_general`` anywhere in the lowered kernel), hypothesis property
coverage over random shapes, and the NeonVpuEngine's registry contract
(capabilities + a rate that keeps auto-dispatch away from it off-TPU).
"""

import jax
import numpy as np
import pytest

from repro.engines import (CAP_GEMM, CAP_VPU, Dispatcher, NeonVpuEngine,
                           get_engine, list_engines)
from repro.core.job import JobSet
from repro.kernels.vpu_mm import vpu_matmul, vpu_mm_ref


def _ab(m, k, n, seed=0):
    ka, kb = jax.random.split(jax.random.key(seed))
    return (jax.random.normal(ka, (m, k)), jax.random.normal(kb, (k, n)))


@pytest.mark.parametrize("shape", [(32, 32, 32),     # tile-aligned
                                   (33, 40, 45),     # borders everywhere
                                   (1, 129, 17)])    # decode-like row
def test_vpu_matmul_matches_oracle(shape):
    m, k, n = shape
    a, b = _ab(m, k, n)
    bias = jax.random.normal(jax.random.key(2), (n,))
    y = vpu_matmul(a, b, bias=bias, activation=jax.nn.relu,
                   tile=(16, 16, 16), interpret=True)
    ref = vpu_mm_ref(a, b, bias=bias, activation=jax.nn.relu)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_vpu_kernel_never_uses_the_mxu():
    """The structural claim behind the NEON analogy: the kernel's jaxpr
    contains rank-1 broadcast FMAs, never a dot/dot_general (which is what
    Mosaic lowers to the MXU)."""
    a, b = _ab(16, 16, 16)
    jaxpr = jax.make_jaxpr(
        lambda a, b: vpu_matmul(a, b, tile=(8, 8, 8), interpret=True))(a, b)
    flat = str(jaxpr)
    assert "dot_general" not in flat and "dot(" not in flat
    # sanity: the same check DOES trip on the MXU kernel
    from repro.kernels.tiled_mm import tiled_matmul
    mxu = str(jax.make_jaxpr(
        lambda a, b: tiled_matmul(a, b, tile=(8, 8, 8), interpret=True))(a, b))
    assert "dot_general" in mxu


def test_neon_vpu_engine_registered_with_vpu_capability():
    names = {e.name for e in list_engines()}
    assert "neon-vpu" in names
    eng = get_engine("neon-vpu")
    assert eng.supports({CAP_GEMM, CAP_VPU})
    a, b = _ab(20, 24, 18, seed=3)
    y = eng.execute(a, b, tile=(16, 16, 16))
    np.testing.assert_allclose(np.asarray(y), np.asarray(vpu_mm_ref(a, b)),
                               rtol=1e-4, atol=1e-4)


def test_vpu_engine_is_the_slow_pool_member():
    """Off-TPU the interpreter rate keeps auto-dispatch away (the NEON
    role: joins pools explicitly, never wins a solo GEMM)."""
    js = JobSet.for_gemm(0, 64, 64, 64, 32)
    assert Dispatcher().select(js).name != "neon-vpu"
    assert Dispatcher().select(js, engine="neon-vpu").name == "neon-vpu"
    # a custom-cost instance (benchmark pools) honors the injected model
    paperish = NeonVpuEngine("vpu-x", cost=get_engine("F-PE").cost.scaled(0.42))
    assert paperish.cost.macs_per_s == pytest.approx(
        0.42 * get_engine("F-PE").cost.macs_per_s)
