"""repro.quant: the int8 quantized-engine subsystem.

Covers the numeric scheme (per-channel symmetric roundtrip bound), the
QuantizedEngine wrapper (oracle agreement, capability surgery, weight
cache), calibration gating (refusal past tolerance), the dispatcher's
precision-routing policy (decode prefers int8, auto/plain dispatch never
silently quantizes, grad tracing never lands on a CAP_GRAD-free engine),
deterministic split/merge over mixed-precision runtime pools, steal-aware
cost recalibration, and serving's per-precision job accounting.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.job import JobSet
from repro.core.synergy_mm import SynergyTrace, synergy_matmul
from repro.engines import (CAP_GEMM, CAP_GRAD, CAP_INT8, CostModel,
                           Dispatcher, Engine, get_engine, registered)
from repro.engines.sim import SIM_ENGINE_SPECS, SimPEEngine
from repro.quant import (CalibrationError, QuantizedEngine, calibrate,
                         dequantize_weights, quant_gemm, quantize_weights,
                         register_quantized)
from repro.soc import SynergyRuntime


def _ab(m, k, n, seed=0, wscale=0.05):
    ka, kb = jax.random.split(jax.random.key(seed))
    return (jax.random.normal(ka, (m, k)),
            jax.random.normal(kb, (k, n)) * wscale)


# --------------------------------------------------------------- numerics

def test_quantize_roundtrip_error_bound():
    w = jax.random.normal(jax.random.key(0), (96, 40)) * 0.2
    qw = quantize_weights(w)
    assert qw.q.dtype == jnp.int8
    assert qw.scale.shape == (1, 40)
    assert float(jnp.max(jnp.abs(qw.zero_point))) == 0.0   # symmetric
    deq = dequantize_weights(qw)
    # per-channel bound: |err| <= that channel's scale / 2
    err = jnp.abs(deq - w)
    assert bool(jnp.all(err <= qw.scale / 2 + 1e-7))
    assert float(jnp.max(err)) <= qw.error_bound + 1e-7


def test_quant_gemm_close_to_fp32():
    a, w = _ab(16, 64, 24, seed=1)
    qw = quantize_weights(w)
    y = quant_gemm(a, qw, bias=jnp.ones((24,)), activation=jax.nn.relu)
    ref = get_engine("reference").execute(a, w, bias=jnp.ones((24,)),
                                          activation=jax.nn.relu)
    rel = float(jnp.max(jnp.abs(y - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 0.05, rel


# ---------------------------------------------------------------- engine

def test_quantized_engine_wraps_and_strips_grad():
    base = get_engine("xla")
    q = QuantizedEngine(base)
    assert q.name == "xla-int8"
    assert CAP_INT8 in q.capabilities
    assert CAP_GRAD not in q.capabilities
    assert q.cost.macs_per_s == pytest.approx(
        base.cost.macs_per_s * q.speedup)
    a, w = _ab(33, 70, 45, seed=2)        # border shapes
    bias = jax.random.normal(jax.random.key(5), (45,))
    y = q.execute(a, w, bias=bias, activation=jax.nn.relu, tile=(32, 32, 32))
    ref = get_engine("reference").execute(a, w, bias=bias,
                                          activation=jax.nn.relu)
    rel = float(jnp.max(jnp.abs(y - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 0.05, rel


@pytest.mark.parametrize("base_name", ["pallas", "neon-vpu"])
def test_quantized_engine_over_tiled_bases(base_name):
    """Regression: the dequant epilogue must live OUTSIDE the base engine
    — folding the full-width (n,) scale into a tiled base's per-block
    activation hook crashes whenever n > ts_n."""
    q = QuantizedEngine(get_engine(base_name), name=f"{base_name}-q")
    a, w = _ab(8, 64, 48, seed=12)        # n=48 > ts_n=16
    bias = jax.random.normal(jax.random.key(13), (48,))
    y = q.execute(a, w, bias=bias, activation=jax.nn.relu, tile=(16, 16, 16))
    ref = get_engine("reference").execute(a, w, bias=bias,
                                          activation=jax.nn.relu)
    rel = float(jnp.max(jnp.abs(y - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 0.05, rel


def test_quantized_engine_caches_weights_by_identity():
    q = QuantizedEngine(get_engine("xla"))
    _, w = _ab(8, 32, 16, seed=3)
    qw1 = q.quantized(w)
    qw2 = q.quantized(w)
    assert qw1 is qw2                     # identity hit, no requantization
    _, w2 = _ab(8, 32, 16, seed=4)
    assert q.quantized(w2) is not qw1


# ----------------------------------------------------------- calibration

def test_calibrate_attaches_report():
    q = QuantizedEngine(get_engine("xla"))
    report = calibrate(q, tol=0.05)
    assert q.calibration is report
    assert report.passed and report.max_rel_err < 0.05
    assert len(report.rows) >= 4 and "PASS" in str(report)


def test_register_quantized_refuses_past_tolerance():
    from repro.engines import find_engine
    with pytest.raises(CalibrationError):
        register_quantized("xla", name="never-lands", tol=1e-9)
    assert find_engine("never-lands") is None   # refusal = no registration


def test_register_quantized_registers_and_unregisters():
    from repro.engines import find_engine, unregister_engine
    eng = register_quantized("xla", name="tmp-int8", tol=0.05)
    try:
        assert find_engine("tmp-int8") is eng
        assert eng.calibration is not None and eng.calibration.passed
    finally:
        unregister_engine("tmp-int8")


# ------------------------------------------------------ dispatch routing

def test_auto_dispatch_never_silently_quantizes():
    """A registered int8 engine must not win PLAIN auto-dispatch on cost
    alone — precision loss is opt-in via job class or explicit pin."""
    js = JobSet.for_gemm(0, 64, 64, 64, 32)
    q = QuantizedEngine(get_engine("xla"), name="fast-int8")
    with registered(q):
        assert Dispatcher().select(js).name != "fast-int8"
        assert Dispatcher().select(js, job_class="decode").name == "fast-int8"
        assert Dispatcher().select(js, engine="fast-int8") is q
        # prefill/train require grad-safety: int8 is structurally out
        assert CAP_GRAD not in Dispatcher().select(
            js, job_class="decode").capabilities
        for cls in ("prefill", "train"):
            assert CAP_GRAD in Dispatcher().select(
                js, job_class=cls).capabilities


def test_decode_class_falls_back_without_int8_engines():
    js = JobSet.for_gemm(0, 64, 64, 64, 32)
    eng = Dispatcher().select(js, job_class="decode")
    assert CAP_INT8 not in eng.capabilities   # graceful: best fp32 engine


# ------------------------------------------------------------ grad guard

class _GradFreeMock(Engine):
    """Implausibly fast CAP_GRAD-free engine: without the trace guard,
    auto-dispatch would route differentiated GEMMs here."""

    def __init__(self, name="gradfree-mock"):
        super().__init__(name, {CAP_GEMM, "epilogue"},
                         cost=CostModel(macs_per_s=1e18))
        self.calls = 0

    def execute(self, a, b, *, bias=None, activation=None, tile=None,
                out_dtype=None, precision=None):
        self.calls += 1
        return jnp.zeros((a.shape[0], b.shape[1]), a.dtype)  # poisoned


def test_grad_trace_never_selects_grad_free_engine():
    """Regression (ISSUE 3 satellite): under jax.grad the dispatcher must
    require CAP_GRAD even though the grad-free mock ranks cheapest."""
    a, w = _ab(8, 16, 12, seed=6, wscale=1.0)
    mock = _GradFreeMock()
    with registered(mock):
        tr = SynergyTrace()
        with tr.activate():
            g = jax.grad(
                lambda b: jnp.sum(synergy_matmul(a, b, tile=8)))(w)
        assert "gradfree-mock" not in tr.engine_stats
        assert mock.calls == 0
        assert bool(jnp.any(g != 0))          # real gradient, not poisoned
        # outside grad the mock IS the auto pick (the guard is the only
        # thing standing between it and differentiated GEMMs)
        tr2 = SynergyTrace()
        with tr2.activate():
            synergy_matmul(a, w, tile=8)
        assert set(tr2.engine_stats) == {"gradfree-mock"}


def test_grad_of_vmap_never_selects_grad_free_engine():
    """Regression: vmap's BatchTracer wraps the JVP tracer in ``.val`` —
    the guard must see through it, or per-example gradients land on
    grad-free engines."""
    a, w = _ab(4, 8, 6, seed=15, wscale=1.0)
    mock = _GradFreeMock(name="gradfree-vmap")
    with registered(mock):
        def loss(a):
            return jnp.sum(jax.vmap(
                lambda row: synergy_matmul(row[None, :], w, tile=8))(a))
        g = jax.grad(loss)(a)
        assert mock.calls == 0
        assert bool(jnp.any(g != 0))


def test_grad_trace_rejects_explicit_int8_pin():
    a, w = _ab(8, 16, 12, seed=7)
    q = QuantizedEngine(get_engine("xla"), name="pin-int8")
    with registered(q):
        with pytest.raises(ValueError, match="grad"):
            jax.grad(lambda b: jnp.sum(
                synergy_matmul(a, b, tile=8, engine="pin-int8")))(w)


# ------------------------------------------- mixed-precision runtime pool

def _mixed_pool(seed=0):
    fp32 = SimPEEngine(f"mp-fp32-{seed}", SIM_ENGINE_SPECS["F-PE"])
    int8 = QuantizedEngine(fp32, name=f"mp-int8-{seed}")
    return fp32, int8


def test_mixed_pool_split_is_deterministic():
    """Real-array splits over a mixed fp32+int8 pool pin panels to the
    deterministic LPT seed (stealing across precision classes would make
    the merged numerics a function of thread timing)."""
    fp32, int8 = _mixed_pool()
    a, w = _ab(20 * 16, 40, 24, seed=8)
    js = JobSet.for_gemm(0, a.shape[0], 24, 40, 16)
    outs = []
    for trial in range(3):
        with SynergyRuntime([fp32, int8], name=f"det{trial}") as rt:
            y = rt.submit_gemm(a, w, jobset=js, tile=(16, 16, 16),
                               job_class="decode").result(60)
            outs.append(np.asarray(y))
    assert all(np.array_equal(outs[0], o) for o in outs[1:])
    # merged result stays within the int8 tolerance of the fp32 oracle
    ref = np.asarray(jnp.dot(a, w))
    rel = float(np.max(np.abs(outs[0] - ref)) / (np.max(np.abs(ref)) + 1e-9))
    assert rel < 0.05, rel


def test_mixed_pool_split_is_precision_opt_in():
    """Regression: a GEMM that did NOT opt into int8 (no job class) must
    come out of a mixed-pool split at FULL precision — panels seed only
    onto fp32 workers, mirroring the dispatcher's auto-dispatch
    exclusion.  A decode-class split may use the whole pool."""
    fp32, int8 = _mixed_pool(seed=3)
    a, w = _ab(10 * 16, 40, 24, seed=14)
    js = JobSet.for_gemm(0, a.shape[0], 24, 40, 16)
    ref = fp32.execute(a, w)
    with SynergyRuntime([fp32, int8], name="optin") as rt:
        y_plain = rt.submit_gemm(a, w, jobset=js,
                                 tile=(16, 16, 16)).result(60)
        fut = rt.submit_gemm(a, w, jobset=js, tile=(16, 16, 16),
                             job_class="decode")
        fut.result(60)
    # no job class: full precision, no panel quantized
    np.testing.assert_allclose(np.asarray(y_plain), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
    # decode class: the int8 engine took real panels
    assert int8.name in fut.accounting


def test_mixed_pool_merges_partials_in_fp32():
    """Dequant-aware accumulation: bf16-requested outputs round ONCE from
    fp32-merged partials, not per panel per engine."""
    fp32, int8 = _mixed_pool(seed=1)
    a, w = _ab(8 * 16, 32, 16, seed=9)
    a16 = a.astype(jnp.bfloat16)
    js = JobSet.for_gemm(0, a16.shape[0], 16, 32, 16)
    with SynergyRuntime([fp32, int8], name="bf16") as rt:
        y = rt.submit_gemm(a16, w.astype(jnp.bfloat16), jobset=js,
                           tile=(16, 16, 16)).result(60)
    assert y.dtype == jnp.bfloat16
    ref = jnp.dot(a.astype(jnp.float32), w)
    rel = float(jnp.max(jnp.abs(y.astype(jnp.float32) - ref))
                / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 0.1, rel


def test_accounting_jobs_still_steal_across_mixed_pool():
    """Serving proxies carry no numerics — mixed pools keep them STEALABLE
    (that is where the heterogeneous throughput comes from); only
    real-array panels are precision-pinned."""
    fp32, int8 = _mixed_pool(seed=2)
    js = JobSet.for_gemm(0, 640, 128, 64, 32, name="acct-proxy")
    a, w = _ab(2 * 16, 32, 16, seed=11)
    js_real = JobSet.for_gemm(0, a.shape[0], 16, 32, 16, name="real-split")
    with SynergyRuntime([fp32, int8], name="acct") as rt:
        assert rt._mixed_precision_pool()
        seen = {}
        orig = rt._seed_locked

        # every submission path (submit/submit_many/submit_gemm/graphs)
        # funnels through _seed_locked: record the per-job stealable flag
        def spy(jobs, affinity):
            for j in jobs:
                seen.setdefault(j.sub.future.jobset.name, j.stealable)
            return orig(jobs, affinity)

        rt._seed_locked = spy
        fut = rt.submit(js, affinity=fp32.name)
        fut.result(30)
        assert sum(x["jobs"] for x in fut.accounting.values()) == js.num_jobs
        rt.submit_gemm(a, w, jobset=js_real, tile=(16, 16, 16)).result(30)
    assert seen[js.name] is True          # accounting: free to steal
    assert seen[js_real.name] is False    # real arrays: precision-pinned


class _SlowFp32(Engine):
    """Deterministic slow fp32 engine: keeps its queue populated long
    enough for mid-run pool changes to act on queued panels."""

    def __init__(self, name, delay_s=0.01):
        super().__init__(name, {CAP_GEMM, "epilogue"},
                         cost=CostModel(macs_per_s=1e9))
        self._delay_s = delay_s

    def execute(self, a, b, *, bias=None, activation=None, tile=None,
                out_dtype=None, precision=None):
        import time
        time.sleep(self._delay_s)
        return jnp.dot(a.astype(jnp.float32),
                       b.astype(jnp.float32)).astype(out_dtype or a.dtype)


def test_int8_hotplug_never_quantizes_inflight_fp32_panels():
    """Regression: adding an int8 engine mid-run must not rebalance or
    steal queued panels of a GEMM that never opted into int8 — the
    opt-in travels ON the job, not just in the seed-time pool check."""
    slow = _SlowFp32("hp-fp32")
    fast_int8 = QuantizedEngine(get_engine("xla"), name="hp-int8")
    a, w = _ab(24 * 16, 32, 16, seed=16)
    js = JobSet.for_gemm(0, a.shape[0], 16, 32, 16)
    with SynergyRuntime([slow]) as rt:
        fut = rt.submit_gemm(a, w, jobset=js, tile=(16, 16, 16))
        rt.add_engine(fast_int8)          # rebalance while panels queued
        y = fut.result(120)
        assert "hp-int8" not in fut.accounting   # int8 never touched them
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.dot(a, w)),
                               rtol=1e-6, atol=1e-6)


def test_unknown_job_class_raises():
    """A typo'd job class must fail loudly, not silently drop routing."""
    js = JobSet.for_gemm(0, 64, 64, 64, 32)
    with pytest.raises(KeyError, match="unknown job class"):
        Dispatcher().select(js, job_class="training")   # 'train' exists
    fp32, int8 = _mixed_pool(seed=4)
    a, w = _ab(2 * 16, 32, 16, seed=17)
    js2 = JobSet.for_gemm(0, a.shape[0], 16, 32, 16)
    with SynergyRuntime([fp32, int8], name="typo") as rt:
        with pytest.raises(KeyError, match="unknown job class"):
            rt.submit_gemm(a, w, jobset=js2, tile=(16, 16, 16),
                           job_class="Decode")


# -------------------------------------------------------- recalibration

class _MiscalibratedEngine(Engine):
    """Claims ``claimed`` MAC/s; actually delivers ``actual`` (simulated
    by a deterministic per-job sleep)."""

    def __init__(self, name, claimed, actual):
        super().__init__(name, {CAP_GEMM, "epilogue"},
                         cost=CostModel(macs_per_s=claimed))
        self.actual = actual

    def execute(self, a, b, *, bias=None, activation=None, tile=None,
                out_dtype=None, precision=None):
        import time
        macs = a.shape[0] * a.shape[1] * b.shape[1]
        time.sleep(macs / self.actual)
        return jnp.dot(a, b).astype(out_dtype or a.dtype)


def test_recalibrate_converges_toward_measured_rate():
    """ISSUE 3 satellite: an engine mis-calibrated 100x fast converges
    toward its measured rate under the EMA (each window halves the error
    at alpha=0.5), so LPT seeding stops over-seeding it."""
    true_rate = 2e8
    eng = _MiscalibratedEngine("liar", claimed=100 * true_rate,
                               actual=true_rate)
    a, w = _ab(12 * 16, 32, 16, seed=10)
    js = JobSet.for_gemm(0, a.shape[0], 16, 32, 16)
    errors = [eng.cost.macs_per_s / true_rate]
    with SynergyRuntime([eng], name="recal") as rt:
        for _ in range(6):
            rt.submit_gemm(a, w, jobset=js, tile=(16, 16, 16)).result(60)
            updated = rt.recalibrate(alpha=0.5)
            assert "liar" in updated
            errors.append(eng.cost.macs_per_s / true_rate)
    # strictly decreasing over-estimate (alpha=0.5 halves the error each
    # window), within 4x of the measured rate after six windows
    assert all(e2 < e1 for e1, e2 in zip(errors, errors[1:]))
    assert errors[-1] < 4.0, errors
    # the consumed window yields nothing until new work arrives
    with SynergyRuntime([eng], name="recal2") as rt:
        assert rt.recalibrate() == {}


def test_recalibrate_never_touches_sim_engines():
    """CAP_SIM cost models are the paper's calibrated constants; a
    measured host-oracle rate must never overwrite them."""
    fpe = get_engine("F-PE")
    before = fpe.cost.macs_per_s
    a, w = _ab(4 * 16, 32, 16, seed=18)
    js = JobSet.for_gemm(0, a.shape[0], 16, 32, 16)
    with SynergyRuntime(["F-PE"], name="simcal") as rt:
        rt.submit_gemm(a, w, jobset=js, tile=(16, 16, 16)).result(60)
        assert rt.recalibrate() == {}
    assert fpe.cost.macs_per_s == before


# --------------------------------------------------------------- serving

def test_server_reports_per_precision_jobs():
    from repro.configs import ARCHS, reduced
    from repro.core.serving import Request, SynergyServer
    from repro.models import init_model
    cfg = reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=32,
                  n_heads=2, d_ff=64, vocab=128)
    params = init_model(cfg, jax.random.key(0))
    q = QuantizedEngine(get_engine("xla"), name="serve-int8")
    with registered(q):
        srv = SynergyServer(cfg, params, slots=2, max_len=32, prefill_len=4)
        for i in range(3):
            srv.submit(Request(i, jax.random.randint(jax.random.key(i),
                                                     (4,), 0, 128),
                               max_new_tokens=4))
        stats = srv.run()
    # decode routed to the int8 engine, prefill stayed grad-safe fp32
    assert stats.job_engine["decode"] == "serve-int8"
    assert stats.job_engine["prefill"] != "serve-int8"
    assert stats.precision_jobs["int8"] > 0
    assert stats.precision_jobs["fp32"] > 0
    # every decode-class tile job landed on the int8 engine
    assert stats.precision_jobs["int8"] == q.telemetry.jobs
