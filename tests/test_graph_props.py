"""Hypothesis property tests for dataflow-graph submissions (ISSUE 6
satellite 3).

For RANDOM dag topologies, node-kind mixes (real GEMM run nodes vs
accounting JobSet nodes), steal-timing seeds, and mixed fp32/int8 pools:

  * every node executes exactly once (run bodies counted, accounting
    jobs summed against ``num_jobs``);
  * the completion order respects every dependency edge (predecessors
    reap strictly before successors);
  * each GEMM node's value is bitwise equal to submitting the same GEMMs
    one-at-a-time in topological order (the single-submit reference) —
    graph overlap must never change numerics.

The seeded deterministic sweep in ``test_graph_runtime.py`` covers the
same invariants when the hypothesis dev-dependency is absent.
"""

import random
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev deps
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.job import JobSet                         # noqa: E402
from repro.engines import (CAP_GEMM, CostModel, Engine,   # noqa: E402
                           get_engine)
from repro.quant import QuantizedEngine                   # noqa: E402
from repro.soc import GraphNode, SynergyRuntime           # noqa: E402
from repro.soc.graph import validate_dag                  # noqa: E402


class _DelayEngine(Engine):
    """Deterministic-output engine with seeded random per-job delays."""

    def __init__(self, name, macs_per_s=1e9, seed=0, max_delay_s=0.002):
        super().__init__(name, {CAP_GEMM, "epilogue"},
                         cost=CostModel(macs_per_s=macs_per_s))
        self._rng = random.Random(seed)
        self._max_delay_s = max_delay_s

    def execute(self, a, b, *, bias=None, activation=None, tile=None,
                out_dtype=None, precision=None):
        time.sleep(self._rng.random() * self._max_delay_s)
        y = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
        if bias is not None:
            y = y + bias
        if activation is not None:
            y = activation(y)
        return y.astype(out_dtype or a.dtype)


@settings(max_examples=12, deadline=None)
@given(topo_seed=st.integers(0, 2**16), steal_seed=st.integers(0, 2**16),
       with_int8=st.booleans())
def test_random_dag_exactly_once_ordered_bitwise(topo_seed, steal_seed,
                                                 with_int8):
    rng = random.Random(topo_seed)
    n = rng.randint(2, 6)
    edges = [(u, v) for u in range(n) for v in range(u + 1, n)
             if rng.random() < 0.5]
    kinds = [rng.choice(["gemm", "acct"]) for _ in range(n)]
    _, preds = validate_dag(n, edges)

    d = 32
    base = [jax.random.normal(jax.random.key(1000 + i), (48, d))
            for i in range(n)]
    w = jax.random.normal(jax.random.key(5), (d, d))
    ran: list[int] = []

    def make_node(i):
        if kinds[i] == "acct":
            return GraphNode(name=f"acct{i}",
                             jobset=JobSet.for_gemm(i, 96, 64, 32, 32,
                                                    name=f"acct{i}"))

        def run(rt, *pvals, _i=i):
            ran.append(_i)
            x = base[_i]
            for pv in pvals:
                if pv is not None:   # accounting predecessors: no value
                    x = x + pv
            return rt.submit_gemm(x, w, jobset=JobSet.for_gemm(
                _i, 48, d, d, 16, name=f"gemm{_i}"), tile=(16, 16, 16))
        return GraphNode(name=f"gemm{i}", run=run)

    pool = [_DelayEngine("dly-a", seed=steal_seed),
            _DelayEngine("dly-b", seed=steal_seed + 1)]
    if with_int8:
        pool.append(QuantizedEngine(get_engine("xla"),
                                    name=f"int8-{topo_seed % 97}"))
    with SynergyRuntime(pool, name="prop") as rt:
        gf = rt.submit_graph([make_node(i) for i in range(n)], edges,
                             name="prop")
        vals = gf.result(120)
        # single-submit reference on the SAME runtime, topological order,
        # identical pred-value accumulation order (edge order)
        ref: list = [None] * n
        for i in range(n):
            if kinds[i] == "acct":
                continue
            x = base[i]
            for p in preds[i]:
                if ref[p] is not None:
                    x = x + ref[p]
            ref[i] = rt.submit_gemm(x, w, jobset=JobSet.for_gemm(
                i, 48, d, d, 16, name=f"ref{i}"),
                tile=(16, 16, 16)).result(120)

    # exactly once
    assert sorted(ran) == [i for i in range(n) if kinds[i] == "gemm"]
    acct_jobs = sum(a["jobs"] for a in gf.accounting.values())
    # every node reaped, predecessors strictly first
    assert sorted(gf.finish_order) == list(range(n))
    pos = {nid: i for i, nid in enumerate(gf.finish_order)}
    for u, v in edges:
        assert pos[u] < pos[v]
    assert gf.node_states() == ["done"] * n
    # bitwise vs the single-submit reference
    for i in range(n):
        if kinds[i] == "gemm":
            assert np.array_equal(np.asarray(vals[i]),
                                  np.asarray(ref[i])), i
        else:
            assert vals[i] is None
    # accounting: graph booked at least the accounting nodes' jobs plus
    # one panel per GEMM node
    min_jobs = (sum(JobSet.for_gemm(i, 96, 64, 32, 32).num_jobs
                    for i in range(n) if kinds[i] == "acct")
                + sum(1 for i in range(n) if kinds[i] == "gemm"))
    assert acct_jobs >= min_jobs
