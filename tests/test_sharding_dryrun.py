"""Multi-device sharding integration tests.

These need fake host devices, and the dry-run contract forbids setting
xla_force_host_platform_device_count globally — so each test execs a small
script in a subprocess with the flag set there."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_train_step_runs_sharded_multipod():
    """Reduced archs train + agree numerically on a (2,2,2) pod mesh."""
    print(_run("""
        import jax, jax.numpy as jnp
        from repro.configs import ARCHS, reduced
        from repro.configs.base import ShapeCell
        from repro.launch.mesh import make_test_mesh
        from repro.launch.train import build_train_step, make_train_state
        mesh = make_test_mesh(data=2, model=2, pod=2)
        cell = ShapeCell("t", 16, 8, "train")
        for name in ("granite-3-2b", "dbrx-132b", "mamba2-130m"):
            cfg = reduced(ARCHS[name])
            with mesh:
                jfn, _, _ = build_train_step(cfg, cell, mesh, donate=False)
                state = make_train_state(cfg, jax.random.key(0))
                batch = {"tokens": jnp.zeros((8, 16), jnp.int32),
                         "labels": jnp.zeros((8, 16), jnp.int32)}
                state, m = jfn(state, batch)
                assert jnp.isfinite(m["loss"]), name
                print(name, float(m["loss"]))
    """))


def test_sharded_loss_matches_single_device():
    """The same reduced model must produce the same loss on a 4x2 mesh as
    on one device (SPMD correctness)."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import ARCHS, reduced
        from repro.models import init_model, lm_loss
        from repro.launch.mesh import make_test_mesh
        from repro.launch.sharding import param_pspecs, to_shardings
        cfg = reduced(ARCHS["granite-3-2b"])
        params = init_model(cfg, jax.random.key(0))
        batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 16), 0, 100),
                 "labels": jax.random.randint(jax.random.key(2), (8, 16), 0, 100)}
        l1 = jax.jit(lambda p: lm_loss(cfg, p, batch))(params)
        mesh = make_test_mesh(data=4, model=2)
        with mesh:
            specs = param_pspecs(cfg, jax.eval_shape(lambda: params), mesh)
            p_sh = jax.device_put(params, to_shardings(specs, mesh))
            l2 = jax.jit(lambda p: lm_loss(cfg, p, batch))(p_sh)
        print(float(l1), float(l2))
        assert abs(float(l1) - float(l2)) < 2e-4, (float(l1), float(l2))
    """)
    print(out)


def test_hlo_analysis_counts_scan_trips():
    """A k-layer scan must multiply collective bytes by k."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.hlo_analysis import analyze_hlo
        mesh = jax.make_mesh((4,), ("model",))
        w = jnp.zeros((6, 64, 64))
        x = jnp.zeros((8, 64))

        def f(w, x):
            def body(h, wi):
                return jnp.dot(h, wi), None
            h, _ = jax.lax.scan(body, x, w)
            return h

        jf = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, None, "model")),
                                      NamedSharding(mesh, P(None, None))))
        txt = jf.lower(w, x).compile().as_text()
        acct = analyze_hlo(txt)
        # one collective per scan layer (XLA picks all-gather or
        # all-reduce) -> the trip-count multiplier must surface >= 6
        n_coll = sum(acct.coll_count_by_type.values())
        print("collectives:", acct.coll_count_by_type, "flops:", acct.flops)
        assert n_coll >= 6, acct.coll_count_by_type
        assert acct.flops >= 2 * 8 * 64 * 64 * 6 / 4  # per-device share
    """)
    print(out)


def test_gpipe_spmd_matches_reference():
    """The shard_map GPipe pipeline over a 4-stage axis must reproduce the
    sequential stage composition (valid outputs on the last stage)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core.pipeline import gpipe_reference, gpipe_spmd
        mesh = jax.make_mesh((4,), ("stage",))
        S, M = 4, 8
        params = jax.random.normal(jax.random.key(0), (S, 16, 16)) * 0.3
        mbs = jax.random.normal(jax.random.key(1), (M, 2, 16))

        def stage_fn(p, x):
            return jnp.tanh(x @ p)

        ref = gpipe_reference(stage_fn, list(params), mbs)

        def pipelined(params, mbs):
            my_p = params[0]   # (1,16,16) shard -> (16,16)
            return gpipe_spmd(stage_fn, my_p, mbs, axis_name="stage",
                              num_stages=S)

        f = jax.jit(shard_map(pipelined, mesh=mesh,
                              in_specs=(P("stage"), P()),
                              out_specs=P("stage")))
        out = np.asarray(f(params, mbs))        # (S*M, 2, 16) stacked
        got = out.reshape(S, M, 2, 16)[-1]      # last stage's outputs
        np.testing.assert_allclose(got, np.asarray(ref), rtol=2e-5,
                                   atol=2e-5)
        print("gpipe ok")
    """)
    assert "gpipe ok" in out


def test_pp_mode_matches_sequential():
    """Pipeline-parallel launch mode (stages over a pod-like axis) must
    reproduce the sequential layer stack."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS, reduced
        from repro.models import init_model
        from repro.models.transformer import _attn_block_fwd, _scan_blocks
        from repro.launch.pipeline_mode import split_stages, build_pp_forward
        cfg = reduced(ARCHS["granite-3-2b"], n_layers=4, d_model=32,
                      n_heads=2, d_ff=64, vocab=128)
        params = init_model(cfg, jax.random.key(0))
        mesh = jax.make_mesh((4, 2), ("pod", "model"))
        M, B, S = 6, 1, 8
        mbs = jax.random.normal(jax.random.key(1), (M, B, S, cfg.d_model),
                                jnp.float32)
        # sequential reference
        body = lambda p, h: _attn_block_fwd(cfg, p, h)
        ref = jnp.stack([_scan_blocks(body, mbs[i], params["blocks"], False)
                         for i in range(M)])
        staged = split_stages(params, 4)
        fn, S_ = build_pp_forward(cfg, mesh, stage_axis="pod", microbatches=M)
        out = np.asarray(fn(staged, mbs)).reshape(4, M, B, S, cfg.d_model)
        np.testing.assert_allclose(out[-1], np.asarray(ref), rtol=3e-4,
                                   atol=3e-4)
        print("pp ok")
    """)
    assert "pp ok" in out
