from .mesh import make_production_mesh, make_test_mesh, dp_axes, MODEL_AXIS
from .sharding import (param_pspecs, input_pspecs, opt_pspecs, state_pspecs,
                       to_shardings, cache_pspecs)
