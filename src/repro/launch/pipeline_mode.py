"""Pipeline-parallel launch mode: the Synergy inter-frame pipeline at POD
granularity (DESIGN §4 'PP over pod').

The multi-pod mesh's inter-pod links are the slowest fabric; a GPipe
microbatch pipeline keeps that traffic point-to-point (ppermute ring) —
the same communication-pattern argument the paper makes for pipelining
across heterogeneous interconnect.  Stages = contiguous layer groups; each
pod holds one stage's parameters; microbatches stream through
``repro.core.pipeline.gpipe_spmd``.

Demonstrated for the dense family (block stacks split evenly across the
stage axis); validated against the sequential reference in
tests/test_sharding_dryrun.py::test_pp_mode_matches_sequential.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.pipeline import gpipe_spmd
from repro.models.transformer import _attn_block_fwd

__all__ = ["split_stages", "build_pp_forward"]


def split_stages(params: dict, num_stages: int) -> dict:
    """Reshape the stacked (L, ...) block params to (S, L/S, ...)."""
    blocks = params["blocks"]
    return jax.tree.map(
        lambda a: a.reshape((num_stages, a.shape[0] // num_stages)
                            + a.shape[1:]), blocks)


def build_pp_forward(cfg: ArchConfig, mesh, *, stage_axis: str = "pod",
                     microbatches: int = 8):
    """Returns a jitted pipelined backbone forward:
    fn(staged_blocks, embeds (M*mb_sz, S, d)) -> activations, with stages
    mapped onto the ``stage_axis`` of the mesh via shard_map."""
    num_stages = mesh.shape[stage_axis]
    assert cfg.n_layers % num_stages == 0

    def stage_fn(stage_blocks, x):
        def body(h, p):
            return _attn_block_fwd(cfg, p, h), None
        h, _ = jax.lax.scan(body, x, stage_blocks)
        return h

    def pipelined(staged_blocks, mbs):
        my_blocks = jax.tree.map(lambda a: a[0], staged_blocks)
        return gpipe_spmd(stage_fn, my_blocks, mbs,
                          axis_name=stage_axis, num_stages=num_stages)

    shard = jax.shard_map if hasattr(jax, "shard_map") else None
    if shard is None:  # pragma: no cover
        from jax.experimental.shard_map import shard_map as shard
    f = shard(pipelined, mesh=mesh,
              in_specs=(P(stage_axis), P()), out_specs=P(stage_axis))
    return jax.jit(f), num_stages
