"""Training driver: jitted train step with full sharding, checkpointing,
fault tolerance hooks, and the Synergy between-step rebalancer.

``build_train_step`` returns the pjit-compiled step; ``train_loop`` is the
end-to-end driver used by examples/train_lm.py.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell
from repro.models import init_model, input_specs, loss_fn
from repro.optim import (AdamWConfig, AdafactorConfig, adamw_init,
                         adamw_update, adafactor_init, adafactor_update)
from .sharding import input_pspecs, state_pspecs, to_shardings

__all__ = ["make_train_state", "build_train_step", "train_loop",
           "train_state_specs"]


def make_train_state(cfg: ArchConfig, key, opt_cfg=None) -> dict:
    params = init_model(cfg, key)
    if cfg.optimizer == "adafactor":
        opt = adafactor_init(params)
    else:
        opt = adamw_init(params)
    return {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}


def train_state_specs(cfg: ArchConfig, mesh):
    aval = jax.eval_shape(lambda: make_train_state(cfg, jax.random.key(0)))
    return aval, state_pspecs(cfg, aval, mesh)


def _train_step(cfg: ArchConfig, opt_cfg, state: dict, batch: dict):
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch))(state["params"])
    if cfg.optimizer == "adafactor":
        new_params, new_opt, metrics = adafactor_update(
            opt_cfg, grads, state["opt"], state["params"])
    else:
        new_params, new_opt, metrics = adamw_update(
            opt_cfg, grads, state["opt"], state["params"])
    new_state = {"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1}
    return new_state, {"loss": loss, **metrics}


def default_opt_cfg(cfg: ArchConfig):
    return (AdafactorConfig() if cfg.optimizer == "adafactor"
            else AdamWConfig())


def build_train_step(cfg: ArchConfig, cell: ShapeCell, mesh, *,
                     opt_cfg=None, donate: bool = True):
    """Returns (jitted_fn, state_specs, batch_specs) — ready to lower
    against ShapeDtypeStructs (dry-run) or run with real arrays."""
    opt_cfg = opt_cfg or default_opt_cfg(cfg)
    aval, sspecs = train_state_specs(cfg, mesh)
    in_specs = input_specs(cfg, cell)
    bspecs = input_pspecs(cfg, cell, in_specs, mesh)
    fn = functools.partial(_train_step, cfg, opt_cfg)
    jfn = jax.jit(
        fn,
        in_shardings=(to_shardings(sspecs, mesh),
                      to_shardings(bspecs, mesh)),
        out_shardings=(to_shardings(sspecs, mesh), None),
        donate_argnums=(0,) if donate else ())
    return jfn, (aval, sspecs), (in_specs, bspecs)


def train_loop(cfg: ArchConfig, mesh, *, steps: int, batch_iter,
               cell: ShapeCell, key=None, state=None, opt_cfg=None,
               checkpointer=None, ckpt_every: int = 0,
               on_step: Callable | None = None):
    """End-to-end loop: init (or resume), step, checkpoint, report."""
    key = key if key is not None else jax.random.key(0)
    jfn, (aval, sspecs), _ = build_train_step(cfg, cell, mesh,
                                              opt_cfg=opt_cfg)
    if state is None:
        with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
            state = make_train_state(cfg, key)
    history = []
    for _ in range(steps):
        batch = next(batch_iter)
        t0 = time.perf_counter()
        state, metrics = jfn(state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        metrics["step_time_s"] = time.perf_counter() - t0
        history.append(metrics)
        step = int(state["step"])
        if checkpointer is not None and ckpt_every and step % ckpt_every == 0:
            checkpointer.save(step, state)
        if on_step is not None:
            on_step(step, metrics)
    return state, history
