import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh, record memory/cost/collective analysis.

THE FIRST TWO LINES of this file force 512 host devices BEFORE any jax
import — jax locks the device count at first init.  Never import this
module from tests/benches (they want 1 device); run it as a process:

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
        --shape train_4k [--multipod] [--out results/dryrun]

Outputs one JSON per cell with: per-device memory analysis, HLO FLOPs and
bytes (cost_analysis), and collective-traffic accounting (hlo_analysis) —
the inputs to EXPERIMENTS.md §Dry-run and §Roofline.
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_analysis import analyze_hlo


def _mem_dict(mem) -> dict:
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes", "peak_memory_in_bytes"]
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def run_cell(arch: str, shape: str, multi_pod: bool,
             overrides: dict | None = None) -> dict:
    import dataclasses
    from repro.launch.train import build_train_step
    from repro.launch.serve import build_prefill_step, build_decode_step

    cfg = ARCHS[arch]
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    cell = SHAPES[shape]
    rec: dict = {"arch": arch, "shape": shape,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "kind": cell.kind}

    if cell.name == "long_500k" and not cfg.sub_quadratic:
        rec.update(status="skipped",
                   reason="full-attention arch: long_500k requires "
                          "sub-quadratic attention (DESIGN.md)")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    # `with mesh` = legacy physical-mesh context (required by the dry-run
    # contract); jax.set_mesh additionally exposes the abstract mesh so the
    # model's with_sharding_constraint hooks can see the axis names.
    with mesh, jax.set_mesh(mesh):
        if cell.kind == "train":
            jfn, (aval, _), (in_specs, _) = build_train_step(
                cfg, cell, mesh, donate=False)
            lowered = jfn.lower(aval, in_specs)
        elif cell.kind == "prefill":
            jfn, (aval, _), (in_specs, _) = build_prefill_step(cfg, cell, mesh)
            lowered = jfn.lower(aval, in_specs)
        else:
            jfn, (aval, _), (in_specs, _) = build_decode_step(
                cfg, cell, mesh, donate=False)
            lowered = jfn.lower(aval, in_specs["cache"], in_specs["tokens"],
                                in_specs["pos"])
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        mem = compiled.memory_analysis()
        print(mem)                      # proves it fits (or doesn't)
        cost = compiled.cost_analysis()
        print({k: cost.get(k) for k in ("flops", "bytes accessed")})
        rec["memory"] = _mem_dict(mem)
        cost_d = dict(cost) if cost else {}
        rec["cost"] = {k: float(v) for k, v in cost_d.items()
                       if isinstance(v, (int, float)) and (
                           "flops" in k or "bytes" in k or k == "optimal_seconds")}
        text = compiled.as_text()
        rec["hlo_bytes"] = len(text)
        acct = analyze_hlo(text)  # loop-aware FLOPs/bytes/collectives
        rec["hlo_accounting"] = acct.to_dict()
        rec["analyzer_version"] = 4
        rec["status"] = "ok"
        if os.environ.get("DRYRUN_SAVE_HLO"):
            import zstandard
            d = os.path.join(os.environ.get("DRYRUN_OUT", "results/dryrun"),
                             "hlo")
            os.makedirs(d, exist_ok=True)
            tag = (f"{arch}__{shape}__"
                   f"{'2x16x16' if multi_pod else '16x16'}")
            suffix = os.environ.get("DRYRUN_TAG", "")
            if suffix:
                tag += "__" + suffix
            with open(os.path.join(d, tag + ".hlo.zst"), "wb") as f:
                f.write(zstandard.ZstdCompressor(level=6).compress(
                    text.encode()))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=False)
    ap.add_argument("--shape", required=False)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="ArchConfig override key=value (perf iterations, "
                         "e.g. --set param_dtype=int8)")
    ap.add_argument("--tag", default="",
                    help="suffix for the output json (perf iterations)")
    args = ap.parse_args()

    if args.list:
        for a in ARCHS:
            for s in SHAPES:
                print(a, s)
        return

    assert args.arch and args.shape
    os.makedirs(args.out, exist_ok=True)
    tag = f"{args.arch}__{args.shape}__{'2x16x16' if args.multipod else '16x16'}"
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        overrides[k] = v
    if args.tag:
        tag += "__" + args.tag
        os.environ["DRYRUN_TAG"] = args.tag
    try:
        rec = run_cell(args.arch, args.shape, args.multipod,
                       overrides=overrides or None)
    except Exception as e:  # record failures — they are bugs to fix
        rec = {"arch": args.arch, "shape": args.shape,
               "mesh": "2x16x16" if args.multipod else "16x16",
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("traceback",)}, indent=1))


if __name__ == "__main__":
    main()
