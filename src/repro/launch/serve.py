"""Serving driver: prefill + decode steps and the Synergy continuous-batch
serving loop (inter-frame pipeline, C4, at request granularity).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell
from repro.models import decode_fn, input_specs, param_specs, prefill_fn
from .sharding import input_pspecs, param_pspecs, to_shardings

__all__ = ["build_prefill_step", "build_decode_step", "serve_state_specs"]


def serve_state_specs(cfg: ArchConfig, mesh, mode: str = "train"):
    aval = param_specs(cfg)
    return aval, param_pspecs(cfg, aval, mesh, mode=mode)


def build_prefill_step(cfg: ArchConfig, cell: ShapeCell, mesh):
    aval, pspecs = serve_state_specs(cfg, mesh)
    in_specs = input_specs(cfg, cell)
    bspecs = input_pspecs(cfg, cell, in_specs, mesh)

    def step(params, batch):
        return prefill_fn(cfg, params,
                          tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"),
                          enc_embeds=batch.get("enc_embeds"))

    jfn = jax.jit(step,
                  in_shardings=(to_shardings(pspecs, mesh),
                                to_shardings(bspecs, mesh)),
                  out_shardings=None)
    return jfn, (aval, pspecs), (in_specs, bspecs)


def build_decode_step(cfg: ArchConfig, cell: ShapeCell, mesh, *,
                      donate: bool = True):
    """serve_step for decode cells: one new token, seq_len-deep cache."""
    aval, pspecs = serve_state_specs(cfg, mesh, mode="decode")
    in_specs = input_specs(cfg, cell)
    bspecs = input_pspecs(cfg, cell, in_specs, mesh)

    def step(params, cache, tokens, pos):
        return decode_fn(cfg, params, cache, tokens, pos)

    jfn = jax.jit(
        step,
        in_shardings=(to_shardings(pspecs, mesh),
                      to_shardings(bspecs["cache"], mesh),
                      to_shardings(bspecs["tokens"], mesh),
                      to_shardings(bspecs["pos"], mesh)),
        out_shardings=(None, to_shardings(bspecs["cache"], mesh)),
        donate_argnums=(1,) if donate else ())
    return jfn, (aval, pspecs), (in_specs, bspecs)
