"""Post-partitioning HLO text analysis: loop-aware FLOP / HBM / collective
accounting.

Why not ``compiled.cost_analysis()``?  XLA's HloCostAnalysis visits every
computation ONCE — a 40-layer ``lax.scan`` body is counted a single time,
under-reporting FLOPs and bytes by ~n_layers.  This analyzer parses
``compiled.as_text()`` (the per-device partitioned module) and multiplies
each op by the trip count of its enclosing while loops (recovered from the
loop-condition constants).

Accounting model:
  * flops        — dot/convolution ops: 2 * prod(result dims) *
                   prod(lhs contracting dims).  Elementwise flops ignored
                   (the MXU roofline term is dot-dominated).
  * hbm_bytes    — for every top-level op with real traffic (post-fusion
                   HLO: fusions, dots, collectives, copies, slices...),
                   result bytes + operand bytes, operands resolved through
                   a per-computation symbol table.  In optimized HLO each
                   such op is one kernel, so operands+results approximate
                   its HBM traffic.
  * collectives  — result-shape bytes per op type with loop multiplicity.
                   The link-time model (2x ring all-reduce etc.) is applied
                   by the roofline layer.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

__all__ = ["HloAccounting", "analyze_hlo", "analyze_collectives"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_OP_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-_]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?)|(?:\w+\[\]))\s+"
    r"([\w\-]+)\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-_]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-_]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-_]+)")
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-_]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TF_RE = re.compile(r"(?:true_computation|false_computation)=%?([\w.\-_]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-_]+)\s*(?:\([^)]*\))?.*\{")

_COLLECTIVE_OPS = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                   "collective-permute", "all-reduce-start",
                   "all-gather-start", "collective-permute-start",
                   "reduce-scatter-start", "all-to-all-start"}
_NO_TRAFFIC_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "partition-id", "replica-id", "copy-start",
    "copy-done", "while", "conditional", "call", "all-reduce-done",
    "all-gather-done", "collective-permute-done", "reduce-scatter-done",
    "all-to-all-done", "opt-barrier",
    # loop-carry copies: XLA:CPU materializes full-buffer copies for
    # read+update-in-iteration carries (e.g. the KV cache); TPU aliases
    # donated buffers in place, so copies are excluded from HBM traffic.
    "copy",
}


def _prod(dims_txt: str) -> int:
    p = 1
    for d in dims_txt.split(","):
        if d:
            p *= int(d)
    return p


def _shape_bytes(shape_txt: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_txt):
        size = _DTYPE_BYTES.get(m.group(1))
        if size is None:
            continue
        total += size * _prod(m.group(2))
    return total


def _first_shape(shape_txt: str):
    m = _SHAPE_RE.search(shape_txt)
    if not m:
        return None, []
    return m.group(1), [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class HloAccounting:
    flops: float
    hbm_bytes: float
    coll_bytes_by_type: dict
    coll_count_by_type: dict

    @property
    def collective_bytes(self) -> float:
        return float(sum(self.coll_bytes_by_type.values()))

    def to_dict(self) -> dict:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "bytes_by_type": dict(self.coll_bytes_by_type),
                "count_by_type": dict(self.coll_count_by_type),
                "total_bytes": self.collective_bytes}


def _split_computations(text: str):
    comps: dict[str, list[str]] = {}
    cur = None
    entry = None
    for line in text.splitlines():
        if not line.startswith(" ") and "{" in line:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.strip().startswith("ENTRY"):
                    entry = cur
                continue
        if cur is not None and line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps, entry


def _trip_count(cond_lines: list[str]) -> int:
    best = 1
    for line in cond_lines:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


def _symbols(lines: list[str]) -> dict[str, str]:
    """name -> result-shape text for one computation."""
    table = {}
    for line in lines:
        m = _OP_RE.match(line)
        if m:
            table[m.group(1)] = m.group(2)
    return table


# cast-like ops: XLA:CPU legalizes bf16 dots by upcasting operands to f32
# (and hoists weight-stack converts out of scan loops).  A TPU Mosaic
# pipeline fuses these casts into the consumer, so HBM sees the STORAGE
# dtype.  We resolve an operand's dtype through chains of such ops.
_CAST_OPS = {"convert", "bitcast", "copy"}

# ops that make a fusion "cast/layout-only" (no real compute): such fusion
# kernels exist on CPU but fuse into their consumer on TPU
_CAST_FUSION_OPS = _CAST_OPS | {"reshape", "transpose", "broadcast",
                                "parameter", "tuple", "get-tuple-element",
                                "slice"}


def _is_cast_fusion(body_lines: list[str]) -> bool:
    for line in body_lines:
        m = _OP_RE.match(line)
        if m and m.group(3) not in _CAST_FUSION_OPS:
            return False
    return True


def _defs(lines: list[str]) -> dict[str, tuple[str, str | None, str | None]]:
    """name -> (opcode, first operand name, called computation if fusion)."""
    table: dict[str, tuple[str, str | None, str | None]] = {}
    for line in lines:
        m = _OP_RE.match(line)
        if m:
            ops = _OPERAND_NAME_RE.findall(
                line[m.end(3):line.find(")", m.end(3)) + 1])
            call = _CALL_RE.search(line)
            table[m.group(1)] = (m.group(3), ops[0] if ops else None,
                                 call.group(1) if call else None)
    return table


def _resolved_bytes(name: str, sym: dict, defs: dict,
                    cast_fusions: set | None = None) -> int:
    """Bytes of value `name`: its own element count, dtype resolved through
    cast chains (storage dtype, as a fused TPU pipeline would see)."""
    shape_txt = sym.get(name, "")
    dt, dims = _first_shape(shape_txt)
    if dt is None:
        return 0
    elems = 1
    for d in dims:
        elems *= d
    cur = name
    for _ in range(6):
        entry = defs.get(cur)
        if not entry or not entry[1]:
            break
        opcode, first_op, called = entry
        chase = (opcode in _CAST_OPS
                 or (opcode == "fusion" and cast_fusions
                     and called in cast_fusions))
        if not chase:
            break
        cur = first_op
        src_dt, _ = _first_shape(sym.get(cur, ""))
        if src_dt is not None:
            dt = src_dt
    return _DTYPE_BYTES.get(dt, 4) * elems


def _operands(line: str, op_end: int) -> list[str]:
    """Operand names inside opcode( ... ) — up to the closing paren before
    any `, attr=` section."""
    start = line.index("(", op_end)
    depth = 0
    end = start
    for i in range(start, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return _OPERAND_NAME_RE.findall(line[start:end + 1])


_PARAM_RE = re.compile(
    r"^\s+%?([\w.\-_]+)\s*=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))"
    r"\s+parameter\((\d+)\)")


def _fusion_touched(body_lines: list[str], body_sym: dict) -> dict[int, int]:
    """For each fusion parameter index: bytes actually touched.  A parameter
    consumed ONLY by dynamic-slice ops contributes its slice results (the
    kernel gathers a window of a big buffer, e.g. one scan step's saved
    activations), not the whole buffer."""
    params: dict[str, tuple[int, int]] = {}   # name -> (idx, full_bytes)
    for line in body_lines:
        pm = _PARAM_RE.match(line)
        if pm:
            params[pm.group(1)] = (int(pm.group(3)), _shape_bytes(pm.group(2)))
    touched: dict[int, int] = {}
    for name, (idx, full) in params.items():
        ds_bytes = 0
        other_use = False
        ref = "%" + name
        for line in body_lines:
            if ref not in line:
                continue
            om = _OP_RE.match(line)
            if om and om.group(1) == name:
                continue  # the definition line
            if om and om.group(3) == "dynamic-slice":
                ds_bytes += _shape_bytes(om.group(2))
            else:
                other_use = True
        if not other_use and ds_bytes:
            touched[idx] = min(full, ds_bytes)
        else:
            touched[idx] = full
    return touched


def analyze_hlo(hlo_text: str) -> HloAccounting:
    comps, entry = _split_computations(hlo_text)
    entry_lines = comps.get(entry, []) if entry else (
        max(comps.values(), key=len) if comps else [])
    symtabs = {name: _symbols(lines) for name, lines in comps.items()}
    deftabs = {name: _defs(lines) for name, lines in comps.items()}
    touched_cache: dict[str, dict[int, int]] = {}
    cast_fusions = {name for name, lines in comps.items()
                    if _is_cast_fusion(lines)}
    if entry:
        sym_entry = symtabs[entry]
    else:
        sym_entry = {}

    flops = 0.0
    hbm = 0.0
    coll_b = defaultdict(float)
    coll_n = defaultdict(float)
    stack: set[str] = set()
    _use_cache: dict[str, dict] = {}

    def use_index(comp_name: str) -> dict:
        """name -> [(consumer opcode, consumer name)] for one computation."""
        if comp_name in _use_cache:
            return _use_cache[comp_name]
        idx: dict[str, list] = {}
        for line2 in comps.get(comp_name, []):
            m2 = _OP_RE.match(line2)
            if not m2:
                continue
            for o in _operands(line2, m2.end(3)):
                idx.setdefault(o, []).append((m2.group(3), m2.group(1)))
        _use_cache[comp_name] = idx
        return idx

    def walk(comp_name: str, lines: list[str], mult: float,
             count_bytes: bool) -> None:
        nonlocal flops, hbm
        sym = symtabs.get(comp_name, sym_entry)
        dfs = deftabs.get(comp_name, {})
        for line in lines:
            om = _OP_RE.match(line)
            if not om:
                continue
            opcode = om.group(3)
            result_txt = om.group(2)

            if opcode in ("dot", "convolution"):
                _, rdims = _first_shape(result_txt)
                r_elems = 1
                for d in rdims:
                    r_elems *= d
                k = 1
                cm = _CONTRACT_RE.search(line)
                ops = _operands(line, om.end(3))
                if cm and ops:
                    lhs_shape = sym.get(ops[0], "")
                    _, ldims = _first_shape(lhs_shape)
                    for ci in cm.group(1).split(","):
                        if ci and int(ci) < len(ldims):
                            k *= ldims[int(ci)]
                flops += 2.0 * r_elems * k * mult

            base_op = opcode.replace("-start", "")
            if opcode in _COLLECTIVE_OPS:
                dt, dims = _first_shape(result_txt)
                elems = 1
                for dd in dims:
                    elems *= dd
                # XLA:CPU legalizes bf16 dots to f32, so reduces of dot
                # partials appear in f32; a TPU program reduces in the
                # compute dtype.  If every consumer of this collective is a
                # down-cast, count at the consumer dtype.
                name = om.group(1)
                uses = use_index(comp_name)
                consumers = uses.get(name, [])
                if consumers and all(c[0] == "convert" for c in consumers):
                    cdts = [_first_shape(sym.get(c[1], ""))[0]
                            for c in consumers]
                    sizes = [_DTYPE_BYTES.get(c, 4) for c in cdts if c]
                    if sizes:
                        dt_size = min(min(sizes), _DTYPE_BYTES.get(dt, 4))
                    else:
                        dt_size = _DTYPE_BYTES.get(dt, 4)
                else:
                    dt_size = _DTYPE_BYTES.get(dt, 4)
                coll_b[base_op] += dt_size * elems * mult
                coll_n[base_op] += mult

            is_cast_fus = False
            if opcode == "fusion":
                cm0 = _CALL_RE.search(line)
                is_cast_fus = bool(cm0 and cm0.group(1) in cast_fusions)
            if (count_bytes and opcode not in _NO_TRAFFIC_OPS
                    and opcode not in _CAST_OPS and not is_cast_fus):
                op_names = _operands(line, om.end(3))
                ops_b = [_resolved_bytes(o, sym, dfs, cast_fusions)
                         for o in op_names]
                # match both HLO opcode (dash) and jax metadata (underscore)
                if ("dynamic-update-slice" in line
                        or "dynamic_update_slice" in line):
                    # in-place update: traffic = 2x the written slice, not
                    # the whole (possibly multi-GB cache/carry) buffer
                    traffic = 2.0 * (sum(ops_b) - max(ops_b, default=0))
                elif "dynamic-slice" in line and opcode != "fusion":
                    traffic = 2.0 * _shape_bytes(result_txt)
                else:
                    if opcode == "fusion":
                        cm4 = _CALL_RE.search(line)
                        if cm4 and cm4.group(1) in comps:
                            body = cm4.group(1)
                            if body not in touched_cache:
                                touched_cache[body] = _fusion_touched(
                                    comps[body], symtabs.get(body, {}))
                            tmap = touched_cache[body]
                            ops_b = [min(b, tmap.get(i, b))
                                     for i, b in enumerate(ops_b)]
                    traffic = _shape_bytes(result_txt) + sum(ops_b)
                hbm += traffic * mult

            if opcode == "while":
                bm = _BODY_RE.search(line)
                cm2 = _COND_RE.search(line)
                if bm and bm.group(1) in comps and bm.group(1) not in stack:
                    trips = (_trip_count(comps[cm2.group(1)])
                             if cm2 and cm2.group(1) in comps else 1)
                    stack.add(bm.group(1))
                    walk(bm.group(1), comps[bm.group(1)], mult * trips,
                         count_bytes)
                    stack.discard(bm.group(1))
            elif opcode == "conditional":
                names = []
                m3 = _BRANCH_RE.search(line)
                if m3:
                    names += [n.strip().lstrip("%")
                              for n in m3.group(1).split(",")]
                names += _TF_RE.findall(line)
                for name in names:
                    if name in comps and name not in stack:
                        stack.add(name)
                        walk(name, comps[name], mult, count_bytes)
                        stack.discard(name)
            else:
                # fusions / reducers / calls: count dot flops inside, but
                # traffic is already accounted at this (kernel) level.
                for m4 in _CALL_RE.finditer(line):
                    name = m4.group(1)
                    if name in comps and name not in stack:
                        stack.add(name)
                        walk(name, comps[name], mult, False)
                        stack.discard(name)

    walk(entry or "", entry_lines, 1.0, True)
    return HloAccounting(flops, hbm, dict(coll_b), dict(coll_n))


def analyze_collectives(hlo_text: str):
    """Back-compat wrapper returning the full accounting."""
    return analyze_hlo(hlo_text)
