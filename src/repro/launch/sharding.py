"""Sharding rules: ArchConfig + mesh -> PartitionSpecs for params, inputs,
caches, optimizer state.

Scheme (see DESIGN.md §4):
  * DP/FSDP over ('pod','data') / 'data'; TP/EP over 'model'.
  * Megatron column/row parallel attention+MLP; vocab-sharded embeddings;
    expert-sharded MoE; P-dim-sharded SSD (see models/ssm.py docstring).
  * Divisibility fallbacks are automatic: an axis is only assigned when it
    divides the dim (so reduced test configs on 2x2 meshes and full configs
    on 16x16 use the same rule table).
  * KV caches shard the SEQUENCE dim on 'model' (flash-decoding style):
    the three decode psums (max, sum, PV-combine) are tiny, and S always
    divides 16 for the assigned cells.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from .mesh import MODEL_AXIS, dp_axes

__all__ = ["param_pspecs", "input_pspecs", "opt_pspecs", "state_pspecs",
           "to_shardings", "cache_pspecs"]


def _div(axis: str | tuple, size: int, mesh) -> Any:
    """Return axis spec if it evenly divides `size`, else None."""
    if axis is None:
        return None
    names = (axis,) if isinstance(axis, str) else axis
    total = 1
    for n in names:
        total *= mesh.shape[n]
    return axis if size % total == 0 else None


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def param_pspecs(cfg: ArchConfig, params_aval, mesh,
                 mode: str = "train") -> Any:
    """PartitionSpec tree matching the params tree.

    mode='decode' (§Perf D2): attention projections shard only when the KV
    heads divide the mesh — the decode cache is hd-sharded, and
    head-sharded Q against hd-sharded K makes the partitioner all-gather
    the whole cache (13.7 GB/layer on dbrx).  Attention FLOPs are trivial
    at decode, so replicating those projections is the right trade."""
    m = MODEL_AXIS
    fsdp = "data" if cfg.fsdp and "data" in mesh.axis_names else None
    shard_heads = cfg.n_heads and cfg.n_heads % mesh.shape[m] == 0
    shard_kv = cfg.n_kv_heads and cfg.n_kv_heads % mesh.shape[m] == 0
    if mode == "decode":
        shard_heads = shard_heads and shard_kv

    def spec_for(path: str, v) -> P:
        shape = v.shape
        # strip the stacked-layer leading dim for blocks/encoder stacks
        stacked = (path.startswith("blocks/") or path.startswith("encoder/"))
        inner = shape[1:] if stacked else shape

        def out(*axes):
            axes = [_div(a, d, mesh) if a else None
                    for a, d in zip(axes, inner)]
            return P(*( [None] + axes if stacked else axes ))

        if path == "embed":
            return P(_div(m, shape[0], mesh), _div(fsdp, shape[1], mesh))
        if path == "lm_head":
            return P(_div(fsdp, shape[0], mesh), _div(m, shape[1], mesh))
        if path in ("final_norm", "enc_norm"):
            return P(None)

        leaf = path.split("/")[-1]
        if "/attn/" in path or "/cross/" in path:
            if leaf == "wq":
                return out(fsdp, m if shard_heads else None)
            if leaf in ("wk", "wv"):
                # kv shards with heads only when kv divides (g==1 archs);
                # otherwise replicated and activations are repeated to Hq.
                return out(fsdp, m if (shard_heads and shard_kv) else None)
            if leaf == "wo":
                return out(m if shard_heads else None, fsdp)
        if "/mlp/" in path:
            if leaf == "wi":
                return out(fsdp, m)
            if leaf == "wo":
                return out(m, fsdp)
        if "/moe/" in path:
            if leaf == "router":
                return out(None, None)
            if leaf == "w1":
                return out(m, fsdp, None)
            if leaf == "w2":
                return out(m, None, fsdp)
        if "/mixer/" in path:
            if leaf in ("wz", "wx"):
                return out(fsdp, None, m)      # (d, H, P): shard P
            if leaf in ("wbc", "wdt"):
                return out(fsdp, None)
            if leaf == "conv_wx":
                return out(None, None, m)
            if leaf == "norm_scale":
                return out(None, m)
            if leaf == "out_proj":
                return out(None, m, fsdp)      # (H, P, d): row-parallel on P
            return out(*([None] * len(inner)))
        # norms / biases / anything else: replicated (beyond leading L)
        return out(*([None] * len(inner)))

    return jax.tree_util.tree_map_with_path(
        lambda path, v: spec_for(_path_str(path), v), params_aval)


def cache_pspecs(cfg: ArchConfig, cache_aval, mesh, batch: int) -> Any:
    m = MODEL_AXIS
    dp = dp_axes(mesh)

    def spec_for(path: str, v) -> P:
        shape = v.shape
        if path.endswith(("k", "v", "xk", "xv")):
            # (n_layers, B, Hkv, S, hd): shard HEAD_DIM on model (§Perf D2).
            # Sequence-sharding made the per-token cache write a dynamic-
            # position update into a sharded dim — the SPMD partitioner
            # lowers that to a masked SELECT over the FULL cache per layer.
            # hd % 16 == 0 for every assigned arch; the cost is a small
            # per-layer scores psum instead.
            return P(None, _div(dp, shape[1], mesh), None, None,
                     _div(m, shape[4], mesh))
        if path.endswith("ssm"):
            # (L, B, H, P, N): shard P
            return P(None, _div(dp, shape[1], mesh), None,
                     _div(m, shape[3], mesh), None)
        if path.endswith("conv_x"):
            # (L, B, K-1, H, P)
            return P(None, _div(dp, shape[1], mesh), None, None,
                     _div(m, shape[4], mesh))
        if path.endswith("conv_bc"):
            return P(None, _div(dp, shape[1], mesh), None, None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(
        lambda path, v: spec_for(_path_str(path), v), cache_aval)


def input_pspecs(cfg: ArchConfig, cell: ShapeCell, specs: dict, mesh) -> dict:
    dp = dp_axes(mesh)
    b = cell.global_batch
    out: dict[str, Any] = {}
    for name, v in specs.items():
        if name == "pos":
            out[name] = P()
        elif name == "cache":
            out[name] = cache_pspecs(cfg, v, mesh, b)
        else:
            batch_axis = _div(dp, v.shape[0], mesh)
            out[name] = P(batch_axis, *([None] * (len(v.shape) - 1)))
    return out


def opt_pspecs(param_specs, opt_aval, optimizer: str) -> Any:
    """Optimizer-state specs derived from param specs."""
    if optimizer == "adamw":
        return {"m": param_specs, "v": param_specs, "step": P()}
    # adafactor: vr drops last dim's spec, vc drops second-to-last
    def stats_spec(pspec: P, stat: dict) -> dict:
        parts = list(pspec)
        if "vr" in stat:
            return {"vr": P(*parts[:-1]),
                    "vc": P(*(parts[:-2] + parts[-1:]))}
        return {"v": pspec}

    flat_p, treedef = jax.tree.flatten(param_specs,
                                       is_leaf=lambda x: isinstance(x, P))
    flat_s = treedef.flatten_up_to(opt_aval["stats"])
    stats = treedef.unflatten([stats_spec(p, s)
                               for p, s in zip(flat_p, flat_s)])
    return {"stats": stats, "step": P()}


def state_pspecs(cfg: ArchConfig, state_aval, mesh) -> dict:
    pspecs = param_pspecs(cfg, state_aval["params"], mesh)
    return {
        "params": pspecs,
        "opt": opt_pspecs(pspecs, state_aval["opt"], cfg.optimizer),
        "step": P(),
    }


def to_shardings(spec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
