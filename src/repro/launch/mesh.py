"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax init)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh", "dp_axes", "MODEL_AXIS"]

MODEL_AXIS = "model"


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != MODEL_AXIS)
