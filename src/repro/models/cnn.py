"""CNNs via conv-as-tiled-GEMM — the paper's own benchmark networks.

Every CONV layer lowers to im2col + :func:`synergy_matmul` (so its tile-job
decomposition is visible to the schedulers), pooling/activation/FC stay on
the "CPU side" exactly as in the paper (§3.1.4).  ``build_simnet`` exports
the same network as a :class:`repro.core.scheduler.SimNet` for the
discrete-event runtime reproduction.

Layer dims are modeled from the Darknet/Caffe configs the paper trained
(Table 2); per-frame op counts land within ~10-20% of the paper's reported
GOPS-at-fps for MNIST and CIFAR_full (Table 4), which is what the scheduler
trends depend on.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.im2col import conv2d_gemm, conv_out_shape, im2col
from repro.core.job import JobSet
from repro.core.scheduler import SimLayer, SimNet
from repro.core.synergy_mm import synergy_matmul

__all__ = ["CNNConfig", "init_cnn", "cnn_forward", "build_simnet",
           "conv_jobsets", "conv_graph_steps", "conv_wave_graph",
           "maxpool2d", "cnn_flops_per_frame"]


def maxpool2d(x: jax.Array, size: int) -> jax.Array:
    """Non-overlapping max pool (stride == size), cropping odd edges —
    the paper's CPU-side pooling (§3.1.4).  ONE implementation shared by
    ``cnn_forward`` and the serving prefill chain, so their activations
    cannot silently diverge."""
    n, h, w, c = x.shape
    x = x[:, : h - h % size, : w - w % size, :]
    return x.reshape(n, h // size, size, w // size, size, c).max(axis=(2, 4))

# layer spec forms:
#   ("conv", cout, k, stride, pad)
#   ("pool", size)           max pool, stride == size
#   ("fc", n_out)
Layer = tuple


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    input_hw: int
    cin: int
    layers: tuple[Layer, ...]
    num_classes: int = 10
    tile: int = 32            # the paper's TS=32

    def trace_shapes(self):
        """Walk the net, yielding (layer, h, w, c_in) before each layer."""
        h = w = self.input_hw
        c = self.cin
        out = []
        for spec in self.layers:
            out.append((spec, h, w, c))
            if spec[0] == "conv":
                _, cout, k, s, p = spec
                h, w = conv_out_shape(h, w, k, k, s, p)
                c = cout
            elif spec[0] == "pool":
                size = spec[1]
                h, w = h // size, w // size
            elif spec[0] == "fc":
                h = w = 1
                c = spec[1]
        return out, (h, w, c)


def init_cnn(cfg: CNNConfig, key: jax.Array, dtype=jnp.float32) -> dict:
    params = {}
    shapes, _ = cfg.trace_shapes()
    for i, (spec, h, w, c) in enumerate(shapes):
        if spec[0] == "conv":
            _, cout, k, s, p = spec
            key, sub = jax.random.split(key)
            scale = (2.0 / (k * k * c)) ** 0.5
            params[f"conv{i}_w"] = (jax.random.normal(sub, (k, k, c, cout)) * scale).astype(dtype)
            params[f"conv{i}_b"] = jnp.zeros((cout,), dtype)
        elif spec[0] == "fc":
            n_in = h * w * c
            n_out = spec[1]
            key, sub = jax.random.split(key)
            scale = (2.0 / n_in) ** 0.5
            params[f"fc{i}_w"] = (jax.random.normal(sub, (n_in, n_out)) * scale).astype(dtype)
            params[f"fc{i}_b"] = jnp.zeros((n_out,), dtype)
    return params


def _conv_via_jobs(x, w, b, stride, pad, tile, name, engine=None,
                   job_class=None):
    """CONV -> im2col -> synergy_matmul (tile jobs) -> bias+relu epilogue."""
    kh, kw, cin, cout = w.shape
    n, h, wd, _ = x.shape
    oh, ow = conv_out_shape(h, wd, kh, kw, stride, pad)
    a = im2col(x, kh, kw, stride, pad).reshape(n * oh * ow, kh * kw * cin)
    y = synergy_matmul(a, w.reshape(-1, cout), bias=b,
                       activation=jax.nn.relu, tile=tile, name=name,
                       engine=engine, job_class=job_class)
    return y.reshape(n, oh, ow, cout)


def cnn_forward(cfg: CNNConfig, params: dict, x: jax.Array, *,
                engine: str | None = None,
                job_class: str | None = None,
                runtime=None) -> jax.Array:
    """x: (N, H, W, Cin) -> logits (N, num_classes).

    ``engine``: pin every GEMM to a registered engine; None lets the
    dispatcher rank capable engines per GEMM (the default).
    ``job_class``: precision-routing policy for every GEMM
    (:data:`repro.engines.JOB_CLASSES`) — ``"decode"`` prefers registered
    int8 engines (error-tolerant inference), ``"train"`` requires
    grad-safe full-precision paths.
    ``runtime``: a :class:`repro.soc.SynergyRuntime` — every CONV/FC GEMM
    is split across its engine pool and balanced by work stealing (with
    ``engine`` demoted to a queue-affinity hint).  Don't combine with
    ``jax.jit`` — traced arrays fall back to single-engine dispatch."""
    import contextlib
    if runtime is not None:
        from repro.soc import runtime_scope
        scope = runtime_scope(runtime)
    else:
        scope = contextlib.nullcontext()
    with scope:
        return _cnn_forward(cfg, params, x, engine=engine,
                            job_class=job_class)


def _cnn_forward(cfg: CNNConfig, params: dict, x: jax.Array, *,
                 engine: str | None = None,
                 job_class: str | None = None) -> jax.Array:
    shapes, _ = cfg.trace_shapes()
    for i, (spec, *_rest) in enumerate(shapes):
        if spec[0] == "conv":
            _, cout, k, s, p = spec
            x = _conv_via_jobs(x, params[f"conv{i}_w"], params[f"conv{i}_b"],
                               s, p, cfg.tile, f"{cfg.name}/conv{i}",
                               engine=engine, job_class=job_class)
        elif spec[0] == "pool":
            x = maxpool2d(x, spec[1])
        elif spec[0] == "fc":
            n = x.shape[0]
            x = x.reshape(n, -1)
            last = all(s2[0] != "fc" for s2, *_ in shapes[i + 1:])
            act = None if last else jax.nn.relu
            x = synergy_matmul(x, params[f"fc{i}_w"], bias=params[f"fc{i}_b"],
                               activation=act, tile=cfg.tile,
                               name=f"{cfg.name}/fc{i}", engine=engine,
                               job_class=job_class)
    return x


def cnn_flops_per_frame(cfg: CNNConfig) -> int:
    total = 0
    shapes, _ = cfg.trace_shapes()
    for spec, h, w, c in shapes:
        if spec[0] == "conv":
            _, cout, k, s, p = spec
            oh, ow = conv_out_shape(h, w, k, k, s, p)
            total += 2 * oh * ow * cout * k * k * c
        elif spec[0] == "fc":
            total += 2 * h * w * c * spec[1]
    return total


def conv_jobsets(cfg: CNNConfig, n_frames: int = 1, *,
                 tile: int | tuple | None = None,
                 name_prefix: str = "") -> list[tuple[int, JobSet]]:
    """The per-CONV-layer im2col GEMM JobSets of an ``n_frames`` image
    batch: ``[(layer_index, JobSet), ...]`` in network order.

    This is the ONE conv-as-GEMM shape source shared by the DES exporter
    (:func:`build_simnet`, ``n_frames=1``) and the serving prefill path
    (``n_frames`` = all frames of an admission wave), so server prefill
    busy-seconds and simulator busy-seconds read the same cost model over
    the same jobs by construction."""
    out: list[tuple[int, JobSet]] = []
    shapes, _ = cfg.trace_shapes()
    conv_id = 0
    for i, (spec, h, w, c) in enumerate(shapes):
        if spec[0] != "conv":
            continue
        _, cout, k, s, p = spec
        js = JobSet.for_conv(conv_id, n_frames, h, w, c, cout, k, s, p,
                             tile if tile is not None else cfg.tile,
                             name=f"{name_prefix}{cfg.name}/conv{i}")
        out.append((i, js))
        conv_id += 1
    return out


def conv_graph_steps(cfg: CNNConfig) -> list[tuple]:
    """Per-CONV-layer dataflow geometry for graph construction:
    ``[(layer_index, pools_before, (k, stride, pad), (oh, ow, cout)),
    ...]`` in network order, where ``pools_before`` are the CPU-side max
    pool sizes between the previous conv and this one.  The conv
    front-end ends at the first FC layer (matching the serving prefill
    chain)."""
    out: list[tuple] = []
    shapes, _ = cfg.trace_shapes()
    pools: list[int] = []
    for i, (spec, h, w, c) in enumerate(shapes):
        if spec[0] == "pool":
            pools.append(spec[1])
        elif spec[0] == "conv":
            _, cout, k, s, p = spec
            oh, ow = conv_out_shape(h, w, k, k, s, p)
            out.append((i, tuple(pools), (k, s, p), (oh, ow, cout)))
            pools = []
        else:                         # fc: conv front-end ends here
            break
    return out


def conv_wave_graph(cfg: CNNConfig, params: dict, x0: jax.Array,
                    steps: Sequence[tuple], jobsets: Sequence[JobSet],
                    n_frames: int, *, in_shape: tuple | None = None,
                    affinity: str | None = None,
                    job_class: str | None = "prefill",
                    im2col_fn=None, qos=None):
    """Build the ``(nodes, edges)`` dataflow graph of one prefill wave's
    conv front-end over a consecutive slice of :func:`conv_graph_steps`.

    Layer *l* becomes two nodes: a HOST gather node (reshape the previous
    GEMM's flat output, apply the CPU-side pools, one
    :func:`~repro.core.im2col.im2col_wave` over the whole wave) and a
    GEMM node (``submit_gemm`` of the im2col panel against the conv
    weights) — so layer *l+1*'s gather overlaps layer *l*'s GEMM compute,
    the NEURAghe-style producer/consumer overlap the chain never had.

    ``x0``: the slice's input — the stacked wave frames for the first
    chunk, or the previous chunk's flat GEMM output (then pass
    ``in_shape`` to restore (N, H, W, C)).  The LAST node's value is the
    final conv's flat ``(m, cout)`` output.  ``im2col_fn`` overrides the
    gather primitive (the serving engine passes its own module reference
    so instrumentation hooks on that module see every wave gather);
    ``qos`` attaches a :class:`repro.soc.qos_policy.QosTag` to every GEMM
    node's panels, so a chunked prefill wave schedules at its tenants'
    class and decode-class work preempts it at chunk boundaries."""
    from repro.core.im2col import im2col_wave
    from repro.soc.graph import GraphNode
    if im2col_fn is None:
        im2col_fn = im2col_wave

    nodes: list = []
    edges: list[tuple[int, int]] = []
    prev_gemm: int | None = None
    prev_shape = in_shape
    for (i, pools, (k, s, p), (oh, ow, cout)), js in zip(steps, jobsets):

        def gather(rt, *pred, _pools=pools, _k=k, _s=s, _p=p,
                   _shape=prev_shape):
            x = pred[0].reshape(_shape) if pred else (
                x0.reshape(_shape) if _shape is not None else x0)
            for size in _pools:
                x = maxpool2d(x, size)
            return im2col_fn(x, _k, _k, _s, _p)

        def gemm(rt, a, _i=i, _js=js, _cout=cout):
            return rt.submit_gemm(
                a, params[f"conv{_i}_w"].reshape(-1, _cout), jobset=_js,
                bias=params[f"conv{_i}_b"], activation=jax.nn.relu,
                tile=(_js.ts_m, _js.ts_n, _js.ts_k), job_class=job_class,
                affinity=affinity, qos=qos)

        gi = len(nodes)
        nodes.append(GraphNode(name=f"{js.name}/gather", run=gather))
        if prev_gemm is not None:
            edges.append((prev_gemm, gi))
        nodes.append(GraphNode(name=js.name, run=gemm))
        edges.append((gi, gi + 1))
        prev_gemm = gi + 1
        prev_shape = (n_frames, oh, ow, cout)
    return nodes, edges


def build_simnet(cfg: CNNConfig) -> SimNet:
    """Export as a SimNet for the discrete-event runtime simulator.

    CONV layers -> accelerated tile-job stages (+ im2col CPU cost);
    pool/fc -> CPU stages; plus the paper's normalization preprocessing."""
    layers: list[SimLayer] = []
    shapes, _ = cfg.trace_shapes()
    # normalization / scaling preprocessing (§3.1.4)
    n_in_elems = cfg.input_hw * cfg.input_hw * cfg.cin
    layers.append(SimLayer("norm", "cpu", cpu_ops=4 * n_in_elems))
    # DES layer names are bare conv{i} (no net prefix): keep them stable
    conv_js = {i: dataclasses.replace(js, name=f"conv{i}")
               for i, js in conv_jobsets(cfg)}
    for i, (spec, h, w, c) in enumerate(shapes):
        if spec[0] == "conv":
            js = conv_js[i]
            # im2col writes m*k floats (fp32), reads input once
            layers.append(SimLayer(f"conv{i}", "conv", jobset=js,
                                   im2col_bytes=4 * (js.m * js.k
                                                     + h * w * c)))
        elif spec[0] == "pool":
            size = spec[1]
            layers.append(SimLayer(f"pool{i}", "cpu",
                                   cpu_ops=h * w * c))
        elif spec[0] == "fc":
            layers.append(SimLayer(f"fc{i}", "cpu",
                                   cpu_ops=2 * h * w * c * spec[1]))
    return SimNet(cfg.name, tuple(layers))
