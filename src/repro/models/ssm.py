"""Mamba2 block (SSD) — used by mamba2-130m and the zamba2 hybrid.

Block layout (Dao & Gu 2024): projections -> [z | x | B | C | dt], causal
depthwise conv1d over x and (B,C), SiLU, SSD scan (the Pallas/XLA chunked
kernel), gated RMSNorm (y * silu(z)), out projection.

TP note (16-way `model` axis): the SSD head count (24 for mamba2-130m, 80
for zamba2) does not divide 16, but the head dim P (=64) does — and P is a
pure batch dimension of the scan (all SSD einsums contract Q or N, never
P).  So every x/z tensor is kept STRUCTURED as (..., H, P) with P sharded
on `model`: the whole SSM block then runs with zero collectives except the
out-projection psum (row-parallel).  This is why the projections are
separate structured weights instead of one fused in_proj.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd import ssd
from .layers import init_dense

__all__ = ["init_mamba2", "mamba2_block", "mamba2_decode_step",
           "init_mamba2_state", "CONV_K"]

CONV_K = 4


def init_mamba2(key, d_model: int, d_inner: int, ssm_state: int,
                head_dim: int, dtype=jnp.float32) -> dict:
    h = d_inner // head_dim
    n = ssm_state
    keys = jax.random.split(key, 7)
    scale = d_model ** -0.5

    def w3(k, out_a, out_b):
        return (jax.random.normal(k, (d_model, out_a, out_b))
                * scale).astype(dtype)

    return {
        "wz": w3(keys[0], h, head_dim),
        "wx": w3(keys[1], h, head_dim),
        "wbc": init_dense(keys[2], d_model, 2 * n, dtype),
        "wdt": init_dense(keys[3], d_model, h, dtype),
        "conv_wx": (jax.random.normal(keys[4], (CONV_K, h, head_dim))
                    / CONV_K).astype(dtype),
        "conv_wbc": (jax.random.normal(keys[5], (CONV_K, 2 * n))
                     / CONV_K).astype(dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_scale": jnp.ones((h, head_dim), dtype),
        "out_proj": (jax.random.normal(keys[6], (h, head_dim, d_model))
                     * d_inner ** -0.5).astype(dtype),
    }


def _gated_rms_hp(y: jax.Array, z: jax.Array, scale: jax.Array,
                  eps: float) -> jax.Array:
    """RMSNorm over the full (H, P) inner dim of y * silu(z)."""
    dt = y.dtype
    g = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32)))
    var = jnp.mean(jnp.square(g), axis=(-2, -1), keepdims=True)
    return (g * jax.lax.rsqrt(var + eps) * scale).astype(dt)


def _conv_shift(x: jax.Array, i: int, l: int):
    """x padded (B, L+K-1, ...) -> window i (B, L, ...)."""
    return jax.lax.dynamic_slice_in_dim(x, i, l, axis=1)


def mamba2_block(params: dict, x: jax.Array, *, d_inner: int, ssm_state: int,
                 head_dim: int, chunk: int = 128, eps: float = 1e-5,
                 impl: str = "auto", name: str = "mamba") -> jax.Array:
    """x (B, L, d) -> (B, L, d)."""
    b, l, _ = x.shape
    n = ssm_state
    h = d_inner // head_dim

    z = jnp.einsum("bld,dhp->blhp", x, params["wz"].astype(x.dtype))
    xs = jnp.einsum("bld,dhp->blhp", x, params["wx"].astype(x.dtype))
    bc = jnp.einsum("bld,dn->bln", x, params["wbc"].astype(x.dtype))
    dt = jnp.einsum("bld,dh->blh", x, params["wdt"].astype(x.dtype))

    # causal depthwise conv1d (kernel CONV_K), structured for x / flat for BC
    xs_p = jnp.pad(xs, ((0, 0), (CONV_K - 1, 0), (0, 0), (0, 0)))
    xs = sum(_conv_shift(xs_p, i, l) * params["conv_wx"][i][None, None]
             for i in range(CONV_K))
    bc_p = jnp.pad(bc, ((0, 0), (CONV_K - 1, 0), (0, 0)))
    bc = sum(_conv_shift(bc_p, i, l) * params["conv_wbc"][i][None, None]
             for i in range(CONV_K))
    xs = jax.nn.silu(xs)
    bc = jax.nn.silu(bc)
    bm, cm = bc[..., :n], bc[..., n:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    y, _ = ssd(xs, dt, a, bm, cm, chunk=chunk, impl=impl)   # (B,L,H,P)
    y = y + params["d_skip"].astype(y.dtype)[None, None, :, None] * xs
    y = _gated_rms_hp(y, z, params["norm_scale"], eps)
    return jnp.einsum("blhp,hpd->bld", y,
                      params["out_proj"].astype(y.dtype)).astype(x.dtype)


def init_mamba2_state(batch: int, d_inner: int, ssm_state: int,
                      head_dim: int, dtype=jnp.float32) -> dict:
    h = d_inner // head_dim
    return {
        "conv_x": jnp.zeros((batch, CONV_K - 1, h, head_dim), dtype),
        "conv_bc": jnp.zeros((batch, CONV_K - 1, 2 * ssm_state), dtype),
        "ssm": jnp.zeros((batch, h, head_dim, ssm_state), jnp.float32),
    }


def mamba2_decode_step(params: dict, x: jax.Array, state: dict, *,
                       d_inner: int, ssm_state: int, head_dim: int,
                       eps: float = 1e-5, name: str = "mamba"):
    """One-token decode.  x (B, 1, d) -> (y (B, 1, d), new state)."""
    b = x.shape[0]
    n = ssm_state
    h = d_inner // head_dim

    z = jnp.einsum("bld,dhp->blhp", x, params["wz"].astype(x.dtype))
    xs = jnp.einsum("bld,dhp->blhp", x, params["wx"].astype(x.dtype))
    bc = jnp.einsum("bld,dn->bln", x, params["wbc"].astype(x.dtype))
    dt = jnp.einsum("bld,dh->blh", x, params["wdt"].astype(x.dtype))

    win_x = jnp.concatenate([state["conv_x"], xs], axis=1)      # (B,K,H,P)
    win_bc = jnp.concatenate([state["conv_bc"], bc], axis=1)    # (B,K,2N)
    xs1 = jax.nn.silu((win_x * params["conv_wx"][None]).sum(axis=1))
    bc1 = jax.nn.silu((win_bc * params["conv_wbc"][None]).sum(axis=1))
    bm, cm = bc1[..., :n], bc1[..., n:]

    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt1 * a[None, :])                           # (B,H)
    xdt = xs1.astype(jnp.float32) * dt1[..., None]              # (B,H,P)
    s = state["ssm"] * decay[..., None, None] + (
        xdt[..., :, None] * bm[:, None, None, :])               # (B,H,P,N)
    y = jnp.einsum("bhpn,bn->bhp", s, cm.astype(jnp.float32))
    y = y + params["d_skip"][None, :, None] * xs1.astype(jnp.float32)
    y = _gated_rms_hp(y[:, None].astype(x.dtype), z,
                      params["norm_scale"], eps)                # (B,1,H,P)
    out = jnp.einsum("blhp,hpd->bld", y,
                     params["out_proj"].astype(y.dtype)).astype(x.dtype)
    return out, {"conv_x": win_x[:, 1:], "conv_bc": win_bc[:, 1:], "ssm": s}
