"""LM assembly for all assigned families.

Families:
  dense   — pre-norm GQA attention + GLU MLP           (internlm2, granite,
            phi3, gemma; vlm backbone = dense over patch embeddings)
  moe     — attention + expert-choice MoE FFN          (dbrx, kimi-k2)
  ssm     — Mamba2 blocks only                         (mamba2-130m)
  hybrid  — Mamba2 backbone + ONE shared attn+MLP block applied every
            ``attn_every`` layers (zamba2 signature)
  audio   — whisper-style encoder-decoder (frontend stubbed to embeddings)

Layer stacks are parameter-stacked and iterated with ``lax.scan`` (small
HLO for the 512-device dry-run); per-layer remat via ``jax.checkpoint`` when
cfg.remat.  Decode threads per-layer caches through the same scan.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.synergy_mm import synergy_matmul
from .attention import (attention, decode_attend, decode_attention,
                        decode_project_kv, init_attention, project_kv)
from .layers import glu_mlp, init_dense, init_glu_mlp, rms_norm, softmax_xent
from .moe import init_moe, moe_ffn
from .ssm import (CONV_K, init_mamba2, init_mamba2_state, mamba2_block,
                  mamba2_decode_step)

__all__ = ["init_lm", "lm_forward", "lm_loss", "init_cache", "decode_step",
           "prefill"]


# ---------------------------------------------------------------------------
# block init / forward
# ---------------------------------------------------------------------------

def _init_attn_block(cfg: ArchConfig, key, cross: bool = False) -> dict:
    keys = jax.random.split(key, 4)
    dt = cfg.param_jdtype
    p = {
        "ln1": jnp.ones((cfg.d_model,), dt),
        "attn": init_attention(keys[0], cfg.d_model, cfg.n_heads,
                               cfg.n_kv_heads, cfg.resolved_head_dim, dt),
        "ln2": jnp.ones((cfg.d_model,), dt),
    }
    if cross:
        p["ln_x"] = jnp.ones((cfg.d_model,), dt)
        p["cross"] = init_attention(keys[1], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.resolved_head_dim, dt)
    if cfg.family == "moe":
        p["moe"] = init_moe(keys[2], cfg.d_model, cfg.d_ff, cfg.n_experts, dt)
    else:
        p["mlp"] = init_glu_mlp(keys[3], cfg.d_model, cfg.d_ff, dt)
    return p


def _init_mamba_block(cfg: ArchConfig, key) -> dict:
    return {
        "ln": jnp.ones((cfg.d_model,), cfg.param_jdtype),
        "mixer": init_mamba2(key, cfg.d_model, cfg.d_inner, cfg.ssm_state,
                             cfg.ssm_head_dim, cfg.param_jdtype),
    }


def _attn_kw(cfg: ArchConfig) -> dict:
    return dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta)


def _attn_block_fwd(cfg: ArchConfig, p: dict, x: jax.Array, *,
                    causal: bool = True, enc: jax.Array | None = None,
                    impl: str = "auto") -> jax.Array:
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + attention(p["attn"], h, causal=causal, impl=impl, **_attn_kw(cfg))
    if enc is not None:
        h = rms_norm(x, p["ln_x"], cfg.norm_eps)
        x = x + attention(p["cross"], h, kv_x=enc, causal=False,
                          use_rope=False, impl=impl, **_attn_kw(cfg))
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        x = x + moe_ffn(p["moe"], h, top_k=cfg.top_k,
                        capacity_factor=cfg.capacity_factor, act=cfg.act)
    else:
        x = x + glu_mlp(p["mlp"], h, act=cfg.act)
    return x


def _mamba_block_fwd(cfg: ArchConfig, p: dict, x: jax.Array,
                     impl: str = "auto") -> jax.Array:
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    return x + mamba2_block(p["mixer"], h, d_inner=cfg.d_inner,
                            ssm_state=cfg.ssm_state,
                            head_dim=cfg.ssm_head_dim,
                            chunk=cfg.ssm_chunk, eps=cfg.norm_eps, impl=impl)


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def _stacked(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_lm(cfg: ArchConfig, key) -> dict:
    keys = jax.random.split(key, 6)
    dt = cfg.param_jdtype
    params: dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (cfg.padded_vocab, cfg.d_model))
                  * 0.02).astype(dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(keys[1], cfg.d_model,
                                       cfg.padded_vocab, dt)
    if cfg.family in ("dense", "moe", "vlm"):
        params["blocks"] = _stacked(
            lambda k: _init_attn_block(cfg, k), keys[2], cfg.n_layers)
    elif cfg.family == "ssm":
        params["blocks"] = _stacked(
            lambda k: _init_mamba_block(cfg, k), keys[2], cfg.n_layers)
    elif cfg.family == "hybrid":
        params["blocks"] = _stacked(
            lambda k: _init_mamba_block(cfg, k), keys[2], cfg.n_layers)
        params["shared"] = _init_attn_block(cfg, keys[3])
    elif cfg.family == "audio":
        params["blocks"] = _stacked(
            lambda k: _init_attn_block(cfg, k, cross=True), keys[2],
            cfg.n_layers)
        params["encoder"] = _stacked(
            lambda k: _init_attn_block(cfg, k), keys[4], cfg.encoder_layers)
        params["enc_norm"] = jnp.ones((cfg.d_model,), dt)
    else:
        raise ValueError(cfg.family)
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _constrain_batch(x: jax.Array) -> jax.Array:
    """§Perf iteration: pin the residual stream to batch-sharding over the
    data axes.  Without this the partitioner flip-flops (e.g. internlm2
    prefill ran its MLP batch-REPLICATED, paying a 4.3 GB collective-permute
    3x per layer).  No-op outside a mesh context (CPU unit tests)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        names = getattr(mesh, "axis_names", ())
        if not names or "model" not in names:
            return x
        dp = tuple(a for a in names if a != "model")
        if x.shape[0] % _mesh_size(mesh, dp):
            return x
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(
            x, P(dp, *([None] * (x.ndim - 1))))
    except Exception:
        return x


def _mesh_size(mesh, axes) -> int:
    total = 1
    for a in axes:
        total *= dict(zip(mesh.axis_names, mesh.axis_sizes))[a]
    return total


def _scan_blocks(body, x, stacked, remat: bool):
    inner = body

    def constrained(p, h):
        return _constrain_batch(inner(p, _constrain_batch(h)))

    f = jax.checkpoint(constrained) if remat else constrained

    def step(carry, p):
        return f(p, carry), None

    x, _ = jax.lax.scan(step, x, stacked)
    return x


def _grouped(tree, groups: int):
    return jax.tree.map(
        lambda a: a.reshape((groups, a.shape[0] // groups) + a.shape[1:]),
        tree)


def _backbone(cfg: ArchConfig, params: dict, x: jax.Array, *,
              enc: jax.Array | None = None, impl: str = "auto") -> jax.Array:
    if cfg.family in ("dense", "moe", "vlm"):
        body = lambda p, h: _attn_block_fwd(cfg, p, h, impl=impl)
        x = _scan_blocks(body, x, params["blocks"], cfg.remat)
    elif cfg.family == "ssm":
        body = lambda p, h: _mamba_block_fwd(cfg, p, h, impl=impl)
        x = _scan_blocks(body, x, params["blocks"], cfg.remat)
    elif cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        stacked = _grouped(params["blocks"], groups)
        inner = lambda p, h: _mamba_block_fwd(cfg, p, h, impl=impl)
        shared = params["shared"]

        def group_body(h, grp):
            h = _scan_blocks(inner, h, grp, cfg.remat)
            h = _attn_block_fwd(cfg, shared, h, impl=impl)
            return h, None

        x, _ = jax.lax.scan(group_body, x, stacked)
    elif cfg.family == "audio":
        body = lambda p, h: _attn_block_fwd(cfg, p, h, enc=enc, impl=impl)
        x = _scan_blocks(body, x, params["blocks"], cfg.remat)
    return x


def _encode(cfg: ArchConfig, params: dict, enc_embeds: jax.Array,
            impl: str = "auto") -> jax.Array:
    body = lambda p, h: _attn_block_fwd(cfg, p, h, causal=False, impl=impl)
    enc = _scan_blocks(body, enc_embeds.astype(cfg.compute_jdtype),
                       params["encoder"], cfg.remat)
    return rms_norm(enc, params["enc_norm"], cfg.norm_eps)


def _head(cfg: ArchConfig, params: dict, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return synergy_matmul(x, w.astype(x.dtype), name="lm_head",
                          out_dtype=jnp.float32)


def lm_forward(cfg: ArchConfig, params: dict, *,
               tokens: jax.Array | None = None,
               embeds: jax.Array | None = None,
               enc_embeds: jax.Array | None = None,
               impl: str = "auto") -> jax.Array:
    """Full-sequence forward -> logits (B, S, padded_vocab) fp32."""
    if embeds is None:
        embeds = params["embed"][tokens]
    x = embeds.astype(cfg.compute_jdtype)
    enc = (_encode(cfg, params, enc_embeds, impl)
           if cfg.family == "audio" else None)
    x = _backbone(cfg, params, x, enc=enc, impl=impl)
    return _head(cfg, params, x)


def lm_loss(cfg: ArchConfig, params: dict, batch: dict, *,
            impl: str = "auto") -> jax.Array:
    logits = lm_forward(
        cfg, params,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        enc_embeds=batch.get("enc_embeds"),
        impl=impl)
    return softmax_xent(logits, batch["labels"], z_loss=1e-4)


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode with caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=None) -> dict:
    dtype = dtype or (jnp.dtype(cfg.cache_dtype) if cfg.cache_dtype
                      else cfg.compute_jdtype)
    hd = cfg.resolved_head_dim if cfg.n_heads else 0
    kv = lambda n, s: jnp.zeros((n, batch, cfg.n_kv_heads, s, hd), dtype)

    def mamba_states(n):
        # SSM states stay in the compute dtype (they concatenate with live
        # activations each step); only attention K/V quantize.
        st = init_mamba2_state(batch, cfg.d_inner, cfg.ssm_state,
                               cfg.ssm_head_dim, cfg.compute_jdtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), st)

    if cfg.family in ("dense", "moe", "vlm"):
        return {"k": kv(cfg.n_layers, max_len), "v": kv(cfg.n_layers, max_len)}
    if cfg.family == "ssm":
        return mamba_states(cfg.n_layers)
    if cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        return {"mamba": mamba_states(cfg.n_layers),
                "k": kv(groups, max_len), "v": kv(groups, max_len)}
    if cfg.family == "audio":
        return {"k": kv(cfg.n_layers, max_len), "v": kv(cfg.n_layers, max_len),
                "xk": kv(cfg.n_layers, cfg.encoder_len),
                "xv": kv(cfg.n_layers, cfg.encoder_len)}
    raise ValueError(cfg.family)


def prepare_cross_cache(cfg: ArchConfig, params: dict,
                        enc_embeds: jax.Array, impl: str = "auto"):
    """Whisper: run the encoder and project per-decoder-layer cross K/V."""
    enc = _encode(cfg, params, enc_embeds, impl)

    def per_layer(p):
        return project_kv(p["cross"], enc, n_kv_heads=cfg.n_kv_heads,
                          head_dim=cfg.resolved_head_dim, use_rope=False)

    xk, xv = jax.vmap(per_layer)(params["blocks"])
    return xk, xv


def _layer_slice(tree, l):
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, l, 0, keepdims=False),
        tree)


def _write_token_kv(K, V, kk, vv, l, pos):
    """§Perf D1: in-place token-slice insert into the global (L,B,H,S,hd)
    caches — a scan-ys formulation rewrites the ENTIRE cache every decode
    step (measured 10-20x the minimal decode traffic).

    ``pos`` scalar: every batch row writes at the same position.
    ``pos`` (B,) vector: each slot writes at ITS OWN position; rows with
    ``pos < 0`` are skipped entirely (inactive / non-target slots — the
    continuous-batching server relies on this to keep live requests' cache
    entries untouched during another request's prefill)."""
    if jnp.ndim(pos) == 0:
        zero = jnp.int32(0)
        K = jax.lax.dynamic_update_slice(K, kk[None].astype(K.dtype),
                                         (l, zero, zero, pos, zero))
        V = jax.lax.dynamic_update_slice(V, vv[None].astype(V.dtype),
                                         (l, zero, zero, pos, zero))
        return K, V

    def write(full, new):
        layer = jax.lax.dynamic_index_in_dim(full, l, 0, keepdims=False)

        def one_row(row, tok, p):        # row (H,S,hd); tok (H,1,hd)
            # masked rows re-write their CURRENT slice (token-sized no-op)
            # instead of selecting over the whole layer — keeps the D1
            # token-slice traffic profile for the vector-pos path too
            p0 = jnp.maximum(p, 0)
            cur = jax.lax.dynamic_slice(
                row, (0, p0, 0), (row.shape[0], 1, row.shape[2]))
            tok = jnp.where(p >= 0, tok.astype(row.dtype), cur)
            return jax.lax.dynamic_update_slice(row, tok, (0, p0, 0))

        layer = jax.vmap(one_row)(layer, new, pos)
        return jax.lax.dynamic_update_index_in_dim(full, layer, l, 0)

    return write(K, kk), write(V, vv)


def _decode_attn_block_inplace(cfg, p, x, K, V, l, pos, xk=None, xv=None):
    """One decoder block; K/V are the GLOBAL stacked caches."""
    kw = _attn_kw(cfg)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    kk, vv = decode_project_kv(p["attn"], h, pos,
                               n_kv_heads=cfg.n_kv_heads,
                               head_dim=cfg.resolved_head_dim,
                               rope_theta=cfg.rope_theta)
    K, V = _write_token_kv(K, V, kk, vv, l, pos)
    x = x + decode_attend(p["attn"], h, _layer_slice(K, l),
                          _layer_slice(V, l), pos, **kw)
    if xk is not None:
        h = rms_norm(x, p["ln_x"], cfg.norm_eps)
        x = x + decode_attend(p["cross"], h, xk, xv,
                              jnp.int32(cfg.encoder_len - 1),
                              use_rope=False, **kw)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        b = h.shape[0]
        y = moe_ffn(p["moe"], h.reshape(1, b, cfg.d_model), top_k=cfg.top_k,
                    capacity_factor=cfg.capacity_factor, act=cfg.act)
        x = x + y.reshape(b, 1, cfg.d_model)
    else:
        x = x + glu_mlp(p["mlp"], h, act=cfg.act)
    return x, K, V


def _decode_mamba_inplace(cfg, p, x, mcache, l, pos=None):
    """Mamba block with in-place state update into the stacked caches.

    Per-slot ``pos`` (B,) vectors mask the recurrent-state update the same
    way ``_write_token_kv`` masks K/V: rows with ``pos < 0`` keep their
    state untouched (bystander slots during another request's prefill)."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    st_old = _layer_slice(mcache, l)
    y, st = mamba2_decode_step(p["mixer"], h, st_old, d_inner=cfg.d_inner,
                               ssm_state=cfg.ssm_state,
                               head_dim=cfg.ssm_head_dim,
                               eps=cfg.norm_eps)
    if pos is not None and jnp.ndim(pos):
        keep = pos >= 0
        st = jax.tree.map(
            lambda new, old: jnp.where(
                keep.reshape((-1,) + (1,) * (old.ndim - 1)),
                new.astype(old.dtype), old),
            st, st_old)
    mcache = jax.tree.map(
        lambda full, new: jax.lax.dynamic_update_index_in_dim(
            full, new.astype(full.dtype), l, 0),
        mcache, st)
    return x + y, mcache


def decode_step(cfg: ArchConfig, params: dict, cache: dict,
                tokens: jax.Array, pos: jax.Array) -> tuple[jax.Array, dict]:
    """One decode step.  tokens (B, 1) int32 (or (B, 1, d) embeds for
    frontend archs); pos: scalar cache index, OR a per-slot (B,) vector for
    continuous batching — each slot reads/writes at its own position, and
    slots with ``pos < 0`` are masked out of every cache write (their
    logits are garbage and must be ignored).  Returns
    (logits (B, 1, V), new cache).

    §Perf D1: layers iterate via fori_loop carrying the GLOBAL caches and
    updating them with token-sized dynamic slices — the cache buffers alias
    in place under donation instead of being rewritten every step."""
    if cfg.takes_embeddings and tokens.ndim == 3:
        x = tokens.astype(cfg.compute_jdtype)
    else:
        x = params["embed"][tokens].astype(cfg.compute_jdtype)

    if cfg.family in ("dense", "moe", "vlm"):
        def step(l, carry):
            h, K, V = carry
            p = _layer_slice(params["blocks"], l)
            h, K, V = _decode_attn_block_inplace(cfg, p, h, K, V, l, pos)
            return h, K, V
        x, k, v = jax.lax.fori_loop(0, cfg.n_layers, step,
                                    (x, cache["k"], cache["v"]))
        cache = {"k": k, "v": v}
    elif cfg.family == "ssm":
        def step(l, carry):
            h, mc = carry
            p = _layer_slice(params["blocks"], l)
            h, mc = _decode_mamba_inplace(cfg, p, h, mc, l, pos)
            return h, mc
        x, cache = jax.lax.fori_loop(0, cfg.n_layers, step, (x, cache))
    elif cfg.family == "hybrid":
        per = cfg.attn_every
        groups = cfg.n_layers // per
        shared = params["shared"]

        def group(g, carry):
            h, mc, K, V = carry

            def inner(i, c2):
                hh, mc2 = c2
                l = g * per + i
                p = _layer_slice(params["blocks"], l)
                hh, mc2 = _decode_mamba_inplace(cfg, p, hh, mc2, l, pos)
                return hh, mc2
            h, mc = jax.lax.fori_loop(0, per, inner, (h, mc))
            h, K, V = _decode_attn_block_inplace(cfg, shared, h, K, V, g,
                                                 pos)
            return h, mc, K, V

        x, mst, k, v = jax.lax.fori_loop(
            0, groups, group, (x, cache["mamba"], cache["k"], cache["v"]))
        cache = {"mamba": mst, "k": k, "v": v}
    elif cfg.family == "audio":
        def step(l, carry):
            h, K, V = carry
            p = _layer_slice(params["blocks"], l)
            xk = _layer_slice(cache["xk"], l)
            xv = _layer_slice(cache["xv"], l)
            h, K, V = _decode_attn_block_inplace(cfg, p, h, K, V, l, pos,
                                                 xk, xv)
            return h, K, V
        x, k, v = jax.lax.fori_loop(0, cfg.n_layers, step,
                                    (x, cache["k"], cache["v"]))
        cache = {"k": k, "v": v, "xk": cache["xk"], "xv": cache["xv"]}
    else:
        raise ValueError(cfg.family)

    return _head(cfg, params, x), cache


def prefill(cfg: ArchConfig, params: dict, *,
            tokens: jax.Array | None = None,
            embeds: jax.Array | None = None,
            enc_embeds: jax.Array | None = None,
            impl: str = "auto") -> jax.Array:
    """Prefill forward: full-sequence backbone, last-token logits only
    (sliced BEFORE the vocab head so the (B, S, V) logits tensor never
    materializes at 32k/500k sequence lengths)."""
    if embeds is None:
        embeds = params["embed"][tokens]
    x = embeds.astype(cfg.compute_jdtype)
    enc = (_encode(cfg, params, enc_embeds, impl)
           if cfg.family == "audio" else None)
    x = _backbone(cfg, params, x, enc=enc, impl=impl)
    return _head(cfg, params, x[:, -1:, :])
