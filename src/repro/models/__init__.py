from .model_zoo import (init_model, loss_fn, prefill_fn, decode_fn,
                        input_specs, cache_specs, param_specs, model_flops)
from .transformer import init_lm, lm_forward, lm_loss, init_cache, decode_step, prefill
from .cnn import CNNConfig, init_cnn, cnn_forward, build_simnet
