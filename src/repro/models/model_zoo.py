"""Model-zoo facade: ArchConfig -> init / step fns / input specs.

``input_specs(cfg, cell)`` returns ShapeDtypeStruct stand-ins for every
model input of a shape cell — weak-type-correct, shardable, zero
allocation — which is what the multi-pod dry-run lowers against.
Modality frontends (vlm/audio) are STUBS per the assignment: the specs
carry precomputed patch/frame embeddings instead of pixels/audio.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell
from .transformer import (decode_step, init_cache, init_lm, lm_forward,
                          lm_loss, prefill)

__all__ = ["init_model", "loss_fn", "prefill_fn", "decode_fn",
           "input_specs", "cache_specs", "param_specs", "model_flops"]

init_model = init_lm
loss_fn = lm_loss
prefill_fn = prefill
decode_fn = decode_step


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one (arch x shape) cell."""
    b, s = cell.global_batch, cell.seq_len
    d = cfg.d_model
    specs: dict[str, Any] = {}
    if cell.kind == "train":
        if cfg.takes_embeddings:
            specs["embeds"] = _sds((b, s, d), jnp.bfloat16)
        else:
            specs["tokens"] = _sds((b, s), jnp.int32)
        if cfg.family == "audio":
            specs["enc_embeds"] = _sds((b, cfg.encoder_len, d), jnp.bfloat16)
        specs["labels"] = _sds((b, s), jnp.int32)
    elif cell.kind == "prefill":
        if cfg.takes_embeddings:
            specs["embeds"] = _sds((b, s, d), jnp.bfloat16)
        else:
            specs["tokens"] = _sds((b, s), jnp.int32)
        if cfg.family == "audio":
            specs["enc_embeds"] = _sds((b, cfg.encoder_len, d), jnp.bfloat16)
    else:  # decode: one new token against a seq_len-deep cache
        if cfg.takes_embeddings:
            specs["tokens"] = _sds((b, 1, d), jnp.bfloat16)
        else:
            specs["tokens"] = _sds((b, 1), jnp.int32)
        specs["pos"] = _sds((), jnp.int32)
        specs["cache"] = cache_specs(cfg, b, s)
    return specs


def cache_specs(cfg: ArchConfig, batch: int, max_len: int):
    dt = jnp.dtype(cfg.cache_dtype) if cfg.cache_dtype else jnp.bfloat16
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len, dtype=dt))


def param_specs(cfg: ArchConfig):
    return jax.eval_shape(
        lambda: init_lm(cfg, jax.random.key(0)))


def model_flops(cfg: ArchConfig, cell: ShapeCell) -> float:
    """MODEL_FLOPS: 6·N·D for train, 2·N·D forward-only (N = active params)."""
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    n = cfg.n_active_params()
    mult = 6 if cell.kind == "train" else 2
    return float(mult) * n * tokens
