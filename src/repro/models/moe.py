"""Mixture-of-Experts FFN (dbrx, kimi-k2) — Synergy job view: each expert's
FFN GEMMs are tile-job sets; routing decides which jobs exist per step, the
EP sharding spreads them over the `model` axis.

Dispatch is **expert-choice with per-group capacity** (Zhou et al.; also the
shape-friendly scheme TPU MoE frameworks use): within each token group,
every expert picks its top-C tokens by router score.  This keeps all shapes
static (C = T·k·cf/E), needs no sorting network, and under pjit the
gather/scatter lower to clean collectives: token groups shard over `data`,
the expert dimension of the weights over `model`, and the combine psum is
the only cross-`model` traffic.

Token-choice top-k with a one-hot capacity dispatch (the dbrx/kimi papers'
routing) is provided as a small-scale oracle (``moe_ffn_tc``) and used in
tests; the EC adaptation is recorded in DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import _ACTS, init_dense

__all__ = ["init_moe", "moe_ffn", "moe_ffn_tc", "ec_capacity"]


def init_moe(key, d_model: int, d_ff: int, n_experts: int,
             dtype=jnp.float32) -> dict:
    kg, k1, k2 = jax.random.split(key, 3)
    scale_in = d_model ** -0.5
    scale_out = d_ff ** -0.5
    return {
        "router": init_dense(kg, d_model, n_experts, jnp.float32),
        "w1": (jax.random.normal(k1, (n_experts, d_model, 2 * d_ff))
               * scale_in).astype(dtype),
        "w2": (jax.random.normal(k2, (n_experts, d_ff, d_model))
               * scale_out).astype(dtype),
    }


def ec_capacity(tokens_per_group: int, n_experts: int, top_k: int,
                capacity_factor: float) -> int:
    c = int(tokens_per_group * top_k * capacity_factor / n_experts)
    c = -(-max(c, 1) // 4) * 4          # round up to a multiple of 4
    return max(1, min(tokens_per_group, c))


def moe_ffn(params: dict, x: jax.Array, *, top_k: int,
            capacity_factor: float = 1.25, act: str = "silu",
            name: str = "moe") -> jax.Array:
    """Expert-choice MoE.  x (G, T, d) — G token groups (batch dim for
    train/prefill; a single group for decode).  Returns (G, T, d)."""
    g, t, d = x.shape
    e = params["router"].shape[1]
    c = ec_capacity(t, e, top_k, capacity_factor)

    logits = jnp.einsum("gtd,de->gte", x.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                    # (G,T,E)
    gate, idx = jax.lax.top_k(probs.transpose(0, 2, 1), c)     # (G,E,C)

    xe = jnp.take_along_axis(x[:, None, :, :],
                             idx[..., None], axis=2)           # (G,E,C,d)
    h = jnp.einsum("gecd,edf->gecf", xe, params["w1"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    gate_h, up = jnp.split(h, 2, axis=-1)
    h = _ACTS[act](gate_h) * up
    o = jnp.einsum("gecf,efd->gecd", h, params["w2"].astype(x.dtype),
                   preferred_element_type=jnp.float32)
    o = o * gate[..., None].astype(o.dtype)

    y = jnp.zeros((g, t, d), o.dtype)
    y = jax.vmap(lambda yg, og, ig: yg.at[ig.reshape(-1)].add(
        og.reshape(-1, d)))(y, o, idx)
    return y.astype(x.dtype)


def moe_ffn_tc(params: dict, x: jax.Array, *, top_k: int,
               act: str = "silu") -> jax.Array:
    """Token-choice top-k oracle (dense over experts — small scale only).
    Every token's output = sum of its top-k experts weighted by the
    normalized router probabilities (dbrx/kimi routing semantics)."""
    g, t, d = x.shape
    e = params["router"].shape[1]
    logits = jnp.einsum("gtd,de->gte", x.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, top_k)                   # (G,T,K)
    topv = topv / topv.sum(axis=-1, keepdims=True)
    # dense compute of all experts, then gather the chosen ones
    h = jnp.einsum("gtd,edf->gtef", x, params["w1"])
    gate_h, up = jnp.split(h, 2, axis=-1)
    h = _ACTS[act](gate_h) * up
    o = jnp.einsum("gtef,efd->gted", h, params["w2"])          # (G,T,E,d)
    sel = jnp.take_along_axis(o, topi[..., None], axis=2)      # (G,T,K,d)
    return (sel * topv[..., None].astype(sel.dtype)).sum(axis=2).astype(x.dtype)
