"""Attention: GQA projections (Synergy GEMM jobs) + three score engines,
registered as ``attention_scores`` op variants in :mod:`repro.engines`:

  * 'pallas'    — the flash-attention Pallas kernel (TPU target).
  * 'flash_xla' — the same online-softmax tiling expressed as a double
                  lax.scan over (q-block, kv-block).  This is what the
                  512-device dry-run lowers: O(blk_q x blk_k) live buffers
                  instead of the O(S^2) naive score matrix.
  * 'ref'       — naive reference (small shapes / oracles only).

GQA is computed grouped — q reshaped to (B, Hkv, group, S, D) — so KV is
never materialized repeated.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.synergy_mm import synergy_matmul
from repro.engines import register_op_impl, resolve_op
from repro.kernels.flash_attention import attention_ref, flash_attention
from .layers import init_dense, rope

__all__ = ["init_attention", "attention", "decode_attention",
           "flash_attention_xla", "project_kv"]

_NEG = -1e30


def _match_vma(init: jax.Array, *refs: jax.Array) -> jax.Array:
    """Give scan-carry initializers the union of the refs' varying manual
    axes (shard_map contexts); no-op outside shard_map or on older jax."""
    try:
        vma: set = set()
        for r in refs:
            vma |= set(getattr(jax.typeof(r), "vma", ()) or ())
        pcast = getattr(jax.lax, "pcast", None)
        if vma and pcast is not None:
            return pcast(init, tuple(sorted(vma)), to="varying")
    except Exception:
        pass
    return init


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype=jnp.float32) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": init_dense(k1, d_model, n_heads * head_dim, dtype),
        "wk": init_dense(k2, d_model, n_kv_heads * head_dim, dtype),
        "wv": init_dense(k3, d_model, n_kv_heads * head_dim, dtype),
        "wo": init_dense(k4, n_heads * head_dim, d_model, dtype,
                         scale=(n_heads * head_dim) ** -0.5),
    }


def flash_attention_xla(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        scale: float | None = None,
                        blk_q: int = 512,
                        blk_k: int = 1024) -> jax.Array:
    """Online-softmax attention as a double scan (XLA path).

    q (B, Hq, S, D); k/v (B, Hkv, Sk, D).  Non-divisible S/Sk are padded
    internally and masked (whisper's 1500-frame encoder etc.)."""
    b, hq, s, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    blk_q = min(blk_q, s)
    blk_k = min(blk_k, sk)
    s_orig, sk_valid = s, sk
    if s % blk_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, (-s) % blk_q), (0, 0)))
        s = q.shape[2]
    if sk % blk_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, (-sk) % blk_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, (-sk) % blk_k), (0, 0)))
        sk = k.shape[2]
    nq, nk = s // blk_q, sk // blk_k
    qg = q.reshape(b, hkv, g, nq, blk_q, d)
    kb = k.reshape(b, hkv, nk, blk_k, d)
    vb = v.reshape(b, hkv, nk, blk_k, d)

    # §Perf A1: the Synergy view of causal flash attention — enumerate the
    # VALID (q-block, kv-block) tile jobs statically and stream them
    # through ONE scan.  Fully-masked future blocks never become jobs, so
    # causal attention does ~half the block work of the naive nq x nk
    # double loop; the scan has a STATIC trip count (differentiable, and
    # the dry-run accounting is exact, unlike a dynamic-bound fori_loop).
    if causal:
        pairs = [(qi, ki) for qi in range(nq)
                 for ki in range(min(nk, (qi * blk_q + blk_q + blk_k - 1)
                                    // blk_k))]
    else:
        pairs = [(qi, ki) for qi in range(nq) for ki in range(nk)]
    qi_arr = jnp.array([p[0] for p in pairs], jnp.int32)
    ki_arr = jnp.array([p[1] for p in pairs], jnp.int32)

    def job(carry, idx):
        m, l, acc, outputs = carry
        qi, ki = qi_arr[idx], ki_arr[idx]
        reset = (ki == 0)
        m = jnp.where(reset, _NEG, m)
        l = jnp.where(reset, 0.0, l)
        acc = jnp.where(reset, 0.0, acc)
        qcur = jax.lax.dynamic_index_in_dim(qg, qi, axis=3, keepdims=False)
        kcur = jax.lax.dynamic_index_in_dim(kb, ki, axis=2, keepdims=False)
        vcur = jax.lax.dynamic_index_in_dim(vb, ki, axis=2, keepdims=False)
        sres = jnp.einsum("bhgqd,bhkd->bhgqk", qcur, kcur,
                          preferred_element_type=jnp.float32) * scale
        # §Perf A2: ADDITIVE (blk_q, blk_k) penalty — broadcast-adds and
        # fuses; a jnp.where select materialized (B,H,g,blk_q,blk_k)
        # pred+f32 buffers per job.
        cols = jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
        pen = jnp.zeros((blk_q, blk_k), jnp.float32)
        if causal:
            rows = jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
            pen = jnp.where(qi * blk_q + rows >= ki * blk_k + cols,
                            pen, _NEG)
        if sk_valid != sk:
            pen = jnp.where(ki * blk_k + cols < sk_valid, pen, _NEG)
        sres = sres + pen[None, None, None]
        m_new = jnp.maximum(m, sres.max(axis=-1, keepdims=True))
        p = jnp.exp(sres - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(vcur.dtype), vcur,
            preferred_element_type=jnp.float32)
        # write the running normalized block at position qi; later jobs of
        # the same q-block overwrite it, so the final write (ki == last)
        # is the complete softmax — no masking, slice-sized traffic.
        out_blk = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
        outputs = jax.lax.dynamic_update_slice_in_dim(
            outputs, out_blk[None], qi, axis=0)
        return (m_new, l, acc, outputs), None

    m0 = jnp.full((b, hkv, g, blk_q, 1), _NEG, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, blk_q, 1), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, blk_q, d), jnp.float32)
    out0 = jnp.zeros((nq, b, hkv, g, blk_q, d), q.dtype)
    # under shard_map (e.g. the pipeline-parallel launch mode) the scan
    # body is device-varying; the zero initializers must carry the same
    # varying-axes type
    m0, l0, a0, out0 = (_match_vma(t, q, k, v) for t in (m0, l0, a0, out0))
    (_, _, _, blocks), _ = jax.lax.scan(
        job, (m0, l0, a0, out0), jnp.arange(len(pairs)))
    # blocks: (nq, B, Hkv, g, blk_q, D) -> (B, Hq, S, D)
    out = jnp.moveaxis(blocks, 0, 3)                 # (B, Hkv, g, nq, blk_q, D)
    return out.reshape(b, hq, s, d)[:, :, :s_orig, :]


register_op_impl(
    "attention_scores", "pallas",
    lambda q, k, v, *, causal, blk_q, blk_k: flash_attention(
        q, k, v, causal=causal, impl="pallas"),
    priority=10, available=lambda: jax.default_backend() == "tpu")
register_op_impl(
    "attention_scores", "flash_xla",
    lambda q, k, v, *, causal, blk_q, blk_k: flash_attention_xla(
        q, k, v, causal=causal, blk_q=blk_q, blk_k=blk_k),
    priority=0)
register_op_impl(
    "attention_scores", "ref",
    lambda q, k, v, *, causal, blk_q, blk_k: attention_ref(
        q, k, v, causal=causal),
    priority=-10)


def _scores_engine(q, k, v, *, causal, impl, blk_q=512, blk_k=1024):
    return resolve_op("attention_scores", impl)(q, k, v, causal=causal,
                                                blk_q=blk_q, blk_k=blk_k)


def attention(params: dict, x: jax.Array, *, n_heads: int, n_kv_heads: int,
              head_dim: int, positions: jax.Array | None = None,
              rope_theta: float = 1e4, causal: bool = True,
              kv_x: jax.Array | None = None, use_rope: bool = True,
              impl: str = "auto", name: str = "attn") -> jax.Array:
    """Full-sequence attention (train / prefill / encoder / cross).

    x (B, S, d).  kv_x: source for K/V (cross-attention); defaults to x.
    """
    b, s, _ = x.shape
    src = x if kv_x is None else kv_x
    sk = src.shape[1]
    q = synergy_matmul(x, params["wq"], name=f"{name}/wq")
    kk = synergy_matmul(src, params["wk"], name=f"{name}/wk")
    vv = synergy_matmul(src, params["wv"], name=f"{name}/wv")
    q = q.reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3)
    kk = kk.reshape(b, sk, n_kv_heads, head_dim).transpose(0, 2, 1, 3)
    vv = vv.reshape(b, sk, n_kv_heads, head_dim).transpose(0, 2, 1, 3)
    if use_rope:
        pos_q = positions if positions is not None else jnp.arange(s)
        q = rope(q, pos_q[None, None, :], rope_theta)
        kk = rope(kk, jnp.arange(sk)[None, None, :], rope_theta)
    if n_heads != n_kv_heads:
        # TP note: under a 16-way model axis none of the GQA archs' kv-head
        # counts divide the mesh, so K/V are expanded to q-heads here (the
        # expanded tensors shard on the q-head dim; the K/V weights stay
        # replicated).  See DESIGN.md sharding fallbacks.
        g = n_heads // n_kv_heads
        kk = jnp.repeat(kk, g, axis=1)
        vv = jnp.repeat(vv, g, axis=1)
    o = _scores_engine(q, kk, vv, causal=causal, impl=impl)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, n_heads * head_dim)
    return synergy_matmul(o, params["wo"], name=f"{name}/wo")


def project_kv(params: dict, src: jax.Array, *, n_kv_heads: int,
               head_dim: int, rope_theta: float = 1e4,
               use_rope: bool = True) -> tuple[jax.Array, jax.Array]:
    """K/V projection for cache prefill (encoder output or prompt)."""
    b, sk, _ = src.shape
    kk = synergy_matmul(src, params["wk"], name="kv/wk")
    vv = synergy_matmul(src, params["wv"], name="kv/wv")
    kk = kk.reshape(b, sk, n_kv_heads, head_dim).transpose(0, 2, 1, 3)
    vv = vv.reshape(b, sk, n_kv_heads, head_dim).transpose(0, 2, 1, 3)
    if use_rope:
        kk = rope(kk, jnp.arange(sk)[None, None, :], rope_theta)
    return kk, vv


def _rope_positions(pos: jax.Array, b: int) -> jax.Array:
    """Broadcastable rope positions for one decode token: scalar ``pos`` ->
    (1, 1, 1); per-slot vector (B,) -> (B, 1, 1).  Negative entries mark
    inactive slots (continuous batching) and are clamped — their output is
    discarded and their cache writes masked."""
    p = jnp.asarray(pos)
    if p.ndim == 0:
        return jnp.full((1, 1, 1), p)
    return jnp.maximum(p, 0).reshape(b, 1, 1)


def _cache_valid_mask(pos: jax.Array, s_max: int) -> jax.Array:
    """(..., s_max) attention mask over cache positions for scalar or
    per-slot (B,) ``pos``."""
    p = jnp.asarray(pos)
    if p.ndim == 0:
        return (jnp.arange(s_max) <= p)[None, None, None, None, :]
    return (jnp.arange(s_max)[None, :]
            <= jnp.maximum(p, 0)[:, None])[:, None, None, None, :]


def decode_project_kv(params: dict, x: jax.Array, pos: jax.Array, *,
                      n_kv_heads: int, head_dim: int,
                      rope_theta: float = 1e4, use_rope: bool = True):
    """Project the new token's K/V -> (B, Hkv, 1, hd) each (for in-place
    cache insertion — §Perf D1).  ``pos``: scalar or per-slot (B,)."""
    b = x.shape[0]
    kk = synergy_matmul(x, params["wk"], name="attn/wk")
    vv = synergy_matmul(x, params["wv"], name="attn/wv")
    kk = kk.reshape(b, 1, n_kv_heads, head_dim).transpose(0, 2, 1, 3)
    vv = vv.reshape(b, 1, n_kv_heads, head_dim).transpose(0, 2, 1, 3)
    if use_rope:
        kk = rope(kk, _rope_positions(pos, b), rope_theta)
    return kk, vv


def decode_attend(params: dict, x: jax.Array, k_cache: jax.Array,
                  v_cache: jax.Array, pos: jax.Array, *, n_heads: int,
                  n_kv_heads: int, head_dim: int, rope_theta: float = 1e4,
                  use_rope: bool = True, name: str = "attn") -> jax.Array:
    """One-token attention against a READ-ONLY cache slice (the new
    token's K/V must already be inserted).  x (B,1,d) -> (B,1,d).
    ``pos``: scalar, or per-slot (B,) vector (continuous batching — each
    slot attends only to its own prefix)."""
    b = x.shape[0]
    g = n_heads // n_kv_heads
    s_max = k_cache.shape[2]
    q = synergy_matmul(x, params["wq"], name=f"{name}/wq")
    q = q.reshape(b, 1, n_heads, head_dim).transpose(0, 2, 1, 3)
    if use_rope:
        q = rope(q, _rope_positions(pos, b), rope_theta)
    qg = q.reshape(b, n_kv_heads, g, 1, head_dim)
    # read the cache at its STORAGE dtype; f32 happens in the MXU
    # accumulator (an astype here materializes an f32 copy of the cache)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(k_cache.dtype), k_cache,
                   preferred_element_type=jnp.float32) / math.sqrt(head_dim)
    s = jnp.where(_cache_valid_mask(pos, s_max), s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, n_heads, 1, head_dim).transpose(0, 2, 1, 3)
    o = o.reshape(b, 1, n_heads * head_dim).astype(x.dtype)
    return synergy_matmul(o, params["wo"], name=f"{name}/wo")


def decode_attention(params: dict, x: jax.Array, k_cache: jax.Array,
                     v_cache: jax.Array, pos: jax.Array, *, n_heads: int,
                     n_kv_heads: int, head_dim: int, rope_theta: float = 1e4,
                     update_cache: bool = True, use_rope: bool = True,
                     name: str = "attn"):
    """One-token decode with KV cache.

    x (B, 1, d); caches (B, Hkv, S_max, hd); pos scalar int32 (current index).
    Returns (y (B, 1, d), k_cache, v_cache).
    """
    b = x.shape[0]
    g = n_heads // n_kv_heads
    s_max = k_cache.shape[2]
    q = synergy_matmul(x, params["wq"], name=f"{name}/wq")
    q = q.reshape(b, 1, n_heads, head_dim).transpose(0, 2, 1, 3)
    if use_rope:
        q = rope(q, jnp.full((1, 1, 1), pos), rope_theta)
    if update_cache:
        kk = synergy_matmul(x, params["wk"], name=f"{name}/wk")
        vv = synergy_matmul(x, params["wv"], name=f"{name}/wv")
        kk = kk.reshape(b, 1, n_kv_heads, head_dim).transpose(0, 2, 1, 3)
        vv = vv.reshape(b, 1, n_kv_heads, head_dim).transpose(0, 2, 1, 3)
        if use_rope:
            kk = rope(kk, jnp.full((1, 1, 1), pos), rope_theta)
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, kk.astype(k_cache.dtype), pos, axis=2)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, vv.astype(v_cache.dtype), pos, axis=2)
    qg = q.reshape(b, n_kv_heads, g, 1, head_dim)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / math.sqrt(head_dim)
    valid = jnp.arange(s_max) <= pos
    s = jnp.where(valid[None, None, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v_cache.astype(jnp.float32))
    o = o.reshape(b, n_heads, 1, head_dim).transpose(0, 2, 1, 3)
    o = o.reshape(b, 1, n_heads * head_dim).astype(x.dtype)
    return synergy_matmul(o, params["wo"], name=f"{name}/wo"), k_cache, v_cache
