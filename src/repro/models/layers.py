"""Shared neural-net layers (functional, pytree params).

All dense projections route through :func:`repro.core.synergy_mm.synergy_matmul`
so every GEMM in every architecture is visible to the Synergy job tracer.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.synergy_mm import synergy_matmul

__all__ = ["rms_norm", "layer_norm", "rope", "dense", "glu_mlp",
           "init_dense", "init_glu_mlp", "softmax_xent"]


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * scale + bias).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """Rotary embedding.  x (..., S, D) with D even; positions (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense / MLP
# ---------------------------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, dtype=jnp.float32,
               scale: float | None = None) -> jax.Array:
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def dense(x: jax.Array, w: jax.Array, name: str = "dense", **kw) -> jax.Array:
    return synergy_matmul(x, w, name=name, **kw)


_ACTS: dict[str, Callable] = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def init_glu_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "wi": init_dense(k1, d_model, 2 * d_ff, dtype),   # gate & up fused
        "wo": init_dense(k2, d_ff, d_model, dtype),
    }


def glu_mlp(params: dict, x: jax.Array, act: str = "silu",
            name: str = "mlp") -> jax.Array:
    """SwiGLU (act='silu', llama-style) or GeGLU (act='gelu', gemma-style)."""
    h = dense(x, params["wi"], name=f"{name}/wi")
    gate, up = jnp.split(h, 2, axis=-1)
    return dense(_ACTS[act](gate) * up, params["wo"], name=f"{name}/wo")


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 z_loss: float = 0.0) -> jax.Array:
    """Mean token cross-entropy; logits (..., V) fp32-softmaxed."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = (lse - ll).mean()
    if z_loss:
        loss = loss + z_loss * jnp.square(lse).mean()
    return loss
