"""Cross-pod local SGD with compressed delta synchronization.

The inter-pod links are the slowest fabric in a multi-pod job, and the
per-step gradient all-reduce crosses them 100s of times per second.  Local
SGD (a.k.a. periodic parameter averaging) trains each pod's DP group
independently for ``sync_every`` steps, then averages PARAMETER DELTAS
across pods — with blockwise-int8 compression + error feedback
(``repro.optim.compress``), cutting cross-pod traffic by
~4x * sync_every compared to per-step fp32 gradient all-reduce.

Expressed as a pure function over the 'pod' mesh axis so it jits into the
multi-pod program (tested on the 2x2x2 CPU mesh in tests/test_runtime.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.compress import quantize_int8, dequantize_int8

__all__ = ["sync_pods_compressed", "crosspod_traffic_bytes"]


def sync_pods_compressed(params, anchor, err, *, axis_name: str = "pod"):
    """INSIDE shard_map/pjit over the pod axis: average each pod's drift
    from the shared anchor, int8-compressed, with error feedback.

    params: current per-pod params; anchor: params at last sync (identical
    across pods); err: error-feedback state.  Returns (new params, new
    anchor, new err)."""
    n_pods = jax.lax.psum(1, axis_name)

    def sync_leaf(p, a, e):
        delta = (p - a).astype(jnp.float32) + e
        q, scale, pad = quantize_int8(delta)
        deq = dequantize_int8(q, scale, pad, p.shape)
        new_e = delta - deq
        # the all-reduce moves int8+scales in a real fabric; numerically we
        # average the dequantized deltas (bit-identical to decompress-sum)
        mean_delta = jax.lax.pmean(deq, axis_name)
        new_p = (a.astype(jnp.float32) + mean_delta).astype(p.dtype)
        return new_p, new_e

    flat_p, treedef = jax.tree.flatten(params)
    flat_a = treedef.flatten_up_to(anchor)
    flat_e = treedef.flatten_up_to(err)
    out = [sync_leaf(p, a, e) for p, a, e in zip(flat_p, flat_a, flat_e)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_err = treedef.unflatten([o[1] for o in out])
    return new_params, new_params, new_err


def crosspod_traffic_bytes(params, *, compressed: bool) -> int:
    """Per-sync traffic: int8 + fp32 block scales vs fp32."""
    total = 0
    for p in jax.tree.leaves(params):
        n = p.size
        if compressed:
            total += n + (-(-n // 256)) * 4
        else:
            total += n * 4
    return total
