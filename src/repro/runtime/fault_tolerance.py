"""Fault tolerance for 1000+-node runs: failure detection, checkpoint/
restart, and elastic re-meshing.

Architecture (mirrors what production TPU frameworks do, testable on CPU):

  * ``HeartbeatMonitor`` — every worker (host) posts a heartbeat each step;
    the coordinator flags hosts silent for > ``timeout_steps`` as failed.
  * ``run_with_recovery`` — the supervisor loop: run the train loop; on
    worker failure (or any step exception), restore the latest atomic
    checkpoint, optionally RE-MESH to the surviving device set (elastic:
    drop a data-parallel replica, keep model-parallel intact), and resume
    from the same data step (the pipeline is deterministic in (seed, step),
    so no data is skipped or repeated).
  * Straggler mitigation lives in ``straggler.py`` (the Synergy
    work-stealing insight applied between steps).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

__all__ = ["HeartbeatMonitor", "FailureEvent", "run_with_recovery",
           "plan_elastic_mesh"]


@dataclasses.dataclass
class FailureEvent:
    step: int
    kind: str                  # 'host-timeout' | 'step-exception'
    detail: str


class HeartbeatMonitor:
    """Step-granularity heartbeat tracking (wall-clock optional)."""

    def __init__(self, n_hosts: int, timeout_steps: int = 3):
        self.n_hosts = n_hosts
        self.timeout_steps = timeout_steps
        self.last_seen = [0] * n_hosts

    def beat(self, host: int, step: int) -> None:
        self.last_seen[host] = step

    def failed_hosts(self, step: int) -> list[int]:
        return [h for h, s in enumerate(self.last_seen)
                if step - s > self.timeout_steps]


def plan_elastic_mesh(n_devices: int, model_parallel: int,
                      pods: int = 1) -> tuple[int, ...]:
    """Largest (data, model) mesh fitting the surviving devices: model
    parallelism is load-bearing (weights are sharded 16-way), so the DATA
    axis absorbs the loss — drop whole DP replicas of `model_parallel`
    devices.  Returns the new mesh shape."""
    if n_devices < model_parallel:
        raise RuntimeError(
            f"cannot re-mesh: {n_devices} survivors < model={model_parallel}")
    data = n_devices // model_parallel
    if pods > 1:
        return (pods, max(1, data // pods), model_parallel)
    return (data, model_parallel)


def run_with_recovery(*,
                      steps: int,
                      run_steps: Callable[[int, int, Any], Any],
                      checkpointer,
                      state0: Any,
                      max_restarts: int = 3,
                      on_failure: Callable[[FailureEvent], None] | None = None,
                      ) -> tuple[Any, list[FailureEvent]]:
    """Supervisor: ``run_steps(start, end, state) -> state`` may raise at
    any step; we restore the latest checkpoint and resume.  Returns
    (final state, failure log)."""
    failures: list[FailureEvent] = []
    restarts = 0
    state = state0
    start = 0
    while start < steps:
        try:
            state = run_steps(start, steps, state)
            break
        except Exception as e:  # noqa: BLE001 — any worker fault
            restarts += 1
            ev = FailureEvent(step=start, kind="step-exception",
                              detail=f"{type(e).__name__}: {e}")
            failures.append(ev)
            if on_failure:
                on_failure(ev)
            if restarts > max_restarts:
                raise RuntimeError(
                    f"exceeded {max_restarts} restarts") from e
            ckpt_step = checkpointer.latest_step()
            if ckpt_step is None:
                state = state0
                start = 0
            else:
                state = checkpointer.restore(state)
                start = ckpt_step
    return state, failures
