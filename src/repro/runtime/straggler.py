"""Straggler mitigation = the Synergy work-stealing insight at pod scale.

On the Zynq SoC, Synergy's thief thread moves tile jobs from busy clusters
to idle ones at runtime (paper §3.1.3).  A lockstep SPMD program cannot
steal mid-step, but the SAME job-granularity rebalancing applies BETWEEN
steps: device groups ("clusters") that consistently finish late (thermal
throttling, degraded ICI, a slow host) should own a smaller share of the
tile-job space next step.

``StragglerRebalancer`` keeps an EMA of per-cluster step times and re-plans
the work shares with :func:`repro.core.scheduler.rebalance` — the identical
math the DES validates against the paper's Figure 13/14.  Used by the
serving engine (prefill/decode job mix across replica groups) and by
microbatch-level DP splits.
"""

from __future__ import annotations

import dataclasses

from repro.core.scheduler import rebalance

__all__ = ["StragglerRebalancer"]


@dataclasses.dataclass
class StragglerRebalancer:
    n_clusters: int
    ema: float = 0.3
    min_share: float = 0.02

    def __post_init__(self):
        self.shares = [1.0 / self.n_clusters] * self.n_clusters
        self.ema_times = [0.0] * self.n_clusters
        self.history: list[list[float]] = []

    def observe(self, step_times: list[float]) -> list[float]:
        """Feed measured per-cluster wall times; returns new shares."""
        for i, t in enumerate(step_times):
            self.ema_times[i] = (self.ema * t + (1 - self.ema) *
                                 (self.ema_times[i] or t))
        new = rebalance(self.shares, self.ema_times, ema=self.ema)
        new = [max(self.min_share, s) for s in new]
        total = sum(new)
        self.shares = [s / total for s in new]
        self.history.append(list(self.shares))
        return self.shares

    def split_jobs(self, n_jobs: int) -> list[int]:
        """Integer job counts per cluster matching current shares."""
        counts = [int(s * n_jobs) for s in self.shares]
        rem = n_jobs - sum(counts)
        order = sorted(range(self.n_clusters),
                       key=lambda i: -(self.shares[i] * n_jobs
                                       - counts[i]))
        for i in range(rem):
            counts[order[i % self.n_clusters]] += 1
        return counts
