from .fault_tolerance import (HeartbeatMonitor, FailureEvent,
                              run_with_recovery, plan_elastic_mesh)
from .straggler import StragglerRebalancer
from .local_sgd import sync_pods_compressed, crosspod_traffic_bytes
