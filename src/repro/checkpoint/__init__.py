from .checkpoint import Checkpointer
