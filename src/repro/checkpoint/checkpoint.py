"""Sharded, atomic, async checkpointing — the restart half of fault
tolerance.

Layout: ``<dir>/step_<N>/`` holding one ``.npy``-in-``.npz`` bundle per
host-shard group plus a msgpack manifest (tree structure, shapes, dtypes,
partition specs).  Writes go to ``step_<N>.tmp`` and are atomically renamed
— a crashed writer never corrupts the latest checkpoint.  ``save`` can run
on a background thread (async=True) double-buffering against training.

On restore, arrays are re-sharded to the CURRENT mesh — a checkpoint taken
on 512 chips restores onto 256 (elastic re-mesh after losing a pod) because
specs are stored logically (PartitionSpec names, not device ids).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Checkpointer"]


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return flat, treedef


def _key_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return ".".join(parts)


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)
        self._gc_stale_tmp()

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, *, block: bool = False) -> None:
        flat, _ = _flatten(state)
        # pull to host BEFORE handing to the writer thread (cheap copy vs
        # holding device buffers hostage during training)
        host = [(_key_str(p), np.asarray(v)) for p, v in flat]
        if self.async_write and not block:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, host)

    def _gc_stale_tmp(self) -> None:
        """Remove ``step_*.tmp`` wreckage from a writer killed mid-save.
        A ``.tmp`` that was never renamed holds a partial array set; left
        in place it would seed a later same-step save with stale files."""
        for name in os.listdir(self.directory):
            if name.startswith("step_") and name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.directory, name),
                              ignore_errors=True)

    def _write(self, step: int, host: list) -> None:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            # a previous writer died mid-save at this very step: start clean
            # rather than inherit its partial (possibly stale-shaped) files
            shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        manifest = {}
        for key, arr in host:
            fname = key.replace("/", "_") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest[key] = {"file": fname, "shape": list(arr.shape),
                             "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "arrays": manifest}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic publish
        self._gc()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if (name.startswith("step_") and not name.endswith(".tmp")
                    and os.path.isdir(os.path.join(self.directory, name))):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: int | None = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching tree of
        NamedShardings for the CURRENT mesh (elastic restore)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)["arrays"]

        flat, treedef = _flatten(like)
        shard_flat = (jax.tree_util.tree_leaves(shardings)
                      if shardings is not None else [None] * len(flat))
        out = []
        for (path, ref), shd in zip(flat, shard_flat):
            key = _key_str(path)
            info = manifest[key]
            arr = np.load(os.path.join(d, info["file"]))
            if shd is not None:
                out.append(jax.device_put(arr, shd))
            else:
                out.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)
