"""Online activation quantization — per-tensor int8 scales calibrated
from live decode batches.

Weight scales are known offline (the weights never change at serve
time); activation ranges are a property of the TRAFFIC, so they must be
learned online.  The scheme is the standard serving one (TensorRT-style
EMA range calibration): per GEMM shape, track an exponential moving
average of the per-batch max |a| and derive one symmetric per-tensor
scale ``amax / 127`` from it.  Once a shape has seen ``min_updates``
batches the scale is published and the quantized engine family's
int8×int8 fast path switches on for that shape; until then (and for
trace-time Tracers, which have no values to observe) execution falls
back to the weight-only fp32-cast dot.

Determinism: calibration is a pure fold over the observation sequence —
same batches in the same order produce bit-identical scales, so a
seeded workload calibrates identically across runs (property-tested).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Hashable, Optional

import jax
import jax.numpy as jnp

__all__ = ["ActScale", "ActCalibrator", "quantize_activations",
           "one_shot_act_scale", "DEFAULT_MOMENTUM", "DEFAULT_MIN_UPDATES"]

_QMAX = 127.0

#: EMA momentum: high enough to ride out one outlier batch, low enough
#: that a few decode steps converge the range
DEFAULT_MOMENTUM = 0.9

#: batches a shape must contribute before its scale is published
DEFAULT_MIN_UPDATES = 1


@dataclasses.dataclass(frozen=True)
class ActScale:
    """Calibrated activation range of one GEMM shape.

    ``amax``    EMA of per-batch max |a|.
    ``updates`` batches folded in so far.
    """

    amax: float
    updates: int

    @property
    def scale(self) -> float:
        """Symmetric per-tensor int8 scale: ``a ~= q * scale``."""
        return max(self.amax, 1e-12) / _QMAX


def one_shot_act_scale(a: jax.Array) -> float:
    """The scale one batch implies on its own — ``max|a| / 127``, i.e.
    :class:`ActScale` after a single observation.  Benchmarks and tests
    that quantize a known batch use this so they measure the SAME range
    convention the online calibrator publishes."""
    return float(jnp.max(jnp.abs(a))) / _QMAX


def quantize_activations(a: jax.Array, scale) -> jax.Array:
    """a -> symmetric per-tensor int8 at the calibrated scale (a Python
    float or traced scalar).  Values beyond the calibrated range saturate
    at ±127 (the EMA absorbs range drift over the next batches)."""
    return jnp.clip(jnp.round(a.astype(jnp.float32) / scale),
                    -_QMAX, _QMAX).astype(jnp.int8)


class ActCalibrator:
    """Per-GEMM-shape online range calibrator.

    ``observe(a, key)`` folds one live batch into the shape's EMA;
    ``scale_for(key)`` returns the published scale (a Python float — it
    closes over jit traces as a constant) or None while the shape is
    still warming up.  Thread-safe: runtime workers and serving threads
    observe concurrently."""

    def __init__(self, momentum: float = DEFAULT_MOMENTUM,
                 min_updates: int = DEFAULT_MIN_UPDATES):
        self.momentum = momentum
        self.min_updates = min_updates
        self._scales: dict[Hashable, ActScale] = {}
        self._lock = threading.Lock()

    def observe(self, a: jax.Array, key: Hashable) -> Optional[ActScale]:
        """Fold one concrete activation batch into ``key``'s EMA.
        Tracers are ignored (trace-time values do not exist yet).

        Note the ``float(max|a|)`` is a host sync: the batch must land
        before the EMA updates, which is inherent — the very next step
        quantizes at the scale this observation publishes.  The runtime
        amortizes it to one sync per SUBMISSION (the split plan observes
        the whole activation once, panels reuse the quantization); a
        deployment that wants zero syncs on the decode path can observe
        on a cadence instead of every batch."""
        if isinstance(a, jax.core.Tracer):
            return self._scales.get(key)
        return self.observe_amax(float(jnp.max(jnp.abs(a))), key)

    def observe_amax(self, amax: float, key: Hashable) -> ActScale:
        """Fold one precomputed per-batch ``max|a|`` into ``key``'s EMA.

        This is the ASYNC half of :meth:`observe`: a submit phase can
        launch the ``jnp.max(jnp.abs(a))`` reduction on device (no host
        sync) and fold the float here at reap time — the serving engine's
        in-flight window does exactly that, so the decode hot path never
        blocks on a calibration sync.  The fold itself is the same pure
        EMA, so submit-time and reap-time feeding produce bit-identical
        scale trajectories for the same observation sequence."""
        with self._lock:
            prev = self._scales.get(key)
            if prev is None:
                cur = ActScale(amax=amax, updates=1)
            else:
                cur = ActScale(
                    amax=self.momentum * prev.amax
                    + (1.0 - self.momentum) * amax,
                    updates=prev.updates + 1)
            self._scales[key] = cur
            return cur

    def scale_for(self, key: Hashable) -> Optional[float]:
        """The published per-tensor scale for ``key``, or None while the
        shape has fewer than ``min_updates`` observations."""
        with self._lock:
            s = self._scales.get(key)
        if s is None or s.updates < self.min_updates:
            return None
        return s.scale

    def state(self) -> dict:
        """Snapshot of every calibrated shape (diagnostics / serving
        stats)."""
        with self._lock:
            return dict(self._scales)

    def export_state(self) -> list:
        """JSON-safe dump of every shape's EMA for durable snapshots.
        Keys are hashables (usually ``(k, n)`` tuples); tuples serialize
        as lists and :meth:`import_state` turns them back."""
        with self._lock:
            return [[list(k) if isinstance(k, tuple) else k,
                     s.amax, s.updates]
                    for k, s in self._scales.items()]

    def import_state(self, state: list) -> None:
        """Restore :meth:`export_state` output — restored scales resume
        the exact EMA trajectory (same floats, same update counts)."""
        with self._lock:
            self._scales = {
                tuple(k) if isinstance(k, list) else k:
                    ActScale(amax=float(amax), updates=int(updates))
                for k, amax, updates in state}

    def reset(self) -> None:
        with self._lock:
            self._scales.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._scales)

    def __repr__(self) -> str:
        return (f"<ActCalibrator {len(self)} shapes "
                f"momentum={self.momentum} min_updates={self.min_updates}>")
