"""Symmetric per-channel int8 weight quantization — the numeric core of the
quantized engine family.

The scheme is the standard weight-only one (NEURAghe-style CPU/FPGA splits
and the mobile-SoC heterogeneity studies both lean on it): weights of a
GEMM ``A[m, k] @ W[k, n]`` quantize along the contraction axis with one
fp32 scale per output channel, activations stay in floating point, and the
dequant multiplier is applied as a *fused epilogue* after the int8 weights
are read — so the weight stream costs 1 byte/element of bandwidth, which
is where the decode-time speedup comes from.

Symmetric means the zero point is identically 0; the container still
carries it so asymmetric schemes can slot in without changing consumers.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["QuantizedWeight", "quantize_weights", "dequantize_weights",
           "dequant_epilogue", "dequant_finish", "quant_gemm",
           "quantization_error"]

#: int8 symmetric range: round-to-nearest lands within scale/2 per element
_QMAX = 127.0


@dataclasses.dataclass(frozen=True)
class QuantizedWeight:
    """One quantized GEMM weight: ``w ~= (q - zero_point) * scale``.

    ``q``          int8, same shape as the source weight (k, n).
    ``scale``      fp32 (1, n) — one scale per output channel.
    ``zero_point`` int32 (1, n) — identically 0 for the symmetric scheme.
    """

    q: jax.Array
    scale: jax.Array
    zero_point: jax.Array

    @property
    def shape(self) -> tuple[int, ...]:
        return self.q.shape

    @property
    def nbytes(self) -> int:
        return self.q.nbytes + self.scale.nbytes + self.zero_point.nbytes

    @property
    def error_bound(self) -> float:
        """Per-element worst-case reconstruction error: round-to-nearest
        symmetric int8 is off by at most scale/2."""
        return float(jnp.max(self.scale)) / 2.0


def quantize_weights(w: jax.Array) -> QuantizedWeight:
    """w (k, n) -> symmetric per-output-channel int8 (quantize along k)."""
    w32 = w.astype(jnp.float32)
    scale = jnp.max(jnp.abs(w32), axis=-2, keepdims=True) / _QMAX
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(w32 / scale), -_QMAX, _QMAX).astype(jnp.int8)
    zp = jnp.zeros_like(scale, dtype=jnp.int32)
    return QuantizedWeight(q=q, scale=scale, zero_point=zp)


def dequantize_weights(qw: QuantizedWeight, dtype=jnp.float32) -> jax.Array:
    return ((qw.q.astype(jnp.float32) - qw.zero_point.astype(jnp.float32))
            * qw.scale).astype(dtype)


def dequant_epilogue(acc: jax.Array, qw: QuantizedWeight) -> jax.Array:
    """Fold the per-channel scale into an fp32 GEMM accumulator:
    ``(a @ q) * scale == a @ (q * scale)`` because the scale is constant
    along the contraction axis."""
    return acc * qw.scale.reshape(1, -1).astype(jnp.float32)


def dequant_finish(acc: jax.Array, qw: QuantizedWeight, *,
                   act_scale: float | None = None,
                   bias: jax.Array | None = None,
                   activation: Callable | None = None,
                   out_dtype) -> jax.Array:
    """The ONE epilogue tail every quantized path shares (the standalone
    ``quant_gemm``, ``QuantizedEngine.execute`` and the runtime's
    split/merge must stay numerically identical): dequant scale -> bias
    -> activation -> final cast, all in fp32 until the cast.

    ``acc`` is either an fp32 accumulator of the weight-only path
    (``act_scale`` None) or the raw int32 accumulator of the int8×int8
    path, whose per-tensor activation scale composes multiplicatively
    with the per-channel weight scale."""
    y = dequant_epilogue(acc.astype(jnp.float32), qw)
    if act_scale is not None:
        y = y * float(act_scale)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if activation is not None:
        y = activation(y)
    return y.astype(out_dtype)


def quant_gemm(a: jax.Array, qw: QuantizedWeight, *,
               act_scale: float | None = None,
               bias: jax.Array | None = None,
               activation: Callable | None = None,
               out_dtype=None,
               tile: tuple[int, int, int] | int = (256, 256, 256),
               interpret: bool = False) -> jax.Array:
    """act(A @ dequant(q) + bias) over int8 weights, two compute paths:

    ``act_scale`` given (the calibrated per-tensor activation scale) —
    the TRUE int8×int8 path: quantize A at that scale and run the qmm
    kernel, whose contraction consumes int8 operands with exact int32
    accumulation; scale -> bias -> activation fuse into the epilogue.

    ``act_scale`` None — the weight-only fallback: int8 weights enter a
    floating dot at activation dtype (1 byte/elem weight read),
    accumulation in fp32, then the shared dequant tail."""
    if act_scale is not None:
        from repro.kernels.qmm import qmm_matmul
        from .act import quantize_activations
        a_q = quantize_activations(a, act_scale)
        lead = a_q.shape[:-1]
        a_q = a_q.reshape(-1, a_q.shape[-1])   # kernel contract is 2-D;
        y = qmm_matmul(a_q, qw.q, qw.scale,    # batched a folds into m
                       act_scale=act_scale,
                       bias=bias, activation=activation,
                       out_dtype=out_dtype or a.dtype, tile=tile,
                       interpret=interpret)
        return y.reshape(*lead, y.shape[-1])
    acc = jax.lax.dot_general(
        a, qw.q.astype(a.dtype),
        (((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return dequant_finish(acc, qw, bias=bias, activation=activation,
                          out_dtype=out_dtype or a.dtype)


def quantization_error(w: jax.Array, qw: QuantizedWeight | None = None) -> dict:
    """Reconstruction-error metrics of one weight (the calibration module
    aggregates these per GEMM shape)."""
    if qw is None:
        qw = quantize_weights(w)
    deq = dequantize_weights(qw, dtype=jnp.float32)
    err = jnp.abs(deq - w.astype(jnp.float32))
    denom = float(jnp.max(jnp.abs(w))) + 1e-12
    return {
        "max_abs_err": float(jnp.max(err)),
        "max_rel_err": float(jnp.max(err)) / denom,
        "mean_abs_err": float(jnp.mean(err)),
        "error_bound": qw.error_bound,
    }
