"""``repro.quant`` — the int8 quantized-engine subsystem.

Four layers, mirroring how the paper treats its accelerators:

  * :mod:`repro.quant.quantize`  — the numeric scheme (symmetric
    per-output-channel int8 weights; ``quant_gemm`` runs the TRUE
    int8×int8 qmm kernel when an activation scale is available, the
    weight-only fp32-cast dot otherwise).
  * :mod:`repro.quant.act`       — online activation quantization:
    per-GEMM-shape :class:`ActScale` EMAs calibrated from live decode
    batches (deterministic given the observation sequence).
  * :mod:`repro.quant.engine`    — :class:`QuantizedEngine`, which adapts
    any CAP_GEMM engine into a CAP_GRAD-free ``int8`` registry entry.
  * :mod:`repro.quant.calibrate` — measured error vs the fp32 oracle on
    the int8×int8 path; :func:`register_quantized` refuses engines past
    tolerance and replaces the nominal 4x cost guess with the rate
    measured on the real kernel.

Typical serving setup::

    from repro.quant import register_quantized
    register_quantized("xla", tol=0.05)   # 'xla-int8' joins the registry
    # decode-class jobs now prefer the int8 engine (Dispatcher policy);
    # live decode batches calibrate activation scales online, flipping
    # each GEMM shape onto the int8×int8 kernel as it warms up;
    # prefill/training stay on CAP_GRAD full-precision paths.
"""

from .quantize import (QuantizedWeight, dequant_epilogue, dequant_finish,
                       dequantize_weights, quant_gemm, quantization_error,
                       quantize_weights)
from .act import (ActCalibrator, ActScale, DEFAULT_MIN_UPDATES,
                  DEFAULT_MOMENTUM, one_shot_act_scale,
                  quantize_activations)
from .engine import INT8_SPEEDUP, QuantizedEngine
from .calibrate import (DEFAULT_SHAPES, DEFAULT_TOL, CalibrationError,
                        CalibrationReport, calibrate, register_quantized,
                        rel_err)

__all__ = [
    "QuantizedWeight", "quantize_weights", "dequantize_weights",
    "dequant_epilogue", "dequant_finish", "quant_gemm",
    "quantization_error",
    "ActScale", "ActCalibrator", "quantize_activations",
    "one_shot_act_scale", "DEFAULT_MOMENTUM", "DEFAULT_MIN_UPDATES",
    "QuantizedEngine", "INT8_SPEEDUP",
    "CalibrationError", "CalibrationReport", "DEFAULT_SHAPES", "DEFAULT_TOL",
    "calibrate", "register_quantized", "rel_err",
]
