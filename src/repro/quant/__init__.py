"""``repro.quant`` — the int8 quantized-engine subsystem.

Three layers, mirroring how the paper treats its accelerators:

  * :mod:`repro.quant.quantize`  — the numeric scheme (symmetric
    per-output-channel int8 weights, fp32 dequant epilogue).
  * :mod:`repro.quant.engine`    — :class:`QuantizedEngine`, which adapts
    any CAP_GEMM engine into a CAP_GRAD-free ``int8`` registry entry with
    a higher calibrated rate.
  * :mod:`repro.quant.calibrate` — measured error vs the fp32 oracle;
    :func:`register_quantized` refuses engines past tolerance.

Typical serving setup::

    from repro.quant import register_quantized
    register_quantized("xla", tol=0.05)   # 'xla-int8' joins the registry
    # decode-class jobs now prefer the int8 engine (Dispatcher policy);
    # prefill/training stay on CAP_GRAD full-precision paths.
"""

from .quantize import (QuantizedWeight, dequant_epilogue, dequant_finish,
                       dequantize_weights, quant_gemm, quantization_error,
                       quantize_weights)
from .engine import INT8_SPEEDUP, QuantizedEngine
from .calibrate import (DEFAULT_SHAPES, DEFAULT_TOL, CalibrationError,
                        CalibrationReport, calibrate, register_quantized,
                        rel_err)

__all__ = [
    "QuantizedWeight", "quantize_weights", "dequantize_weights",
    "dequant_epilogue", "dequant_finish", "quant_gemm",
    "quantization_error",
    "QuantizedEngine", "INT8_SPEEDUP",
    "CalibrationError", "CalibrationReport", "DEFAULT_SHAPES", "DEFAULT_TOL",
    "calibrate", "register_quantized", "rel_err",
]
