"""Calibration: measure a quantized engine's error against the fp32 oracle
and refuse registration past tolerance.

The paper's engines are trusted because they are *calibrated* — rate
constants measured on hardware back every scheduling decision.  The
quantized family extends that discipline to numerics: before an int8
engine may enter the registry, it must demonstrate, per GEMM shape, that
its output stays within a configured relative tolerance of the fp32
reference.  The resulting :class:`CalibrationReport` travels with the
engine (``engine.calibration``) so dispatch policies and serving stats can
cite the bound they are trading against.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.engines.base import CAP_SIM, CostModel, Engine
from repro.engines.registry import register_engine

from .engine import INT8_SPEEDUP, QuantizedEngine

__all__ = ["CalibrationError", "CalibrationReport", "DEFAULT_SHAPES",
           "calibrate", "register_quantized", "rel_err"]

#: (m, k, n) GEMM shapes spanning the serving mix: tiny memory-bound
#: decode steps up to prefill/CNN-sized panels (border shapes included)
DEFAULT_SHAPES: tuple[tuple[int, int, int], ...] = (
    (1, 64, 64),       # single-token decode
    (4, 128, 256),     # batched decode
    (33, 70, 45),      # border tiles in every dimension
    (128, 256, 128),   # prefill / conv panel
)

#: default max relative error vs the fp32 oracle (per-channel symmetric
#: int8 on well-scaled weights lands well under this)
DEFAULT_TOL = 0.05


class CalibrationError(ValueError):
    """Raised when a quantized engine exceeds the error tolerance."""


@dataclasses.dataclass(frozen=True)
class CalibrationReport:
    """Quant-error metadata: per-shape relative error vs the fp32 oracle,
    plus the rate measured on the engine's REAL compute path (since the
    qmm kernel landed, that is the int8×int8 path for engines whose
    activation calibrator published scales during the sweep)."""

    engine: str
    base: str
    tol: float
    rows: tuple[dict, ...]            # {"m", "k", "n", "rel_err", "wall_s"}
    max_rel_err: float
    #: MACs/s measured over the calibration sweep's timed pass (None when
    #: the sweep was too fast to time) — what replaces the simulated 4x
    measured_macs_per_s: float | None = None
    #: whether the timed pass ran the int8×int8 kernel (an engine without
    #: an activation calibrator is gated on its weight-only path)
    int8_path: bool = False

    @property
    def passed(self) -> bool:
        return self.max_rel_err <= self.tol

    def __str__(self) -> str:
        worst = max(self.rows, key=lambda r: r["rel_err"])
        return (f"CalibrationReport({self.engine}: max_rel_err="
                f"{self.max_rel_err:.2e} @ {worst['m']}x{worst['k']}x"
                f"{worst['n']}, tol={self.tol:g}, "
                f"{'int8x8' if self.int8_path else 'weight-only'}, "
                f"{'PASS' if self.passed else 'FAIL'})")


def rel_err(got: jax.Array, want: jax.Array) -> float:
    """Max relative error vs a reference — the ONE formula both the
    calibration gate and the acceptance benchmarks measure with."""
    got32 = got.astype(jnp.float32)
    want32 = want.astype(jnp.float32)
    denom = float(jnp.max(jnp.abs(want32))) + 1e-12
    return float(jnp.max(jnp.abs(got32 - want32))) / denom


def calibrate(engine: Engine, *,
              shapes=DEFAULT_SHAPES, tol: float = DEFAULT_TOL,
              seed: int = 0) -> CalibrationReport:
    """Run ``engine`` over random GEMMs of each shape and compare against
    the fp32 oracle.  Pure measurement — registration gating happens in
    :func:`register_quantized`.

    For a :class:`QuantizedEngine` with an activation calibrator, the
    first (untimed) pass per shape feeds the calibrator its seeded batch,
    so the error rows AND the timed rate measure the engine exactly as it
    will serve: through the int8×int8 qmm kernel, not the weight-only
    fp32-cast dot it used to be gated on.  The first pass also absorbs
    jit compilation, so ``measured_macs_per_s`` is a steady-state rate."""
    from repro.kernels.tiled_mm.ref import tiled_mm_ref
    rows = []
    total_macs, total_wall = 0, 0.0
    # Warm passes: enough observations to cross the calibrator's publish
    # threshold AND compile the path the timed pass will take — a
    # min_updates=2 calibrator flips onto the int8 kernel on pass 2, so
    # timing pass 2 would measure jit compilation and poison the rate
    # the registration installs.  Re-observing the same batch is an EMA
    # fixed point, so every pass quantizes at the identical scale.
    cal = getattr(engine, "calibrator", None)
    warm_passes = max(1, cal.min_updates) if cal is not None else 1
    key = jax.random.key(seed)
    for m, k, n in shapes:
        key, ka, kb = jax.random.split(key, 3)
        a = jax.random.normal(ka, (m, k), jnp.float32)
        w = jax.random.normal(kb, (k, n), jnp.float32) * 0.05
        want = tiled_mm_ref(a, w)
        for _ in range(warm_passes):
            jax.block_until_ready(engine.execute(a, w, tile=(32, 32, 32)))
        t0 = time.perf_counter()
        got = jax.block_until_ready(engine.execute(a, w, tile=(32, 32, 32)))
        wall = time.perf_counter() - t0
        total_macs += m * k * n
        total_wall += wall
        rows.append({"m": m, "k": k, "n": n, "rel_err": rel_err(got, want),
                     "wall_s": wall})
    report = CalibrationReport(
        engine=engine.name,
        base=getattr(getattr(engine, "base", None), "name", engine.name),
        tol=tol, rows=tuple(rows),
        max_rel_err=max(r["rel_err"] for r in rows),
        measured_macs_per_s=(total_macs / total_wall
                             if total_wall > 1e-9 else None),
        int8_path=bool(getattr(engine, "act_scale_for", lambda k, n: None)(
            shapes[-1][1], shapes[-1][2]) is not None))
    if isinstance(engine, QuantizedEngine) or hasattr(engine, "calibration"):
        engine.calibration = report
    return report


def register_quantized(base: Engine | str, *,
                       name: str | None = None,
                       speedup: float = INT8_SPEEDUP,
                       cost: CostModel | None = None,
                       shapes=DEFAULT_SHAPES, tol: float = DEFAULT_TOL,
                       seed: int = 0,
                       measure_rate: bool = True,
                       override: bool = False) -> QuantizedEngine:
    """Wrap ``base`` as an int8 engine, calibrate it, and register it —
    REFUSING registration if the measured error exceeds ``tol``.

        eng = register_quantized("xla")        # 'xla-int8' joins the pool

    The error gate now measures the int8×int8 qmm path (the calibration
    sweep warms the activation calibrator), and — unless ``measure_rate``
    is False or ``cost`` was passed explicitly — the engine's cost model
    drops the simulated ``speedup``x guess in favor of the rate measured
    on that real kernel during the sweep.  CAP_SIM bases keep their
    scaled paper constants: their virtual time must never absorb a host
    rate.  The attached :class:`CalibrationReport` is the engine's
    quant-error metadata; ``unregister_engine(eng.name)`` retires it."""
    from repro.engines.registry import get_engine
    if isinstance(base, str):
        base = get_engine(base)
    eng = QuantizedEngine(base, name=name, speedup=speedup, cost=cost)
    report = calibrate(eng, shapes=shapes, tol=tol, seed=seed)
    if not report.passed:
        raise CalibrationError(
            f"refusing to register {eng.name!r}: max relative error "
            f"{report.max_rel_err:.3e} exceeds tolerance {tol:g} ({report})")
    if (measure_rate and cost is None
            and report.measured_macs_per_s is not None
            and CAP_SIM not in base.capabilities):
        eng.recalibrate(report.measured_macs_per_s, alpha=1.0)
    return register_engine(eng, override=override)
