"""Calibration: measure a quantized engine's error against the fp32 oracle
and refuse registration past tolerance.

The paper's engines are trusted because they are *calibrated* — rate
constants measured on hardware back every scheduling decision.  The
quantized family extends that discipline to numerics: before an int8
engine may enter the registry, it must demonstrate, per GEMM shape, that
its output stays within a configured relative tolerance of the fp32
reference.  The resulting :class:`CalibrationReport` travels with the
engine (``engine.calibration``) so dispatch policies and serving stats can
cite the bound they are trading against.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.engines.base import CostModel, Engine
from repro.engines.registry import register_engine

from .engine import INT8_SPEEDUP, QuantizedEngine

__all__ = ["CalibrationError", "CalibrationReport", "DEFAULT_SHAPES",
           "calibrate", "register_quantized", "rel_err"]

#: (m, k, n) GEMM shapes spanning the serving mix: tiny memory-bound
#: decode steps up to prefill/CNN-sized panels (border shapes included)
DEFAULT_SHAPES: tuple[tuple[int, int, int], ...] = (
    (1, 64, 64),       # single-token decode
    (4, 128, 256),     # batched decode
    (33, 70, 45),      # border tiles in every dimension
    (128, 256, 128),   # prefill / conv panel
)

#: default max relative error vs the fp32 oracle (per-channel symmetric
#: int8 on well-scaled weights lands well under this)
DEFAULT_TOL = 0.05


class CalibrationError(ValueError):
    """Raised when a quantized engine exceeds the error tolerance."""


@dataclasses.dataclass(frozen=True)
class CalibrationReport:
    """Quant-error metadata: per-shape relative error vs the fp32 oracle."""

    engine: str
    base: str
    tol: float
    rows: tuple[dict, ...]            # {"m", "k", "n", "rel_err"}
    max_rel_err: float

    @property
    def passed(self) -> bool:
        return self.max_rel_err <= self.tol

    def __str__(self) -> str:
        worst = max(self.rows, key=lambda r: r["rel_err"])
        return (f"CalibrationReport({self.engine}: max_rel_err="
                f"{self.max_rel_err:.2e} @ {worst['m']}x{worst['k']}x"
                f"{worst['n']}, tol={self.tol:g}, "
                f"{'PASS' if self.passed else 'FAIL'})")


def rel_err(got: jax.Array, want: jax.Array) -> float:
    """Max relative error vs a reference — the ONE formula both the
    calibration gate and the acceptance benchmarks measure with."""
    got32 = got.astype(jnp.float32)
    want32 = want.astype(jnp.float32)
    denom = float(jnp.max(jnp.abs(want32))) + 1e-12
    return float(jnp.max(jnp.abs(got32 - want32))) / denom


def calibrate(engine: Engine, *,
              shapes=DEFAULT_SHAPES, tol: float = DEFAULT_TOL,
              seed: int = 0) -> CalibrationReport:
    """Run ``engine`` over random GEMMs of each shape and compare against
    the fp32 oracle.  Pure measurement — registration gating happens in
    :func:`register_quantized`."""
    from repro.kernels.tiled_mm.ref import tiled_mm_ref
    rows = []
    key = jax.random.key(seed)
    for m, k, n in shapes:
        key, ka, kb = jax.random.split(key, 3)
        a = jax.random.normal(ka, (m, k), jnp.float32)
        w = jax.random.normal(kb, (k, n), jnp.float32) * 0.05
        want = tiled_mm_ref(a, w)
        got = engine.execute(a, w, tile=(32, 32, 32))
        rows.append({"m": m, "k": k, "n": n, "rel_err": rel_err(got, want)})
    report = CalibrationReport(
        engine=engine.name,
        base=getattr(getattr(engine, "base", None), "name", engine.name),
        tol=tol, rows=tuple(rows),
        max_rel_err=max(r["rel_err"] for r in rows))
    if isinstance(engine, QuantizedEngine) or hasattr(engine, "calibration"):
        engine.calibration = report
    return report


def register_quantized(base: Engine | str, *,
                       name: str | None = None,
                       speedup: float = INT8_SPEEDUP,
                       cost: CostModel | None = None,
                       shapes=DEFAULT_SHAPES, tol: float = DEFAULT_TOL,
                       seed: int = 0,
                       override: bool = False) -> QuantizedEngine:
    """Wrap ``base`` as an int8 engine, calibrate it, and register it —
    REFUSING registration if the measured error exceeds ``tol``.

        eng = register_quantized("xla")        # 'xla-int8' joins the pool

    The attached :class:`CalibrationReport` is the engine's quant-error
    metadata; ``unregister_engine(eng.name)`` retires it as usual."""
    from repro.engines.registry import get_engine
    if isinstance(base, str):
        base = get_engine(base)
    eng = QuantizedEngine(base, name=name, speedup=speedup, cost=cost)
    report = calibrate(eng, shapes=shapes, tol=tol, seed=seed)
    if not report.passed:
        raise CalibrationError(
            f"refusing to register {eng.name!r}: max relative error "
            f"{report.max_rel_err:.3e} exceeds tolerance {tol:g} ({report})")
    return register_engine(eng, override=override)
