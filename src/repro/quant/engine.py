"""QuantizedEngine — adapt any CAP_GEMM engine into an int8 weight-only
variant.

The wrapper is what makes the engine pool *genuinely* heterogeneous: the
same physical backend shows up twice in the registry, once at full
precision and once as a CAP_GRAD-free ``int8`` engine with a higher
calibrated MAC rate (weight-only quantization is a bandwidth play — int8
weights stream at 1 byte/elem, which is the roofline limiter for the
small memory-bound GEMMs of decode).  The dispatcher's job-class policy
and the SynergyRuntime then trade precision for throughput per job class.

Capability surgery on wrap:

  * ``+ int8``     — the dispatcher's decode policy prefers these.
  * ``- grad``     — round/clip have zero gradient almost everywhere, so a
    quantized path silently kills weight gradients; dropping CAP_GRAD (and
    the guard in ``synergy_matmul``) keeps training traffic off it.
  * ``- oracle``   — a lossy engine is never a numerical reference.
  * ``- epilogue`` — the wrapper applies dequant -> bias -> activation as
    a separate pass over C (see execute), so the "fused, no extra HBM
    trip" promise the capability stands for does not hold here.
"""

from __future__ import annotations

import collections
import threading
from typing import Callable

import jax

from repro.engines.base import (CAP_EPILOGUE, CAP_GRAD, CAP_INT8,
                                CAP_ORACLE, CostModel, Engine)

from .quantize import QuantizedWeight, quantize_weights

__all__ = ["QuantizedEngine", "INT8_SPEEDUP"]

#: default calibrated rate advantage of the int8 path over its fp32 base.
#: Weight-only int8 reads weights at 1/4 the fp32 bytes; decode GEMMs are
#: weight-bandwidth-bound, so the sustained rate scales close to 4x.
INT8_SPEEDUP = 4.0

#: weight-cache capacity (decode reuses the same handful of weights every
#: step; 32 covers every layer of the reduced zoo configs)
_CACHE_SLOTS = 32


class QuantizedEngine(Engine):
    """Int8 weight-only view of a wrapped full-precision engine.

    ``execute`` quantizes ``b`` per output channel (cached by array
    identity — decode calls reuse the same weights every step), runs the
    raw ``a @ q`` on the BASE engine at fp32 output precision, then
    applies dequant scale -> bias -> activation at the wrapper level.
    The epilogue deliberately stays OUTSIDE the base engine: a tiled base
    (Pallas kernels) runs its epilogue per (ts_m, ts_n) block, where a
    full-width ``(n,)`` multiplicative scale cannot broadcast — folding
    the dequant into the base's activation hook would crash any CAP_TILED
    backend.  Costs one unfused epilogue pass over C; the int8 weight
    stream (the bandwidth win) is unaffected.

    ``calibration`` is attached by :func:`repro.quant.calibrate.calibrate`
    / ``register_quantized`` — the quant-error metadata that travels with
    the cost model."""

    def __init__(self, base: Engine, *, name: str | None = None,
                 speedup: float = INT8_SPEEDUP,
                 cost: CostModel | None = None):
        caps = (base.capabilities
                - {CAP_GRAD, CAP_ORACLE, CAP_EPILOGUE}) | {CAP_INT8}
        super().__init__(name or f"{base.name}-int8", caps,
                         cost=cost or base.cost.scaled(speedup))
        self.base = base
        self.speedup = speedup
        #: CalibrationReport once calibrated (quant-error metadata)
        self.calibration = None
        # identity-keyed LRU: holding the key array alive guarantees its
        # id() cannot be reused while the entry exists
        self._cache: collections.OrderedDict = collections.OrderedDict()
        self._cache_lock = threading.Lock()

    def available(self) -> bool:
        return self.base.available()

    # ------------------------------------------------------------- weights
    def quantized(self, b: jax.Array) -> QuantizedWeight:
        """Quantize (or fetch the cached quantization of) one weight."""
        if isinstance(b, jax.core.Tracer):
            return quantize_weights(b)     # never cache trace-time values
        key = id(b)
        with self._cache_lock:
            hit = self._cache.get(key)
            if hit is not None and hit[0] is b:
                self._cache.move_to_end(key)
                return hit[1]
        qw = quantize_weights(b)
        with self._cache_lock:
            self._cache[key] = (b, qw)
            self._cache.move_to_end(key)
            while len(self._cache) > _CACHE_SLOTS:
                self._cache.popitem(last=False)
        return qw

    # ------------------------------------------------------------- execute
    def execute(self, a, b, *, bias=None, activation: Callable | None = None,
                tile=(256, 256, 256), out_dtype=None, precision=None):
        import jax.numpy as jnp

        from .quantize import dequant_finish
        qw = self.quantized(b)
        acc = self.base.execute(
            a, qw.q.astype(a.dtype), bias=None, activation=None,
            tile=tile, out_dtype=jnp.float32, precision=precision)
        return dequant_finish(acc, qw, bias=bias, activation=activation,
                              out_dtype=out_dtype or a.dtype)

    def __repr__(self) -> str:
        caps = ",".join(sorted(self.capabilities))
        return (f"<QuantizedEngine {self.name!r} base={self.base.name!r} "
                f"[{caps}]>")
