"""QuantizedEngine — adapt any CAP_GEMM engine into an int8 variant.

The wrapper is what makes the engine pool *genuinely* heterogeneous: the
same physical backend shows up twice in the registry, once at full
precision and once as a CAP_GRAD-free ``int8`` engine.  Since the qmm
kernel landed, the int8 engine is no longer just a bandwidth play — it
has two compute paths:

  * **int8×int8 fast path** (the real one): once the engine's
    :class:`~repro.quant.act.ActCalibrator` has published a per-tensor
    activation scale for a GEMM shape, ``execute`` quantizes the
    activations and runs the qmm Pallas kernel — int8 operands into the
    contraction, exact int32 accumulation, dequant (w_scale × act_scale)
    + bias + activation fused into the epilogue.  No fp32-cast dot.
  * **weight-only fallback**: shapes still warming up (or Tracers, or a
    disabled calibrator) run the old path — int8 weights cast up into
    the BASE engine's floating dot, dequant applied as a separate tail.

Calibration is ONLINE: every concrete ``execute`` folds its activation
batch into the EMA before routing, so live decode traffic converges the
scales and flips shapes onto the fast path as they warm up.

Capability surgery on wrap:

  * ``+ int8``     — the dispatcher's decode policy prefers these.
  * ``- grad``     — round/clip have zero gradient almost everywhere, so a
    quantized path silently kills weight gradients; dropping CAP_GRAD (and
    the guard in ``synergy_matmul``) keeps training traffic off it.
  * ``- oracle``   — a lossy engine is never a numerical reference.
  * ``- epilogue`` — the weight-only fallback applies dequant -> bias ->
    activation as a separate pass over C (a tiled base's per-block
    epilogue cannot broadcast the full-width (n,) scale); the qmm fast
    path does fuse, but the capability describes the worst case.
"""

from __future__ import annotations

import collections
import threading
from typing import Callable, Hashable, Optional

import jax

from repro.engines.base import (CAP_EPILOGUE, CAP_GRAD, CAP_INT8,
                                CAP_ORACLE, CostModel, Engine)

from .act import ActCalibrator
from .quantize import QuantizedWeight, quantize_weights

__all__ = ["QuantizedEngine", "INT8_SPEEDUP"]

#: nominal rate advantage of the int8 path over its fp32 base — the
#: roofline argument (1-byte operand streams, int8 MXU mode).  This is
#: only the STARTING cost model: ``register_quantized`` replaces it with
#: a rate measured on the real qmm kernel for non-sim bases, and runtime
#: recalibration keeps folding measured rates in afterwards.
INT8_SPEEDUP = 4.0

#: weight-cache capacity (decode reuses the same handful of weights every
#: step; 32 covers every layer of the reduced zoo configs)
_CACHE_SLOTS = 32


class QuantizedEngine(Engine):
    """Int8 view of a wrapped full-precision engine.

    ``calibrator`` owns the per-shape activation scales ("auto" builds a
    private :class:`ActCalibrator`; pass None to pin the engine to the
    weight-only fallback forever, or share one instance across engines so
    serving and runtime traffic calibrate the same EMAs).

    ``calibration`` is attached by :func:`repro.quant.calibrate.calibrate`
    / ``register_quantized`` — the quant-error metadata that travels with
    the cost model."""

    def __init__(self, base: Engine, *, name: str | None = None,
                 speedup: float = INT8_SPEEDUP,
                 cost: CostModel | None = None,
                 calibrator: ActCalibrator | str | None = "auto"):
        caps = (base.capabilities
                - {CAP_GRAD, CAP_ORACLE, CAP_EPILOGUE}) | {CAP_INT8}
        super().__init__(name or f"{base.name}-int8", caps,
                         cost=cost or base.cost.scaled(speedup))
        self.base = base
        self.speedup = speedup
        self.calibrator = (ActCalibrator() if calibrator == "auto"
                           else calibrator)
        #: CalibrationReport once calibrated (quant-error metadata)
        self.calibration = None
        # identity-keyed LRU: holding the key array alive guarantees its
        # id() cannot be reused while the entry exists
        self._cache: collections.OrderedDict = collections.OrderedDict()
        self._cache_lock = threading.Lock()

    def available(self) -> bool:
        return self.base.available()

    # ------------------------------------------------------------- weights
    def quantized(self, b: jax.Array) -> QuantizedWeight:
        """Quantize (or fetch the cached quantization of) one weight."""
        if isinstance(b, jax.core.Tracer):
            return quantize_weights(b)     # never cache trace-time values
        key = id(b)
        with self._cache_lock:
            hit = self._cache.get(key)
            if hit is not None and hit[0] is b:
                self._cache.move_to_end(key)
                return hit[1]
        qw = quantize_weights(b)
        with self._cache_lock:
            self._cache[key] = (b, qw)
            self._cache.move_to_end(key)
            while len(self._cache) > _CACHE_SLOTS:
                self._cache.popitem(last=False)
        return qw

    # --------------------------------------------------------- activations
    @staticmethod
    def act_key(k: int, n: int) -> Hashable:
        """Activation scales are keyed per GEMM shape by the WEIGHT'S
        (k, n): the batch dimension varies step to step, but a layer's
        activation statistics belong to the layer."""
        return (int(k), int(n))

    def observe_activations(self, a: jax.Array, k: int, n: int) -> None:
        """Fold one live activation batch into the (k, n) shape's EMA —
        how serving decode (and every concrete ``execute``) feeds the
        calibrator."""
        if self.calibrator is not None:
            self.calibrator.observe(a, self.act_key(k, n))

    def observe_amax(self, amax: float, k: int, n: int) -> None:
        """Reap-time feed: fold a precomputed batch ``max|a|`` into the
        (k, n) shape's EMA (the serving in-flight window computes the
        reduction on device at submit and folds the float here)."""
        if self.calibrator is not None:
            self.calibrator.observe_amax(float(amax), self.act_key(k, n))

    def act_scale_for(self, k: int, n: int) -> Optional[float]:
        """The published activation scale for a (k, n) GEMM shape, or
        None while it is warming up (weight-only fallback applies)."""
        if self.calibrator is None:
            return None
        return self.calibrator.scale_for(self.act_key(k, n))

    # ------------------------------------------------------------- execute
    def execute(self, a, b, *, bias=None, activation: Callable | None = None,
                tile=(256, 256, 256), out_dtype=None, precision=None):
        from .quantize import quant_gemm
        k, n = b.shape
        self.observe_activations(a, k, n)
        scale = self.act_scale_for(k, n)
        if scale is not None:
            # the TRUE int8×int8 path: quantized operands into the qmm
            # kernel, int32 accumulation, fused dequant epilogue
            return quant_gemm(a, self.quantized(b), act_scale=scale,
                              bias=bias, activation=activation,
                              out_dtype=out_dtype or a.dtype, tile=tile)
        return self.execute_weight_only(a, b, bias=bias,
                                        activation=activation, tile=tile,
                                        out_dtype=out_dtype,
                                        precision=precision)

    def execute_weight_only(self, a, b, *, bias=None,
                            activation: Callable | None = None,
                            tile=(256, 256, 256), out_dtype=None,
                            precision=None):
        """The weight-only fallback path, with NO online observation and
        no chance of flipping onto the int8×int8 kernel mid-flight: int8
        weights cast up into the base engine's floating dot, dequant
        applied as the shared tail.  The runtime's precision-pinned
        mixed-pool splits call this directly — a path choice that
        depended on concurrent panel completion order would make the
        merged numerics a function of thread timing."""
        import jax.numpy as jnp

        from .quantize import dequant_finish
        qw = self.quantized(b)
        acc = self.base.execute(
            a, qw.q.astype(a.dtype), bias=None, activation=None,
            tile=tile, out_dtype=jnp.float32, precision=precision)
        return dequant_finish(acc, qw, bias=bias, activation=activation,
                              out_dtype=out_dtype or a.dtype)

    def execute_int8(self, a_q, qw: QuantizedWeight, *,
                     tile=(256, 256, 256)):
        """Raw int8×int8 partial: the int32 accumulator with NO dequant.
        The SynergyRuntime splits a quantized GEMM into row panels in
        this mode — integer partials are exact on every engine, so the
        merge concatenates them and applies the shared ``dequant_finish``
        ONCE (never rounding twice, bitwise-stable under stealing)."""
        from repro.kernels.qmm import qmm_matmul
        return qmm_matmul(a_q, qw.q, qw.scale, fuse_dequant=False,
                          tile=tile)

    def __repr__(self) -> str:
        caps = ",".join(sorted(self.capabilities))
        return (f"<QuantizedEngine {self.name!r} base={self.base.name!r} "
                f"[{caps}]>")
