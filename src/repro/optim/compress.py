"""Gradient / delta compression: blockwise int8 quantization with error
feedback.  Used by the cross-pod local-SGD synchronizer
(``repro.runtime.local_sgd``) to cut inter-pod ICI traffic ~4x, and
available for any explicit gradient exchange.

Error feedback (Seide et al. 2014): the quantization residual is carried to
the next round so the compression bias vanishes in expectation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compress_tree",
           "decompress_tree", "init_error_feedback"]

_BLOCK = 256


def _blocked(x: jax.Array):
    flat = x.reshape(-1)
    pad = (-flat.size) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, _BLOCK), pad


def quantize_int8(x: jax.Array):
    """-> (q int8 blocks, scales fp32, pad).  Blockwise symmetric."""
    blocks, pad = _blocked(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, pad


def dequantize_int8(q: jax.Array, scale: jax.Array, pad: int, shape,
                    dtype=jnp.float32) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape).astype(dtype)


def init_error_feedback(tree):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), tree)


def compress_tree(tree, err):
    """Quantize tree + error feedback -> (quantized tree, new error)."""
    def one(x, e):
        x32 = x.astype(jnp.float32) + e
        q, s, pad = quantize_int8(x32)
        deq = dequantize_int8(q, s, pad, x.shape)
        return (q, s), x32 - deq

    flat_x, treedef = jax.tree.flatten(tree)
    flat_e = treedef.flatten_up_to(err)
    out = [one(x, e) for x, e in zip(flat_x, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def decompress_tree(qtree, shapes_tree, dtype=jnp.float32):
    def one(qs, ref):
        q, s = qs
        pad = (-ref.size) % _BLOCK
        return dequantize_int8(q, s, pad, ref.shape, dtype)

    flat_q, treedef = jax.tree.flatten(qtree,
                                       is_leaf=lambda x: isinstance(x, tuple))
    flat_r = treedef.flatten_up_to(shapes_tree)
    return treedef.unflatten([one(q, r) for q, r in zip(flat_q, flat_r)])
