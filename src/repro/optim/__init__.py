from .adamw import (AdamWConfig, adamw_init, adamw_update, cosine_lr,
                    clip_by_global_norm, global_norm)
from .adafactor import AdafactorConfig, adafactor_init, adafactor_update
from .compress import (quantize_int8, dequantize_int8, compress_tree,
                       decompress_tree, init_error_feedback)
