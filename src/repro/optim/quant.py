"""Weight-only int8 quantization for serving (§Perf B1/B2 production path).

Per-output-channel symmetric scales (the standard weight-only scheme):
matmul weights (d_in, d_out) quantize along d_in.  ``quantize_params``
walks a param tree and quantizes every >=2D matmul weight, leaving norms,
biases and embeddings' scales attached; ``QuantizedLinear`` application is
`(x @ q.astype(bf16)) * scale` — the dequant multiplier fuses into the
matmul epilogue on TPU.

The NUMERIC core lives in :mod:`repro.quant.quantize` (the quantized-
engine subsystem); this module is the param-tree view of the same scheme,
plus the tuple-based API the serving path predates.  The dry-run's
`--set param_dtype=int8` models the same traffic without the scale
plumbing; tests/test_quant.py validates roundtrip + logits-drift bounds.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.quantize import quantize_weights as _quantize_weights

__all__ = ["quantize_weight", "dequantize_weight", "quantize_params",
           "quant_matmul"]


def quantize_weight(w: jax.Array):
    """w (..., d_in, d_out) -> (q int8, scale (..., 1, d_out) f32)."""
    qw = _quantize_weights(w)
    return qw.q, qw.scale


def dequantize_weight(q: jax.Array, scale: jax.Array,
                      dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def quant_matmul(x: jax.Array, q: jax.Array, scale: jax.Array) -> jax.Array:
    """act(x) @ dequant(q) with the scale applied as a fused epilogue:
    (x @ q) * scale — int8 weights are read at 1 byte/elem from HBM."""
    y = jax.lax.dot_general(
        x, q.astype(x.dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return (y * scale.reshape(1, -1).astype(jnp.float32)).astype(x.dtype)


def _is_matmul_weight(path: str, v) -> bool:
    if v.ndim < 2 or v.dtype == jnp.int32:
        return False
    leaf = path.split("/")[-1]
    return leaf in ("wq", "wk", "wv", "wo", "wi", "w1", "w2", "lm_head",
                    "in_proj", "out_proj", "wz", "wx", "wbc", "wdt")


def quantize_params(params):
    """-> tree where matmul weights become {"q": int8, "scale": f32};
    everything else passes through.  Structure-compatible consumers use
    `dequantize_weight` / `quant_matmul`."""
    def _path_str(path):
        out = []
        for p in path:
            if hasattr(p, "key"):
                out.append(str(p.key))
        return "/".join(out)

    def one(path, v):
        ps = _path_str(path)
        if _is_matmul_weight(ps, v):
            q, s = quantize_weight(v)
            return {"q": q, "scale": s}
        return v

    return jax.tree_util.tree_map_with_path(one, params)
