"""Adafactor (Shazeer & Stern 2018): factored second moments, no first
moment — the memory-sane optimizer for the 132B/1T MoE archs (second-moment
storage drops from O(params) fp32 to O(rows + cols))."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["AdafactorConfig", "adafactor_init", "adafactor_update"]


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-2
    decay: float = 0.8           # beta2_t = 1 - step**-decay
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    min_dim_size_to_factor: int = 32


def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 32 and p.shape[-2] >= 32


def adafactor_init(params) -> dict:
    def init(p):
        if _factored(p):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"stats": jax.tree.map(init, params,
                                  is_leaf=lambda x: hasattr(x, "shape")),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: AdafactorConfig, grads, state: dict, params):
    step = state["step"] + 1
    beta2 = 1.0 - jnp.asarray(step, jnp.float32) ** (-cfg.decay)

    def upd(g, s, p):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + cfg.eps
        if _factored(p):
            vr = beta2 * s["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * s["vc"] + (1 - beta2) * g2.mean(axis=-2)
            denom = (vr[..., None] / jnp.maximum(
                vr.mean(axis=-1, keepdims=True)[..., None], cfg.eps)
                * vc[..., None, :])
            update = g * jax.lax.rsqrt(jnp.maximum(denom, cfg.eps))
            new_s = {"vr": vr, "vc": vc}
        else:
            v = beta2 * s["v"] + (1 - beta2) * g2
            update = g * jax.lax.rsqrt(jnp.maximum(v, cfg.eps))
            new_s = {"v": v}
        # update clipping (RMS)
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + 1e-30)
        update = update / jnp.maximum(1.0, rms / cfg.clip_threshold)
        p32 = p.astype(jnp.float32)
        if cfg.weight_decay:
            update = update + cfg.weight_decay * p32
        return (p32 - cfg.lr * update).astype(p.dtype), new_s

    flat_g, treedef = jax.tree.flatten(grads)
    flat_s = treedef.flatten_up_to(state["stats"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_s = treedef.unflatten([o[1] for o in out])
    return new_p, {"stats": new_s, "step": step}, {}
