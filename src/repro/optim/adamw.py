"""AdamW + cosine schedule + global-norm clipping (functional, pytree)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_lr",
           "clip_by_global_norm", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = cfg.lr * step / max(1, cfg.warmup_steps)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * cfg.lr * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: AdamWConfig, grads, state: dict, params):
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)

    def upd(g, m, v, p):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / (1 - cfg.b1 ** step)
        vh = v / (1 - cfg.b2 ** step)
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "lr": lr, "grad_norm": gnorm}
