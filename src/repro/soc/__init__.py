"""``repro.soc`` — the Synergy SoC execution layer (paper §4.3).

Where :mod:`repro.engines` answers "*which* engine should run this JobSet",
this package answers "*run it*": a live work-stealing runtime
(:class:`SynergyRuntime`) with one worker per engine and per-engine job
deques, the shared steal policy (:mod:`repro.soc.policy`) the discrete-event
simulator applies, and a virtual-time conformance twin (:class:`SimRuntime`)
so simulated and live steal decisions agree for identical cost models.

    from repro.soc import SynergyRuntime, runtime_scope

    with SynergyRuntime(["F-PE", "S-PE"]) as rt, rt.scope():
        y = synergy_matmul(a, b)      # tiles split across BOTH engines
    print(rt.stats()["total_steals"])
"""

from .durable import (CrashPlan, Durability, RequestJournal,
                      RestoreMismatch, SimulatedCrash,
                      install_sigterm_drain, install_sigterm_handler)
from .faults import (FAULT_KINDS, CorruptOutput, DroppedCompletion,
                     FaultPlan, FaultSpec, FaultyEngine, InjectedFault,
                     PanelRetryExhausted, RetryPolicy, WorkerKilled,
                     wrap_pool)
from .graph import GraphCancelled, GraphFuture, GraphNode
from .policy import (STEAL_QUEUE_DEPTH, STEAL_RATE_FLOOR, lpt_pick,
                     pick_victim, should_steal)
from .qos import (AdmissionRejected, EngineHealth, HealthPolicy, Tenant)
from .qos_policy import (BEST_EFFORT, BULK, DEFAULT_CLASS, INTERACTIVE,
                         NEUTRAL_TAG, FairShare, QosClass, QosTag,
                         effective_deadline, qos_victim, queue_insert_index)
from .runtime import (RuntimeFuture, SynergyRuntime, current_runtime,
                      runtime_scope)
from .simrt import (SimGraphResult, SimQosResult, SimRuntime,
                    SimRuntimeResult)

__all__ = [
    "SynergyRuntime", "RuntimeFuture", "runtime_scope", "current_runtime",
    "SimRuntime", "SimRuntimeResult", "SimGraphResult", "SimQosResult",
    "GraphNode", "GraphFuture", "GraphCancelled",
    "should_steal", "pick_victim", "lpt_pick",
    "STEAL_RATE_FLOOR", "STEAL_QUEUE_DEPTH",
    "QosClass", "QosTag", "NEUTRAL_TAG", "DEFAULT_CLASS", "INTERACTIVE",
    "BULK", "BEST_EFFORT", "FairShare", "effective_deadline",
    "qos_victim", "queue_insert_index",
    "Tenant", "AdmissionRejected", "HealthPolicy", "EngineHealth",
    "FAULT_KINDS", "FaultPlan", "FaultSpec", "FaultyEngine", "RetryPolicy",
    "InjectedFault", "CorruptOutput", "WorkerKilled", "DroppedCompletion",
    "PanelRetryExhausted", "wrap_pool",
    "Durability", "RequestJournal", "CrashPlan", "SimulatedCrash",
    "RestoreMismatch", "install_sigterm_handler", "install_sigterm_drain",
]
