"""SynergyRuntime — live work-stealing execution over engine pools (§4.3).

PR-1 gave every GEMM a *router* (the Dispatcher picks ONE engine per
JobSet).  This module gives it an *executor*: a runtime that owns one
worker thread per engine, a per-engine job deque, and the paper's thief
protocol — the manager notices idle engines (the idle book), the stealer
moves jobs from the busiest victim queue at job granularity, guarded by the
shared tail policy in :mod:`repro.soc.policy` (the same function the
discrete-event simulator applies).

Execution model
---------------
A *submission* is one JobSet plus its executable decomposition.  For a real
GEMM the unit of scheduling is a **row panel** — one grid row of the
paper's (t1, t2) tile jobs; every tile job belongs to exactly one panel, so
panels steal freely while the merge stays a concatenation (no cross-engine
accumulation).  Accounting-only submissions (serving prefill/decode
proxies) schedule at single tile-job granularity.

Engines come and go mid-run: ``add_engine`` / ``remove_engine`` (or the
process registry's ``register_engine`` / ``unregister_engine`` when
``follow_registry=True``) trigger a live rebalance — queued jobs are
re-seeded across the surviving pool proportional to cost-model rates.  This
is the paper's "adapt to different network configurations at runtime
without changing the hardware" as an API property.

Telemetry flows through the per-engine :class:`repro.engines.Telemetry`
(cost-model ``busy_s`` on the simulator's accounting basis, plus measured
``wall_busy_s``/``idle_s`` and ``steals``), so ``benchmarks/run.py`` and
the Table-6 utilization metric read the same counters.
"""

from __future__ import annotations

import contextlib
import json
import math
import os
import threading
import time
from collections import deque
from typing import Any, Callable, Iterable, Optional, Sequence, Union

import jax

from repro.engines.base import CAP_GEMM, CAP_INT8, CAP_SIM, Engine
from repro.engines.dispatch import JOB_CLASSES
from repro.obs.flightrec import FlightRecorder
from repro.obs.trace import get_default_tracer
from repro.engines.registry import (add_registry_listener, get_engine,
                                    remove_registry_listener)
from .faults import (CorruptOutput, DroppedCompletion, PanelRetryExhausted,
                     RetryPolicy, WorkerKilled)
from .policy import lpt_pick, should_steal
from .qos import EngineHealth, HealthPolicy
from .qos_policy import (NEUTRAL_TAG, QosTag, effective_deadline,
                         qos_victim, queue_insert_index)

__all__ = ["SynergyRuntime", "RuntimeFuture", "RetryPolicy",
           "runtime_scope", "current_runtime"]

#: idle-book wait quantum.  Wakeups are notify-driven (submit / pool change
#: / shutdown all notify_all); the timeout is only a lost-wakeup backstop.
_IDLE_WAIT_S = 0.5


def __getattr__(name):
    # The worker-death detector REUSES the elastic-training heartbeat (one
    # timeout definition, not two — see RetryPolicy.timeout_steps).  The
    # import must be lazy: repro.runtime's package init reaches back into
    # repro.core.scheduler, which imports repro.soc.policy, and a top-level
    # import here would close that cycle.
    if name == "HeartbeatMonitor":
        from repro.runtime.fault_tolerance import HeartbeatMonitor
        return HeartbeatMonitor
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def _admits_int8(job_class: Optional[str]) -> bool:
    """Whether a job class opts into int8 engines (the dispatcher's
    precision policy, read here so runtime splits honor the same
    opt-in invariant).  Unknown classes raise — a typo must not silently
    drop the routing the caller asked for."""
    if job_class is None:
        return False
    try:
        policy = JOB_CLASSES[job_class]
    except KeyError:
        raise KeyError(f"unknown job class {job_class!r}; known: "
                       f"{sorted(JOB_CLASSES)}") from None
    return CAP_INT8 in (policy.prefer | policy.require)


# ---------------------------------------------------------------------------
# Futures + submissions
# ---------------------------------------------------------------------------

class RuntimeFuture:
    """Completion handle for one submission."""

    def __init__(self, jobset):
        self.jobset = jobset
        self._event = threading.Event()
        self._value: Any = None
        self._error: Optional[BaseException] = None
        self._cb_lock = threading.Lock()
        self._callbacks: list[Callable[["RuntimeFuture"], None]] = []
        #: engine name -> {"jobs", "est_s", "bytes", "steals"} for the share
        #: of this submission each engine actually executed.
        self.accounting: dict[str, dict] = {}
        #: panel retries this submission consumed (RetryPolicy runs only)
        self.retries = 0

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"submission {self.jobset.name!r} not done in {timeout}s")
        if self._error is not None:
            raise self._error
        return self._value

    def add_done_callback(
            self, cb: Callable[["RuntimeFuture"], None]) -> None:
        """Run ``cb(self)`` when the submission completes (immediately if
        it already has).  This is how a dataflow graph adopts a
        submission as one of its nodes: the tail panel's completion
        decrements successor dependency counters without polling."""
        with self._cb_lock:
            if not self._event.is_set():
                self._callbacks.append(cb)
                return
        cb(self)

    # internal ------------------------------------------------------------
    def _finish(self, value: Any, error: Optional[BaseException]) -> None:
        self._value, self._error = value, error
        with self._cb_lock:
            self._event.set()
            cbs, self._callbacks = self._callbacks, []
        for cb in cbs:
            cb(self)


class _RuntimeJob:
    """One schedulable unit: ``n_jobs`` identical tile jobs of a submission.

    ``fn(engine) -> part`` does the actual compute (None = accounting-only);
    ``index`` is the merge slot.  ``stealable=False`` pins the job to the
    queue it was seeded on — used for real-array splits over MIXED-precision
    pools, where a steal would nondeterministically swap an fp32 panel for
    an int8 one (accounting-only jobs always steal freely).  ``int8_ok``
    carries the caller's precision opt-in ON the job, so every placement
    path — seed, steal, rebalance, engine removal, hotplug — enforces it:
    a job that never opted into int8 cannot land on a CAP_INT8 worker, no
    matter how the pool changes after submission.

    ``priority``/``deadline_at`` carry the submission's QoS tag the same
    way (see :mod:`repro.soc.qos_policy`): every placement path orders by
    them, and a queue stays sorted non-increasing in priority, so the
    head is always the most urgent panel and the tail the most stealable
    one.  Neutral jobs (priority 0, no deadline) place exactly as the
    pre-QoS runtime did."""

    __slots__ = ("sub", "index", "fn", "n_jobs", "job_macs", "job_bytes",
                 "stealable", "int8_ok", "priority", "deadline_at",
                 "attempts", "failed_on")

    def __init__(self, sub: "_Submission", index: int, fn, n_jobs: int,
                 job_macs: int, job_bytes: int, stealable: bool = True,
                 int8_ok: bool = True, priority: int = 0,
                 deadline_at: float = math.inf):
        self.sub = sub
        self.index = index
        self.fn = fn
        self.n_jobs = n_jobs
        self.job_macs = job_macs
        self.job_bytes = job_bytes
        self.stealable = stealable
        self.int8_ok = int8_ok
        self.priority = priority
        self.deadline_at = deadline_at
        # retry bookkeeping (RetryPolicy runs only): executions consumed,
        # and engines this panel already failed on (None until the first
        # failure — the fault-free hot path never allocates the list)
        self.attempts = 0
        self.failed_on: Optional[list[str]] = None


class _Submission:
    def __init__(self, jobset, n_parts: int,
                 merge: Optional[Callable[[list], Any]],
                 on_done: Optional[Callable[["RuntimeFuture"], None]] = None):
        self.future = RuntimeFuture(jobset)
        self.merge = merge
        self.on_done = on_done
        self.parts: list = [None] * n_parts
        self.exec_counts = [0] * n_parts   # work-conservation audit trail
        self.future.execution_counts = self.exec_counts
        self.pending = n_parts
        #: idempotent-completion flags: a DUPLICATE completion for an
        #: already-done index (stall-sweep re-execution racing the slow
        #: original) is dropped whole — parts, accounting and the pending
        #: countdown see exactly one completion per index, so duplicate
        #: re-execution is always merge-safe
        self.done_flags = [False] * n_parts
        self.error: Optional[BaseException] = None
        self.lock = threading.Lock()

    def complete(self, job: _RuntimeJob, engine_name: str, part: Any,
                 err: Optional[BaseException], est_s: float,
                 stolen: bool) -> None:
        with self.lock:
            if self.done_flags[job.index]:
                return                     # first completion won the race
            self.done_flags[job.index] = True
            self.parts[job.index] = part
            self.exec_counts[job.index] += 1
            acct = self.future.accounting.setdefault(
                engine_name, {"jobs": 0, "est_s": 0.0, "bytes": 0,
                              "steals": 0})
            acct["jobs"] += job.n_jobs
            acct["est_s"] += est_s
            acct["bytes"] += job.n_jobs * job.job_bytes
            acct["steals"] += int(stolen)
            if err is not None and self.error is None:
                self.error = err
            self.pending -= 1
            last = self.pending == 0
        if not last:
            return
        if self.error is not None:
            self.future._finish(None, self.error)
        else:
            try:
                value = self.merge(self.parts) if self.merge else None
            except BaseException as e:      # merge bug must not hang callers
                self.future._finish(None, e)
            else:
                self.future._finish(value, None)
        if self.on_done is not None:
            self.on_done(self.future)


class _Worker:
    def __init__(self, engine: Engine):
        self.engine = engine
        self.queue: deque[_RuntimeJob] = deque()
        #: EngineHealth when the runtime runs a HealthPolicy, else None
        self.health: Optional[EngineHealth] = None
        self.thread: Optional[threading.Thread] = None
        self.stopped = False
        self.idle = False
        # per-runtime counters (engine.telemetry is process-global)
        self.jobs = 0
        self.steals = 0
        self.est_busy_s = 0.0
        self.wall_busy_s = 0.0
        self.idle_s = 0.0
        # recalibration window (zeroed by SynergyRuntime.recalibrate)
        self.cal_macs = 0
        self.cal_wall_s = 0.0

    @property
    def rate(self) -> float:
        try:
            return self.engine.cost.macs_per_s
        except NotImplementedError:
            return 1.0

    def job_time(self, macs: int, n_bytes: int) -> float:
        try:
            return self.engine.cost.job_time(macs, n_bytes)
        except NotImplementedError:
            return 0.0

    @property
    def quarantined(self) -> bool:
        return self.health is not None and self.health.quarantined


# ---------------------------------------------------------------------------
# The runtime
# ---------------------------------------------------------------------------

class SynergyRuntime:
    """Work-stealing executor over a pool of registered engines.

    engines: engine names/instances; None = every non-sim GEMM-capable
    engine the default dispatcher would consider.  ``follow_registry=True``
    mirrors ``register_engine``/``unregister_engine`` into the live pool.
    Use as a context manager, or ``start()``/``shutdown()`` explicitly.
    """

    def __init__(self, engines: Optional[Iterable[Union[str, Engine]]] = None,
                 *, require: Iterable[str] = (CAP_GEMM,),
                 follow_registry: bool = False, name: str = "runtime",
                 recalibrate_every: Optional[int] = None,
                 recalibrate_alpha: float = 0.5,
                 rates_path: Optional[Union[str, os.PathLike]] = None,
                 health: Optional[HealthPolicy] = None,
                 retry: Optional[RetryPolicy] = None,
                 tracer=None, flight_recorder=None):
        """``recalibrate_every=N`` makes the runtime self-calibrating: every
        N completed submissions it folds measured worker rates into the
        cost models (the serving analog of the paper's offline
        calibration) — no caller-driven ``recalibrate()`` needed.
        ``rates_path`` persists the learned ``macs_per_s`` to a JSON
        sidecar after each recalibration and re-applies it on
        construction, so a restarted process starts from the measured
        rates (e.g. the real qmm kernel's) instead of the nominal
        constants.  CAP_SIM engines are excluded from both directions.

        ``health=HealthPolicy(...)`` makes the pool SELF-HEALING: every
        worker's measured per-panel MAC rate feeds an EMA, a worker whose
        rate decays below the policy threshold is quarantined (deque
        rebalanced onto the survivors, cost model decayed to the measured
        rate, no new seeds or steals), probed on a cadence, and
        re-admitted once it measures healthy again (see
        :mod:`repro.soc.qos`).  ``health=None`` (default) disables all
        of it — zero overhead, zero behavior change.

        ``retry=RetryPolicy(...)`` (see :mod:`repro.soc.faults`) makes
        the pool FAULT-TOLERANT: a panel that raises (or fails the
        opt-in NaN/Inf output screen) is re-seeded onto a surviving
        engine instead of failing its submission — up to
        ``max_attempts`` executions, avoiding engines it already failed
        on — a worker thread that DIES is detected by a heartbeat
        monitor (the :class:`repro.runtime.fault_tolerance.
        HeartbeatMonitor` semantics, ticked by a runtime monitor
        thread) and its queued + in-flight panels re-seed onto the
        survivors, and a panel in flight longer than
        ``stall_timeout_s`` gets a duplicate attempt (first completion
        wins — the merge is idempotent per panel index).  Every fault
        feeds the worker's health EMA when a ``HealthPolicy`` is also
        active, so chronically flaky engines quarantine through the
        same machinery as slow ones.  ``retry=None`` (default) keeps
        the first-error-wins behavior, zero overhead: no monitor
        thread, no in-flight registry.

        ``tracer=Tracer(...)`` (see :mod:`repro.obs.trace`) records typed
        scheduling events — seed/enqueue/dequeue, panel spans, steals,
        quarantines — exportable as a Chrome trace.  ``tracer=None``
        falls back to the process default installed by
        ``repro.obs.trace.set_default_tracer`` (e.g. by
        ``benchmarks/run.py --trace``); with neither, every
        instrumentation site is a single ``is None`` attribute check and
        scheduling is bitwise identical to the untraced runtime.  When a
        tracer is active, a :class:`~repro.obs.flightrec.FlightRecorder`
        (auto-created unless ``flight_recorder`` is passed) dumps the
        event tail + ``stats()`` on every quarantine."""
        self.name = name
        self._tracer = tracer if tracer is not None else get_default_tracer()
        if flight_recorder is None and self._tracer is not None:
            flight_recorder = FlightRecorder(self._tracer)
        self._flight = flight_recorder
        self.require = frozenset(require)
        self._recal_every = recalibrate_every
        self._recal_alpha = recalibrate_alpha
        self._health = health
        self._retry = retry
        self._retries = 0
        self._worker_deaths = 0
        self._orphan_reseeds = 0
        #: panels currently executing, job -> (engine_name, t_start) —
        #: maintained ONLY under a RetryPolicy (the monitor's view of
        #: what a dead worker orphans / what the stall sweep re-seeds)
        self._live_panels: dict[_RuntimeJob, tuple[str, float]] = {}
        self._monitor: Optional[threading.Thread] = None
        self._quarantines = 0
        self._rates_path = os.fspath(rates_path) if rates_path else None
        self._completed = 0    # finished submissions (cadence counter)
        # RLock: submission-completion hooks can fire from paths that
        # already hold the runtime lock (cancel / orphan-fail)
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._workers: dict[str, _Worker] = {}
        self._retired: list[threading.Thread] = []
        #: counters of removed engines, so stats() totals never go backwards
        self._retired_counters = {"jobs": 0, "steals": 0, "est_busy_s": 0.0,
                                  "wall_busy_s": 0.0, "idle_s": 0.0}
        self._started = False
        self._stopping = False
        self._rebalances = 0
        self._submissions = 0
        self._inflight = 0     # incomplete submissions (gates idle booking)
        self._listener = None
        #: active dataflow-graph runs (see repro.soc.graph) — cancelled on
        #: shutdown so an abandoned DAG can never hang a reaper on workers
        #: that no longer exist
        self._graphs: set = set()
        #: lazy host-side executor for graph CPU nodes (im2col gathers,
        #: pooling) — NEVER an engine worker, so a host stage cannot stall
        #: an accelerator queue
        self._host_pool = None
        if engines is None:
            from repro.engines.dispatch import DEFAULT_DISPATCHER
            pool: list[Engine] = DEFAULT_DISPATCHER.candidates(require)
        else:
            pool = [get_engine(e) if isinstance(e, str) else e
                    for e in engines]
        if not pool:
            raise ValueError("SynergyRuntime needs at least one engine")
        for eng in pool:
            self._workers[eng.name] = self._new_worker(eng)
        self._follow_registry = follow_registry
        if self._rates_path:
            self._load_rates()

    def _new_worker(self, eng: Engine) -> _Worker:
        w = _Worker(eng)
        if self._health is not None:
            w.health = EngineHealth()
        return w

    # ---------------------------------------------------------- lifecycle
    def start(self) -> "SynergyRuntime":
        with self._cond:
            if self._started:
                return self
            self._started = True
            self._stopping = False
            for w in self._workers.values():
                self._spawn(w)
            if self._retry is not None and self._monitor is None:
                self._monitor = threading.Thread(
                    target=self._monitor_loop, daemon=True,
                    name=f"synergy-{self.name}-monitor")
                self._monitor.start()
        if self._follow_registry and self._listener is None:
            self._listener = add_registry_listener(self._on_registry_event)
        return self

    def _spawn(self, w: _Worker) -> None:
        w.thread = threading.Thread(
            target=self._worker_loop, args=(w,), daemon=True,
            name=f"synergy-{self.name}-{w.engine.name}")
        w.thread.start()

    def shutdown(self, *, drain: bool = True,
                 timeout: float = 30.0) -> None:
        if self._listener is not None:
            remove_registry_listener(self._listener)
            self._listener = None
        with self._cond:
            if not self._started:
                return
            # graphs whose pending nodes would seed work AFTER the workers
            # exit can never complete — cancel them first (reap graphs
            # before shutting down to avoid this)
            for g in list(self._graphs):
                g.cancel("runtime shut down")
            if not drain:
                self._cancel_queued_locked("runtime shut down")
            self._stopping = True
            self._cond.notify_all()
            threads = [w.thread for w in self._workers.values()
                       if w.thread is not None] + self._retired
        for t in threads:
            t.join(timeout)
        with self._cond:
            self._started = False
            self._monitor = None       # stale monitor loops see the swap
            self._live_panels.clear()
            self._retired.clear()
            pool, self._host_pool = self._host_pool, None
        if pool is not None:
            pool.shutdown(wait=False)

    def _cancel_queued_locked(self, why: str) -> None:
        for w in self._workers.values():
            while w.queue:
                job = w.queue.popleft()
                job.sub.complete(job, w.engine.name, None,
                                 RuntimeError(why), 0.0, False)

    def __enter__(self) -> "SynergyRuntime":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # ------------------------------------------------------- pool changes
    @property
    def engine_names(self) -> list[str]:
        with self._lock:
            return list(self._workers)

    def find_engine(self, name: str) -> Optional[Engine]:
        """The live pool member under ``name`` (pool engines need not be
        in the process registry — accounting consumers resolve here)."""
        with self._lock:
            w = self._workers.get(name)
            return w.engine if w is not None else None

    def add_engine(self, engine: Union[str, Engine]) -> None:
        """Bring an engine online mid-run; queued work rebalances onto it."""
        eng = get_engine(engine) if isinstance(engine, str) else engine
        with self._cond:
            if eng.name in self._workers:
                return
            w = self._new_worker(eng)
            self._workers[eng.name] = w
            if self._started:
                self._spawn(w)
                self._rebalance_locked()
            self._cond.notify_all()

    def remove_engine(self, name: str) -> bool:
        """Retire an engine mid-run; its queued jobs move to survivors (the
        in-flight job, if any, finishes on the retiring engine, and its
        counters fold into the runtime totals).  Orphans keep their
        precision eligibility: an fp32-only panel re-seeds onto
        full-precision survivors, and FAILS its submission if none remain
        (see ``_seed_locked``) rather than silently quantizing."""
        with self._cond:
            w = self._workers.pop(name, None)
            if w is None:
                return False
            orphans = self._retire_worker_locked(w)
            if self._workers:
                self._seed_locked(orphans, affinity=None)
                self._rebalance_locked()
            else:
                for job in orphans:
                    job.sub.complete(job, name, None,
                                     RuntimeError("no engines left"), 0.0,
                                     False)
            self._cond.notify_all()
            return True

    def _retire_worker_locked(self, w: _Worker) -> list[_RuntimeJob]:
        w.stopped = True
        orphans = list(w.queue)
        w.queue.clear()
        if w.thread is not None:
            self._retired.append(w.thread)
        c = self._retired_counters
        c["jobs"] += w.jobs
        c["steals"] += w.steals
        c["est_busy_s"] += w.est_busy_s
        c["wall_busy_s"] += w.wall_busy_s
        c["idle_s"] += w.idle_s
        return orphans

    def _on_registry_event(self, event: str, engine: Engine) -> None:
        if not engine.supports(self.require):
            return
        if event == "register":
            # re-registration under the same name swaps the live engine
            # ATOMICALLY: the replacement inherits the old queue, so a
            # single-engine pool never transits through "no engines left"
            with self._cond:
                old = self._workers.pop(engine.name, None)
                orphans = (self._retire_worker_locked(old)
                           if old is not None else [])
                w = self._new_worker(engine)
                self._workers[engine.name] = w
                w.queue.extend(orphans)
                if self._started:
                    self._spawn(w)
                    self._rebalance_locked()
                self._cond.notify_all()
        elif event == "unregister":
            self.remove_engine(engine.name)

    def _rebalance_locked(self) -> None:
        """Gather every queued (unstarted) STEALABLE job and re-seed
        proportional to the current pool's cost-model rates.  Precision-
        pinned panels (mixed-pool splits) stay on the queue the LPT seed
        chose — a hotplug mid-GEMM must not silently move an fp32 panel
        onto an int8 engine.  (A REMOVED engine's pinned orphans do
        migrate — see remove_engine — there is no engine left to honor.)"""
        pending: list[_RuntimeJob] = []
        for w in self._workers.values():
            pinned = [j for j in w.queue if not j.stealable]
            pending.extend(j for j in w.queue if j.stealable)
            w.queue.clear()
            w.queue.extend(pinned)
        if pending:
            self._seed_locked(pending, affinity=None)
        self._rebalances += 1

    # --------------------------------------------------------- scheduling
    @staticmethod
    def _seed_order(jobs: Sequence[_RuntimeJob],
                    best_rate: float) -> Sequence[_RuntimeJob]:
        """Deadline-aware seed order: priority descending, then earliest
        EFFECTIVE deadline (deadline minus the fastest healthy member's
        cost-model service estimate) within a class, submission order as
        the stable tie-break.  All-neutral batches return unsorted — the
        pre-QoS FIFO order, byte for byte."""
        if all(j.priority == 0 and j.deadline_at == math.inf for j in jobs):
            return jobs

        def key(j: _RuntimeJob):
            est = (j.n_jobs * j.job_macs / best_rate if best_rate > 0
                   else 0.0)
            return (-j.priority, effective_deadline(j.deadline_at, est))

        return sorted(jobs, key=key)

    @staticmethod
    def _enqueue(q: deque, job: _RuntimeJob) -> None:
        """Priority insertion that keeps the deque sorted non-increasing
        in priority (head = most urgent, tail = most stealable).  Neutral
        traffic into a neutral queue is a plain O(1) append."""
        if not q or job.priority <= q[-1].priority:
            q.append(job)
        else:
            q.insert(queue_insert_index([j.priority for j in q],
                                        job.priority), job)

    def _seed_locked(self, jobs: Sequence[_RuntimeJob],
                     affinity: Optional[str]) -> None:
        """Seed jobs with per-job precision eligibility: a job whose
        ``int8_ok`` is False never lands on a CAP_INT8 worker (the
        dispatcher's opt-in invariant, enforced at the queue level so
        rebalances and removals preserve it too).  A job with NO eligible
        worker fails its submission instead of crashing the seed.

        QoS: jobs are seeded in deadline-aware order (priority, then
        effective deadline), quarantined workers are skipped unless the
        job has no healthy eligible engine, and each job enters its queue
        at its priority position (:func:`~repro.soc.qos_policy.
        queue_insert_index`) — a decode panel lands ahead of queued bulk
        prefill panels, never mid-panel."""
        tr = self._tracer
        if tr is not None:
            tr.emit("seed", "manager", runtime=self.name,
                    n_jobs=len(jobs), affinity=affinity)
        workers = list(self._workers.values())
        is_int8 = [CAP_INT8 in w.engine.capabilities for w in workers]
        quar = [w.quarantined for w in workers]
        loads = [sum(j.n_jobs * w.job_time(j.job_macs, j.job_bytes)
                     for j in w.queue) for w in workers]
        best_rate = max((w.rate for w, q in zip(workers, quar) if not q),
                        default=0.0)
        avoid_on = (self._retry is not None
                    and self._retry.avoid_failed_engine)
        for job in self._seed_order(jobs, best_rate):
            elig = [i for i in range(len(workers))
                    if job.int8_ok or not is_int8[i]]
            idxs = [i for i in elig if not quar[i]]
            if avoid_on and job.failed_on:
                # retry placement: skip the engines this panel already
                # failed on — unless that leaves nowhere to go
                avoided = [i for i in idxs
                           if workers[i].engine.name not in job.failed_on]
                if avoided:
                    idxs = avoided
            if not idxs:
                # every eligible engine quarantined: degraded placement
                # beats failing the submission
                idxs = elig
            if not idxs:
                job.sub.complete(
                    job, "<unplaceable>", None,
                    RuntimeError("no precision-eligible engine in the pool "
                                 "for this job"), 0.0, False)
                continue
            ai = next((i for i in idxs
                       if workers[i].engine.name == affinity), None)
            if ai is None:
                # LPT-style seed (§3.1.1): smallest projected finish time
                # among eligible workers; stealing fixes the rest
                costs = [workers[i].job_time(job.job_macs, job.job_bytes)
                         * job.n_jobs for i in range(len(workers))]
                ai = lpt_pick(idxs, loads, costs)
            loads[ai] += (workers[ai].job_time(job.job_macs, job.job_bytes)
                          * job.n_jobs)
            self._enqueue(workers[ai].queue, job)
            if tr is not None:
                tr.emit("enqueue", workers[ai].engine.name,
                        jobset=job.sub.future.jobset.name,
                        n_jobs=job.n_jobs, priority=job.priority)

    def _try_steal_locked(self, thief: _Worker):
        """The stealer: priority-aware victim choice over VIABLE queues,
        shared tail-guard policy, steal from the TAIL (victims pop their
        own head).  A queue whose tail job is precision-pinned
        (mixed-pool panel), or whose tail the THIEF may not run (int8
        thief, non-opted-in job), is not viable — but other queues still
        are, so interleaved accounting traffic keeps stealing even while
        a pinned split is in flight.

        QoS: among viable victims, thieves prefer the one holding the
        LOWEST-priority tail (:func:`~repro.soc.qos_policy.qos_victim` —
        bulk panels move out of the way first; queues are priority-sorted
        so a tail is always its queue's least important panel).  A
        quarantined thief steals nothing except its probation probe: one
        panel per ``probe_interval_s``, to re-measure itself."""
        h = thief.health
        probe = False
        if h is not None and h.quarantined:
            if not h.probe_due(time.monotonic(), self._health):
                return None
            probe = True
        thief_int8 = CAP_INT8 in thief.engine.capabilities
        # avoid_failed_engine must hold at STEAL time too: an engine whose
        # panels fault instantly is always hungry and would steal its own
        # failed retry straight back off the survivor it re-seeded to
        avoid = (self._retry is not None
                 and self._retry.avoid_failed_engine)
        names = [n for n, w in self._workers.items()
                 if n != thief.engine.name and w.queue
                 and w.queue[-1].stealable
                 and (w.queue[-1].int8_ok or not thief_int8)
                 and not (avoid and w.queue[-1].failed_on
                          and thief.engine.name in w.queue[-1].failed_on)]
        if not names:
            return None
        prios = [self._workers[n].queue[-1].priority for n in names]
        lens = [len(self._workers[n].queue) for n in names]
        victim = self._workers[names[qos_victim(prios, lens)]]
        fastest = max((w.rate for w in self._workers.values()
                       if not w.quarantined), default=thief.rate)
        rel = thief.rate / fastest if fastest > 0 else 1.0
        if should_steal(rel, len(victim.queue)):
            if probe:
                h.last_probe_s = time.monotonic()
            job = victim.queue.pop()
            tr = self._tracer
            if tr is not None:
                tr.emit("steal", thief.engine.name,
                        victim=victim.engine.name,
                        jobset=job.sub.future.jobset.name,
                        priority=job.priority, probe=probe)
            return job
        return None

    def _worker_loop(self, w: _Worker) -> None:
        while True:
            job, stolen = None, False
            with self._cond:
                while True:
                    if w.queue:
                        job = w.queue.popleft()
                        tr = self._tracer
                        if tr is not None:
                            tr.emit("dequeue", w.engine.name,
                                    jobset=job.sub.future.jobset.name,
                                    n_jobs=job.n_jobs)
                        break
                    if w.stopped:      # retired: never steal NEW work
                        return
                    job = self._try_steal_locked(w)
                    if job is not None:
                        stolen = True
                        break
                    if self._stopping:  # shutdown drain: all queues empty
                        return
                    # idle book: park until the manager (a submit/notify)
                    # wakes us.  Idle is booked only while a submission is
                    # actually outstanding, so busy_fraction measures
                    # utilization of the WORKLOAD, not runtime lifetime.
                    w.idle = True
                    t0 = time.perf_counter()
                    busy_elsewhere = self._inflight > 0
                    self._cond.wait(_IDLE_WAIT_S)
                    if busy_elsewhere:
                        dt = time.perf_counter() - t0
                        w.idle_s += dt
                        w.engine.telemetry.record_runtime(idle_s=dt)
                w.idle = False
            try:
                self._execute(w, job, stolen)
            except WorkerKilled:
                # injected mid-panel death: the thread exits without
                # completing its panel (the live-panel registry entry
                # survives for the heartbeat monitor to orphan-reseed)
                return
            if w.stopped:
                return

    def _execute(self, w: _Worker, job: _RuntimeJob, stolen: bool) -> None:
        eng = w.engine
        err, part = None, None
        retry = self._retry
        if retry is not None:
            with self._lock:
                self._live_panels[job] = (eng.name, time.monotonic())
        t0 = time.perf_counter()
        try:
            if job.fn is not None:
                # block on async dispatch: an unrealized jax.Array returns
                # in ~µs and would make the measured (recalibration) rate
                # orders of magnitude too high on real backends
                part = jax.block_until_ready(job.fn(eng))
        except WorkerKilled:
            # mid-panel worker death: re-raise WITHOUT completing and
            # WITHOUT clearing the live-panel entry — the monitor reads
            # it to know what the corpse was holding
            raise
        except DroppedCompletion:
            # the panel computed but its completion was lost: the worker
            # moves on; only the stall sweep (which still sees the live
            # entry) can recover the submission
            return
        except BaseException as e:
            err = e
        dt = time.perf_counter() - t0
        tr = self._tracer
        if tr is not None:
            tags = {"jobset": job.sub.future.jobset.name,
                    "n_jobs": job.n_jobs, "stolen": stolen,
                    "priority": job.priority}
            if err is not None:
                tags["err"] = type(err).__name__
            tr.span("panel", eng.name, t0, dt, **tags)
        est = job.n_jobs * w.job_time(job.job_macs, job.job_bytes)
        w.jobs += job.n_jobs
        w.steals += int(stolen)
        w.est_busy_s += est
        w.wall_busy_s += dt
        if job.fn is not None:
            # recalibration window: only REAL compute measures a rate —
            # accounting-only jobs finish in ~0 wall time at full MACs and
            # would blow the observed rate sky-high
            w.cal_macs += job.n_jobs * job.job_macs
            w.cal_wall_s += dt
        eng.telemetry.record_jobs(job.n_jobs, est, job.n_jobs * job.job_bytes,
                                  steals=int(stolen))
        eng.telemetry.record_runtime(wall_busy_s=dt)
        if (self._health is not None and job.fn is not None
                and err is None and dt > 0 and job.job_macs > 0):
            # self-healing: only REAL compute measures a health rate, for
            # the same reason recalibration ignores accounting-only jobs
            self._health_tick(w, job.n_jobs * job.job_macs / dt)
        if retry is not None:
            with self._lock:
                self._live_panels.pop(job, None)
            if err is None and retry.check_outputs \
                    and self._screen_output(part):
                err = CorruptOutput(
                    f"panel of {job.sub.future.jobset.name!r} returned "
                    f"non-finite values on {eng.name!r}")
            if err is not None:
                err = self._maybe_retry(w, job, err)
                if err is None:
                    return             # re-seeded: another attempt runs
                part = None
        job.sub.complete(job, eng.name, part, err, est, stolen)

    # ------------------------------------------------------- self-healing
    def _health_tick(self, w: _Worker, rate: float) -> None:
        """Fold one measured per-panel rate into the worker's health EMA
        and act on the quarantine / readmission thresholds."""
        pol = self._health
        with self._cond:
            h = w.health
            if h is None or w.stopped:
                return
            h.observe(rate, pol)
            if h.should_quarantine(pol):
                self._quarantine_locked(w)
            elif h.quarantined and h.recovered(pol):
                self._readmit_locked(w)

    def _quarantine_locked(self, w: _Worker) -> None:
        """Quarantine a sick worker: decay its cost model to the MEASURED
        rate (planning must see the truth, not the nominal constant),
        drain its stealable queued panels onto the survivors via the
        hotplug seeding path, and stop seeding/stealing to it — it still
        runs its own pinned leftovers, and probes one stolen panel per
        ``probe_interval_s`` to earn readmission.  The LAST healthy
        worker is never quarantined: a degraded pool beats a dead one."""
        others = [o for o in self._workers.values()
                  if o is not w and not o.stopped and not o.quarantined]
        if not others:
            return
        h = w.health
        h.enter_quarantine(time.monotonic())
        self._quarantines += 1
        w.engine.telemetry.record_runtime(quarantines=1)
        tr = self._tracer
        if tr is not None:
            tr.emit("quarantine", w.engine.name, runtime=self.name,
                    health=h.health, ema_rate=h.ema_rate)
        if CAP_SIM not in w.engine.capabilities and h.ema_rate > 0:
            # alpha=1: the decayed measurement IS the engine's rate now
            w.engine.recalibrate(h.ema_rate, alpha=1.0)
        stealable = [j for j in w.queue if j.stealable]
        pinned = [j for j in w.queue if not j.stealable]
        w.queue.clear()
        w.queue.extend(pinned)
        if stealable:
            self._seed_locked(stealable, affinity=None)
        self._rebalances += 1
        self._cond.notify_all()
        if self._flight is not None:
            # post-mortem without a re-run: event tail + the stats view
            # AFTER the drain, so the dump shows where the work went
            self._flight.dump(
                "quarantine", stats=self.stats(),
                context={"runtime": self.name, "engine": w.engine.name,
                         "health": h.snapshot()})

    def _readmit_locked(self, w: _Worker) -> None:
        """Probation exit: the probes measured healthy again — restore the
        cost model to the recovered rate and rebalance queued work back
        across the full pool."""
        h = w.health
        h.exit_quarantine()
        tr = self._tracer
        if tr is not None:
            tr.emit("readmit", w.engine.name, runtime=self.name,
                    health=h.health, ema_rate=h.ema_rate)
        if CAP_SIM not in w.engine.capabilities and h.ema_rate > 0:
            w.engine.recalibrate(h.ema_rate, alpha=1.0)
        self._rebalance_locked()
        self._cond.notify_all()

    # ------------------------------------------------------ fault recovery
    def _monitor_loop(self) -> None:
        """The RetryPolicy's watchdog thread: one HeartbeatMonitor "step"
        per ``monitor_interval_s`` tick.  Each tick beats every worker
        whose thread is still alive; a worker silent for
        ``timeout_steps`` ticks (``heartbeat_timeout_s``) is declared
        dead and its queued + in-flight panels re-seed onto survivors.
        The monitor is rebuilt (everyone re-beaten at the current tick)
        whenever pool membership changes, so a hotplugged engine never
        starts life already timed out.  Also runs the stall sweep when
        ``stall_timeout_s`` is set."""
        from repro.runtime.fault_tolerance import HeartbeatMonitor
        pol = self._retry
        me = threading.current_thread()
        hb: Optional[HeartbeatMonitor] = None
        names: list[str] = []
        tick = 0
        while True:
            time.sleep(pol.monitor_interval_s)
            with self._cond:
                if (self._stopping or not self._started
                        or self._monitor is not me):
                    return
                cur = [n for n, w in self._workers.items() if not w.stopped]
                if hb is None or cur != names:
                    names = cur
                    hb = HeartbeatMonitor(
                        len(names), timeout_steps=pol.timeout_steps)
                    tick = 0
                tick += 1
                for h, n in enumerate(names):
                    w = self._workers.get(n)
                    if (w is not None and w.thread is not None
                            and w.thread.is_alive()):
                        hb.beat(h, tick)
                dead = [names[h] for h in hb.failed_hosts(tick)]
                for n in dead:
                    w = self._workers.get(n)
                    if w is not None and not w.stopped:
                        self._on_worker_death_locked(w)
                if dead:
                    hb = None          # membership changed: rebuild
                if pol.stall_timeout_s is not None:
                    self._stall_sweep_locked()

    def _on_worker_death_locked(self, w: _Worker) -> None:
        """A worker thread died (crash, ``WorkerKilled`` injection): pop
        it from the pool via the hotplug retirement path, reclaim BOTH
        its queued panels and the panel it died holding (the live-panel
        registry entry its crash left behind), and re-seed everything
        onto the survivors.  An empty surviving pool fails the orphans —
        same contract as ``remove_engine``."""
        name = w.engine.name
        self._workers.pop(name, None)
        orphans = self._retire_worker_locked(w)
        inflight = [job for job, (wn, _) in list(self._live_panels.items())
                    if wn == name]
        for job in inflight:
            self._live_panels.pop(job, None)
            if job.failed_on is None:
                job.failed_on = []
            if name not in job.failed_on:
                job.failed_on.append(name)
        orphans.extend(inflight)
        self._worker_deaths += 1
        tr = self._tracer
        if tr is not None:
            tr.emit("worker_death", name, runtime=self.name,
                    queued=len(orphans) - len(inflight),
                    in_flight=len(inflight))
        if self._workers and orphans:
            self._orphan_reseeds += len(orphans)
            if tr is not None:
                tr.emit("orphan_reseed", name, runtime=self.name,
                        n_jobs=len(orphans))
            self._seed_locked(orphans, affinity=None)
        else:
            for job in orphans:
                job.sub.complete(job, name, None,
                                 RuntimeError(f"worker {name!r} died with "
                                              "no engines left"), 0.0, False)
        self._cond.notify_all()
        if self._flight is not None:
            self._flight.dump(
                "worker_death", stats=self.stats(),
                context={"runtime": self.name, "engine": name,
                         "orphans": len(orphans),
                         "in_flight": len(inflight)})

    def _stall_sweep_locked(self) -> None:
        """Presume panels in flight past ``stall_timeout_s`` wedged (or
        their completion dropped) and re-seed a DUPLICATE attempt.  The
        per-index idempotent merge makes the duplicate safe: first
        completion wins, so a slow-but-alive original costs nothing but
        the redundant compute."""
        pol = self._retry
        now = time.monotonic()
        stalled = [(job, wn) for job, (wn, t0) in self._live_panels.items()
                   if now - t0 >= pol.stall_timeout_s]
        if not stalled:
            return
        tr = self._tracer
        for job, wn in stalled:
            self._live_panels.pop(job, None)
            dup = _RuntimeJob(job.sub, job.index, job.fn, job.n_jobs,
                              job.job_macs, job.job_bytes, job.stealable,
                              job.int8_ok, job.priority, job.deadline_at)
            dup.attempts = job.attempts + 1
            dup.failed_on = [wn] if pol.avoid_failed_engine else []
            self._retries += 1
            job.sub.future.retries += 1
            if tr is not None:
                tr.emit("panel_retry", wn,
                        jobset=job.sub.future.jobset.name,
                        attempt=dup.attempts, err="stall")
            self._seed_locked([dup], affinity=None)
        self._cond.notify_all()

    def _maybe_retry(self, w: _Worker, job: _RuntimeJob,
                     err: BaseException) -> Optional[BaseException]:
        """Decide a failed panel's fate under the RetryPolicy.  Returns
        None when the panel was re-seeded for another attempt (the
        submission hears nothing), or the error to complete with —
        :class:`PanelRetryExhausted` once the budget ran out.  Every
        fault also feeds the worker's health EMA, so a chronically
        faulty engine quarantines through the PR 7 machinery."""
        retry = self._retry
        if not isinstance(err, Exception):
            return err                 # WorkerKilled etc. never retry here
        name = job.sub.future.jobset.name
        with self._cond:
            job.attempts += 1
            if job.failed_on is None:
                job.failed_on = []
            if w.engine.name not in job.failed_on:
                job.failed_on.append(w.engine.name)
            if w.health is not None and self._health is not None:
                w.health.record_fault(self._health)
                if w.health.should_quarantine(self._health):
                    self._quarantine_locked(w)
            if job.attempts >= retry.max_attempts:
                exhausted = PanelRetryExhausted(name, job.attempts,
                                                job.failed_on, err)
                if self._flight is not None:
                    self._flight.dump(
                        "retry_exhausted", stats=self.stats(),
                        context={"runtime": self.name, "jobset": name,
                                 "attempts": job.attempts,
                                 "engines": list(job.failed_on),
                                 "last_error": f"{type(err).__name__}: "
                                               f"{err}"})
                return exhausted
            self._retries += 1
            job.sub.future.retries += 1
            tr = self._tracer
            if tr is not None:
                tr.emit("panel_retry", w.engine.name, jobset=name,
                        attempt=job.attempts, err=type(err).__name__)
            if retry.backoff_s > 0:
                t = threading.Timer(retry.backoff_s, self._reseed_retry,
                                    args=(job,))
                t.daemon = True
                t.start()
            else:
                self._seed_locked([job], affinity=None)
                self._cond.notify_all()
        return None

    def _reseed_retry(self, job: _RuntimeJob) -> None:
        """Backoff-timer body: re-seed one retried panel, or fail it if
        the runtime went away while it waited."""
        with self._cond:
            if not self._started or self._stopping:
                job.sub.complete(
                    job, "<retry>", None,
                    RuntimeError("runtime shut down before retry"),
                    0.0, False)
                return
            self._seed_locked([job], affinity=None)
            self._cond.notify_all()

    @staticmethod
    def _screen_output(part) -> bool:
        """True when a panel partial fails the NaN/Inf integrity screen.
        Float outputs only: the int8 path's int32 accumulators cannot
        encode a NaN, and casting them through float to check would cost
        exactness for nothing."""
        import jax.numpy as jnp
        if part is None or not hasattr(part, "dtype"):
            return False
        if not jnp.issubdtype(part.dtype, jnp.floating):
            return False
        return not bool(jnp.isfinite(part).all())

    # -------------------------------------------------------- submissions
    def _on_submission_done(self, fut: RuntimeFuture) -> None:
        with self._cond:
            self._inflight -= 1
            self._completed += 1
            recal_due = (self._recal_every is not None
                         and self._completed % self._recal_every == 0)
            # one split GEMM is still ONE gemm: credit it to the engine
            # that executed the largest share (dispatcher-path parity)
            eng = None
            if fut.accounting:
                dom = max(fut.accounting,
                          key=lambda n: fut.accounting[n]["jobs"])
                w = self._workers.get(dom)
                eng = w.engine if w is not None else None
        if eng is not None:
            eng.telemetry.record_jobs(0, 0.0, 0, gemms=1)
        if recal_due:
            # auto-recalibration cadence: consume the measurement window
            # opened N submissions ago and persist what it taught us
            self._save_rates(self.recalibrate(self._recal_alpha))

    # -------------------------------------------------- rate persistence
    def _load_rates(self) -> None:
        """Re-apply persisted measured rates (the serving analog of the
        paper's offline calibration surviving a power cycle).  A missing
        or unreadable sidecar means a fresh start, never an error."""
        try:
            with open(self._rates_path) as f:
                data = json.load(f).get("macs_per_s", {})
        except (OSError, ValueError):
            return
        for w in self._workers.values():
            rate = data.get(w.engine.name)
            if rate and rate > 0 and CAP_SIM not in w.engine.capabilities:
                # alpha=1: the sidecar IS the measured rate, not a hint
                w.engine.recalibrate(float(rate), alpha=1.0)

    def _save_rates(self, updated: dict[str, float]) -> None:
        """Merge freshly learned rates into the JSON sidecar (atomically:
        a crash mid-write must not corrupt the previous calibration)."""
        if not self._rates_path or not updated:
            return
        data: dict = {}
        try:
            with open(self._rates_path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            pass
        rates = data.setdefault("macs_per_s", {})
        rates.update(updated)
        tmp = f"{self._rates_path}.tmp"
        try:
            with open(tmp, "w") as f:
                json.dump(data, f, indent=1, sort_keys=True)
            os.replace(tmp, self._rates_path)
        except OSError:
            pass               # persistence is best-effort, never fatal

    # ------------------------------------------------- durable snapshots
    def quiesce(self, timeout: float = 30.0) -> bool:
        """Wait until no submission is in flight (a quiescent boundary a
        crash-consistent snapshot can be taken at).  Admission is the
        CALLER's job to stop — this only waits out what was already
        submitted.  Returns False on timeout."""
        deadline = time.monotonic() + timeout
        with self._cond:
            while self._inflight > 0:
                left = deadline - time.monotonic()
                if left <= 0:
                    return False
                self._cond.wait(min(left, 0.05))
        return True

    def state_snapshot(self) -> dict:
        """Learned state worth surviving a process crash: per-engine
        calibrated rates (what the sidecar persists, read from the live
        cost models) and full health records.  JSON-safe."""
        with self._lock:
            rates = {}
            health = {}
            for name, w in self._workers.items():
                if CAP_SIM not in w.engine.capabilities:
                    try:
                        rates[name] = float(w.engine.cost.macs_per_s)
                    except NotImplementedError:
                        pass
                if w.health is not None:
                    health[name] = w.health.export_state()
        return {"macs_per_s": rates, "health": health}

    def restore_state(self, state: dict) -> None:
        """Re-apply :meth:`state_snapshot` onto the current pool.  Only
        engines present in both the snapshot and the pool are touched
        (the pool may have been reconfigured across the restart)."""
        rates = state.get("macs_per_s", {})
        health = state.get("health", {})
        with self._lock:
            workers = dict(self._workers)
        for name, w in workers.items():
            rate = rates.get(name)
            if rate and rate > 0 and CAP_SIM not in w.engine.capabilities:
                # alpha=1: the snapshot IS the measured rate, as _load_rates
                w.engine.recalibrate(float(rate), alpha=1.0)
            if w.health is not None and name in health:
                w.health.import_state(health[name])

    def _submit_jobs(self, jobset, units: list[tuple], merge,
                     affinity: Optional[str],
                     stealable: bool = True,
                     int8_ok: bool = True,
                     qos: Optional[QosTag] = None) -> RuntimeFuture:
        """units: list of (fn, n_jobs, job_macs, job_bytes)."""
        tag = qos or NEUTRAL_TAG
        sub = _Submission(jobset, len(units), merge,
                          on_done=self._on_submission_done)
        jobs = [_RuntimeJob(sub, i, fn, n_jobs, macs, nbytes, stealable,
                            int8_ok, tag.priority, tag.deadline_at)
                for i, (fn, n_jobs, macs, nbytes) in enumerate(units)]
        with self._cond:
            if not self._started:
                raise RuntimeError(f"runtime {self.name!r} is not started")
            self._submissions += 1
            self._inflight += 1
            self._seed_locked(jobs, affinity)
            self._cond.notify_all()
        return sub.future

    @staticmethod
    def _accounting_units(jobset, granularity: str) -> list[tuple]:
        """The (fn=None, n_jobs, macs, bytes) scheduling units of one
        accounting-only JobSet at ``"job"`` or ``"row"`` granularity."""
        j = next(jobset.jobs()) if jobset.num_jobs else None
        if j is None:
            return []
        if granularity == "job":
            return [(None, 1, j.macs, j.bytes_moved)] * jobset.num_jobs
        gm, gn = jobset.grid        # "row": one unit per grid row of tiles
        return [(None, gn, j.macs, j.bytes_moved)] * gm

    def submit(self, jobset, *, affinity: Optional[str] = None,
               granularity: str = "job",
               qos: Optional[QosTag] = None) -> RuntimeFuture:
        """Accounting-only submission: the JobSet's tile jobs are scheduled
        (and stolen) across the pool, booking cost-model busy time per
        engine, with no array compute.  This is how serving prefill/decode
        proxies flow through the runtime."""
        return self.submit_many([jobset], affinity=affinity,
                                granularity=granularity, qos=qos)[0]

    def submit_many(self, jobsets, *, affinity: Optional[str] = None,
                    granularity: str = "job",
                    qos: Optional[QosTag] = None) -> list[RuntimeFuture]:
        """Batched accounting submission — the server-scale amortization
        path (ISSUE 5 §4): every JobSet of one admission wave goes through
        ONE manager-lock acquisition, one LPT seeding pass over ALL the
        batch's jobs, and one worker wakeup, instead of a lock + seed +
        notify per request.  Each jobset still completes as its own
        submission (own future, own accounting, own recalibration-cadence
        tick), so callers reap per-request accounting exactly as with N
        separate :meth:`submit` calls — only the dispatch overhead is
        shared.  Empty jobsets return already-finished futures in place."""
        tag = qos or NEUTRAL_TAG
        futs: list[RuntimeFuture] = []
        jobs: list[_RuntimeJob] = []
        n_live = 0
        for jobset in jobsets:
            units = self._accounting_units(jobset, granularity)
            if not units:
                fut = RuntimeFuture(jobset)
                fut._finish(None, None)
                futs.append(fut)
                continue
            sub = _Submission(jobset, len(units), None,
                              on_done=self._on_submission_done)
            jobs.extend(_RuntimeJob(sub, i, fn, n_jobs, macs, nbytes,
                                    priority=tag.priority,
                                    deadline_at=tag.deadline_at)
                        for i, (fn, n_jobs, macs, nbytes)
                        in enumerate(units))
            futs.append(sub.future)
            n_live += 1
        if n_live:
            with self._cond:
                if not self._started:
                    raise RuntimeError(
                        f"runtime {self.name!r} is not started")
                self._submissions += n_live
                self._inflight += n_live
                self._seed_locked(jobs, affinity)
                self._cond.notify_all()
        return futs

    def submit_graph(self, nodes, edges, *, affinity: Optional[str] = None,
                     granularity: str = "job", name: str = "graph",
                     qos: Optional[QosTag] = None, node_retries: int = 0):
        """Submit a dependency GRAPH of nodes: each node is a
        :class:`~repro.core.job.JobSet` (accounting-only) or a
        :class:`repro.soc.graph.GraphNode` (host compute / nested
        ``submit_gemm``); ``edges`` is an iterable of ``(pred, succ)``
        index pairs.  A node's work enters the pool the moment its last
        predecessor's tail panel lands: the completion callback decrements
        the successor's dependency counter under the manager lock and
        LPT-seeds the newly ready units into the existing worker deques,
        so stealing, hotplug rebalances and ``submit_timeout`` apply to
        graph work unchanged.  Returns a
        :class:`repro.soc.graph.GraphFuture` (per-node values, merged
        accounting, ``cancel()``).  ``node_retries=N`` relaunches a
        failed node (whole, as a fresh submission) up to N times before
        its descendants are cancelled — the graph-level complement of
        the runtime's panel-level :class:`RetryPolicy`."""
        from .graph import _GraphRun
        run = _GraphRun(self, nodes, edges, affinity=affinity,
                        granularity=granularity, name=name, qos=qos,
                        node_retries=node_retries)
        run.start()
        return run.future

    def _host_submit(self, fn, *args) -> None:
        """Run ``fn(*args)`` on the runtime's host-side executor (graph
        CPU nodes).  Lazy: serving without graphs never spawns it."""
        import concurrent.futures
        with self._lock:
            if self._host_pool is None:
                self._host_pool = concurrent.futures.ThreadPoolExecutor(
                    max_workers=2,
                    thread_name_prefix=f"synergy-{self.name}-host")
            pool = self._host_pool
        pool.submit(fn, *args)

    @staticmethod
    def _drain_error(error: BaseException, job: _RuntimeJob) -> BaseException:
        """A PER-JOB copy of a drain error.  Completing multiple jobs with
        the SAME exception instance raises one object into every waiter
        thread — each ``raise`` rewrites ``__traceback__``, so concurrent
        waiters see each other's (cross-contaminated) tracebacks.  Each
        drained jobset gets its own instance, naming the jobset it
        drained."""
        name = job.sub.future.jobset.name
        try:
            return type(error)(f"{error} [drained jobset {name!r}]")
        except Exception:
            # error types with non-message constructors still get a
            # fresh per-job instance, just a plainer one
            return RuntimeError(f"{type(error).__name__}: {error} "
                                f"[drained jobset {name!r}]")

    def _drain_jobs_locked(self, predicate, error: BaseException) -> int:
        """Remove queued (unstarted) jobs matching ``predicate`` from every
        worker deque, completing each with a PER-JOB copy of ``error``
        (see :meth:`_drain_error`); in-flight jobs are untouched.  The
        cancellation half of ``GraphFuture.cancel``: a failed upstream
        node must not leave orphan panels running."""
        n = 0
        for w in self._workers.values():
            drained = [j for j in w.queue if predicate(j)]
            if not drained:
                continue
            kept = [j for j in w.queue if not predicate(j)]
            w.queue.clear()
            w.queue.extend(kept)
            for job in drained:
                job.sub.complete(job, w.engine.name, None,
                                 self._drain_error(error, job), 0.0, False)
            n += len(drained)
        return n

    def submit_gemm(self, a, b, *, jobset, bias=None, activation=None,
                    tile=(256, 256, 256), out_dtype=None, precision=None,
                    affinity: Optional[str] = None,
                    job_class: Optional[str] = None,
                    observe_acts: bool = True,
                    qos: Optional[QosTag] = None) -> RuntimeFuture:
        """Split one GEMM's tile jobs across the pool as row panels; the
        future's result is the merged ``act(A @ B + bias)``.

        Dequant-aware accumulation: every panel executes at fp32 output
        precision (a quantized engine's dequant epilogue lands in fp32)
        and the requested ``out_dtype`` is applied ONCE to the merged
        result, so mixed fp32/int8 partials never round twice.

        Precision is OPT-IN, matching the dispatcher's invariant: unless
        ``job_class`` admits int8 (decode), every panel carries
        ``int8_ok=False`` and can never be placed on a CAP_INT8 worker —
        at seed time, by a steal, by a hotplug rebalance, or on engine
        removal.

        An opted-in GEMM whose activation scale has been calibrated takes
        the **int32-partial path** instead: the activations quantize ONCE
        at submit time, every panel computes the raw int8×int8 int32
        accumulator (exact integer math — bitwise identical on every
        engine, so these panels steal freely even across precision
        classes), and the merge concatenates the partials and applies the
        shared ``dequant_finish`` exactly once.  The submission also
        feeds the calibrator, so the first decode split calibrates and
        the rest run quantized.

        Otherwise mixed-pool panels are pinned to the deterministic LPT
        seed (stealable=False) — stealing an fp32 panel across precision
        classes would make the merged numerics a function of thread
        timing — and panels landing on a quantized engine run its
        weight-only fallback (never the order-dependent online fast
        path).  Accounting-only ``submit`` traffic (serving proxies)
        keeps stealing across the whole pool.

        ``observe_acts=False`` skips the submit-time calibrator feed: a
        caller that controls its own calibration cadence (the serving
        engine observes ONCE per decode step at reap time, whether the
        step went out as one coalesced GEMM or as per-slot submissions)
        must not have every sub-submission fold an extra EMA update, or
        batched and per-slot decode would calibrate — and therefore
        quantize — differently."""
        import jax.numpy as jnp
        ts_m = jobset.ts_m
        m = a.shape[0]
        gm, gn = jobset.grid
        j = next(jobset.jobs())
        final_dtype = out_dtype or a.dtype
        int8_ok = _admits_int8(job_class)

        plan = (self._plan_int8_split(a, b, observe=observe_acts)
                if int8_ok else None)
        if plan is not None:
            qw, act_scale, a_q = plan
            tile_t = tile if isinstance(tile, tuple) else (tile,) * 3

            def make_qfn(r0: int, r1: int):
                def fn(eng: Engine):
                    fn8 = getattr(eng, "execute_int8", None)
                    if fn8 is not None:
                        return fn8(a_q[r0:r1], qw, tile=tile_t)
                    # any engine can compute the exact integer partial
                    # through the shared kernel (steals/hotplug-safe)
                    from repro.kernels.qmm import qmm_matmul
                    return qmm_matmul(a_q[r0:r1], qw.q, qw.scale,
                                      fuse_dequant=False, tile=tile_t)
                return fn

            units = [(make_qfn(t1 * ts_m, min((t1 + 1) * ts_m, m)),
                      gn, j.macs, j.bytes_moved) for t1 in range(gm)]

            def merge_q(parts: list):
                from repro.quant.quantize import dequant_finish
                acc = (parts[0] if len(parts) == 1
                       else jnp.concatenate(parts, 0))
                return dequant_finish(acc, qw, act_scale=act_scale,
                                      bias=bias, activation=activation,
                                      out_dtype=final_dtype)

            return self._submit_jobs(jobset, units, merge_q, affinity,
                                     stealable=True, int8_ok=True, qos=qos)

        def make_fn(r0: int, r1: int):
            def fn(eng: Engine):
                ex = getattr(eng, "execute_weight_only", eng.execute)
                return ex(a[r0:r1], b, bias=bias,
                          activation=activation, tile=tile,
                          out_dtype=jnp.float32,
                          precision=precision)
            return fn

        units = []
        for t1 in range(gm):
            r0, r1 = t1 * ts_m, min((t1 + 1) * ts_m, m)
            units.append((make_fn(r0, r1), gn, j.macs, j.bytes_moved))

        def merge(parts: list):
            y = parts[0] if len(parts) == 1 else jnp.concatenate(parts, 0)
            return y.astype(final_dtype)

        # the mixed check and the enqueue must be one atomic step: a
        # hotplug between them would enqueue stealable panels into a
        # now-mixed pool and break the determinism pin (the Condition's
        # underlying RLock makes the nested acquire in _submit_jobs safe)
        with self._cond:
            mixed = self._mixed_precision_pool()
            return self._submit_jobs(jobset, units, merge,
                                     None if mixed else affinity,
                                     stealable=not mixed, int8_ok=int8_ok,
                                     qos=qos)

    def _plan_int8_split(self, a, b, observe: bool = True):
        """Plan the shared quantization of an opted-in GEMM: observe the
        live activations into the pool's quantized engine (unless the
        caller feeds the calibrator itself — ``observe=False``), and —
        once a scale is published for this (k, n) shape — quantize
        activations and weights ONCE for the whole split.  Returns
        ``(qw, act_scale, a_q)`` or None (no quantized engine in the
        pool, shape still warming up, or trace-time Tracers)."""
        tracer = getattr(jax.core, "Tracer", ())
        if isinstance(a, tracer) or isinstance(b, tracer):
            return None
        with self._lock:
            engs = [w.engine for w in self._workers.values()]
        qengs = [e for e in engs
                 if CAP_INT8 in e.capabilities
                 and hasattr(e, "execute_int8")
                 and hasattr(e, "act_scale_for")]
        if not qengs:
            return None
        qeng = qengs[0]
        k, n = b.shape
        if observe:
            qeng.observe_activations(a, k, n)  # decode feeds the calibrator
        scale = qeng.act_scale_for(k, n)
        if scale is None:
            return None
        from repro.quant.act import quantize_activations
        return qeng.quantized(b), scale, quantize_activations(a, float(scale))

    def _mixed_precision_pool(self) -> bool:
        """True when the live pool mixes int8 and full-precision engines
        (numerics then depend on which engine runs which panel)."""
        with self._lock:
            classes = {CAP_INT8 in w.engine.capabilities
                       for w in self._workers.values()}
        return len(classes) > 1

    def run_matmul(self, jobset, a, b, *, bias=None, activation=None,
                   tile=(256, 256, 256), out_dtype=None, precision=None,
                   affinity: Optional[str] = None,
                   job_class: Optional[str] = None,
                   timeout: float = 300.0,
                   qos: Optional[QosTag] = None):
        """Blocking ``submit_gemm`` — what ``synergy_matmul`` calls under a
        :func:`runtime_scope`.  Returns (result, accounting)."""
        fut = self.submit_gemm(a, b, jobset=jobset, bias=bias,
                               activation=activation, tile=tile,
                               out_dtype=out_dtype, precision=precision,
                               affinity=affinity, job_class=job_class,
                               qos=qos)
        return fut.result(timeout), fut.accounting

    # ----------------------------------------------------- recalibration
    def recalibrate(self, alpha: float = 0.5, *,
                    min_wall_s: float = 1e-4) -> dict[str, float]:
        """Steal-aware cost recalibration: fold each worker's MEASURED
        rate (MACs executed / wall seconds busy, real compute only) back
        into its engine's ``CostModel.macs_per_s`` via an EMA.

        LPT seeding, steal tail-guards and dispatcher ranking all read the
        cost model, so a mis-calibrated engine (cost says fast, hardware
        says slow) stops being over-seeded after a few windows — the
        planning analog of what the straggler rebalancer already does for
        SPMD shares.  Each call consumes the measurement window opened by
        the previous one.  CAP_SIM engines are never touched: their cost
        models are the PAPER's calibrated constants and their execute is a
        host-side oracle, so a measured host rate would corrupt every DES
        and planner result.  Returns ``{engine: macs_per_s now in
        effect}`` for the workers that had enough signal."""
        updated: dict[str, float] = {}
        with self._lock:
            windows = [(w, w.cal_macs, w.cal_wall_s)
                       for w in self._workers.values()]
            for w, _, _ in windows:
                w.cal_macs = 0
                w.cal_wall_s = 0.0
        for w, macs, wall_s in windows:
            if (wall_s < min_wall_s or macs <= 0
                    or CAP_SIM in w.engine.capabilities):
                continue
            updated[w.engine.name] = w.engine.recalibrate(macs / wall_s,
                                                          alpha)
        return updated

    # -------------------------------------------------------------- stats
    def stats(self) -> dict:
        with self._lock:
            per = {}
            for name, w in self._workers.items():
                denom = w.wall_busy_s + w.idle_s
                per[name] = {
                    "jobs": w.jobs, "steals": w.steals,
                    "est_busy_s": w.est_busy_s,
                    "wall_busy_s": w.wall_busy_s, "idle_s": w.idle_s,
                    "busy_fraction": w.wall_busy_s / denom if denom else 0.0,
                    "queued": len(w.queue),
                    "health": (w.health.health if w.health is not None
                               else None),
                    "quarantined": w.quarantined,
                    "faults": (w.health.faults if w.health is not None
                               else 0),
                }
            ests = [p["est_busy_s"] for p in per.values()]
            agg = (sum(ests) / (len(ests) * max(ests))
                   if ests and max(ests) > 0 else 0.0)
            retired = dict(self._retired_counters)
            return {
                "engines": per,
                "retired": retired,
                "submissions": self._submissions,
                "rebalances": self._rebalances,
                "quarantines": self._quarantines,
                "retries": self._retries,
                "worker_deaths": self._worker_deaths,
                "orphan_reseeds": self._orphan_reseeds,
                # totals include retired engines' work so a hot-unplug
                # never makes the counters go backwards
                "total_jobs": sum(p["jobs"] for p in per.values())
                + retired["jobs"],
                "total_steals": sum(p["steals"] for p in per.values())
                + retired["steals"],
                # Table-6 analog on the cost-model basis: total busy over
                # pool-size x makespan-proxy (busiest CURRENT engine's est)
                "aggregate_busy_fraction": agg,
            }

    def reset_stats(self) -> None:
        with self._lock:
            for w in self._workers.values():
                w.jobs = w.steals = 0
                w.est_busy_s = w.wall_busy_s = w.idle_s = 0.0
            self._submissions = 0
            self._rebalances = 0
            self._quarantines = 0
            self._retries = 0
            self._worker_deaths = 0
            self._orphan_reseeds = 0

    def scope(self):
        """``with rt.scope(): ...`` — route every ``synergy_matmul`` in the
        process through this runtime (see :func:`runtime_scope`)."""
        return runtime_scope(self)

    def __repr__(self) -> str:
        return (f"<SynergyRuntime {self.name!r} "
                f"engines={self.engine_names}>")


# ---------------------------------------------------------------------------
# Scope plumbing (how synergy_matmul finds the runtime)
# ---------------------------------------------------------------------------

_tls = threading.local()


def current_runtime() -> Optional[SynergyRuntime]:
    """The innermost runtime scope active in THIS thread (scopes are
    strictly thread-local, so a scope in one thread never hijacks GEMMs —
    or explicit ``engine=`` pins — in unrelated threads).  Components that
    fan work out to their own threads propagate the scope explicitly:
    ``ThreadedPipeline.run`` captures the caller's scope and re-enters it
    in every stage worker."""
    stack = getattr(_tls, "stack", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def runtime_scope(rt: SynergyRuntime):
    """Route every ``synergy_matmul`` in this thread under the block
    through ``rt``: JobSets are SPLIT across the pool and merged, instead
    of routed whole to one engine.  Starts the runtime if needed; does not
    shut it down on exit."""
    rt.start()
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    stack.append(rt)
    try:
        yield rt
    finally:
        stack.pop()


def is_concrete(*arrays) -> bool:
    """Runtime splitting needs concrete arrays (worker threads cannot share
    another thread's JAX trace); under jit we fall back to single-engine
    dispatch."""
    tracer = getattr(jax.core, "Tracer", ())
    return not any(isinstance(x, tracer) for x in arrays)
