"""repro.soc.faults — deterministic fault injection for engine pools.

Synergy's runtime (§3.1.3, §4.3) adapts to workload imbalance but the
paper assumes every accelerator invocation returns a correct result.  On
real embedded SoCs — thermal throttling, driver faults, transient compute
errors — that assumption fails routinely, and a runtime that cannot even
*provoke* those paths deterministically cannot claim to survive them.

This module is the provocation half: a seed-reproducible
:class:`FaultPlan` (which panel executions on which engine misbehave,
and how) applied through the :class:`FaultyEngine` wrapper.  The recovery
half lives in :class:`~repro.soc.runtime.SynergyRuntime`, configured with
a :class:`RetryPolicy` — a failed stealable panel is re-seeded onto a
surviving engine (exactly-once merge preserved), a dead worker's queued
and in-flight panels migrate to survivors, and repeated faults feed the
:class:`~repro.soc.qos.HealthPolicy` EMA so flaky engines get
quarantined through the existing self-healing machinery.

Fault vocabulary (``FaultSpec.kind``):

* ``"raise"`` — the panel raises :class:`InjectedFault` instead of
  computing (driver invocation failure).
* ``"corrupt"`` — the panel computes, then its float output is poisoned
  with NaN (silent data corruption; caught by the runtime's opt-in
  output-integrity guard, ``RetryPolicy.check_outputs``).  Integer
  outputs (int8 int32-exact partials) pass through unchanged — there is
  no "slightly wrong" int32 accumulator to model without breaking the
  bitwise contract the guard exists to protect.
* ``"slowdown"`` — the panel computes correctly but takes
  ``factor`` × longer (fixed), or ramps by ``ramp`` per affected call
  (progressive thermal throttling).  Feeds the health EMA naturally.
* ``"stall"`` — the panel hangs for ``duration_s`` before completing
  (a wedged accelerator queue; recoverable via
  ``RetryPolicy.stall_timeout_s`` duplicate re-execution).
* ``"die"`` — the worker thread executing the panel dies mid-panel
  (:class:`WorkerKilled` propagates out of ``execute``); the runtime's
  heartbeat monitor detects the death and re-seeds the orphans.
* ``"drop"`` — the panel computes but its completion is lost
  (:class:`DroppedCompletion`): the worker moves on as if nothing
  happened, leaving the panel in-flight forever.  Only the stall sweep
  recovers it.

Determinism: a plan is a pure function of its specs — per-engine call
counters select which executions fault, so the same plan against the
same submission order injects the same faults.  ``FaultPlan.random``
derives a plan from a seed via ``random.Random`` (never global state).

The keystone invariant (tested in ``tests/test_faults.py``): for any
retryable plan, merged GEMM outputs and serving token streams are
**bitwise identical** to the fault-free run — int8 int32-exact panels
make this provable on any engine, and fp32 panels re-execute whole,
never partially.
"""

from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Optional, Sequence

import jax.numpy as jnp

from repro.engines.base import Engine

__all__ = ["FAULT_KINDS", "FaultSpec", "FaultPlan", "FaultyEngine",
           "RetryPolicy", "InjectedFault", "CorruptOutput", "WorkerKilled",
           "DroppedCompletion", "PanelRetryExhausted", "wrap_pool"]

#: the closed fault vocabulary (see module docstring)
FAULT_KINDS = ("raise", "corrupt", "slowdown", "stall", "die", "drop")


# ---------------------------------------------------------------------------
# Error taxonomy
# ---------------------------------------------------------------------------

class InjectedFault(RuntimeError):
    """A panel execution failed by plan (the accelerator-invocation-error
    analog).  Retryable."""


class CorruptOutput(RuntimeError):
    """A panel's output failed the NaN/Inf integrity screen — raised both
    by the ``"corrupt"`` injection path (via the guard) and by the guard
    itself on genuinely corrupted engines.  Retryable."""


class WorkerKilled(BaseException):
    """Kills the worker thread mid-panel (``"die"``).  Deliberately NOT a
    ``RuntimeError``: nothing downstream may catch-and-continue it —
    the worker loop exits and the heartbeat monitor takes over."""


class DroppedCompletion(BaseException):
    """The panel computed but its completion signal was lost (``"drop"``).
    The worker survives and moves on; the submission never hears back.
    Only the runtime's stall sweep (duplicate re-execution) recovers it."""


class PanelRetryExhausted(RuntimeError):
    """A panel failed on every attempt the :class:`RetryPolicy` allowed.
    Carries the audit trail the flight recorder dumps."""

    def __init__(self, jobset_name: str, attempts: int,
                 engines: Sequence[str], last: BaseException):
        self.jobset_name = jobset_name
        self.attempts = attempts
        self.engines = list(engines)
        self.last = last
        super().__init__(
            f"panel of {jobset_name!r} failed {attempts} attempt(s) "
            f"on {self.engines}: {type(last).__name__}: {last}")


# ---------------------------------------------------------------------------
# Recovery policy (consumed by SynergyRuntime)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How :class:`~repro.soc.runtime.SynergyRuntime` survives panel
    faults.

    ``max_attempts``: total executions a panel may consume (first try
    included) before its submission fails with
    :class:`PanelRetryExhausted`.
    ``backoff_s``: delay before a retry is re-seeded (0 = immediate).
    ``avoid_failed_engine``: re-seed excludes engines the panel already
    failed on, unless no other eligible engine remains.
    ``check_outputs``: opt-in NaN/Inf screen on float panel partials —
    corruption becomes a retryable :class:`CorruptOutput` instead of a
    silently wrong merge.  Off by default: the screen costs one device
    reduction per panel.
    ``heartbeat_timeout_s``: a worker thread silent (dead) this long is
    declared failed and its queued + in-flight panels re-seed onto
    survivors.  The semantics are
    :class:`repro.runtime.fault_tolerance.HeartbeatMonitor`'s — the
    monitor thread ticks one "step" per ``monitor_interval_s`` and the
    timeout is expressed in those steps — one definition, not two.
    ``stall_timeout_s``: a panel in flight this long is presumed wedged
    or dropped and a DUPLICATE attempt is re-seeded; first completion
    wins (idempotent merge), so a slow-but-alive original stays safe.
    None disables the sweep.
    ``monitor_interval_s``: monitor thread tick period."""

    max_attempts: int = 3
    backoff_s: float = 0.0
    avoid_failed_engine: bool = True
    check_outputs: bool = False
    heartbeat_timeout_s: float = 0.5
    stall_timeout_s: Optional[float] = None
    monitor_interval_s: float = 0.05

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.monitor_interval_s <= 0:
            raise ValueError("monitor_interval_s must be > 0")

    @property
    def timeout_steps(self) -> int:
        """``heartbeat_timeout_s`` in monitor ticks — the value handed to
        :class:`~repro.runtime.fault_tolerance.HeartbeatMonitor`."""
        return max(1, int(self.heartbeat_timeout_s
                          / self.monitor_interval_s))


# ---------------------------------------------------------------------------
# Fault plans
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One planned misbehavior: executions ``at_call .. at_call+count-1``
    (0-based, per-engine counter of REAL panel executions) on ``engine``
    fault with ``kind``.

    ``factor``/``ramp`` parameterize ``"slowdown"`` (sleep the measured
    compute time × (factor − 1), ramping by ``ramp`` per faulted call);
    ``duration_s`` parameterizes ``"stall"``."""

    engine: str
    kind: str
    at_call: int = 0
    count: int = 1
    factor: float = 4.0
    ramp: float = 0.0
    duration_s: float = 1.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {FAULT_KINDS}")
        if self.count < 1:
            raise ValueError("count must be >= 1")
        if self.at_call < 0:
            raise ValueError("at_call must be >= 0")

    def hits(self, call: int) -> bool:
        return self.at_call <= call < self.at_call + self.count


class FaultPlan:
    """A deterministic schedule of :class:`FaultSpec` injections.

    The plan itself is immutable scheduling data; ``injected`` is the
    mutable audit log the wrappers append to (thread-safe), so a test can
    assert exactly which faults actually fired."""

    def __init__(self, specs: Sequence[FaultSpec], seed: Optional[int] = None):
        self.specs = tuple(specs)
        self.seed = seed
        self._lock = threading.Lock()
        #: (engine, kind, call) tuples, in injection order
        self.injected: list[tuple[str, str, int]] = []

    def for_engine(self, name: str) -> tuple[FaultSpec, ...]:
        return tuple(s for s in self.specs if s.engine == name)

    def record(self, engine: str, kind: str, call: int) -> None:
        with self._lock:
            self.injected.append((engine, kind, call))

    def __repr__(self) -> str:
        return f"<FaultPlan seed={self.seed} specs={list(self.specs)}>"

    @classmethod
    def random(cls, seed: int, engines: Sequence[str], *,
               n_faults: int = 3, max_call: int = 8,
               kinds: Sequence[str] = ("raise", "corrupt", "slowdown"),
               ) -> "FaultPlan":
        """A seed-reproducible plan over ``engines``: ``n_faults`` specs
        drawn from ``kinds`` via ``random.Random(seed)`` (never global
        state — the same (seed, engines) always yields the same plan).
        Defaults draw only RETRYABLE kinds, the chaos-sweep contract."""
        rng = random.Random(seed)
        specs = [FaultSpec(engine=rng.choice(list(engines)),
                           kind=rng.choice(list(kinds)),
                           at_call=rng.randrange(max_call),
                           factor=rng.uniform(2.0, 6.0),
                           duration_s=rng.uniform(0.2, 1.0))
                 for _ in range(n_faults)]
        return cls(specs, seed=seed)


# ---------------------------------------------------------------------------
# The wrapper engine
# ---------------------------------------------------------------------------

class FaultyEngine(Engine):
    """Wraps a real engine, applying a :class:`FaultPlan` to its panel
    executions.

    Delegation is attribute-faithful: ``execute_int8`` /
    ``execute_weight_only`` / ``observe_amax`` / calibration hooks only
    exist on the wrapper when the inner engine has them, so every
    ``hasattr``-based capability probe in the runtime and serving layers
    sees the wrapped engine exactly as it would the real one.

    The per-call counter counts REAL panel executions (any of the execute
    entry points) and is touched without a lock: a pool engine executes
    only on its own worker thread, and the counter is advisory for any
    other caller."""

    def __init__(self, inner: Engine, plan: FaultPlan, *,
                 tracer=None):
        super().__init__(inner.name, set(inner.capabilities),
                         cost=inner._cost)
        self.inner = inner
        self.plan = plan
        self._specs = plan.for_engine(inner.name)
        self._calls = 0
        self._tracer = tracer
        # share the inner engine's telemetry: runtime counters must not
        # split between wrapper and wrapped
        self.telemetry = inner.telemetry
        for name in ("execute_int8", "execute_weight_only"):
            if hasattr(inner, name):
                setattr(self, name, self._wrap(getattr(inner, name)))

    # ------------------------------------------------------------ plumbing
    def __getattr__(self, name):
        # only consulted for attributes NOT set on the wrapper — i.e.
        # inner-engine extras (observe_amax, quantized, act_scale_for, ...)
        if name == "inner":          # guard: __init__ not yet complete
            raise AttributeError(name)
        return getattr(self.inner, name)

    def available(self) -> bool:
        return self.inner.available()

    def estimate(self, jobset) -> float:
        return self.inner.estimate(jobset)

    def recalibrate(self, measured_rate: float, alpha: float = 0.5) -> float:
        out = self.inner.recalibrate(measured_rate, alpha)
        self._cost = self.inner._cost
        return out

    @property
    def cost(self):
        return self.inner.cost

    # ------------------------------------------------------------ faulting
    def _due(self, call: int) -> Optional[FaultSpec]:
        for s in self._specs:
            if s.hits(call):
                return s
        return None

    def _emit(self, spec: FaultSpec, call: int) -> None:
        self.plan.record(self.name, spec.kind, call)
        tr = self._tracer
        if tr is None:
            from repro.obs.trace import get_default_tracer
            tr = get_default_tracer()
        if tr is not None:
            # tag is "fault", not "kind" — emit()'s first positional IS kind
            tr.emit("fault_injected", self.name, fault=spec.kind,
                    call=call, at_call=spec.at_call)

    def _apply(self, fn, *args, **kwargs):
        call = self._calls
        self._calls += 1
        spec = self._due(call)
        if spec is None:
            return fn(*args, **kwargs)
        self._emit(spec, call)
        if spec.kind == "raise":
            raise InjectedFault(
                f"injected fault on {self.name!r} (call {call})")
        if spec.kind == "die":
            raise WorkerKilled(
                f"worker for {self.name!r} killed mid-panel (call {call})")
        if spec.kind == "stall":
            time.sleep(spec.duration_s)
            return fn(*args, **kwargs)
        if spec.kind == "slowdown":
            t0 = time.perf_counter()
            out = fn(*args, **kwargs)
            dt = time.perf_counter() - t0
            extra = spec.factor + spec.ramp * (call - spec.at_call) - 1.0
            if extra > 0:
                time.sleep(dt * extra)
            return out
        if spec.kind == "drop":
            fn(*args, **kwargs)          # the compute happens, then is lost
            raise DroppedCompletion(
                f"completion dropped on {self.name!r} (call {call})")
        # "corrupt": poison float outputs; integer partials pass through
        out = fn(*args, **kwargs)
        if hasattr(out, "dtype") and jnp.issubdtype(out.dtype,
                                                    jnp.floating):
            return jnp.full_like(out, jnp.nan)
        return out

    def _wrap(self, fn):
        def wrapped(*args, **kwargs):
            return self._apply(fn, *args, **kwargs)
        return wrapped

    def execute(self, a, b, *, bias=None, activation=None,
                tile=(256, 256, 256), out_dtype=None, precision=None):
        return self._apply(self.inner.execute, a, b, bias=bias,
                           activation=activation, tile=tile,
                           out_dtype=out_dtype, precision=precision)

    def __repr__(self) -> str:
        return f"<FaultyEngine {self.name!r} plan={self.plan!r}>"


def wrap_pool(engines: Sequence[Engine], plan: FaultPlan, *,
              tracer=None) -> list[Engine]:
    """Wrap every engine the plan names; pass the rest through untouched
    (an unwrapped engine has zero fault-layer overhead)."""
    targeted = {s.engine for s in plan.specs}
    return [FaultyEngine(e, plan, tracer=tracer)
            if e.name in targeted else e for e in engines]
