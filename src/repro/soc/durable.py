"""repro.soc.durable — process-level durability for the serving engine.

PR 9 made the *pool* survive a misbehaving accelerator; this module makes
the *process* survive.  An embedded deployment like Synergy's runs for
weeks — a crash must not lose admitted requests, the online-calibrated
int8 activation scales, the learned engine rates, or the QoS health
baselines, and a restart must not serve anything twice.  Three pieces:

* **Write-ahead request journal** (:class:`RequestJournal`): every
  admission-accepted request and every emitted token is appended —
  length-prefixed, CRC'd, fsync'd — BEFORE it becomes externally
  visible.  A record half-written by a dying process is a *torn tail*:
  detected by the CRC/length scan, truncated on reopen, and by
  construction it only ever covers state that was never externally
  visible, so dropping it is correct.
* **Crash-consistent snapshots**: the server persists its full state
  through the seed :class:`~repro.checkpoint.Checkpointer` (atomic
  ``step_N.tmp`` rename, async double-buffered) on a step cadence —
  K/V + SSM caches, slot positions, pending queues, the chunked-prefill
  cursor, calibrator EMA state, runtime sidecar rates, health baselines,
  FairShare virtual times, and the journal offset the snapshot covers.
* **Deterministic restore**: ``SynergyServer.restore`` loads the latest
  snapshot and *re-executes* the journal suffix — admissions are forced
  from the journaled waves (scheduling is wall-clock dependent; token
  values are not), recomputed emissions are verified bitwise against the
  journal (a mismatch flight-dumps and raises :class:`RestoreMismatch`),
  and replayed work books into ``ServeStats.replayed_tokens`` /
  ``replayed_jobs`` instead of re-inflating throughput counters.

:class:`CrashPlan` is the process-level complement of PR 9's
engine-level ``FaultPlan``: a deterministic crash point (engine step)
at which the server raises :class:`SimulatedCrash`, so the keystone
property — *token streams after restore are bitwise identical to the
uninterrupted run, every accepted request served exactly once* — is
testable over arbitrary crash points without actually killing pytest.

SIGTERM wiring: servers constructed with a :class:`Durability` register
themselves here; :func:`install_sigterm_handler` (called by
``benchmarks/run.py`` and the examples) turns SIGTERM into a graceful
``request_drain()`` — finish live generations, snapshot, release the
pool — instead of a dead pool and a torn journal.
"""

from __future__ import annotations

import dataclasses
import json
import os
import signal
import struct
import threading
import weakref
import zlib
from typing import Optional

import numpy as np

__all__ = ["Durability", "RequestJournal", "CrashPlan", "SimulatedCrash",
           "RestoreMismatch", "load_snapshot", "meta_to_array",
           "array_to_meta", "install_sigterm_handler",
           "install_sigterm_drain", "register_server",
           "request_drain_all"]

#: journal record header: payload length + CRC32 of the payload
_HDR = struct.Struct("<II")


class SimulatedCrash(BaseException):
    """The deterministic crash point of a :class:`CrashPlan` fired.

    Deliberately NOT a ``RuntimeError``: nothing in the serving loop may
    catch-and-continue it — the harness that installed the plan treats
    the server object as dead and restores a fresh one from disk, which
    is the whole point."""


class RestoreMismatch(RuntimeError):
    """Replay re-executed a journaled step and produced different bytes.

    The journal is the record of what was externally delivered; a
    recomputation that disagrees means the restored state is NOT the
    crashed process's state (corrupted snapshot, different params, a
    nondeterministic model).  Serving must not continue from it."""

    def __init__(self, expected, got):
        self.expected = expected
        self.got = got
        super().__init__(
            f"journal replay diverged: expected {expected!r}, "
            f"recomputed {got!r}")


@dataclasses.dataclass(frozen=True)
class Durability:
    """Durable-serving configuration (``SynergyServer(durable=...)``).

    ``directory`` holds ``journal.bin`` plus ``snapshots/step_N/``.
    ``snapshot_every=N`` snapshots at every N-th engine step (0 = only
    on ``close()``); ``fsync=False`` trades crash safety of the last few
    records for journal append latency; ``async_snapshots`` writes
    snapshots on the Checkpointer's background thread, double-buffered
    against serving."""

    directory: str
    snapshot_every: int = 0
    fsync: bool = True
    keep: int = 3
    async_snapshots: bool = True

    @property
    def journal_path(self) -> str:
        return os.path.join(self.directory, "journal.bin")

    @property
    def snapshot_dir(self) -> str:
        return os.path.join(self.directory, "snapshots")


@dataclasses.dataclass(frozen=True)
class CrashPlan:
    """Deterministic process-crash point: raise :class:`SimulatedCrash`
    at the START of engine step ``at_step`` (0-based — before the step
    does any work or journals anything, the same boundary a SIGKILL
    between steps lands on).  The engine-level analog is
    :class:`~repro.soc.faults.FaultPlan`."""

    at_step: int

    def due(self, engine_steps: int) -> bool:
        return engine_steps >= self.at_step


class RequestJournal:
    """Append-only write-ahead log of serving's externally visible events.

    Record framing: ``<u32 length><u32 crc32><payload>`` with a compact
    JSON payload.  Appends are flushed (and fsync'd unless disabled)
    before the caller makes the event visible, so the journal is always
    at least as new as the world.  Opening an existing journal scans it
    and TRUNCATES a torn tail (``truncated_bytes`` reports how much) —
    a half-written record must never corrupt records appended after
    restart."""

    def __init__(self, path, *, fsync: bool = True):
        self.path = os.fspath(path)
        self.fsync = fsync
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        _, end, torn = self.scan(self.path)
        self.truncated_bytes = 0
        if torn:
            self.truncated_bytes = os.path.getsize(self.path) - end
            with open(self.path, "rb+") as f:
                f.truncate(end)
        self._f = open(self.path, "ab")
        self._lock = threading.Lock()

    def append(self, rec: dict) -> int:
        """Durably append one record; returns the offset AFTER it (the
        value a snapshot stores as the journal position it covers)."""
        payload = json.dumps(rec, separators=(",", ":")).encode("utf-8")
        with self._lock:
            self._f.write(_HDR.pack(len(payload), zlib.crc32(payload)))
            self._f.write(payload)
            self._f.flush()
            if self.fsync:
                os.fsync(self._f.fileno())
            return self._f.tell()

    def offset(self) -> int:
        with self._lock:
            self._f.flush()
            return self._f.tell()

    def close(self) -> None:
        with self._lock:
            if self._f.closed:
                return
            self._f.flush()
            if self.fsync:
                os.fsync(self._f.fileno())
            self._f.close()

    @staticmethod
    def scan(path, start: int = 0) -> tuple[list, int, bool]:
        """Read records from byte ``start`` (a record boundary).

        Returns ``(records, end_offset, torn)`` — ``end_offset`` is the
        last valid record boundary; ``torn`` is True when trailing bytes
        past it fail the length/CRC check (crash mid-append)."""
        records: list[dict] = []
        path = os.fspath(path)
        if not os.path.exists(path):
            return records, start, False
        with open(path, "rb") as f:
            f.seek(0, os.SEEK_END)
            size = f.tell()
            f.seek(start)
            off = start
            while off + _HDR.size <= size:
                ln, crc = _HDR.unpack(f.read(_HDR.size))
                if off + _HDR.size + ln > size:
                    return records, off, True
                payload = f.read(ln)
                if zlib.crc32(payload) != crc:
                    return records, off, True
                try:
                    records.append(json.loads(payload.decode("utf-8")))
                except ValueError:
                    return records, off, True
                off += _HDR.size + ln
            return records, off, off < size


# ---------------------------------------------------------------------------
# Snapshot meta encoding — JSON as a uint8 leaf, so the WHOLE snapshot
# (arrays + metadata) travels through the seed Checkpointer unchanged
# ---------------------------------------------------------------------------

def meta_to_array(meta: dict) -> np.ndarray:
    """Encode a JSON-safe dict as a uint8 array — one more Checkpointer
    leaf, covered by the same atomic-rename publish as the cache arrays
    (no second metadata file with its own torn-write failure mode)."""
    return np.frombuffer(
        json.dumps(meta, separators=(",", ":")).encode("utf-8"),
        dtype=np.uint8).copy()


def array_to_meta(arr) -> dict:
    return json.loads(np.asarray(arr).tobytes().decode("utf-8"))


def load_snapshot(ck, step: Optional[int] = None) -> tuple[int, dict]:
    """Load one Checkpointer snapshot as ``(step, {key: array})``.

    Server snapshots are FLAT string-keyed dicts, so the restore ``like``
    tree is reconstructed from the manifest's keys alone — no caller
    needs to know the snapshot's dynamic shape (whether a chunked-prefill
    cursor was in flight, how many cache leaves the family has) before
    reading it."""
    step = step if step is not None else ck.latest_step()
    if step is None:
        raise FileNotFoundError(f"no snapshots in {ck.directory}")
    d = os.path.join(ck.directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        keys = list(json.load(f)["arrays"])
    return step, ck.restore({k: 0 for k in keys}, step=step)


# ---------------------------------------------------------------------------
# SIGTERM → graceful drain
# ---------------------------------------------------------------------------

#: live durable servers (weak: a collected server needs no deregistration)
_SERVERS: "weakref.WeakSet" = weakref.WeakSet()


def register_server(server) -> None:
    """Called by ``SynergyServer`` when constructed with a Durability."""
    _SERVERS.add(server)


def request_drain_all() -> int:
    """Flag every registered durable server to drain (async-signal-safe:
    sets flags only; the serving loops notice at their next step)."""
    n = 0
    for srv in list(_SERVERS):
        srv.request_drain()
        n += 1
    return n


def install_sigterm_handler(signum: int = signal.SIGTERM) -> bool:
    """Turn SIGTERM into a graceful drain of every durable server in the
    process (benchmarks/run.py installs this, so a long benchmark run
    dies with a clean snapshot instead of a dead pool).  Returns False
    when handlers cannot be installed (non-main thread)."""
    def _handler(sig, frame):
        request_drain_all()
    try:
        signal.signal(signum, _handler)
    except ValueError:
        return False
    return True


def install_sigterm_drain(server, signum: int = signal.SIGTERM) -> None:
    """Single-server variant for examples: SIGTERM flags ``server`` to
    drain at its next step; ``run()`` then closes it (drain → snapshot →
    release pool)."""
    def _handler(sig, frame):
        server.request_drain()
    signal.signal(signum, _handler)
