"""The ONE work-stealing policy (paper §4.3) shared by every executor.

The thief protocol has three actors in the paper — the *manager* notices an
idle cluster (the idle book), the *stealer* picks a victim queue and moves a
job.  The decision itself is two pure functions, and the discrete-event
simulator (:func:`repro.core.scheduler.simulate`), the live
:class:`repro.soc.SynergyRuntime` workers, and the virtual-time
:class:`repro.soc.SimRuntime` all import THESE so a steal decision made in
simulation is the decision made on live engines for identical cost models.

The QoS layer (:mod:`repro.soc.qos_policy`) composes with — never replaces
— these functions: deadline-aware seeding still places with
:func:`lpt_pick`, and priority-aware victim choice
(:func:`~repro.soc.qos_policy.qos_victim`) restricts the candidate set by
tail priority and then breaks ties with :func:`pick_victim` verbatim, so
an all-neutral workload takes exactly the decisions written here.
"""

from __future__ import annotations

from typing import Sequence

__all__ = ["STEAL_RATE_FLOOR", "STEAL_QUEUE_DEPTH", "should_steal",
           "pick_victim", "lpt_pick"]

#: a thief at >= this rate (relative to the fastest pool member) may steal
#: unconditionally; slower thieves only steal from deep queues.
STEAL_RATE_FLOOR = 0.9

#: queue depth above which even a slow thief helps: stealing one of many
#: queued jobs cannot make the slow engine the frame's straggler.
STEAL_QUEUE_DEPTH = 2


def should_steal(thief_rel_rate: float, victim_queue_len: int) -> bool:
    """Tail guard (§4.3): on the last jobs of a layer a 2x-slower engine
    would become the straggler that stalls the whole frame, so a slow
    thief only steals while the victim queue is deep."""
    if victim_queue_len <= 0:
        return False
    return (thief_rel_rate >= STEAL_RATE_FLOOR
            or victim_queue_len > STEAL_QUEUE_DEPTH)


def pick_victim(queue_lens: Sequence[int]) -> int:
    """Index of the busiest victim queue (ties -> lowest index, matching
    the simulator's ``max(range(n), key=len)`` from day one)."""
    return max(range(len(queue_lens)), key=lambda i: queue_lens[i])


def lpt_pick(eligible: Sequence[int], loads: Sequence[float],
             costs: Sequence[float]) -> int:
    """LPT-style seed (§3.1.1): among ``eligible`` queue indices, the one
    with the smallest projected finish time ``loads[i] + costs[i]`` (ties ->
    lowest index).  The live runtime seeds submissions with this, and graph
    nodes becoming ready mid-run re-enter the SAME decision, so a DAG
    successor is placed exactly as a fresh submission would be."""
    return min(eligible, key=lambda i: loads[i] + costs[i])
