"""SimRuntime — the live runtime's scheduling loop in virtual time.

Conformance mode for :class:`repro.soc.SynergyRuntime`: identical queues,
identical seeding, and the SAME :func:`repro.soc.policy.should_steal` /
:func:`~repro.soc.policy.pick_victim` the discrete-event simulator uses —
but service times come from the engine cost models instead of wall clock,
so steal decisions are deterministic and can be checked against
``repro.core.scheduler.simulate(policy="ws")`` for identical cost models.

Event semantics mirror the DES: jobs are seeded onto one queue (the static
mapping), every free engine is kicked in pool order, and on each completion
the finishing engine pops its own queue or steals from the busiest victim
under the tail guard.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Optional, Sequence, Union

from repro.engines.base import Engine
from repro.engines.registry import get_engine

from .policy import pick_victim, should_steal

__all__ = ["SimRuntime", "SimRuntimeResult", "SimGraphResult"]


@dataclasses.dataclass
class SimRuntimeResult:
    makespan_s: float
    per_engine_jobs: dict[str, int]
    per_engine_busy: dict[str, float]
    per_engine_steals: dict[str, int]

    @property
    def total_steals(self) -> int:
        return sum(self.per_engine_steals.values())

    @property
    def aggregate_busy_fraction(self) -> float:
        """Table-6 analog: total busy over pool-size x makespan."""
        if self.makespan_s <= 0:
            return 0.0
        n = len(self.per_engine_busy)
        return sum(self.per_engine_busy.values()) / (n * self.makespan_s)


@dataclasses.dataclass
class SimGraphResult(SimRuntimeResult):
    """One graph run in virtual time: per-node completion stamps on top of
    the usual per-engine accounting."""

    node_finish_s: tuple[float, ...] = ()


class SimRuntime:
    """Virtual-time work-stealing executor over engine cost models."""

    def __init__(self, engines: Sequence[Union[str, Engine]]):
        self.engines = [get_engine(e) if isinstance(e, str) else e
                        for e in engines]
        if not self.engines:
            raise ValueError("SimRuntime needs at least one engine")

    def run(self, jobset, *, affinity: Optional[str] = None,
            granularity: str = "job") -> SimRuntimeResult:
        """Execute one JobSet in virtual time.  ``affinity`` seeds every
        job on that engine's queue (the live runtime's queue-affinity hint;
        default: first engine, matching the DES static map of one layer to
        one cluster); stealing distributes from there."""
        j = next(jobset.jobs()) if jobset.num_jobs else None
        if j is None:
            zero = {e.name: 0 for e in self.engines}
            return SimRuntimeResult(0.0, dict(zero),
                                    {e.name: 0.0 for e in self.engines},
                                    dict(zero))
        if granularity == "job":
            units = [(1, j.macs, j.bytes_moved)] * jobset.num_jobs
        else:
            gm, gn = jobset.grid
            units = [(gn, j.macs, j.bytes_moved)] * gm

        names = [e.name for e in self.engines]
        queues: list[list] = [[] for _ in self.engines]
        home = names.index(affinity) if affinity in names else 0
        queues[home].extend(units)

        rates = [e.cost.macs_per_s for e in self.engines]
        fastest = max(rates)
        busy = [0.0] * len(self.engines)
        jobs_run = [0] * len(self.engines)
        steals = [0] * len(self.engines)
        free = [True] * len(self.engines)

        events: list = []
        seq = itertools.count()
        now = 0.0

        def unit_time(i: int, unit) -> float:
            n_jobs, macs, nbytes = unit
            return n_jobs * self.engines[i].cost.job_time(macs, nbytes)

        def try_dispatch(i: int) -> None:
            if not free[i]:
                return
            unit = None
            stolen = False
            if queues[i]:
                unit = queues[i].pop(0)
            else:
                lens = [len(q) for q in queues]
                if any(lens):
                    v = pick_victim(lens)
                    if v != i and should_steal(rates[i] / fastest, lens[v]):
                        unit = queues[v].pop()     # steal from the tail
                        stolen = True
            if unit is None:
                return
            dt = unit_time(i, unit)
            free[i] = False
            busy[i] += dt
            jobs_run[i] += unit[0]
            steals[i] += int(stolen)
            heapq.heappush(events, (now + dt, next(seq), i))

        def kick_all() -> None:
            for i in range(len(self.engines)):
                try_dispatch(i)

        kick_all()
        while events:
            now, _, i = heapq.heappop(events)
            free[i] = True
            try_dispatch(i)

        return SimRuntimeResult(
            makespan_s=now,
            per_engine_jobs=dict(zip(names, jobs_run)),
            per_engine_busy=dict(zip(names, busy)),
            per_engine_steals=dict(zip(names, steals)))

    def run_graph(self, jobsets, edges, *, affinity: Optional[str] = None,
                  granularity: str = "job") -> SimGraphResult:
        """Execute a DAG of accounting JobSets in virtual time — the
        conformance twin of :meth:`SynergyRuntime.submit_graph`.

        A node's units enter the home queue at the virtual instant its
        last predecessor's tail unit completes; every free engine is then
        kicked in pool order (exactly the state a fresh seed would see,
        since the finishing engine is free and all others drained
        earlier), so for a chain graph the trace is unit-for-unit
        identical to running the jobsets back-to-back through
        :meth:`run` — which is itself DES-conformant."""
        from .graph import validate_dag
        n = len(jobsets)
        succs, preds = validate_dag(n, edges)
        remaining = [len(p) for p in preds]

        def node_units(js) -> list:
            j = next(js.jobs()) if js.num_jobs else None
            if j is None:
                return []
            if granularity == "job":
                return [(1, j.macs, j.bytes_moved)] * js.num_jobs
            gm, gn = js.grid
            return [(gn, j.macs, j.bytes_moved)] * gm

        units = [node_units(js) for js in jobsets]
        pending = [len(u) for u in units]
        node_finish = [0.0] * n

        names = [e.name for e in self.engines]
        queues: list[list] = [[] for _ in self.engines]
        home = names.index(affinity) if affinity in names else 0

        rates = [e.cost.macs_per_s for e in self.engines]
        fastest = max(rates)
        busy = [0.0] * len(self.engines)
        jobs_run = [0] * len(self.engines)
        steals = [0] * len(self.engines)
        free = [True] * len(self.engines)

        events: list = []
        seq = itertools.count()
        now = 0.0

        def release(ready: list[int]) -> None:
            """Enqueue newly ready nodes at virtual time ``now``; empty
            nodes complete instantly and cascade."""
            while ready:
                nid = ready.pop(0)
                if pending[nid] == 0:        # no units: done on release
                    node_finish[nid] = now
                    for s in succs[nid]:
                        remaining[s] -= 1
                        if remaining[s] == 0:
                            ready.append(s)
                    continue
                queues[home].extend((nid,) + u for u in units[nid])

        def try_dispatch(i: int) -> None:
            if not free[i]:
                return
            unit = None
            stolen = False
            if queues[i]:
                unit = queues[i].pop(0)
            else:
                lens = [len(q) for q in queues]
                if any(lens):
                    v = pick_victim(lens)
                    if v != i and should_steal(rates[i] / fastest, lens[v]):
                        unit = queues[v].pop()     # steal from the tail
                        stolen = True
            if unit is None:
                return
            _, n_jobs, macs, nbytes = unit
            dt = n_jobs * self.engines[i].cost.job_time(macs, nbytes)
            free[i] = False
            busy[i] += dt
            jobs_run[i] += n_jobs
            steals[i] += int(stolen)
            heapq.heappush(events, (now + dt, next(seq), i, unit[0]))

        def kick_all() -> None:
            for i in range(len(self.engines)):
                try_dispatch(i)

        release([i for i in range(n) if remaining[i] == 0])
        kick_all()
        while events:
            now, _, i, nid = heapq.heappop(events)
            free[i] = True
            pending[nid] -= 1
            if pending[nid] == 0:
                node_finish[nid] = now
                ready = []
                for s in succs[nid]:
                    remaining[s] -= 1
                    if remaining[s] == 0:
                        ready.append(s)
                release(ready)
                kick_all()
            else:
                try_dispatch(i)

        return SimGraphResult(
            makespan_s=now,
            per_engine_jobs=dict(zip(names, jobs_run)),
            per_engine_busy=dict(zip(names, busy)),
            per_engine_steals=dict(zip(names, steals)),
            node_finish_s=tuple(node_finish))
