"""SimRuntime — the live runtime's scheduling loop in virtual time.

Conformance mode for :class:`repro.soc.SynergyRuntime`: identical queues,
identical seeding, and the SAME :func:`repro.soc.policy.should_steal` /
:func:`~repro.soc.policy.pick_victim` the discrete-event simulator uses —
but service times come from the engine cost models instead of wall clock,
so steal decisions are deterministic and can be checked against
``repro.core.scheduler.simulate(policy="ws")`` for identical cost models.

Event semantics mirror the DES: jobs are seeded onto one queue (the static
mapping), every free engine is kicked in pool order, and on each completion
the finishing engine pops its own queue or steals from the busiest victim
under the tail guard.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Optional, Sequence, Union

from repro.engines.base import Engine
from repro.engines.registry import get_engine

from .policy import lpt_pick, pick_victim, should_steal
from .qos_policy import (NEUTRAL_TAG, effective_deadline, qos_victim,
                         queue_insert_index)

__all__ = ["SimRuntime", "SimRuntimeResult", "SimGraphResult",
           "SimQosResult", "SimFaultResult"]


@dataclasses.dataclass
class SimRuntimeResult:
    makespan_s: float
    per_engine_jobs: dict[str, int]
    per_engine_busy: dict[str, float]
    per_engine_steals: dict[str, int]

    @property
    def total_steals(self) -> int:
        return sum(self.per_engine_steals.values())

    @property
    def aggregate_busy_fraction(self) -> float:
        """Table-6 analog: total busy over pool-size x makespan."""
        if self.makespan_s <= 0:
            return 0.0
        n = len(self.per_engine_busy)
        return sum(self.per_engine_busy.values()) / (n * self.makespan_s)


@dataclasses.dataclass
class SimGraphResult(SimRuntimeResult):
    """One graph run in virtual time: per-node completion stamps on top of
    the usual per-engine accounting."""

    node_finish_s: tuple[float, ...] = ()


@dataclasses.dataclass
class SimFaultResult(SimRuntimeResult):
    """One fault-schedule run in virtual time: the usual per-engine
    accounting plus the recovery audit — retries consumed, workers
    lost, orphans re-seeded, and every injected ``(engine, kind, call)``
    in virtual order.  ``completed_jobs`` counts jobs whose unit
    ultimately completed (the exactly-once conformance surface: it must
    equal the jobset's job count for any retryable plan)."""

    retries: int = 0
    worker_deaths: int = 0
    orphan_reseeds: int = 0
    exhausted: int = 0
    injected: tuple = ()
    completed_jobs: int = 0


@dataclasses.dataclass
class SimQosResult(SimRuntimeResult):
    """A QoS-tagged batch in virtual time: per-submission finish stamps,
    deadline verdicts, and the seed map (engine name per unit, in
    submission order) — the conformance surface against the live
    :meth:`SynergyRuntime._seed_locked`."""

    submission_finish_s: tuple[float, ...] = ()
    deadline_met: tuple[bool, ...] = ()
    seed_map: tuple[tuple[str, ...], ...] = ()


class SimRuntime:
    """Virtual-time work-stealing executor over engine cost models.

    ``tracer=Tracer(...)`` records the SAME event schema the live
    runtime emits (seed/enqueue/dequeue, panel spans, steals, graph node
    transitions) with VIRTUAL timestamps, so a sim trace diffs directly
    against a live trace of the same workload.  Unlike the live runtime
    the sim never falls back to the process-default tracer — a
    ``--trace``'d benchmark must not interleave virtual stamps into its
    wall-clock timeline."""

    def __init__(self, engines: Sequence[Union[str, Engine]], *,
                 tracer=None):
        self.engines = [get_engine(e) if isinstance(e, str) else e
                        for e in engines]
        if not self.engines:
            raise ValueError("SimRuntime needs at least one engine")
        self.tracer = tracer

    def run(self, jobset, *, affinity: Optional[str] = None,
            granularity: str = "job") -> SimRuntimeResult:
        """Execute one JobSet in virtual time.  ``affinity`` seeds every
        job on that engine's queue (the live runtime's queue-affinity hint;
        default: first engine, matching the DES static map of one layer to
        one cluster); stealing distributes from there."""
        j = next(jobset.jobs()) if jobset.num_jobs else None
        if j is None:
            zero = {e.name: 0 for e in self.engines}
            return SimRuntimeResult(0.0, dict(zero),
                                    {e.name: 0.0 for e in self.engines},
                                    dict(zero))
        if granularity == "job":
            units = [(1, j.macs, j.bytes_moved)] * jobset.num_jobs
        else:
            gm, gn = jobset.grid
            units = [(gn, j.macs, j.bytes_moved)] * gm

        names = [e.name for e in self.engines]
        queues: list[list] = [[] for _ in self.engines]
        home = names.index(affinity) if affinity in names else 0
        queues[home].extend(units)

        tr = self.tracer
        if tr is not None:
            tr.emit("seed", "manager", ts=0.0, runtime="sim",
                    n_jobs=len(units), affinity=affinity)
            for u in units:
                tr.emit("enqueue", names[home], ts=0.0,
                        jobset=jobset.name, n_jobs=u[0], priority=0)

        rates = [e.cost.macs_per_s for e in self.engines]
        fastest = max(rates)
        busy = [0.0] * len(self.engines)
        jobs_run = [0] * len(self.engines)
        steals = [0] * len(self.engines)
        free = [True] * len(self.engines)

        events: list = []
        seq = itertools.count()
        now = 0.0

        def unit_time(i: int, unit) -> float:
            n_jobs, macs, nbytes = unit
            return n_jobs * self.engines[i].cost.job_time(macs, nbytes)

        def try_dispatch(i: int) -> None:
            if not free[i]:
                return
            unit = None
            stolen = False
            victim = None
            if queues[i]:
                unit = queues[i].pop(0)
            else:
                lens = [len(q) for q in queues]
                if any(lens):
                    v = pick_victim(lens)
                    if v != i and should_steal(rates[i] / fastest, lens[v]):
                        unit = queues[v].pop()     # steal from the tail
                        stolen = True
                        victim = names[v]
            if unit is None:
                return
            dt = unit_time(i, unit)
            free[i] = False
            busy[i] += dt
            jobs_run[i] += unit[0]
            steals[i] += int(stolen)
            if tr is not None:
                if stolen:
                    tr.emit("steal", names[i], ts=now, victim=victim,
                            jobset=jobset.name, priority=0, probe=False)
                else:
                    tr.emit("dequeue", names[i], ts=now,
                            jobset=jobset.name, n_jobs=unit[0])
                tr.span("panel", names[i], now, dt, jobset=jobset.name,
                        n_jobs=unit[0], stolen=stolen, priority=0)
            heapq.heappush(events, (now + dt, next(seq), i))

        def kick_all() -> None:
            for i in range(len(self.engines)):
                try_dispatch(i)

        kick_all()
        while events:
            now, _, i = heapq.heappop(events)
            free[i] = True
            try_dispatch(i)

        return SimRuntimeResult(
            makespan_s=now,
            per_engine_jobs=dict(zip(names, jobs_run)),
            per_engine_busy=dict(zip(names, busy)),
            per_engine_steals=dict(zip(names, steals)))

    def run_faults(self, jobset, plan, retry, *,
                   affinity: Optional[str] = None,
                   granularity: str = "job") -> SimFaultResult:
        """Execute one JobSet under a :class:`~repro.soc.faults.FaultPlan`
        and :class:`~repro.soc.faults.RetryPolicy` in VIRTUAL time — the
        conformance twin of the live runtime's fault recovery.

        Modeled kinds: ``raise``/``corrupt`` (the unit fails — instantly
        for a raise, after its full service time for corruption, matching
        where the live integrity guard detects it — and re-seeds onto an
        eligible engine avoiding the ones it failed on), ``slowdown``
        (service time × the ramping factor), and ``die`` (the engine
        leaves the pool at the virtual fault instant; its in-flight unit
        and queue re-seed onto the survivors).  ``stall``/``drop`` are
        wall-clock phenomena (the live stall sweep races real threads)
        and raise ``ValueError`` here.

        Emits the SAME event kinds and tag keys the live runtime emits
        (``fault_injected``/``panel_retry``/``worker_death``/
        ``orphan_reseed``) with virtual stamps, so a sim trace schema-
        checks against a live trace of the same plan."""
        for s in plan.specs:
            if s.kind in ("stall", "drop"):
                raise ValueError(
                    f"run_faults cannot model wall-clock kind {s.kind!r}")
        j = next(jobset.jobs()) if jobset.num_jobs else None
        names = [e.name for e in self.engines]
        if j is None:
            zero = {n: 0 for n in names}
            return SimFaultResult(0.0, dict(zero),
                                  {n: 0.0 for n in names}, dict(zero))
        if granularity == "job":
            per = [(1, j.macs, j.bytes_moved)] * jobset.num_jobs
        else:
            gm, gn = jobset.grid
            per = [(gn, j.macs, j.bytes_moved)] * gm
        # mutable unit records: retry bookkeeping rides on the unit
        units = [{"n_jobs": n_jobs, "macs": macs, "nbytes": nbytes,
                  "attempts": 0, "failed": []}
                 for n_jobs, macs, nbytes in per]

        queues: list[list] = [[] for _ in self.engines]
        home = names.index(affinity) if affinity in names else 0
        queues[home].extend(units)

        tr = self.tracer
        if tr is not None:
            tr.emit("seed", "manager", ts=0.0, runtime="sim",
                    n_jobs=len(units), affinity=affinity)
            for u in units:
                tr.emit("enqueue", names[home], ts=0.0,
                        jobset=jobset.name, n_jobs=u["n_jobs"], priority=0)

        rates = [e.cost.macs_per_s for e in self.engines]
        busy = [0.0] * len(self.engines)
        jobs_run = [0] * len(self.engines)
        steals = [0] * len(self.engines)
        free = [True] * len(self.engines)
        alive = [True] * len(self.engines)
        calls = [0] * len(self.engines)
        specs = [plan.for_engine(n) for n in names]

        n_retries = deaths = reseeds = exhausted = 0
        injected: list[tuple[str, str, int]] = []
        completed_jobs = 0

        events: list = []
        seq = itertools.count()
        now = 0.0

        def unit_time(i: int, u: dict) -> float:
            return u["n_jobs"] * self.engines[i].cost.job_time(u["macs"],
                                                               u["nbytes"])

        def queue_load(i: int) -> float:
            return sum(unit_time(i, u) for u in queues[i])

        def reseed(us: list[dict], source: str) -> None:
            """LPT the orphaned/retried units back onto the live pool,
            honoring ``avoid_failed_engine`` where an alternative
            exists."""
            for u in us:
                elig = [i for i in range(len(names)) if alive[i]]
                if retry.avoid_failed_engine:
                    avoided = [i for i in elig
                               if names[i] not in u["failed"]]
                    if avoided:
                        elig = avoided
                loads = [queue_load(i) for i in range(len(names))]
                costs = [unit_time(i, u) for i in range(len(names))]
                ai = lpt_pick(elig, loads, costs)
                queues[ai].append(u)
                if tr is not None:
                    tr.emit("enqueue", names[ai], ts=now,
                            jobset=jobset.name, n_jobs=u["n_jobs"],
                            priority=0)

        def try_dispatch(i: int) -> None:
            nonlocal n_retries, deaths, reseeds
            if not free[i] or not alive[i]:
                return
            unit = None
            stolen = False
            victim = None
            if queues[i]:
                unit = queues[i].pop(0)
            else:
                lens = [len(q) for q in queues]
                if any(lens):
                    v = pick_victim(lens)
                    fastest = max(r for r, a in zip(rates, alive) if a)
                    if v != i and should_steal(rates[i] / fastest,
                                               lens[v]):
                        unit = queues[v].pop()     # steal from the tail
                        stolen = True
                        victim = names[v]
            if unit is None:
                return
            call = calls[i]
            calls[i] += 1
            spec = next((s for s in specs[i] if s.hits(call)), None)
            if spec is not None:
                injected.append((names[i], spec.kind, call))
                if tr is not None:
                    tr.emit("fault_injected", names[i], ts=now,
                            fault=spec.kind, call=call, at_call=spec.at_call)
            if spec is not None and spec.kind == "die":
                # the engine leaves the pool NOW: its in-flight unit and
                # queued units re-seed onto the survivors
                alive[i] = False
                free[i] = False
                unit["failed"].append(names[i])
                orphans = [unit] + queues[i]
                queues[i] = []
                deaths += 1
                reseeds += len(orphans)
                if tr is not None:
                    tr.emit("worker_death", names[i], ts=now,
                            runtime="sim", queued=len(orphans) - 1,
                            in_flight=1)
                    tr.emit("orphan_reseed", names[i], ts=now,
                            runtime="sim", n_jobs=len(orphans))
                reseed(orphans, names[i])
                for k in range(len(names)):
                    try_dispatch(k)
                return
            dt = unit_time(i, unit)
            err = None
            if spec is not None:
                if spec.kind == "raise":
                    err, dt = "InjectedFault", 0.0
                elif spec.kind == "corrupt":
                    # detected by the integrity guard AFTER the compute
                    err = "CorruptOutput"
                elif spec.kind == "slowdown":
                    dt *= spec.factor + spec.ramp * (call - spec.at_call)
            free[i] = False
            busy[i] += dt
            jobs_run[i] += unit["n_jobs"]
            steals[i] += int(stolen)
            if tr is not None:
                if stolen:
                    tr.emit("steal", names[i], ts=now, victim=victim,
                            jobset=jobset.name, priority=0, probe=False)
                else:
                    tr.emit("dequeue", names[i], ts=now,
                            jobset=jobset.name, n_jobs=unit["n_jobs"])
                tags = {"jobset": jobset.name, "n_jobs": unit["n_jobs"],
                        "stolen": stolen, "priority": 0}
                if err is not None:
                    tags["err"] = err
                tr.span("panel", names[i], now, dt, **tags)
            heapq.heappush(events, (now + dt, next(seq), i, unit, err))

        for i in range(len(self.engines)):
            try_dispatch(i)
        while events:
            now, _, i, unit, err = heapq.heappop(events)
            if alive[i]:
                free[i] = True
            if err is not None:
                unit["attempts"] += 1
                if names[i] not in unit["failed"]:
                    unit["failed"].append(names[i])
                if unit["attempts"] >= retry.max_attempts:
                    exhausted += 1       # submission fails; unit is done
                else:
                    n_retries += 1
                    if tr is not None:
                        tr.emit("panel_retry", names[i], ts=now,
                                jobset=jobset.name,
                                attempt=unit["attempts"], err=err)
                    reseed([unit], names[i])
            else:
                completed_jobs += unit["n_jobs"]
            for k in range(len(names)):
                try_dispatch(k)

        return SimFaultResult(
            makespan_s=now,
            per_engine_jobs=dict(zip(names, jobs_run)),
            per_engine_busy=dict(zip(names, busy)),
            per_engine_steals=dict(zip(names, steals)),
            retries=n_retries, worker_deaths=deaths,
            orphan_reseeds=reseeds, exhausted=exhausted,
            injected=tuple(injected), completed_jobs=completed_jobs)

    def run_qos(self, submissions, *, quarantined: Sequence[str] = (),
                granularity: str = "job") -> SimQosResult:
        """Execute a batch of QoS-tagged submissions in virtual time — the
        conformance twin of the live runtime's deadline seeding and
        quarantine exclusion.

        ``submissions``: sequence of ``(jobset, QosTag-or-None)`` pairs
        (one batched admission wave, like ``submit_many``).
        ``quarantined``: engine names currently quarantined — they take no
        seeds and no steals, and drop out of the best-rate/fastest
        denominators, exactly as in :meth:`SynergyRuntime._seed_locked`
        and ``_try_steal_locked`` (the sim models the quarantined steady
        state; probation probes are a wall-clock concern).

        The decisions are the SHARED pure functions —
        :func:`~repro.soc.policy.lpt_pick` over deadline-ordered units,
        :func:`~repro.soc.qos_policy.queue_insert_index` placement,
        :func:`~repro.soc.qos_policy.qos_victim` +
        :func:`~repro.soc.policy.should_steal` stealing — so an
        all-neutral batch reproduces :meth:`run` and the live runtime's
        trace decision-for-decision."""
        subs = [(js, tag or NEUTRAL_TAG) for js, tag in submissions]
        names = [e.name for e in self.engines]
        quar = [e.name in set(quarantined) for e in self.engines]
        if all(quar):
            raise ValueError("run_qos: every engine quarantined")
        rates = [e.cost.macs_per_s for e in self.engines]
        best_rate = max(r for r, q in zip(rates, quar) if not q)

        # one unit = (sub_id, unit_seq, priority, deadline_at, n_jobs,
        #             macs, nbytes); unit_seq keeps the seed order stable
        units: list[tuple] = []
        for sid, (js, tag) in enumerate(subs):
            j = next(js.jobs()) if js.num_jobs else None
            if j is None:
                continue
            if granularity == "job":
                per = [(1, j.macs, j.bytes_moved)] * js.num_jobs
            else:
                gm, gn = js.grid
                per = [(gn, j.macs, j.bytes_moved)] * gm
            base = len(units)
            units.extend((sid, base + u, tag.priority,
                          tag.deadline_at, *pu) for u, pu in enumerate(per))

        # deadline-aware seed order (the live _seed_order, verbatim logic)
        neutral = all(u[2] == 0 and u[3] == float("inf") for u in units)
        if not neutral:
            units = sorted(
                units, key=lambda u: (
                    -u[2],
                    effective_deadline(u[3], u[4] * u[5] / best_rate),
                    u[1]))

        # seed: LPT over non-quarantined engines, priority insertion
        queues: list[list] = [[] for _ in self.engines]
        loads = [0.0] * len(self.engines)
        seeded: dict[int, list[str]] = {sid: [] for sid in range(len(subs))}
        eligible = [i for i in range(len(self.engines)) if not quar[i]]
        tr = self.tracer
        if tr is not None:
            tr.emit("seed", "manager", ts=0.0, runtime="sim",
                    n_jobs=len(units), affinity=None)
        for u in units:
            sid, _, prio, _, n_jobs, macs, nbytes = u
            costs = [n_jobs * e.cost.job_time(macs, nbytes)
                     for e in self.engines]
            ai = lpt_pick(eligible, loads, costs)
            loads[ai] += costs[ai]
            q = queues[ai]
            if not q or prio <= q[-1][2]:
                q.append(u)
            else:
                q.insert(queue_insert_index([x[2] for x in q], prio), u)
            seeded[sid].append(names[ai])
            if tr is not None:
                tr.emit("enqueue", names[ai], ts=0.0,
                        jobset=subs[sid][0].name, n_jobs=n_jobs,
                        priority=prio)

        pending = [0] * len(subs)
        for u in units:
            pending[u[0]] += 1
        sub_finish = [0.0] * len(subs)

        fastest = max(r for r, q in zip(rates, quar) if not q)
        busy = [0.0] * len(self.engines)
        jobs_run = [0] * len(self.engines)
        steals = [0] * len(self.engines)
        free = [True] * len(self.engines)

        events: list = []
        seq = itertools.count()
        now = 0.0

        def try_dispatch(i: int) -> None:
            if not free[i]:
                return
            unit = None
            stolen = False
            victim = None
            if queues[i]:
                unit = queues[i].pop(0)
            elif not quar[i]:
                cand = [v for v in range(len(queues))
                        if v != i and queues[v]]
                if cand:
                    v = cand[qos_victim([queues[c][-1][2] for c in cand],
                                        [len(queues[c]) for c in cand])]
                    if should_steal(rates[i] / fastest, len(queues[v])):
                        unit = queues[v].pop()     # steal from the tail
                        stolen = True
                        victim = names[v]
            if unit is None:
                return
            sid, _, prio, _, n_jobs, macs, nbytes = unit
            dt = n_jobs * self.engines[i].cost.job_time(macs, nbytes)
            free[i] = False
            busy[i] += dt
            jobs_run[i] += n_jobs
            steals[i] += int(stolen)
            if tr is not None:
                jname = subs[sid][0].name
                if stolen:
                    tr.emit("steal", names[i], ts=now, victim=victim,
                            jobset=jname, priority=prio, probe=False)
                else:
                    tr.emit("dequeue", names[i], ts=now, jobset=jname,
                            n_jobs=n_jobs)
                tr.span("panel", names[i], now, dt, jobset=jname,
                        n_jobs=n_jobs, stolen=stolen, priority=prio)
            heapq.heappush(events, (now + dt, next(seq), i, sid))

        for i in range(len(self.engines)):
            try_dispatch(i)
        while events:
            now, _, i, sid = heapq.heappop(events)
            free[i] = True
            pending[sid] -= 1
            if pending[sid] == 0:
                sub_finish[sid] = now
            try_dispatch(i)

        return SimQosResult(
            makespan_s=now,
            per_engine_jobs=dict(zip(names, jobs_run)),
            per_engine_busy=dict(zip(names, busy)),
            per_engine_steals=dict(zip(names, steals)),
            submission_finish_s=tuple(sub_finish),
            deadline_met=tuple(f <= tag.deadline_at
                               for f, (_, tag) in zip(sub_finish, subs)),
            seed_map=tuple(tuple(seeded[sid])
                           for sid in range(len(subs))))

    def run_graph(self, jobsets, edges, *, affinity: Optional[str] = None,
                  granularity: str = "job") -> SimGraphResult:
        """Execute a DAG of accounting JobSets in virtual time — the
        conformance twin of :meth:`SynergyRuntime.submit_graph`.

        A node's units enter the home queue at the virtual instant its
        last predecessor's tail unit completes; every free engine is then
        kicked in pool order (exactly the state a fresh seed would see,
        since the finishing engine is free and all others drained
        earlier), so for a chain graph the trace is unit-for-unit
        identical to running the jobsets back-to-back through
        :meth:`run` — which is itself DES-conformant."""
        from .graph import validate_dag
        n = len(jobsets)
        succs, preds = validate_dag(n, edges)
        remaining = [len(p) for p in preds]

        def node_units(js) -> list:
            j = next(js.jobs()) if js.num_jobs else None
            if j is None:
                return []
            if granularity == "job":
                return [(1, j.macs, j.bytes_moved)] * js.num_jobs
            gm, gn = js.grid
            return [(gn, j.macs, j.bytes_moved)] * gm

        units = [node_units(js) for js in jobsets]
        pending = [len(u) for u in units]
        node_finish = [0.0] * n

        names = [e.name for e in self.engines]
        queues: list[list] = [[] for _ in self.engines]
        home = names.index(affinity) if affinity in names else 0

        rates = [e.cost.macs_per_s for e in self.engines]
        fastest = max(rates)
        busy = [0.0] * len(self.engines)
        jobs_run = [0] * len(self.engines)
        steals = [0] * len(self.engines)
        free = [True] * len(self.engines)

        events: list = []
        seq = itertools.count()
        now = 0.0

        tr = self.tracer

        def release(ready: list[int]) -> None:
            """Enqueue newly ready nodes at virtual time ``now``; empty
            nodes complete instantly and cascade."""
            while ready:
                nid = ready.pop(0)
                if tr is not None:
                    tr.emit("graph_node_ready", "graph", ts=now,
                            graph="sim-graph", node=nid,
                            node_name=jobsets[nid].name)
                if pending[nid] == 0:        # no units: done on release
                    node_finish[nid] = now
                    if tr is not None:
                        tr.emit("graph_node_done", "graph", ts=now,
                                graph="sim-graph", node=nid,
                                node_name=jobsets[nid].name, ok=True)
                    for s in succs[nid]:
                        remaining[s] -= 1
                        if remaining[s] == 0:
                            ready.append(s)
                    continue
                if tr is not None:
                    for u in units[nid]:
                        tr.emit("enqueue", names[home], ts=now,
                                jobset=jobsets[nid].name, n_jobs=u[0],
                                priority=0)
                queues[home].extend((nid,) + u for u in units[nid])

        def try_dispatch(i: int) -> None:
            if not free[i]:
                return
            unit = None
            stolen = False
            victim = None
            if queues[i]:
                unit = queues[i].pop(0)
            else:
                lens = [len(q) for q in queues]
                if any(lens):
                    v = pick_victim(lens)
                    if v != i and should_steal(rates[i] / fastest, lens[v]):
                        unit = queues[v].pop()     # steal from the tail
                        stolen = True
                        victim = names[v]
            if unit is None:
                return
            nid, n_jobs, macs, nbytes = unit
            dt = n_jobs * self.engines[i].cost.job_time(macs, nbytes)
            free[i] = False
            busy[i] += dt
            jobs_run[i] += n_jobs
            steals[i] += int(stolen)
            if tr is not None:
                jname = jobsets[nid].name
                if stolen:
                    tr.emit("steal", names[i], ts=now, victim=victim,
                            jobset=jname, priority=0, probe=False)
                else:
                    tr.emit("dequeue", names[i], ts=now, jobset=jname,
                            n_jobs=n_jobs)
                tr.span("panel", names[i], now, dt, jobset=jname,
                        n_jobs=n_jobs, stolen=stolen, priority=0)
            heapq.heappush(events, (now + dt, next(seq), i, nid))

        def kick_all() -> None:
            for i in range(len(self.engines)):
                try_dispatch(i)

        release([i for i in range(n) if remaining[i] == 0])
        kick_all()
        while events:
            now, _, i, nid = heapq.heappop(events)
            free[i] = True
            pending[nid] -= 1
            if pending[nid] == 0:
                node_finish[nid] = now
                if tr is not None:
                    tr.emit("graph_node_done", "graph", ts=now,
                            graph="sim-graph", node=nid,
                            node_name=jobsets[nid].name, ok=True)
                ready = []
                for s in succs[nid]:
                    remaining[s] -= 1
                    if remaining[s] == 0:
                        ready.append(s)
                release(ready)
                kick_all()
            else:
                try_dispatch(i)

        return SimGraphResult(
            makespan_s=now,
            per_engine_jobs=dict(zip(names, jobs_run)),
            per_engine_busy=dict(zip(names, busy)),
            per_engine_steals=dict(zip(names, steals)),
            node_finish_s=tuple(node_finish))
