"""SimRuntime — the live runtime's scheduling loop in virtual time.

Conformance mode for :class:`repro.soc.SynergyRuntime`: identical queues,
identical seeding, and the SAME :func:`repro.soc.policy.should_steal` /
:func:`~repro.soc.policy.pick_victim` the discrete-event simulator uses —
but service times come from the engine cost models instead of wall clock,
so steal decisions are deterministic and can be checked against
``repro.core.scheduler.simulate(policy="ws")`` for identical cost models.

Event semantics mirror the DES: jobs are seeded onto one queue (the static
mapping), every free engine is kicked in pool order, and on each completion
the finishing engine pops its own queue or steals from the busiest victim
under the tail guard.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Optional, Sequence, Union

from repro.engines.base import Engine
from repro.engines.registry import get_engine

from .policy import pick_victim, should_steal

__all__ = ["SimRuntime", "SimRuntimeResult"]


@dataclasses.dataclass
class SimRuntimeResult:
    makespan_s: float
    per_engine_jobs: dict[str, int]
    per_engine_busy: dict[str, float]
    per_engine_steals: dict[str, int]

    @property
    def total_steals(self) -> int:
        return sum(self.per_engine_steals.values())

    @property
    def aggregate_busy_fraction(self) -> float:
        """Table-6 analog: total busy over pool-size x makespan."""
        if self.makespan_s <= 0:
            return 0.0
        n = len(self.per_engine_busy)
        return sum(self.per_engine_busy.values()) / (n * self.makespan_s)


class SimRuntime:
    """Virtual-time work-stealing executor over engine cost models."""

    def __init__(self, engines: Sequence[Union[str, Engine]]):
        self.engines = [get_engine(e) if isinstance(e, str) else e
                        for e in engines]
        if not self.engines:
            raise ValueError("SimRuntime needs at least one engine")

    def run(self, jobset, *, affinity: Optional[str] = None,
            granularity: str = "job") -> SimRuntimeResult:
        """Execute one JobSet in virtual time.  ``affinity`` seeds every
        job on that engine's queue (the live runtime's queue-affinity hint;
        default: first engine, matching the DES static map of one layer to
        one cluster); stealing distributes from there."""
        j = next(jobset.jobs()) if jobset.num_jobs else None
        if j is None:
            zero = {e.name: 0 for e in self.engines}
            return SimRuntimeResult(0.0, dict(zero),
                                    {e.name: 0.0 for e in self.engines},
                                    dict(zero))
        if granularity == "job":
            units = [(1, j.macs, j.bytes_moved)] * jobset.num_jobs
        else:
            gm, gn = jobset.grid
            units = [(gn, j.macs, j.bytes_moved)] * gm

        names = [e.name for e in self.engines]
        queues: list[list] = [[] for _ in self.engines]
        home = names.index(affinity) if affinity in names else 0
        queues[home].extend(units)

        rates = [e.cost.macs_per_s for e in self.engines]
        fastest = max(rates)
        busy = [0.0] * len(self.engines)
        jobs_run = [0] * len(self.engines)
        steals = [0] * len(self.engines)
        free = [True] * len(self.engines)

        events: list = []
        seq = itertools.count()
        now = 0.0

        def unit_time(i: int, unit) -> float:
            n_jobs, macs, nbytes = unit
            return n_jobs * self.engines[i].cost.job_time(macs, nbytes)

        def try_dispatch(i: int) -> None:
            if not free[i]:
                return
            unit = None
            stolen = False
            if queues[i]:
                unit = queues[i].pop(0)
            else:
                lens = [len(q) for q in queues]
                if any(lens):
                    v = pick_victim(lens)
                    if v != i and should_steal(rates[i] / fastest, lens[v]):
                        unit = queues[v].pop()     # steal from the tail
                        stolen = True
            if unit is None:
                return
            dt = unit_time(i, unit)
            free[i] = False
            busy[i] += dt
            jobs_run[i] += unit[0]
            steals[i] += int(stolen)
            heapq.heappush(events, (now + dt, next(seq), i))

        def kick_all() -> None:
            for i in range(len(self.engines)):
                try_dispatch(i)

        kick_all()
        while events:
            now, _, i = heapq.heappop(events)
            free[i] = True
            try_dispatch(i)

        return SimRuntimeResult(
            makespan_s=now,
            per_engine_jobs=dict(zip(names, jobs_run)),
            per_engine_busy=dict(zip(names, busy)),
            per_engine_steals=dict(zip(names, steals)))
