"""Pure QoS scheduling policy — priorities, deadlines, fair shares.

Like :mod:`repro.soc.policy`, this module is decision functions ONLY: the
live :class:`~repro.soc.SynergyRuntime`, the virtual-time
:class:`~repro.soc.SimRuntime` twin, and the serving admission layer all
import THESE, so a QoS decision made in simulation is the decision made on
live engines (the conformance tests assert function identity).

Semantics
---------
* **Priority** is an integer; HIGHER runs first.  0 is the neutral class —
  jobs with no QoS tag behave exactly as before this module existed
  (FIFO seed order, tail-of-queue placement), so an untagged workload is
  bitwise-indistinguishable from the pre-QoS runtime.
* **Deadlines** are absolute instants on the scheduler's clock (wall
  ``time.monotonic()`` live, virtual seconds in the sim).  Within one
  priority class, seeding orders by *effective* deadline — the latest
  start that still meets the SLO, ``deadline - cost-model estimate`` —
  the deadline-aware LPT of the tentpole.
* **Queues stay sorted** non-increasing in priority: a new job enters
  ahead of strictly-lower-priority queued work and behind its peers
  (FIFO within class).  Workers pop their own HEAD and thieves steal the
  TAIL, so a queue's tail is always its least important panel — which is
  exactly what :func:`qos_victim` sends thieves after.  Preemption is
  therefore at panel granularity: no panel is ever killed mid-flight.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from .policy import pick_victim

__all__ = ["QosClass", "QosTag", "NEUTRAL_TAG", "DEFAULT_CLASS",
           "INTERACTIVE", "BULK", "BEST_EFFORT",
           "PREFILL_PRIORITY_OFFSET", "effective_deadline",
           "queue_insert_index", "qos_victim", "FairShare"]


@dataclasses.dataclass(frozen=True)
class QosClass:
    """One tenant-facing service class.

    ``priority``: integer rank (higher runs first; 0 = neutral).
    ``deadline_s``: relative SLO deadline a request of this class gets by
    default (None = no deadline).
    ``weight``: fair-share weight under admission contention.
    ``sheddable``: may be degraded to int8-only decode by the server's
    load-shedding ladder before anything is rejected.
    """

    name: str = "default"
    priority: int = 0
    deadline_s: Optional[float] = None
    weight: float = 1.0
    sheddable: bool = False


DEFAULT_CLASS = QosClass()
INTERACTIVE = QosClass("interactive", priority=10, deadline_s=1.0,
                       weight=4.0)
BULK = QosClass("bulk", priority=-10, weight=1.0, sheddable=True)
BEST_EFFORT = QosClass("best-effort", priority=-20, weight=0.5,
                       sheddable=True)

#: prefill work of a class queues one notch BELOW its decode: decode-class
#: panels preempt bulk prefill panels at chunk boundaries (PR 6's
#: ``prefill_chunk_macs`` graph chunks are the preemption quantum), while
#: a high-priority tenant's prefill still outranks a bulk tenant's decode.
PREFILL_PRIORITY_OFFSET = -1


@dataclasses.dataclass(frozen=True)
class QosTag:
    """The scheduler-facing tag one submission carries: resolved priority
    plus an ABSOLUTE deadline on the scheduler's clock (``math.inf`` =
    none).  Built by the serving layer from a :class:`QosClass` and the
    request's admission stamp; ``None`` anywhere a tag is accepted means
    :data:`NEUTRAL_TAG`."""

    priority: int = 0
    deadline_at: float = math.inf

    @classmethod
    def for_decode(cls, qos: QosClass, deadline_at: float = math.inf
                   ) -> "QosTag":
        return cls(qos.priority, deadline_at)

    @classmethod
    def for_prefill(cls, qos: QosClass, deadline_at: float = math.inf
                    ) -> "QosTag":
        return cls(qos.priority + PREFILL_PRIORITY_OFFSET, deadline_at)


NEUTRAL_TAG = QosTag()


def effective_deadline(deadline_at: float, est_s: float) -> float:
    """The latest start instant that still meets ``deadline_at`` given a
    cost-model service estimate — the EDF key of the deadline-aware LPT
    seed (earliest effective deadline first WITHIN a priority class)."""
    return deadline_at - est_s


def queue_insert_index(queue_priorities: Sequence[int],
                       priority: int) -> int:
    """Where a job of ``priority`` enters a priority-sorted deque: ahead
    of the first strictly-lower-priority queued job, behind its peers
    (FIFO within class).  With an all-neutral queue this is ``len(q)`` —
    plain append, the pre-QoS behavior."""
    for i, p in enumerate(queue_priorities):
        if p < priority:
            return i
    return len(queue_priorities)


def qos_victim(tail_priorities: Sequence[int],
               queue_lens: Sequence[int]) -> int:
    """Victim choice among viable queues: thieves prefer victims holding
    the LOWEST-priority tail panel (move bulk work out of the way; a
    victim's high-priority head stays put for the victim itself to run
    next), breaking ties by the busiest queue exactly as
    :func:`repro.soc.policy.pick_victim` always has.  All-neutral tails
    reduce to ``pick_victim`` verbatim."""
    lo = min(tail_priorities)
    idxs = [i for i, p in enumerate(tail_priorities) if p == lo]
    return idxs[pick_victim([queue_lens[i] for i in idxs])]


class FairShare:
    """Stride-scheduling virtual time: weighted fair admission across
    tenants under overload.  Each admitted request advances its tenant's
    virtual time by ``1/weight``; the next pick is the highest-priority
    tenant with the smallest virtual time (deadline as the final
    tie-break).  A tenant that was idle rejoins at the current minimum,
    so it cannot hoard credit and starve the others."""

    def __init__(self) -> None:
        self._vt: dict[str, float] = {}

    def pick(self, candidates: Sequence[tuple]) -> str:
        """``candidates``: ``(name, priority, head_deadline_at, weight)``
        per tenant with pending work.  Returns the tenant to admit from
        (does NOT charge — call :meth:`charge` once the pop commits)."""
        self.join(name for name, _, _, _ in candidates)
        return min(candidates,
                   key=lambda c: (-c[1], self._vt[c[0]], c[2], c[0]))[0]

    def join(self, names) -> None:
        """Enter unseen tenants at the current floor (the no-hoarding
        rule).  Factored out of :meth:`pick` so journal replay — which
        forces recorded admissions instead of re-picking — applies the
        SAME entry rule and restored virtual times match exactly."""
        floor = min(self._vt.values()) if self._vt else 0.0
        for name in names:
            if name not in self._vt:
                self._vt[name] = floor

    def charge(self, name: str, weight: float) -> None:
        self._vt[name] = (self._vt.get(name, 0.0)
                          + 1.0 / max(weight, 1e-9))

    def snapshot(self) -> dict[str, float]:
        return dict(self._vt)

    def restore(self, vt: dict) -> None:
        """Adopt a :meth:`snapshot` — a restored server resumes fair
        admission with the exact virtual times the crashed one had."""
        self._vt = {str(k): float(v) for k, v in vt.items()}
