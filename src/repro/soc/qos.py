"""repro.soc.qos — multi-tenant QoS: tenants, admission, engine health.

Three concerns layered over :class:`~repro.soc.SynergyRuntime` and
:class:`~repro.core.serving.SynergyServer`:

* **Service classes** (:class:`~repro.soc.qos_policy.QosClass`, re-exported
  here) attach priorities and SLO deadlines to submissions; the pure
  decision functions live in :mod:`repro.soc.qos_policy` so the live
  runtime and the virtual-time sim share them verbatim.
* **Tenancy** (:class:`Tenant`, :class:`AdmissionRejected`): per-tenant
  bounded queues with weighted fair admission and a load-shedding ladder —
  degrade sheddable traffic to int8-only decode (the existing job-class
  routing) before anything is rejected; rejections carry a cost-model
  retry-after.
* **Self-healing pools** (:class:`HealthPolicy`, :class:`EngineHealth`):
  the :class:`repro.runtime.straggler.StragglerRebalancer` EMA wired into
  the live runtime.  Each worker's measured MAC rate feeds an EMA; a rate
  that decays below ``quarantine_below`` x its healthy baseline gets the
  engine quarantined — its deque rebalanced onto the survivors (the PR 2
  hotplug machinery) and its cost model decayed to the measured rate —
  then probed on a cadence and re-admitted once ``readmit_above`` x the
  baseline holds again.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from .qos_policy import (BEST_EFFORT, BULK, DEFAULT_CLASS, INTERACTIVE,
                         NEUTRAL_TAG, QosClass, QosTag)

__all__ = ["QosClass", "QosTag", "NEUTRAL_TAG", "DEFAULT_CLASS",
           "INTERACTIVE", "BULK", "BEST_EFFORT",
           "Tenant", "AdmissionRejected",
           "HealthPolicy", "EngineHealth"]


# ---------------------------------------------------------------------------
# Tenancy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Tenant:
    """One tenant of a :class:`~repro.core.serving.SynergyServer`.

    ``qos``: the service class every request of this tenant inherits
    (a request's own ``deadline_s`` overrides the class default).
    ``max_pending``: bound of this tenant's pending queue (None = the
    server-wide ``max_pending``)."""

    name: str
    qos: QosClass = DEFAULT_CLASS
    max_pending: Optional[int] = None


class AdmissionRejected(RuntimeError):
    """A request was refused admission (tenant queue at its bound, after
    the shedding ladder already degraded what it could).  ``retry_after_s``
    is the cost-model estimate of when capacity frees up — the serving
    analog of HTTP 429 + Retry-After."""

    def __init__(self, tenant: str, retry_after_s: float,
                 reason: str = "pending queue full"):
        self.tenant = tenant
        self.retry_after_s = float(retry_after_s)
        self.reason = reason
        super().__init__(
            f"tenant {tenant!r}: {reason} "
            f"(retry after ~{self.retry_after_s:.3f}s)")


# ---------------------------------------------------------------------------
# Engine health — the straggler EMA, live
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HealthPolicy:
    """Quarantine/readmission thresholds for self-healing pools.

    ``alpha``: EMA weight of the newest per-panel measured rate (the same
    smoothing :class:`repro.runtime.straggler.StragglerRebalancer` applies
    to step times).
    ``quarantine_below``: quarantine when the EMA rate drops below this
    fraction of the engine's own healthy baseline (its peak EMA — relative
    to ITSELF, so paced, sim and real engines are judged alike).
    ``readmit_above``: probation exit — re-admit once the probed EMA is
    back above this fraction of the baseline.
    ``min_samples``: observations before any quarantine decision (a cold
    engine's first panels must not condemn it).
    ``probe_interval_s``: how often a quarantined worker may steal ONE
    panel to re-measure itself.
    ``min_probe_samples``: recovered probes required before readmission.
    """

    alpha: float = 0.5
    quarantine_below: float = 0.5
    readmit_above: float = 0.8
    min_samples: int = 3
    probe_interval_s: float = 0.25
    min_probe_samples: int = 2


class EngineHealth:
    """Mutable per-worker health record (guarded by the runtime's manager
    lock).  ``baseline`` is the peak healthy EMA; ``health`` is the
    current EMA relative to it (1.0 = nominal)."""

    __slots__ = ("ema_rate", "baseline", "samples", "quarantined",
                 "quarantined_at", "last_probe_s", "probe_samples",
                 "quarantines", "faults")

    def __init__(self) -> None:
        self.ema_rate = 0.0
        self.baseline = 0.0
        self.samples = 0
        self.quarantined = False
        self.quarantined_at: Optional[float] = None
        self.last_probe_s = 0.0
        self.probe_samples = 0
        self.quarantines = 0
        self.faults = 0

    @property
    def health(self) -> float:
        return (self.ema_rate / self.baseline if self.baseline > 0
                else 1.0)

    def snapshot(self) -> dict:
        """JSON-safe view for flight-recorder dumps and metrics export."""
        return {"ema_rate": self.ema_rate, "baseline": self.baseline,
                "health": self.health, "samples": self.samples,
                "quarantined": self.quarantined,
                "quarantines": self.quarantines,
                "probe_samples": self.probe_samples,
                "faults": self.faults}

    def export_state(self) -> dict:
        """Full state for durable snapshots — unlike :meth:`snapshot`
        (a display view), this covers every slot so a restored worker
        resumes with its learned baseline and quarantine status intact."""
        return {s: getattr(self, s) for s in self.__slots__}

    def import_state(self, state: dict) -> None:
        for s in self.__slots__:
            if s in state:
                setattr(self, s, state[s])

    def observe(self, rate: float, policy: HealthPolicy) -> None:
        """Fold one measured per-panel MAC rate into the EMA."""
        self.ema_rate = (rate if self.samples == 0
                         else policy.alpha * rate
                         + (1.0 - policy.alpha) * self.ema_rate)
        self.samples += 1
        if self.quarantined:
            self.probe_samples += 1
        else:
            self.baseline = max(self.baseline, self.ema_rate)

    def record_fault(self, policy: HealthPolicy) -> None:
        """Fold one FAULT (raised panel, corrupted output) into the record:
        count it, and drive the EMA toward zero — a fault is a panel that
        produced no useful work, i.e. a measured rate of 0.  Repeated
        faults therefore push the engine through the SAME quarantine
        threshold a thermal collapse would (one machinery, not two)."""
        self.faults += 1
        self.observe(0.0, policy)

    def should_quarantine(self, policy: HealthPolicy) -> bool:
        if self.quarantined:
            return False
        if (self.baseline == 0 and self.samples >= policy.min_samples
                and self.faults >= policy.min_samples):
            # never produced a single healthy panel — only faults.  The
            # relative-to-baseline test can't condemn it (there IS no
            # baseline), but min_samples straight faults can.
            return True
        return (self.samples >= policy.min_samples
                and self.baseline > 0
                and self.ema_rate < policy.quarantine_below * self.baseline)

    def probe_due(self, now: float, policy: HealthPolicy) -> bool:
        return (self.quarantined
                and now - self.last_probe_s >= policy.probe_interval_s)

    def recovered(self, policy: HealthPolicy) -> bool:
        return (self.quarantined
                and self.probe_samples >= policy.min_probe_samples
                and self.baseline > 0
                and self.ema_rate >= policy.readmit_above * self.baseline)

    def enter_quarantine(self, now: float) -> None:
        self.quarantined = True
        self.quarantined_at = now
        self.last_probe_s = now
        self.probe_samples = 0
        self.quarantines += 1

    def exit_quarantine(self) -> None:
        self.quarantined = False
        self.quarantined_at = None
        self.probe_samples = 0
