"""repro.soc.graph — dataflow-graph submissions over the live runtime.

Synergy's throughput comes from keeping every engine busy at once, but a
chain of dependent GEMMs submitted one-at-a-time serializes at every reap:
the pool idles exactly where the paper's pipeline overlaps (NEURAghe's
producer/consumer overlap between convolution stages is the same
observation).  This module adds the missing structure: a *graph* of nodes
with explicit dependency edges, where a successor's panels enter the
worker deques the moment its predecessors' tail panels land.

Node kinds
----------
* **JobSet node** — an accounting-only submission (the serving proxies'
  currency).  Its tile jobs are scheduled, stolen and booked exactly as a
  :meth:`~repro.soc.runtime.SynergyRuntime.submit` would, but gated on the
  node's predecessors.
* **run node** (:class:`GraphNode` with ``run=``) — a host-side callable
  ``run(runtime, *pred_values)`` executed on the runtime's host executor
  (never an engine worker, so a CPU stage cannot stall an accelerator
  queue).  It may return a plain value (e.g. an im2col gather) or a
  :class:`~repro.soc.runtime.RuntimeFuture` (e.g. a nested
  ``submit_gemm``), which the graph *adopts*: the node completes when the
  submission's tail panel completes.

Scheduling mechanics (the tentpole invariant): per-node remaining-
dependency counters are decremented at (tail) panel completion **under
the manager lock**, and newly ready nodes are LPT-seeded into the
existing per-engine deques — so work stealing, hotplug rebalances and
``submit_timeout`` all apply to graph work unchanged, and the virtual-
time :class:`~repro.soc.simrt.SimRuntime` replays the same decisions via
``run_graph``.

Failure / cancellation: a failed node cancels every not-yet-started
descendant, and :meth:`GraphFuture.cancel` additionally DRAINS the
queued-but-unstarted panels of running graph submissions from the worker
deques (in-flight panels finish).  No orphan panels outlive a dead graph.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Optional, Sequence

from .qos_policy import NEUTRAL_TAG

__all__ = ["GraphNode", "GraphFuture", "GraphCancelled", "validate_dag"]


class GraphCancelled(RuntimeError):
    """The graph (or this node's upstream) was cancelled before it ran."""


@dataclasses.dataclass(frozen=True)
class GraphNode:
    """One dataflow-graph node: exactly one of ``jobset`` / ``run``.

    ``jobset``: an accounting-only JobSet scheduled at ``granularity``
    ("job" or "row", like :meth:`SynergyRuntime.submit`).
    ``run(runtime, *pred_values)``: host-side callable; a returned
    :class:`RuntimeFuture` is adopted as the node's completion."""

    name: str = ""
    jobset: Any = None
    run: Optional[Callable] = None
    granularity: Optional[str] = None

    def __post_init__(self):
        if (self.jobset is None) == (self.run is None):
            raise ValueError(
                f"GraphNode {self.name!r}: exactly one of jobset/run")


def validate_dag(n: int, edges) -> tuple[list[list[int]], list[list[int]]]:
    """Check ``edges`` over ``n`` nodes form a DAG; returns
    ``(successors, predecessors)`` adjacency (edge-order preserved, which
    fixes the argument order of a run node's ``*pred_values``)."""
    succs: list[list[int]] = [[] for _ in range(n)]
    preds: list[list[int]] = [[] for _ in range(n)]
    for e in edges:
        u, v = e
        if not (0 <= u < n and 0 <= v < n):
            raise ValueError(f"edge {e!r} out of range for {n} nodes")
        if u == v:
            raise ValueError(f"self-edge on node {u}")
        succs[u].append(v)
        preds[v].append(u)
    # Kahn: every node must be reachable through a topological order
    indeg = [len(p) for p in preds]
    ready = [i for i in range(n) if indeg[i] == 0]
    seen = 0
    while ready:
        u = ready.pop()
        seen += 1
        for v in succs[u]:
            indeg[v] -= 1
            if indeg[v] == 0:
                ready.append(v)
    if seen != n:
        raise ValueError("graph has a dependency cycle")
    return succs, preds


class GraphFuture:
    """Completion handle for one graph run.

    ``result()`` returns the list of per-node values (None for JobSet
    nodes); ``accounting`` merges every node submission's per-engine
    accounting; ``finish_order`` records node indices in completion order
    (every predecessor strictly before its successors — the reap-order
    audit trail); ``cancel()`` stops everything that has not started."""

    def __init__(self, run: "_GraphRun", name: str):
        self._run = run
        self.name = name
        self._event = threading.Event()
        self._value: Any = None
        self._error: Optional[BaseException] = None
        #: node indices in completion order
        self.finish_order: list[int] = []

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> list:
        if not self._event.wait(timeout):
            raise TimeoutError(f"graph {self.name!r} not done in {timeout}s")
        if self._error is not None:
            raise self._error
        return self._value

    def cancel(self, why: str = "graph cancelled") -> int:
        """Cancel every node that has not started and drain running graph
        submissions' queued panels from the worker deques (in-flight
        panels finish).  Returns the number of nodes cancelled."""
        return self._run.cancel(why)

    def node_future(self, i: int):
        """The RuntimeFuture backing node ``i`` (None until it launches,
        and always None for pure host nodes)."""
        with self._run.rt._lock:
            return self._run.node_futs[i]

    def node_states(self) -> list[str]:
        with self._run.rt._lock:
            return list(self._run.state)

    @property
    def accounting(self) -> dict:
        """Merged per-engine accounting over every node submission so far
        (same schema as ``RuntimeFuture.accounting``)."""
        with self._run.rt._lock:
            futs = [f for f in self._run.node_futs if f is not None]
        merged: dict[str, dict] = {}
        for f in futs:
            for name, a in f.accounting.items():
                m = merged.setdefault(name, {"jobs": 0, "est_s": 0.0,
                                             "bytes": 0, "steals": 0})
                for key in m:
                    m[key] += a.get(key, 0)
        return merged

    @property
    def retries(self) -> int:
        """Recovery work this graph consumed: node-level relaunches plus
        every node submission's panel-level retries."""
        with self._run.rt._lock:
            futs = [f for f in self._run.node_futs if f is not None]
            n = sum(self._run.node_attempts)
        return n + sum(getattr(f, "retries", 0) for f in futs)

    # internal -------------------------------------------------------------
    def _finish(self, value: Any, error: Optional[BaseException]) -> None:
        self._value, self._error = value, error
        self._event.set()


class _GraphRun:
    """Execution state of one graph over a SynergyRuntime.

    All mutation happens under the runtime's manager lock (``rt._cond``):
    node launches, dependency decrements, cancellation.  Completion hooks
    arrive from worker threads (tail-panel completion) and from host
    executor threads; both funnel through :meth:`_node_done`."""

    def __init__(self, rt, nodes, edges, *, affinity: Optional[str],
                 granularity: str, name: str, qos=None,
                 node_retries: int = 0):
        norm: list[GraphNode] = []
        for node in nodes:
            if isinstance(node, GraphNode):
                norm.append(node)
            else:                      # bare JobSet (the public API's core)
                norm.append(GraphNode(name=getattr(node, "name", ""),
                                      jobset=node))
        if not norm:
            raise ValueError("submit_graph needs at least one node")
        self.rt = rt
        self.nodes = norm
        self.succs, self.preds = validate_dag(len(norm), edges)
        self.remaining = [len(p) for p in self.preds]
        self.affinity = affinity
        self.granularity = granularity
        #: QosTag every node submission of this graph carries (None =
        #: neutral) — chunked prefill graphs inherit their wave's class,
        #: which is what lets decode preempt them at chunk boundaries
        self.qos = qos
        n = len(norm)
        self.values: list[Any] = [None] * n
        self.state = ["waiting"] * n   # running | done | failed | cancelled
        self.node_futs: list = [None] * n
        #: whole-node retry budget: a failed node relaunches (fresh
        #: submission) up to ``node_retries`` times BEFORE its descendants
        #: are cancelled — the graph-level second line of defense behind
        #: the runtime's panel-level RetryPolicy
        self.max_node_retries = node_retries
        self.node_attempts = [0] * n
        self.n_left = n
        self.error: Optional[BaseException] = None
        self.cancelled = False
        self.future = GraphFuture(self, name)

    def _emit(self, kind: str, i: int, **tags) -> None:
        """Trace one node transition on the shared ``graph`` track (the
        runtime's tracer; one attribute check when tracing is off)."""
        tr = self.rt._tracer
        if tr is not None:
            tr.emit(kind, "graph", graph=self.future.name, node=i,
                    node_name=self.nodes[i].name, **tags)

    # ------------------------------------------------------------- control
    def start(self) -> None:
        rt = self.rt
        with rt._cond:
            if not rt._started:
                raise RuntimeError(f"runtime {rt.name!r} is not started")
            rt._graphs.add(self)
            for i, r in enumerate(self.remaining):
                if r == 0:
                    self._launch_locked(i)

    def cancel(self, why: str = "graph cancelled") -> int:
        rt = self.rt
        with rt._cond:
            if self.future.done():
                return 0
            self.cancelled = True
            n = 0
            for i, st in enumerate(self.state):
                if st == "waiting":
                    self.state[i] = "cancelled"
                    self.n_left -= 1
                    n += 1
                    self._emit("graph_node_cancelled", i, why=why)
            # drain this graph's queued-but-unstarted panels; their
            # submissions then complete with the cancellation error, which
            # funnels back through _node_done for the affected nodes
            live = {id(f) for i, f in enumerate(self.node_futs)
                    if f is not None and self.state[i] == "running"}
            rt._drain_jobs_locked(lambda job: id(job.sub.future) in live,
                                  GraphCancelled(why))
            if self.n_left == 0:
                self._finish_locked()
            return n

    # ---------------------------------------------------------- launching
    def _launch_locked(self, i: int) -> None:
        if self.cancelled or self.rt._stopping:
            self.state[i] = "cancelled"
            self.n_left -= 1
            self._emit("graph_node_cancelled", i, why="graph cancelled")
            if self.n_left == 0:
                self._finish_locked()
            return
        self.state[i] = "running"
        self._emit("graph_node_ready", i)
        node = self.nodes[i]
        if node.jobset is not None:
            self._submit_jobset_locked(i, node)
        else:
            self.rt._host_submit(self._run_host, i)

    def _submit_jobset_locked(self, i: int, node: GraphNode) -> None:
        from .runtime import RuntimeFuture, _RuntimeJob, _Submission
        rt = self.rt
        units = rt._accounting_units(node.jobset,
                                     node.granularity or self.granularity)
        if not units:
            fut = RuntimeFuture(node.jobset)
            fut._finish(None, None)
            self.node_futs[i] = fut
            self._node_done_locked(i, None, None)
            return

        def on_done(fut, i=i):
            rt._on_submission_done(fut)
            self._node_done(i, fut._value, fut._error)

        sub = _Submission(node.jobset, len(units), None, on_done=on_done)
        tag = self.qos or NEUTRAL_TAG
        jobs = [_RuntimeJob(sub, u, fn, n_jobs, macs, nbytes,
                            priority=tag.priority,
                            deadline_at=tag.deadline_at)
                for u, (fn, n_jobs, macs, nbytes) in enumerate(units)]
        self.node_futs[i] = sub.future
        rt._submissions += 1
        rt._inflight += 1
        rt._seed_locked(jobs, self.affinity)
        rt._cond.notify_all()

    def _run_host(self, i: int) -> None:
        """Host-executor body of a run node."""
        from .runtime import RuntimeFuture
        node = self.nodes[i]
        with self.rt._cond:
            if self.cancelled or self.state[i] != "running":
                self._node_done_locked(
                    i, None, GraphCancelled(f"node {node.name!r} cancelled"))
                return
            pvals = [self.values[p] for p in self.preds[i]]
        try:
            out = node.run(self.rt, *pvals)
        except BaseException as e:
            self._node_done(i, None, e)
            return
        if isinstance(out, RuntimeFuture):
            with self.rt._cond:
                self.node_futs[i] = out
            out.add_done_callback(
                lambda f, i=i: self._node_done(i, f._value, f._error))
        else:
            self._node_done(i, out, None)

    # ---------------------------------------------------------- completion
    def _node_done(self, i: int, value: Any,
                   error: Optional[BaseException]) -> None:
        with self.rt._cond:
            self._node_done_locked(i, value, error)

    def _node_done_locked(self, i: int, value: Any,
                          error: Optional[BaseException]) -> None:
        if self.state[i] not in ("waiting", "running"):
            return
        if (error is not None and isinstance(error, Exception)
                and not isinstance(error, GraphCancelled)
                and not self.cancelled and not self.rt._stopping
                and self.node_attempts[i] < self.max_node_retries):
            # node retry BEFORE descendant-cancel: relaunch the whole node
            # as a fresh submission; descendants only die once the budget
            # is spent.  The node never entered finish_order / n_left, so
            # the graph's completion accounting is untouched.
            self.node_attempts[i] += 1
            self._emit("graph_node_retry", i,
                       attempt=self.node_attempts[i],
                       err=type(error).__name__)
            self.state[i] = "waiting"
            self.node_futs[i] = None
            self._launch_locked(i)
            return
        self.future.finish_order.append(i)
        self.n_left -= 1
        if error is not None:
            self.state[i] = "failed"
            self._emit("graph_node_done", i, ok=False,
                       err=type(error).__name__)
            if self.error is None:
                self.error = error
            self._cancel_descendants_locked(i)
        else:
            self.values[i] = value
            self.state[i] = "done"
            self._emit("graph_node_done", i, ok=True)
            if not self.cancelled:
                for s in self.succs[i]:
                    self.remaining[s] -= 1
                    if self.remaining[s] == 0 and self.state[s] == "waiting":
                        self._launch_locked(s)
        if self.n_left == 0:
            self._finish_locked()

    def _cancel_descendants_locked(self, i: int) -> None:
        """A failed node's descendants can never become ready — finish
        them as cancelled so the graph terminates (satellite invariant:
        downstream jobsets never start)."""
        stack = list(self.succs[i])
        while stack:
            s = stack.pop()
            if self.state[s] == "waiting":
                self.state[s] = "cancelled"
                self.n_left -= 1
                self._emit("graph_node_cancelled", s,
                           why=f"upstream node {i} failed")
                stack.extend(self.succs[s])

    def _finish_locked(self) -> None:
        self.rt._graphs.discard(self)
        if self.error is not None:
            self.future._finish(None, self.error)
        elif self.cancelled or "cancelled" in self.state:
            self.future._finish(None, GraphCancelled(
                f"graph {self.future.name!r} cancelled "
                f"({self.state.count('cancelled')} nodes never started)"))
        else:
            self.future._finish(list(self.values), None)
