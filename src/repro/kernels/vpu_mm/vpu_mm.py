"""VPU-only Pallas tiled matmul — the TPU analog of the paper's NEON cores.

The paper's heterogeneity is real silicon diversity: FPGA tile PEs next to
NEON SIMD units that multiply-accumulate over 128-bit vector lanes.  The
TPU has the same split on one die — the 128x128 MXU systolic array next to
the 8x128-lane VPU.  This kernel is ``tiled_mm`` with the MXU taken away:
the contraction runs as ``ts_k`` rank-1 broadcast updates

    acc += A[:, kk:kk+1] * B[kk:kk+1, :]

which lower to VPU element-wise FMAs (broadcast over lanes), never to a
``dot``.  It is deliberately the *slow, always-available* engine of the
pool — exactly the role NEON plays in the paper's clusters — and shares
the tiled_mm contract: fixed-size zero-padded tiles, fp32 accumulation in
VMEM scratch, fused bias+activation epilogue.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["vpu_mm_pallas"]


def _kernel(a_ref, b_ref, bias_ref, o_ref, acc_ref, *,
            k_steps: int, ts_k: int, activation: Callable | None,
            has_bias: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)           # (ts_m, ts_k)
    b = b_ref[...].astype(jnp.float32)           # (ts_k, ts_n)

    def body(kk, acc):
        a_col = jax.lax.dynamic_slice_in_dim(a, kk, 1, axis=1)  # (ts_m, 1)
        b_row = jax.lax.dynamic_slice_in_dim(b, kk, 1, axis=0)  # (1, ts_n)
        return acc + a_col * b_row               # VPU broadcast FMA

    acc_ref[...] = jax.lax.fori_loop(0, ts_k, body, acc_ref[...])

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        y = acc_ref[...]
        if has_bias:
            y = y + bias_ref[...].astype(jnp.float32)
        if activation is not None:
            y = activation(y)
        o_ref[...] = y.astype(o_ref.dtype)


def vpu_mm_pallas(a: jax.Array, b: jax.Array, *,
                  bias: jax.Array | None = None,
                  activation: Callable | None = None,
                  tile: tuple[int, int, int] = (128, 128, 128),
                  out_dtype=None,
                  interpret: bool = False) -> jax.Array:
    """C[m, n] = act(A[m, k] @ B[k, n] + bias), MXU-free.  Dims must be
    multiples of ``tile`` (ops.py pads borders, same as tiled_mm)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    ts_m, ts_n, ts_k = tile
    assert m % ts_m == 0 and n % ts_n == 0 and k % ts_k == 0, (
        f"padded dims required: {(m, n, k)} vs tile {tile}")
    gm, gn, gk = m // ts_m, n // ts_n, k // ts_k
    out_dtype = out_dtype or a.dtype

    has_bias = bias is not None
    bias2d = (bias.reshape(1, n) if has_bias
              else jnp.zeros((1, n), dtype=jnp.float32))

    kernel = functools.partial(_kernel, k_steps=gk, ts_k=ts_k,
                               activation=activation, has_bias=has_bias)
    flops = 2 * m * n * k
    bytes_accessed = (a.size * a.dtype.itemsize + b.size * b.dtype.itemsize
                      + m * n * jnp.dtype(out_dtype).itemsize)
    return pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((ts_m, ts_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((ts_k, ts_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, ts_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((ts_m, ts_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((ts_m, ts_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        cost_estimate=pl.CostEstimate(flops=flops,
                                      bytes_accessed=bytes_accessed,
                                      transcendentals=0),
        interpret=interpret,
    )(a, b, bias2d)
