"""Jit'd wrapper for the vpu_mm Pallas kernel: border zero-padding plus the
interpret-mode fallback off-TPU.  This is the execution backend of
:class:`repro.engines.NeonVpuEngine`; call sites dispatch through
``synergy_matmul`` / the engine registry rather than importing this
directly."""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .vpu_mm import vpu_mm_pallas

__all__ = ["vpu_matmul"]


def _pad_to(x: jax.Array, mult: tuple[int, ...]) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mult)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=("tile", "activation",
                                             "out_dtype", "interpret"))
def vpu_matmul(a: jax.Array, b: jax.Array, *,
               bias: jax.Array | None = None,
               activation: Callable | None = None,
               tile: tuple[int, int, int] | int = (128, 128, 128),
               out_dtype=None,
               interpret: bool = False) -> jax.Array:
    """act(A @ B + bias) for arbitrary (m, k) x (k, n) on the VPU only:
    pads to tile multiples (the fixed-size PE's zero-padded border jobs)
    and slices the valid region back out."""
    if isinstance(tile, int):
        tile = (tile, tile, tile)
    m, k = a.shape
    _, n = b.shape
    ts_m, ts_n, ts_k = tile
    a_p = _pad_to(a, (ts_m, ts_k))
    b_p = _pad_to(b, (ts_k, ts_n))
    bias_p = _pad_to(bias, (ts_n,)) if bias is not None else None
    y = vpu_mm_pallas(a_p, b_p, bias=bias_p, activation=activation,
                      tile=tile, out_dtype=out_dtype,
                      interpret=interpret or jax.default_backend() != "tpu")
    return y[:m, :n]
