"""vpu_mm — VPU-only (MXU-free) Pallas tiled matmul, the NEON analog."""

from .ops import vpu_matmul
from .ref import vpu_mm_ref
from .vpu_mm import vpu_mm_pallas

__all__ = ["vpu_matmul", "vpu_mm_ref", "vpu_mm_pallas"]
