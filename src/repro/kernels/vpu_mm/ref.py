"""Pure-jnp oracle for the vpu_mm kernel.

Mirrors the kernel's semantics — fp32 rank-1 accumulation over k — but
vectorized as a single fp32 contraction: summation order differs from the
kernel's sequential loop only within fp32 rounding, which is what the
conformance tests' tolerances cover (same contract as tiled_mm's oracle).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def vpu_mm_ref(a: jax.Array, b: jax.Array, *,
               bias: jax.Array | None = None,
               activation: Callable | None = None,
               out_dtype=None) -> jax.Array:
    y = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if activation is not None:
        y = activation(y)
    return y.astype(out_dtype or a.dtype)
