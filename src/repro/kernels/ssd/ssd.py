"""Pallas SSD kernel — Mamba2 state-space duality chunked scan.

The SSD algorithm (Dao & Gu, arXiv:2405.21060) splits the sequence into
chunks of length Q.  Within a chunk the recurrence is computed as a masked
quadratic form (a GEMM — i.e., Synergy tile jobs); across chunks a small
(P x N) state carries the recurrence.  This matches the TPU memory
hierarchy: chunk tiles live in VMEM, the state stays in a VMEM scratch
across the sequential chunk grid dimension.

Inputs are pre-scaled in ops.py so the kernel is pure tile math:
  xdt (B, H, L, P)  = x * dt          (dt-weighted inputs)
  dtA (B, H, L)     = dt * A[h]       (negative decay log-increments)
  Bm, Cm (B, L, N)  (single SSM group, broadcast over heads)

Outputs: y (B, H, L, P) and the final state (B, H, P, N) (for decode
hand-off).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["ssd_pallas"]


def _kernel(xdt_ref, dta_ref, b_ref, c_ref, y_ref, state_out_ref, s_ref, *,
            n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    xdt = xdt_ref[0, 0]            # (Q, P)
    dta = dta_ref[0, 0]            # (Q,)
    bm = b_ref[0]                  # (Q, N)
    cm = c_ref[0]                  # (Q, N)

    seg = jnp.cumsum(dta)          # (Q,) inclusive log-decay within chunk
    total = seg[-1]

    # intra-chunk: y_i += sum_{j<=i} exp(seg_i - seg_j) * (C_i . B_j) xdt_j
    q = seg.shape[0]
    li = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    # mask inside the exp (upper triangle overflows otherwise)
    decay = jnp.exp(jnp.where(li >= lj, seg[:, None] - seg[None, :], -1e30))
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    y = jnp.dot((cb * decay).astype(xdt.dtype), xdt,
                preferred_element_type=jnp.float32)               # (Q, P)

    # inter-chunk: y_i += exp(seg_i) * C_i @ S_prev^T
    s_prev = s_ref[...]                                           # (P, N)
    y += jnp.exp(seg)[:, None] * jax.lax.dot_general(
        cm, s_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                       # (Q, P)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: S = exp(total) S_prev + sum_j exp(total - seg_j) xdt_j^T B_j
    w = jnp.exp(total - seg)[:, None] * xdt                       # (Q, P)
    s_new = jnp.exp(total) * s_prev + jax.lax.dot_general(
        w, bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                       # (P, N)
    s_ref[...] = s_new

    @pl.when(ci == n_chunks - 1)
    def _final():
        state_out_ref[0, 0] = s_new.astype(state_out_ref.dtype)


def ssd_pallas(xdt: jax.Array, dta: jax.Array, bm: jax.Array, cm: jax.Array,
               *, chunk: int = 128,
               interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    b, h, l, p = xdt.shape
    _, _, n = bm.shape
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    kernel = functools.partial(_kernel, n_chunks=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bb, hh, c: (bb, hh, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bb, hh, c: (bb, hh, c)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, c: (bb, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, c: (bb, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bb, hh, c: (bb, hh, c, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bb, hh, c: (bb, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, l, p), xdt.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xdt, dta, bm, cm)
    return y, state
