"""Pure-jnp oracle for the SSD scan: the direct O(L) recurrence.

    S_t = exp(dt_t * A_h) * S_{t-1} + xdt_t (x) B_t
    y_t = S_t @ C_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(xdt: jax.Array, dta: jax.Array, bm: jax.Array, cm: jax.Array,
            state0: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """xdt (B,H,L,P), dta (B,H,L), bm/cm (B,L,N) -> y (B,H,L,P), S (B,H,P,N)."""
    b, h, l, p = xdt.shape
    n = bm.shape[-1]
    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if state0 is None
          else state0.astype(jnp.float32))

    def step(s, t):
        a_t = jnp.exp(dta[:, :, t])[..., None, None]          # (B,H,1,1)
        outer = (xdt[:, :, t, :, None].astype(jnp.float32)
                 * bm[:, None, t, None, :].astype(jnp.float32))  # (B,H,P,N)
        s = a_t * s + outer
        y_t = jnp.einsum("bhpn,bn->bhp", s, cm[:, t].astype(jnp.float32))
        return s, y_t

    s_fin, ys = jax.lax.scan(step, s0, jnp.arange(l))
    y = jnp.moveaxis(ys, 0, 2).astype(xdt.dtype)              # (B,H,L,P)
    return y, s_fin
