"""Jit'd SSD wrapper: pre-scaling, op-variant dispatch via the
``repro.engines`` registry, and the chunked XLA path (same math as the
kernel, expressed with lax.scan over chunks — this is what the 512-device
dry-run lowers so the HLO stays canonical)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.engines import register_op_impl, resolve_op

from .ssd import ssd_pallas
from .ref import ssd_ref

__all__ = ["ssd", "ssd_chunked_xla"]


def _prescale(x, dt, a):
    """x (B,L,H,P), dt (B,L,H) [post-softplus], a (H,) [negative] ->
    kernel layout xdt (B,H,L,P), dta (B,H,L).

    §Perf C1: xdt stays in x's dtype — the f32 dt would otherwise promote
    the whole SSD pipeline (and its out-projection all-reduce) to f32,
    doubling HBM and ICI traffic.  dta stays f32 (tiny; drives exps)."""
    xdt = jnp.swapaxes(x * dt[..., None].astype(x.dtype), 1, 2)
    dta = jnp.swapaxes(dt * a[None, None, :], 1, 2)
    return xdt, dta


def ssd_chunked_xla(xdt, dta, bm, cm, *, chunk: int = 128):
    """Chunked SSD in pure jnp (scan over chunks) — O(L Q) not O(L^2)."""
    b, h, l, p = xdt.shape
    n = bm.shape[-1]
    nc = l // chunk
    xdt_c = xdt.reshape(b, h, nc, chunk, p)
    dta_c = dta.reshape(b, h, nc, chunk)
    bm_c = bm.reshape(b, nc, chunk, n)
    cm_c = cm.reshape(b, nc, chunk, n)

    q = chunk
    li = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tril = li >= lj

    def step(s, inp):
        xdt_i, dta_i, bm_i, cm_i = inp          # (B,H,Q,P),(B,H,Q),(B,Q,N),(B,Q,N)
        cdt = xdt_i.dtype                       # compute dtype (bf16/f32)
        seg = jnp.cumsum(dta_i, axis=-1)        # (B,H,Q) f32
        total = seg[..., -1]
        # mask INSIDE the exp: the j>i half has positive exponents that
        # overflow to inf and poison the backward pass (0 * inf = NaN).
        diff = jnp.where(tril, seg[..., :, None] - seg[..., None, :], -1e30)
        # §Perf C1: the (Q,Q) decay/CB products and the chunk dots run in
        # the model's compute dtype with f32 accumulation — the f32 (Q,Q)
        # buffers were the dominant HBM traffic of the SSM prefill.
        decay = jnp.exp(diff).astype(cdt)       # (B,H,Q,Q)
        cb = jnp.einsum("bqn,bkn->bqk", cm_i, bm_i,
                        preferred_element_type=jnp.float32).astype(cdt)
        y = jnp.einsum("bhqk,bhkp->bhqp", cb[:, None] * decay, xdt_i,
                       preferred_element_type=jnp.float32)
        y += jnp.exp(seg)[..., None] * jnp.einsum(
            "bqn,bhpn->bhqp", cm_i.astype(jnp.float32), s)
        w = jnp.exp(total[..., None] - seg)[..., None].astype(cdt) * xdt_i
        s = (jnp.exp(total)[..., None, None] * s
             + jnp.einsum("bhqp,bqn->bhpn", w, bm_i,
                          preferred_element_type=jnp.float32))
        return s, y

    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    inputs = (jnp.moveaxis(xdt_c, 2, 0), jnp.moveaxis(dta_c, 2, 0),
              jnp.moveaxis(bm_c, 1, 0), jnp.moveaxis(cm_c, 1, 0))
    s_fin, ys = jax.lax.scan(step, s0, inputs)
    y = jnp.moveaxis(ys, 0, 2).reshape(b, h, l, p).astype(xdt.dtype)
    return y, s_fin


register_op_impl(
    "ssd", "pallas",
    lambda xdt, dta, bm, cm, *, chunk: ssd_pallas(
        xdt, dta, bm, cm, chunk=chunk,
        interpret=jax.default_backend() != "tpu"),
    priority=10, available=lambda: jax.default_backend() == "tpu")
register_op_impl(
    "ssd", "xla",
    lambda xdt, dta, bm, cm, *, chunk: ssd_chunked_xla(
        xdt, dta, bm, cm, chunk=chunk),
    priority=0)
register_op_impl(
    "ssd", "ref",
    lambda xdt, dta, bm, cm, *, chunk: ssd_ref(xdt, dta, bm, cm),
    priority=-10)


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd(x: jax.Array, dt: jax.Array, a: jax.Array, bm: jax.Array,
        cm: jax.Array, *, chunk: int = 128,
        impl: str = "auto") -> tuple[jax.Array, jax.Array]:
    """Mamba2 SSD.  x (B,L,H,P), dt (B,L,H) post-softplus, a (H,) negative,
    bm/cm (B,L,N).  Returns y (B,L,H,P) and final state (B,H,P,N).

    L is padded up to a chunk multiple with zeros — zero xdt/dta steps are
    identity for the recurrence (state unchanged), so padding is exact."""
    l_orig = x.shape[1]
    chunk = min(chunk, max(1, l_orig))
    pad = (-l_orig) % chunk
    if pad:
        padl = lambda t: jnp.pad(t, [(0, 0), (0, pad)]
                                 + [(0, 0)] * (t.ndim - 2))
        x, dt, bm, cm = padl(x), padl(dt), padl(bm), padl(cm)
    xdt, dta = _prescale(x, dt, a)
    y, s = resolve_op("ssd", impl)(xdt, dta, bm, cm, chunk=chunk)
    y = jnp.swapaxes(y, 1, 2)
    return (y[:, :l_orig] if pad else y), s
