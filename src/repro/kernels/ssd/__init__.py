from .ops import ssd, ssd_chunked_xla
from .ref import ssd_ref
from .ssd import ssd_pallas
