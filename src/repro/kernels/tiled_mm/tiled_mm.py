"""Pallas tiled-MM kernel — the TPU-native Synergy processing engine (PE).

Paper §3.2.1: a PE is a fixed-size tiled matrix-multiplication engine with
(1) local tile buffers in BRAM, (2) double buffering overlapping fetch with
compute, (3) loop pipelining / array partitioning in the inner loops, and
(4) zero-padding border handling, so ONE engine design serves every layer of
every network.

TPU mapping:
  * BRAM tile buffers     -> VMEM blocks via BlockSpec (index_map carves the
                             job's tiles out of HBM).
  * double buffering      -> the Pallas grid pipeline (automatic prologue
                             prefetch of block k+1 during compute of block k).
  * loop pipelining / MXU -> jnp.dot on (ts_m, ts_k)x(ts_k, ts_n) blocks
                             with fp32 accumulation in a VMEM scratch.
  * border zero-padding   -> operands padded to tile multiples in ops.py
                             (functionally identical to the paper's masked
                             loads/stores; XLA pads are free on HBM).
  * job == grid cell      -> grid (gm, gn, gk); (i, j) is the paper's
                             (t1, t2) tile index; the TPU core scheduler
                             plays the role of the cluster dispatcher.

Beyond the paper: a fused epilogue (bias + activation) saves one HBM round
trip per GEMM; the k dimension is marked "arbitrary" and m/n "parallel" so
Mosaic can parallelize output tiles across cores.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["tiled_mm_pallas"]


def _kernel(a_ref, b_ref, bias_ref, o_ref, acc_ref, *,
            k_steps: int, activation: Callable | None, has_bias: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        y = acc_ref[...]
        if has_bias:
            y = y + bias_ref[...].astype(jnp.float32)
        if activation is not None:
            y = activation(y)
        o_ref[...] = y.astype(o_ref.dtype)


def tiled_mm_pallas(a: jax.Array, b: jax.Array, *,
                    bias: jax.Array | None = None,
                    activation: Callable | None = None,
                    tile: tuple[int, int, int] = (256, 256, 256),
                    out_dtype=None,
                    interpret: bool = False) -> jax.Array:
    """C[m, n] = act(A[m, k] @ B[k, n] + bias).  Dims must be multiples of
    ``tile`` (ops.py pads borders — the paper's zero-padding)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    ts_m, ts_n, ts_k = tile
    assert m % ts_m == 0 and n % ts_n == 0 and k % ts_k == 0, (
        f"padded dims required: {(m, n, k)} vs tile {tile}")
    gm, gn, gk = m // ts_m, n // ts_n, k // ts_k
    out_dtype = out_dtype or a.dtype

    has_bias = bias is not None
    bias2d = (bias.reshape(1, n) if has_bias
              else jnp.zeros((1, n), dtype=jnp.float32))

    kernel = functools.partial(_kernel, k_steps=gk, activation=activation,
                               has_bias=has_bias)
    flops = 2 * m * n * k
    bytes_accessed = (a.size * a.dtype.itemsize + b.size * b.dtype.itemsize
                      + m * n * jnp.dtype(out_dtype).itemsize)
    return pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((ts_m, ts_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((ts_k, ts_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, ts_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((ts_m, ts_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((ts_m, ts_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        cost_estimate=pl.CostEstimate(flops=flops,
                                      bytes_accessed=bytes_accessed,
                                      transcendentals=0),
        interpret=interpret,
    )(a, b, bias2d)
