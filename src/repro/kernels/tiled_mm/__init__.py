from .ops import tiled_matmul
from .ref import tiled_mm_ref
from .tiled_mm import tiled_mm_pallas
