"""Jit'd flash-attention wrapper dispatched through the op-variant
registry (:mod:`repro.engines`): variants ``pallas`` (TPU target;
interpret off-TPU when named explicitly) and ``xla`` (jnp reference — the
dry-run path so HLO stays canonical).  ``auto`` resolves to the
highest-priority variant available on the current backend."""

from __future__ import annotations

import functools

import jax

from repro.engines import register_op_impl, resolve_op

from .flash_attention import flash_attention_pallas
from .ref import attention_ref

__all__ = ["flash_attention"]


def _xla_variant(q, k, v, *, causal, scale, blk_q, blk_k):
    return attention_ref(q, k, v, causal=causal, scale=scale)


def _pallas_variant(q, k, v, *, causal, scale, blk_q, blk_k):
    s, sk = q.shape[2], k.shape[2]
    return flash_attention_pallas(
        q, k, v, causal=causal, scale=scale,
        blk_q=min(blk_q, s), blk_k=min(blk_k, sk),
        interpret=jax.default_backend() != "tpu")


register_op_impl("flash_attention", "xla", _xla_variant, priority=0)
register_op_impl("flash_attention", "pallas", _pallas_variant, priority=10,
                 available=lambda: jax.default_backend() == "tpu")


@functools.partial(jax.jit, static_argnames=("causal", "scale", "blk_q",
                                             "blk_k", "impl"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    scale: float | None = None,
                    blk_q: int = 128,
                    blk_k: int = 128,
                    impl: str = "auto") -> jax.Array:
    """q (B, Hq, S, D); k/v (B, Hkv, Sk, D) -> (B, Hq, S, D).

    impl: a registered ``flash_attention`` variant name, or 'auto'.
    """
    fn = resolve_op("flash_attention", impl)
    return fn(q, k, v, causal=causal, scale=scale, blk_q=blk_q, blk_k=blk_k)
