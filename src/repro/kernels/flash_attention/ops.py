"""Jit'd flash-attention wrapper with engine dispatch + shape handling."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas
from .ref import attention_ref

__all__ = ["flash_attention"]


@functools.partial(jax.jit, static_argnames=("causal", "scale", "blk_q",
                                             "blk_k", "impl"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    scale: float | None = None,
                    blk_q: int = 128,
                    blk_k: int = 128,
                    impl: str = "auto") -> jax.Array:
    """q (B, Hq, S, D); k/v (B, Hkv, Sk, D) -> (B, Hq, S, D).

    impl: 'pallas' (TPU target; interpret on CPU), 'xla' (jnp reference —
    the dry-run path so HLO stays canonical), or 'auto'.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        return attention_ref(q, k, v, causal=causal, scale=scale)
    s, sk = q.shape[2], k.shape[2]
    bq = min(blk_q, s)
    bk = min(blk_k, sk)
    return flash_attention_pallas(
        q, k, v, causal=causal, scale=scale, blk_q=bq, blk_k=bk,
        interpret=jax.default_backend() != "tpu")
