"""Pallas flash attention (causal, GQA) — tile-job-structured attention.

The Synergy view: attention's score/value GEMMs are decomposed into VMEM
tile jobs exactly like the CONV GEMMs — grid cell (b, h, qi) owns one query
tile and streams key/value tiles through VMEM with online softmax, so the
whole network (MLP + attention) runs on fixed-size tile engines.

Layout: q (B, Hq, S, D), k/v (B, Hkv, S, D) with Hq % Hkv == 0 (GQA: the
kv BlockSpec index_map folds q-head -> kv-head, no materialized repeat).
Causal masking by global block indices; fully-masked kv blocks are skipped
by the grid bound (lower-triangular iteration via masking — interpret mode
and Mosaic both honor the @pl.when early-out on block skip).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30
_LANES = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, kv_steps: int, blk_q: int,
            blk_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip fully-masked blocks (strictly above the diagonal)
    run = (not causal) or (ki * blk_k <= qi * blk_q + blk_q - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0]                       # (blk_q, d)
        k = k_ref[0, 0]                       # (blk_k, d)
        v = v_ref[0, 0]                       # (blk_k, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (blk_q, blk_k)
        if causal:
            rows = qi * blk_q + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_k), 0)
            cols = ki * blk_k + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_k), 1)
            s = jnp.where(rows >= cols, s, _NEG_INF)
        m_prev = m_ref[:, :1]                 # (blk_q, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                # (blk_q, blk_k)
        alpha = jnp.exp(m_prev - m_new)       # (blk_q, 1)
        l_new = l_ref[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == kv_steps - 1)
    def _final():
        l = l_ref[:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True,
                           scale: float | None = None,
                           blk_q: int = 128,
                           blk_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    b, hq, s, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    assert s % blk_q == 0 and sk % blk_k == 0, (s, sk, blk_q, blk_k)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    grid = (b, hq, s // blk_q, sk // blk_k)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               kv_steps=sk // blk_k, blk_q=blk_q, blk_k=blk_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, d), lambda bb, h, qi, ki: (bb, h, qi, 0)),
            pl.BlockSpec((1, 1, blk_k, d),
                         lambda bb, h, qi, ki, g=group: (bb, h // g, ki, 0)),
            pl.BlockSpec((1, 1, blk_k, d),
                         lambda bb, h, qi, ki, g=group: (bb, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, d),
                               lambda bb, h, qi, ki: (bb, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, _LANES), jnp.float32),
            pltpu.VMEM((blk_q, _LANES), jnp.float32),
            pltpu.VMEM((blk_q, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
