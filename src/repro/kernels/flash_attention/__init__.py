from .ops import flash_attention
from .ref import attention_ref
from .flash_attention import flash_attention_pallas
