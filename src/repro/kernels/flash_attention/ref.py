"""Pure-jnp oracle for flash attention (causal, GQA)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  scale: float | None = None) -> jax.Array:
    b, hq, s, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s_mat = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, sk), dtype=bool), k=sk - s)
        s_mat = jnp.where(mask, s_mat, -jnp.inf)
    p = jax.nn.softmax(s_mat, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
