"""Jit'd wrapper for the qmm Pallas kernel: border zero-padding plus the
off-TPU fallback.  This is the execution backend of the quantized engine
family's int8×int8 fast path (:mod:`repro.quant`); call sites dispatch
through ``quant_gemm`` / ``QuantizedEngine`` rather than importing this
directly.

Off-TPU the fallback is the int-exact oracle (``ref.py``), NOT the
Pallas interpreter: integer accumulation makes the two bitwise-identical
(there is no fp32 summation-order slack to hide behind), and the oracle's
``lax.dot_general`` keeps int8 operands all the way into the contraction
— so the jaxpr proof of "no fp32 upcast before the dot" holds on every
backend.  ``interpret=True`` still forces the kernel through the Pallas
interpreter for conformance tests."""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .qmm import qmm_pallas
from .ref import qmm_ref

__all__ = ["qmm_matmul"]


def _pad_to(x: jax.Array, mult: tuple[int, int]) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mult)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)   # int8 zeros add exactly 0 to the acc
    return x


@functools.partial(jax.jit, static_argnames=("tile", "activation",
                                             "out_dtype", "fuse_dequant",
                                             "interpret"))
def qmm_matmul(a_q: jax.Array, w_q: jax.Array, w_scale: jax.Array, *,
               act_scale: jax.Array | float = 1.0,
               bias: jax.Array | None = None,
               activation: Callable | None = None,
               tile: tuple[int, int, int] | int = (256, 256, 256),
               out_dtype=jnp.float32,
               fuse_dequant: bool = True,
               interpret: bool = False) -> jax.Array:
    """act((A_q @ W_q) * w_scale * act_scale + bias) for arbitrary
    (m, k) x (k, n) int8 operands: pads to tile multiples and slices the
    valid region back out.  ``act_scale`` is a TRACED scalar (the online
    EMA republises a fresh value per live batch; a static arg would
    recompile per decode step) folded into the (1, n) scale operand.
    ``fuse_dequant=False`` returns raw int32."""
    if isinstance(tile, int):
        tile = (tile, tile, tile)
    m, k = a_q.shape
    _, n = w_q.shape
    scale = (w_scale.reshape(1, n).astype(jnp.float32)
             * jnp.float32(act_scale))
    if jax.default_backend() != "tpu" and not interpret:
        return qmm_ref(a_q, w_q, scale, bias=bias,
                       activation=activation, out_dtype=out_dtype,
                       fuse_dequant=fuse_dequant)
    ts_m, ts_n, ts_k = tile
    a_p = _pad_to(a_q, (ts_m, ts_k))
    w_p = _pad_to(w_q, (ts_k, ts_n))
    scale_p = _pad_to(scale, (1, ts_n))
    bias_p = (_pad_to(bias.reshape(1, n), (1, ts_n)).reshape(-1)
              if bias is not None else None)
    y = qmm_pallas(a_p, w_p, scale_p, bias=bias_p,
                   activation=activation, tile=tile, out_dtype=out_dtype,
                   fuse_dequant=fuse_dequant, interpret=interpret)
    return y[:m, :n]
