"""qmm — int8×int8 Pallas tiled matmul with int32 accumulation, the
quantized engine family's true fixed-point compute path."""

from .ops import qmm_matmul
from .ref import qmm_ref
from .qmm import qmm_pallas

__all__ = ["qmm_matmul", "qmm_ref", "qmm_pallas"]
