"""Int-exact oracle for the qmm kernel.

Unlike the fp32 kernels' oracles (where summation ORDER matters within
rounding), integer accumulation is exact and order-independent, so this
reference and the Pallas kernel agree BITWISE on the int32 accumulator —
and, since the dequant epilogue applies the same fp32 ops in the same
order, on the fused output too.  That exactness is why ops.py can route
the off-TPU fallback here instead of the (slow) Pallas interpreter with
no numeric drift.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def qmm_ref(a_q: jax.Array, w_q: jax.Array, scale: jax.Array, *,
            act_scale: jax.Array | float = 1.0,
            bias: jax.Array | None = None,
            activation: Callable | None = None,
            out_dtype=jnp.float32,
            fuse_dequant: bool = True) -> jax.Array:
    """act((A_q @ W_q) * scale * act_scale + bias), int8 operands into
    the dot, exact int32 accumulation.  ``scale`` is the (1, n) dequant
    multiplier (callers usually pre-fold the activation scale in and
    leave ``act_scale`` at 1).  ``fuse_dequant=False`` returns the raw
    int32 accumulator (runtime split/merge mode)."""
    acc = jax.lax.dot_general(
        a_q, w_q,
        (((a_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    if not fuse_dequant:
        return acc
    y = acc.astype(jnp.float32) * (
        scale.reshape(1, -1).astype(jnp.float32) * jnp.float32(act_scale))
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if activation is not None:
        y = activation(y)
    return y.astype(out_dtype)
