"""Pallas int8×int8 tiled-MM kernel — the quantized Synergy PE.

``tiled_mm`` is the fp32 tile engine; this is its fixed-point twin, the
TPU analog of the paper's reduced-precision datapaths (§3.2: the NEON
cores run 16-bit fixed-point SIMD; embedded FPGA reproductions like
ZynqNet win their speedups with fixed-point MACs end to end).  The MXU
natively consumes int8 operand pairs at int32 accumulation, so the
faithful mapping is

  * operands          -> int8 A (per-tensor scale) and int8 W (per-output-
                         channel scale) blocks, streamed at 1 byte/elem —
                         the contraction NEVER sees an fp32 upcast.
  * accumulation      -> int32 VMEM scratch across the k grid dimension
                         (exact: no rounding until the epilogue, and the
                         partials are order-independent integers, unlike
                         fp32 accumulation).
  * dequant epilogue  -> one fused fp32 pass on the LAST k step:
                         acc * (w_scale[j] * act_scale) -> +bias -> act
                         -> cast, so the low-precision stream still pays
                         only one HBM round trip for C.

Everything else mirrors ``tiled_mm``'s contract: grid (gm, gn, gk) with
(i, j) the paper's (t1, t2) tile index, automatic double buffering from
the grid pipeline, zero-padded borders handled in ops.py (int8 zeros
contribute exactly 0 to the integer accumulator).

``fuse_dequant=False`` returns the raw int32 accumulator instead — the
SynergyRuntime splits a quantized GEMM into row panels in this mode and
applies the shared ``dequant_finish`` ONCE after the merge, so a split
never rounds twice and stolen panels stay bitwise-identical (integer
partials are exact on every engine).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["qmm_pallas"]


def _kernel(a_ref, b_ref, scale_ref, bias_ref, o_ref, acc_ref, *,
            k_steps: int, activation: Callable | None, has_bias: bool,
            fuse_dequant: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # THE point of the kernel: the contraction consumes the int8 blocks
    # directly (MXU int8 mode), accumulating exactly in int32
    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        acc = acc_ref[...]
        if fuse_dequant:
            # scale_ref carries w_scale * act_scale pre-combined (a
            # traced operand — the online EMA republises a new act
            # scale per batch, and a static epilogue constant would
            # retrace the kernel every decode step)
            y = acc.astype(jnp.float32) * scale_ref[...].astype(jnp.float32)
            if has_bias:
                y = y + bias_ref[...].astype(jnp.float32)
            if activation is not None:
                y = activation(y)
            o_ref[...] = y.astype(o_ref.dtype)
        else:
            o_ref[...] = acc


def qmm_pallas(a_q: jax.Array, w_q: jax.Array, scale: jax.Array, *,
               bias: jax.Array | None = None,
               activation: Callable | None = None,
               tile: tuple[int, int, int] = (256, 256, 256),
               out_dtype=jnp.float32,
               fuse_dequant: bool = True,
               interpret: bool = False) -> jax.Array:
    """C[m, n] = act((A_q @ W_q) * scale + bias) with int8 operands and
    int32 accumulation.  ``a_q`` int8 (m, k); ``w_q`` int8 (k, n);
    ``scale`` fp32 (1, n) — the per-output-channel weight scale with the
    per-tensor activation scale already multiplied in (a TRACED operand:
    the online EMA republises a fresh activation scale per live batch,
    and baking it in as a static constant would recompile the kernel on
    every decode step).  Dims must be multiples of ``tile`` (ops.py pads
    borders with int8 zeros).

    ``fuse_dequant=False`` skips the epilogue entirely and returns the
    raw int32 accumulator (runtime split/merge mode)."""
    assert a_q.dtype == jnp.int8 and w_q.dtype == jnp.int8, (
        f"qmm consumes int8 operands, got {a_q.dtype} x {w_q.dtype}")
    m, k = a_q.shape
    k2, n = w_q.shape
    assert k == k2
    ts_m, ts_n, ts_k = tile
    assert m % ts_m == 0 and n % ts_n == 0 and k % ts_k == 0, (
        f"padded dims required: {(m, n, k)} vs tile {tile}")
    gm, gn, gk = m // ts_m, n // ts_n, k // ts_k

    has_bias = bias is not None
    bias2d = (bias.reshape(1, n) if has_bias
              else jnp.zeros((1, n), dtype=jnp.float32))
    scale2d = scale.reshape(1, n).astype(jnp.float32)

    kernel = functools.partial(
        _kernel, k_steps=gk, activation=activation, has_bias=has_bias,
        fuse_dequant=fuse_dequant)
    out_dtype = jnp.int32 if not fuse_dequant else out_dtype
    flops = 2 * m * n * k
    # the bandwidth story: both operand streams are 1 byte/element
    bytes_accessed = (a_q.size + w_q.size
                      + m * n * jnp.dtype(out_dtype).itemsize)
    return pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((ts_m, ts_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((ts_k, ts_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, ts_n), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, ts_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((ts_m, ts_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((ts_m, ts_n), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        cost_estimate=pl.CostEstimate(flops=flops,
                                      bytes_accessed=bytes_accessed,
                                      transcendentals=0),
        interpret=interpret,
    )(a_q, w_q, scale2d, bias2d)
