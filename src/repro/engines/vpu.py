"""NeonVpuEngine — the TPU analog of the paper's NEON SIMD cores.

The paper keeps two NEON cores in the pool even though each is worth only
0.42 of an F-PE (§3.1.1): a slow-but-always-available engine raises
aggregate utilization because the thief protocol hands it tail work no
fast engine would miss.  On TPU the same silicon split exists on one die —
the MXU systolic array vs the 8x128-lane VPU — so this engine runs the
``vpu_mm`` kernel (rank-1 broadcast FMAs, never a ``dot``) and presents a
NEON-calibrated cost model to the shared planners.

Calibration: the VPU's 8x128 lanes against the MXU's 128x128 array give a
1/16 area ratio; measured VPU matmul throughput lands near 5e12 MAC/s vs
the Pallas MXU kernel's 90e12 on the same chip — close to the paper's
NEON:F-PE ratio once dispatch overheads are counted.  Off-TPU the kernel
runs through the Pallas interpreter (validation only — the rate constant
keeps auto-dispatch away from it, exactly like PallasTiledEngine).
"""

from __future__ import annotations

from typing import Callable

import jax

from .base import (CAP_EPILOGUE, CAP_GEMM, CAP_INTERPRET, CAP_TILED,
                   CAP_VPU, CostModel, Engine)

__all__ = ["NeonVpuEngine"]

#: MXU:VPU area ratio on current TPU generations (128x128 vs 8x128 lanes)
_VPU_MXU_RATIO = 1.0 / 16.0


class NeonVpuEngine(Engine):
    """VPU-only (no-MXU) Pallas tiled matmul as a registry engine."""

    def __init__(self, name: str = "neon-vpu", *, interpret: bool = False,
                 cost: CostModel | None = None):
        """``cost`` overrides the backend-derived model — benchmark pools
        inject paper-relative NEON rates to compare against sim PEs."""
        super().__init__(name, {CAP_GEMM, CAP_EPILOGUE, CAP_TILED,
                                CAP_INTERPRET, CAP_VPU}, cost=cost)
        self.interpret = interpret

    @property
    def cost(self) -> CostModel:
        if self._cost is not None:       # steal-aware recalibration applied
            return self._cost
        if jax.default_backend() == "tpu":
            return CostModel(90e12 * _VPU_MXU_RATIO)
        return CostModel(1e6)   # interpreter: auto-dispatch never picks it

    def execute(self, a, b, *, bias=None, activation: Callable | None = None,
                tile=(256, 256, 256), out_dtype=None, precision=None):
        from repro.kernels.vpu_mm import ops as vpu_ops
        if b.dtype != a.dtype:
            b = b.astype(a.dtype)
        # the VPU kernel's rank-1 update loop scales with ts_k; cap tiles
        # at the 128-lane-friendly size regardless of the MXU default
        ts = tuple(min(t, 128) for t in
                   (tile if isinstance(tile, tuple) else (tile,) * 3))
        return vpu_ops.vpu_matmul(a, b, tile=ts, bias=bias,
                                  activation=activation,
                                  out_dtype=out_dtype,
                                  interpret=self.interpret)
