"""Process-wide engine registry + per-op variant registry.

Two registries back the unified dispatch surface:

  * **Engine registry** — named :class:`~repro.engines.base.Engine` objects
    (GEMM backends + simulated paper PEs).  ``register_engine`` is the ONE
    call needed to bring a new backend online: the dispatcher, the
    schedulers and every ``synergy_matmul`` call site pick it up with zero
    edits.
  * **Op-variant registry** — named implementations of non-GEMM kernels
    (flash attention, SSD scan, attention scores).  ``resolve_op`` replaces
    the old string-compare ``impl`` branching: variants carry a priority
    and an availability predicate, and ``"auto"`` resolves to the
    highest-priority variant available on the current backend.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Callable, Iterator, Optional

from .base import Engine

__all__ = [
    "register_engine", "unregister_engine", "get_engine", "find_engine",
    "list_engines", "registered",
    "add_registry_listener", "remove_registry_listener",
    "OpVariant", "register_op_impl", "resolve_op", "op_variants",
]

_LOCK = threading.RLock()
_ENGINES: dict[str, Engine] = {}
_LISTENERS: list[Callable[[str, Engine], None]] = []


# ---------------------------------------------------------------------------
# Engine registry
# ---------------------------------------------------------------------------

def add_registry_listener(fn: Callable[[str, Engine], None]) -> Callable:
    """Subscribe ``fn(event, engine)`` to registry changes; ``event`` is
    ``"register"`` or ``"unregister"``.  The live SynergyRuntime uses this
    to rebalance its worker pool when engines come and go mid-run."""
    with _LOCK:
        _LISTENERS.append(fn)
    return fn


def remove_registry_listener(fn: Callable[[str, Engine], None]) -> None:
    with _LOCK:
        if fn in _LISTENERS:
            _LISTENERS.remove(fn)


def _notify(event: str, engine: Engine) -> None:
    # outside _LOCK: listeners (runtime rebalance) take their own locks and
    # may read the registry
    with _LOCK:
        listeners = list(_LISTENERS)
    for fn in listeners:
        fn(event, engine)


def register_engine(engine: Engine, *, override: bool = False) -> Engine:
    """Register ``engine`` under ``engine.name``; returns it for chaining."""
    with _LOCK:
        if engine.name in _ENGINES and not override:
            raise ValueError(
                f"engine {engine.name!r} already registered "
                f"({_ENGINES[engine.name]!r}); pass override=True to replace")
        _ENGINES[engine.name] = engine
    _notify("register", engine)
    return engine


def unregister_engine(name: str) -> Optional[Engine]:
    with _LOCK:
        engine = _ENGINES.pop(name, None)
    if engine is not None:
        _notify("unregister", engine)
    return engine


def get_engine(name: str) -> Engine:
    with _LOCK:
        try:
            return _ENGINES[name]
        except KeyError:
            known = sorted(_ENGINES)
            raise KeyError(f"no engine {name!r}; registered: {known}") from None


def find_engine(name: str) -> Optional[Engine]:
    with _LOCK:
        return _ENGINES.get(name)


def list_engines() -> list[Engine]:
    with _LOCK:
        return list(_ENGINES.values())


@contextlib.contextmanager
def registered(*engines: Engine) -> Iterator[tuple[Engine, ...]]:
    """Temporarily register engines (tests / scoped experiments), restoring
    any same-named engines they shadowed on exit."""
    shadowed: dict[str, Optional[Engine]] = {}
    with _LOCK:
        for e in engines:
            shadowed[e.name] = _ENGINES.get(e.name)
            _ENGINES[e.name] = e
    for e in engines:
        _notify("register", e)
    try:
        yield engines
    finally:
        with _LOCK:
            for name, prev in shadowed.items():
                if prev is None:
                    _ENGINES.pop(name, None)
                else:
                    _ENGINES[name] = prev
        for e in engines:
            prev = shadowed[e.name]
            if prev is None:
                _notify("unregister", e)
            else:
                _notify("register", prev)


# ---------------------------------------------------------------------------
# Op-variant registry (non-GEMM kernels)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OpVariant:
    """One named implementation of an op family.

    ``priority`` ranks variants for ``"auto"`` resolution (higher wins);
    ``available`` gates auto-selection (an explicitly named variant always
    resolves — e.g. Pallas interpret mode off-TPU)."""

    op: str
    name: str
    fn: Callable
    priority: int = 0
    available: Callable[[], bool] = lambda: True


_OPS: dict[str, dict[str, OpVariant]] = {}


def register_op_impl(op: str, name: str, fn: Callable, *, priority: int = 0,
                     available: Callable[[], bool] | None = None,
                     override: bool = False) -> OpVariant:
    variant = OpVariant(op, name, fn, priority,
                        available if available is not None else (lambda: True))
    with _LOCK:
        table = _OPS.setdefault(op, {})
        if name in table and not override:
            raise ValueError(f"variant {name!r} of op {op!r} already "
                             f"registered; pass override=True to replace")
        table[name] = variant
    return variant


def op_variants(op: str) -> list[OpVariant]:
    with _LOCK:
        return sorted(_OPS.get(op, {}).values(), key=lambda v: -v.priority)


def resolve_op(op: str, name: str = "auto") -> Callable:
    """Resolve an op implementation.  ``"auto"`` picks the highest-priority
    variant whose ``available()`` is true; an explicit name always resolves
    (KeyError if unknown)."""
    with _LOCK:
        table = _OPS.get(op)
        if not table:
            raise KeyError(f"no variants registered for op {op!r}")
        if name != "auto":
            try:
                return table[name].fn
            except KeyError:
                raise KeyError(f"op {op!r} has no variant {name!r}; "
                               f"known: {sorted(table)}") from None
        ranked = sorted(table.values(), key=lambda v: (-v.priority, v.name))
    for v in ranked:
        if v.available():
            return v.fn
    raise RuntimeError(f"no available variant for op {op!r} on this backend")
