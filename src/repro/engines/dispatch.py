"""The Dispatcher: route each GEMM's JobSet to the best-capable engine.

The dispatch rule is the paper's scheduling insight at engine granularity:
filter by capability, rank by the shared cost model, run on the winner.
``synergy_matmul`` consults the default dispatcher for every dense GEMM in
the framework, so registering a faster engine reroutes all work with zero
call-site edits.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Iterable, Iterator, Optional, Union

from .base import CAP_GEMM, CAP_SIM, Engine
from .registry import get_engine, list_engines

__all__ = ["Dispatcher", "DEFAULT_DISPATCHER", "dispatch_gemm",
           "engine_scope", "current_scope_engine"]

_scope = threading.local()


@contextlib.contextmanager
def engine_scope(engine: Union[str, Engine, None]) -> Iterator[None]:
    """Pin every auto-dispatched GEMM in this thread to ``engine`` for the
    duration of the block (trace-time routing: code already jit-compiled
    outside the scope keeps its original routing).  ``None`` restores
    dispatcher auto-selection; scopes nest."""
    prev = getattr(_scope, "engine", None)
    _scope.engine = engine
    try:
        yield
    finally:
        _scope.engine = prev


def current_scope_engine() -> Union[str, Engine, None]:
    return getattr(_scope, "engine", None)


class Dispatcher:
    """Capability-filtered, cost-ranked engine selection.

    ``require``: capabilities every candidate must advertise.
    ``exclude``: capabilities that disqualify a candidate from AUTO
    selection (simulated PEs by default — they model a 0.1 GMAC/s Zynq
    fabric and would never win, but excluding them keeps auto-dispatch
    semantics independent of what simulators are registered).
    """

    def __init__(self, require: Iterable[str] = (CAP_GEMM,),
                 exclude: Iterable[str] = (CAP_SIM,)):
        self.require = frozenset(require)
        self.exclude = frozenset(exclude)

    def candidates(self, require: Iterable[str] = ()) -> list[Engine]:
        req = self.require | frozenset(require)
        return [e for e in list_engines()
                if e.supports(req) and not (e.capabilities & self.exclude)
                and e.available()]

    def select(self, jobset, *, engine: Union[str, Engine, None] = None,
               require: Iterable[str] = ()) -> Engine:
        """Pick the engine for one JobSet.

        An explicit ``engine`` (name or instance) bypasses ranking but is
        still capability-checked; otherwise the cheapest capable candidate
        by cost-model estimate wins."""
        req = self.require | frozenset(require)
        if engine is not None:
            eng = get_engine(engine) if isinstance(engine, str) else engine
            if not eng.supports(req):
                missing = sorted(req - eng.capabilities)
                raise ValueError(f"engine {eng.name!r} lacks required "
                                 f"capabilities {missing}")
            return eng
        cands = self.candidates(require)
        if not cands:
            raise RuntimeError(
                f"no registered engine satisfies capabilities {sorted(req)}")
        return min(cands, key=lambda e: e.estimate(jobset))


DEFAULT_DISPATCHER = Dispatcher()


def dispatch_gemm(jobset, *, engine: Union[str, Engine, None] = None,
                  require: Iterable[str] = ()) -> Engine:
    """Module-level shorthand for ``DEFAULT_DISPATCHER.select``."""
    return DEFAULT_DISPATCHER.select(jobset, engine=engine, require=require)
