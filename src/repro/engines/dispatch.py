"""The Dispatcher: route each GEMM's JobSet to the best-capable engine.

The dispatch rule is the paper's scheduling insight at engine granularity:
filter by capability, rank by the shared cost model, run on the winner.
``synergy_matmul`` consults the default dispatcher for every dense GEMM in
the framework, so registering a faster engine reroutes all work with zero
call-site edits.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Iterable, Iterator, Optional, Union

from repro.obs import trace as _trace

from .base import CAP_GEMM, CAP_GRAD, CAP_INT8, CAP_SIM, Engine
from .registry import get_engine, list_engines

__all__ = ["Dispatcher", "DEFAULT_DISPATCHER", "dispatch_gemm",
           "engine_scope", "current_scope_engine",
           "JobClassPolicy", "JOB_CLASSES"]

_scope = threading.local()


@contextlib.contextmanager
def engine_scope(engine: Union[str, Engine, None]) -> Iterator[None]:
    """Pin every auto-dispatched GEMM in this thread to ``engine`` for the
    duration of the block (trace-time routing: code already jit-compiled
    outside the scope keeps its original routing).  ``None`` restores
    dispatcher auto-selection; scopes nest."""
    prev = getattr(_scope, "engine", None)
    _scope.engine = engine
    try:
        yield
    finally:
        _scope.engine = prev


def current_scope_engine() -> Union[str, Engine, None]:
    return getattr(_scope, "engine", None)


@dataclasses.dataclass(frozen=True)
class JobClassPolicy:
    """Precision-routing policy for one job class.

    ``require``: hard capability filter (candidates lacking any are out).
    ``prefer``:  soft filter — if any candidate advertises every preferred
    capability, selection ranks only those; otherwise it falls back to the
    full candidate set (a pool with no int8 engine still serves decode).
    """

    require: frozenset = frozenset()
    prefer: frozenset = frozenset()


#: the precision-routing table (paper §3 job classes, serving-era names):
#: decode steps are small, memory-bound and error-tolerant — trade
#: precision for rate when an int8 engine is registered.  Prefill feeds
#: the KV cache every later token reads, and training differentiates the
#: GEMM, so both are pinned to grad-safe full-precision paths.  NOTE:
#: CAP_GRAD is a deliberately conservative full-precision proxy — it also
#: keeps prefill off grad-FREE fp32 kernels (Pallas MXU/VPU engines);
#: deployments that trust those for prefill can relax the table
#: (JOB_CLASSES["prefill"] is plain data, not policy machinery).
JOB_CLASSES: dict[str, JobClassPolicy] = {
    "decode": JobClassPolicy(prefer=frozenset({CAP_INT8})),
    # the serving load-shed ladder's degraded tier: sheddable tenants'
    # decode REQUIRES an int8 engine, freeing the fp32 pool for
    # interactive traffic (see repro.soc.qos)
    "decode_degraded": JobClassPolicy(require=frozenset({CAP_INT8}),
                                      prefer=frozenset({CAP_INT8})),
    "prefill": JobClassPolicy(require=frozenset({CAP_GRAD})),
    "train": JobClassPolicy(require=frozenset({CAP_GRAD})),
}


class Dispatcher:
    """Capability-filtered, cost-ranked engine selection.

    ``require``: capabilities every candidate must advertise.
    ``exclude``: capabilities that disqualify a candidate from AUTO
    selection — simulated PEs (they model a 0.1 GMAC/s Zynq fabric and
    would never win, but excluding them keeps auto-dispatch semantics
    independent of what simulators are registered) and int8 quantized
    engines (their cost models beat fp32 peers, so cost ranking alone
    would silently trade away precision process-wide; a job class that
    prefers or requires ``int8`` lifts the exclusion, and an explicit
    ``engine=`` pin bypasses it entirely).
    """

    def __init__(self, require: Iterable[str] = (CAP_GEMM,),
                 exclude: Iterable[str] = (CAP_SIM, CAP_INT8)):
        self.require = frozenset(require)
        self.exclude = frozenset(exclude)

    def candidates(self, require: Iterable[str] = (),
                   exclude: Optional[frozenset] = None) -> list[Engine]:
        req = self.require | frozenset(require)
        exc = self.exclude if exclude is None else exclude
        return [e for e in list_engines()
                if e.supports(req) and not (e.capabilities & exc)
                and e.available()]

    def select(self, jobset, *, engine: Union[str, Engine, None] = None,
               require: Iterable[str] = (),
               job_class: Optional[str] = None) -> Engine:
        """Pick the engine for one JobSet.

        An explicit ``engine`` (name or instance) bypasses ranking but is
        still capability-checked; otherwise the cheapest capable candidate
        by cost-model estimate wins.  ``job_class`` applies the precision
        routing policy in :data:`JOB_CLASSES`: its ``require`` set becomes
        a hard filter (checked even against an explicit engine), and its
        ``prefer`` set narrows auto-selection when any candidate offers it
        (decode prefers ``int8``; prefill/train require ``grad``)."""
        if job_class is None:
            policy = _NO_POLICY
        else:
            try:
                policy = JOB_CLASSES[job_class]
            except KeyError:
                raise KeyError(
                    f"unknown job class {job_class!r}; known: "
                    f"{sorted(JOB_CLASSES)}") from None
        req = self.require | frozenset(require) | policy.require
        if engine is not None:
            eng = get_engine(engine) if isinstance(engine, str) else engine
            if not eng.supports(req):
                missing = sorted(req - eng.capabilities)
                raise ValueError(f"engine {eng.name!r} lacks required "
                                 f"capabilities {missing}")
            return eng
        # a capability the caller/policy asks for cannot also disqualify
        exc = self.exclude - policy.prefer - req
        cands = self.candidates(req - self.require, exclude=exc)
        if not cands:
            raise RuntimeError(
                f"no registered engine satisfies capabilities {sorted(req)}")
        if policy.prefer:
            preferred = [e for e in cands if policy.prefer <= e.capabilities]
            if preferred:
                cands = preferred
        eng = min(cands, key=lambda e: e.estimate(jobset))
        # one module-attribute check: dispatch decisions show up on traces
        # (process-default tracer only; tracing off = no-op)
        if _trace._default is not None:
            _trace._default.emit(
                "dispatch", eng.name, jobset=getattr(jobset, "name", None),
                job_class=job_class, n_candidates=len(cands))
        return eng


_NO_POLICY = JobClassPolicy()

DEFAULT_DISPATCHER = Dispatcher()


def dispatch_gemm(jobset, *, engine: Union[str, Engine, None] = None,
                  require: Iterable[str] = (),
                  job_class: Optional[str] = None) -> Engine:
    """Module-level shorthand for ``DEFAULT_DISPATCHER.select``."""
    return DEFAULT_DISPATCHER.select(jobset, engine=engine, require=require,
                                     job_class=job_class)
