"""Simulated Zynq accelerators as registry engines (paper §3.1.1 / §4).

The calibrated rate constants that used to live as private module globals
in ``repro.core.clusters`` are now the cost models of ordinary registered
engines, so the discrete-event simulator, the LPT planner, the rebalancer
and the dispatcher all read ONE source of truth.  A SimPEEngine is fully
executable (it runs the jnp oracle), so a "paper PE" can also serve real
GEMMs in tests and demos.

Calibration (documented; reproduces the paper's Figures 9/13/14, Table 6):

  * F-PE: HLS loop pipelining at loop2, II limited by BRAM ports to TS/2=16
    cycles per merged iteration; ~2 MAC/cycle @ 100 MHz minus BRAM-port
    stalls and job-fetch gaps -> 0.125 GMAC/s sustained.
  * S-PE: unroll(2) + pipelining at loop3 -> 0.5x F-PE.
  * NEON: calibrated from the paper's measurement that adding 2 NEONs to
    the 6F+2S FPGA config improves latency by ~12% (Fig 11):
    2*x = 0.12*7.0 F-PE-units -> x = 0.42 F-PE-units.
  * ARM A9 (Darknet -O3): Table 3, ~0.14 GMAC/s on conv gemm single
    thread; other layers ~0.5 Gop/s; im2col ~0.8 GB/s effective copy BW.
  * Per-job dispatch: 30 us ReconOS delegate-thread round trip.
"""

from __future__ import annotations

from typing import Callable

from .base import CAP_EPILOGUE, CAP_GEMM, CAP_SIM, CostModel, Engine

__all__ = ["SimPEEngine", "SIM_ENGINE_SPECS"]

_RECONOS_DISPATCH_S = 30e-6
_F_PE_MACS_PER_S = 0.125e9

#: kind -> calibrated cost model (rates in absolute MAC/s)
SIM_ENGINE_SPECS: dict[str, CostModel] = {
    "F-PE": CostModel(_F_PE_MACS_PER_S, dispatch_s=_RECONOS_DISPATCH_S),
    "S-PE": CostModel(0.5 * _F_PE_MACS_PER_S, dispatch_s=_RECONOS_DISPATCH_S),
    "NEON": CostModel(0.42 * _F_PE_MACS_PER_S, dispatch_s=_RECONOS_DISPATCH_S),
    # the host ARM A9 pair: conv MACs + elementwise ops + im2col copies
    "ARM": CostModel(0.14e9, dispatch_s=0.0, bytes_per_s=0.8e9,
                     ops_per_s=0.5e9),
}


class SimPEEngine(Engine):
    """A calibrated paper PE: cost model drives the DES + planners; execute
    falls back to the jnp oracle so the engine is also runnable."""

    def __init__(self, name: str, cost: CostModel,
                 capabilities: frozenset[str] | set[str] = frozenset()):
        super().__init__(name, {CAP_GEMM, CAP_EPILOGUE, CAP_SIM}
                         | set(capabilities), cost=cost)

    def recalibrate(self, observed_macs_per_s: float,
                    alpha: float = 0.5) -> float:
        """No-op: this cost model is the PAPER's calibrated constant for
        hardware that is not actually here — a measured host-oracle rate
        would corrupt every DES/LPT/Table-6 result that reads it."""
        return self.cost.macs_per_s

    def execute(self, a, b, *, bias=None, activation: Callable | None = None,
                tile=(256, 256, 256), out_dtype=None, precision=None):
        from repro.kernels.tiled_mm.ref import tiled_mm_ref
        return tiled_mm_ref(a, b, bias=bias, activation=activation,
                            out_dtype=out_dtype)


def make_sim_engines() -> list[SimPEEngine]:
    return [SimPEEngine(kind, cost) for kind, cost in SIM_ENGINE_SPECS.items()]
