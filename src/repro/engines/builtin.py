"""Built-in GEMM engines: XLA dot, Pallas tiled kernel, jnp oracle.

These are the three execution backends the seed's ``impl`` strings used to
pick by hand; now they are ordinary registry entries ranked by their cost
models.  Rates are deliberately coarse — they only need to order the
engines correctly per backend (XLA wins on CPU where Pallas runs in
interpret mode; the Pallas MXU kernel wins on TPU; the oracle never wins).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .base import (CAP_EPILOGUE, CAP_GEMM, CAP_GRAD, CAP_INTERPRET,
                   CAP_ORACLE, CAP_TILED, CostModel, Engine)

__all__ = ["XlaEngine", "PallasTiledEngine", "ReferenceEngine"]


def _epilogue(y: jax.Array, bias, activation) -> jax.Array:
    if bias is not None:
        y = y + bias
    if activation is not None:
        y = activation(y)
    return y


class XlaEngine(Engine):
    """Canonical ``lax.dot_general`` — the CPU / dry-run path (keeps the
    512-device dry-run HLO clean so ``cost_analysis`` sees canonical dots).
    Handles storage dtype != compute dtype (int8 weight-only quant for
    decode, §Perf B1): dequant-on-read, accumulate in f32."""

    #: coarse sustained MAC rates used only to RANK engines per backend
    _RATES = {"tpu": 60e12, "gpu": 30e12, "cpu": 2e9}

    def __init__(self, name: str = "xla"):
        super().__init__(name, {CAP_GEMM, CAP_EPILOGUE, CAP_GRAD})

    @property
    def cost(self) -> CostModel:
        if self._cost is not None:       # steal-aware recalibration applied
            return self._cost
        return CostModel(self._RATES.get(jax.default_backend(), 2e9))

    def execute(self, a, b, *, bias=None, activation: Callable | None = None,
                tile=(256, 256, 256), out_dtype=None, precision=None):
        if b.dtype != a.dtype:
            b = b.astype(a.dtype)
        y = jax.lax.dot_general(
            a, b, (((a.ndim - 1,), (0,)), ((), ())),
            precision=precision,
            preferred_element_type=jnp.float32)
        y = _epilogue(y, bias, activation)
        return y.astype(out_dtype or a.dtype)


class PallasTiledEngine(Engine):
    """The Pallas ``tiled_mm`` kernel — the TPU-native Synergy PE (grid ==
    job space, VMEM double buffering, fused epilogue).  Interpret-mode
    capable: explicitly requesting it off-TPU runs the kernel through the
    Pallas interpreter (validation path)."""

    def __init__(self, name: str = "pallas", *, interpret: bool = False):
        super().__init__(name, {CAP_GEMM, CAP_EPILOGUE, CAP_TILED,
                                CAP_INTERPRET})
        self.interpret = interpret

    @property
    def cost(self) -> CostModel:
        if self._cost is not None:       # steal-aware recalibration applied
            return self._cost
        if jax.default_backend() == "tpu":
            return CostModel(90e12)
        return CostModel(2e6)   # interpreter: auto-dispatch never picks it

    def execute(self, a, b, *, bias=None, activation: Callable | None = None,
                tile=(256, 256, 256), out_dtype=None, precision=None):
        from repro.kernels.tiled_mm import ops as tiled_ops
        if b.dtype != a.dtype:
            b = b.astype(a.dtype)
        return tiled_ops.tiled_matmul(a, b, tile=tile, bias=bias,
                                      activation=activation,
                                      out_dtype=out_dtype,
                                      interpret=self.interpret)


class ReferenceEngine(Engine):
    """Pure-jnp fp32 oracle — correctness baseline, never speed-ranked."""

    def __init__(self, name: str = "reference"):
        super().__init__(name, {CAP_GEMM, CAP_EPILOGUE, CAP_GRAD, CAP_ORACLE},
                         cost=CostModel(5e7))

    def execute(self, a, b, *, bias=None, activation: Callable | None = None,
                tile=(256, 256, 256), out_dtype=None, precision=None):
        from repro.kernels.tiled_mm.ref import tiled_mm_ref
        return tiled_mm_ref(a, b, bias=bias, activation=activation,
                            out_dtype=out_dtype)
