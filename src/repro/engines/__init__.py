"""``repro.engines`` — the unified accelerator abstraction (paper §3).

One registry, one dispatch surface, every backend:

    from repro.engines import Engine, CostModel, register_engine

    class MyEngine(Engine):
        def __init__(self):
            super().__init__("mine", {"gemm", "epilogue"},
                             cost=CostModel(macs_per_s=1e12))
        def execute(self, a, b, *, bias=None, activation=None, **kw):
            ...

    register_engine(MyEngine())   # every GEMM call site can now route here

Importing this package registers the built-in engines (``xla``,
``pallas``, ``reference``, the VPU-only ``neon-vpu``) and the calibrated
simulated Zynq PEs (``F-PE``, ``S-PE``, ``NEON``, ``ARM``) exactly once.
Quantized int8 variants join on demand via
``repro.quant.register_quantized``.
"""

from .base import (CAP_EPILOGUE, CAP_GEMM, CAP_GRAD, CAP_INT8, CAP_INTERPRET,
                   CAP_ORACLE, CAP_SIM, CAP_TILED, CAP_VPU, CostModel, Engine,
                   Telemetry)
from .registry import (OpVariant, add_registry_listener, find_engine,
                       get_engine, list_engines, op_variants,
                       register_engine, register_op_impl, registered,
                       remove_registry_listener, resolve_op,
                       unregister_engine)
from .builtin import PallasTiledEngine, ReferenceEngine, XlaEngine
from .sim import SIM_ENGINE_SPECS, SimPEEngine, make_sim_engines
from .vpu import NeonVpuEngine
from .dispatch import (DEFAULT_DISPATCHER, JOB_CLASSES, Dispatcher,
                       JobClassPolicy, current_scope_engine, dispatch_gemm,
                       engine_scope)

__all__ = [
    "Engine", "CostModel", "Telemetry",
    "CAP_GEMM", "CAP_EPILOGUE", "CAP_GRAD", "CAP_TILED", "CAP_INTERPRET",
    "CAP_SIM", "CAP_ORACLE", "CAP_INT8", "CAP_VPU",
    "register_engine", "unregister_engine", "get_engine", "find_engine",
    "list_engines", "registered",
    "add_registry_listener", "remove_registry_listener",
    "OpVariant", "register_op_impl", "resolve_op", "op_variants",
    "XlaEngine", "PallasTiledEngine", "ReferenceEngine", "NeonVpuEngine",
    "SimPEEngine", "SIM_ENGINE_SPECS", "make_sim_engines",
    "Dispatcher", "DEFAULT_DISPATCHER", "dispatch_gemm",
    "engine_scope", "current_scope_engine",
    "JobClassPolicy", "JOB_CLASSES",
]


def _register_defaults() -> None:
    for eng in (XlaEngine(), PallasTiledEngine(), ReferenceEngine(),
                NeonVpuEngine(), *make_sim_engines()):
        if find_engine(eng.name) is None:
            register_engine(eng)


_register_defaults()
