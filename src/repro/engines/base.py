"""The unified Engine abstraction (paper §3: "a unified abstraction of the
heterogeneous accelerators").

The paper's F-PEs, S-PEs and NEON cores all present the same contract to the
runtime: take a tile job, return the output tile, at a calibrated rate.  This
module lifts that contract into the framework proper so *every* compute
backend — the XLA dot, the Pallas tiled kernel, the pure-jnp oracle, the
simulated Zynq PEs, or any engine a user registers — is interchangeable
behind one dispatch surface:

  * :class:`CostModel` — calibrated rate constants (the planning oracle the
    schedulers and the dispatcher share).
  * :class:`Telemetry` — per-engine counters (jobs run, busy seconds, bytes
    moved) aggregated by :class:`repro.core.synergy_mm.SynergyTrace`.
  * :class:`Engine`    — name + capabilities + cost model + ``execute``.

Capabilities are plain strings; the dispatcher routes a GEMM only to engines
advertising every required capability.  The core vocabulary:

  ``gemm``      executes dense GEMMs (``execute`` is implemented)
  ``epilogue``  fuses bias + activation into the GEMM (no extra HBM trip)
  ``grad``      safe under ``jax.grad`` (used by training paths)
  ``tiled``     executes through the fixed-size tile-job decomposition
  ``interpret`` Pallas target that can also run in interpret mode off-TPU
  ``sim``       cost-model-only paper PE (executes via the XLA oracle)
  ``oracle``    numerical reference; never auto-selected for speed
  ``int8``      int8 quantized path (low precision, high rate; NOT
                grad-safe — round/clip kill the weight gradient).  Weights
                are always int8; once the engine's online activation
                calibrator publishes a shape's scale the contraction runs
                TRUE int8×int8 with int32 accumulation (kernels/qmm),
                falling back to the weight-only fp32-cast dot before that.
  ``vpu``       vector-unit-only execution (no MXU) — the TPU analog of
                the paper's NEON SIMD cores
"""

from __future__ import annotations

import abc
import dataclasses
import math
import threading
from typing import Callable, Optional

__all__ = [
    "CostModel", "Telemetry", "Engine",
    "CAP_GEMM", "CAP_EPILOGUE", "CAP_GRAD", "CAP_TILED", "CAP_INTERPRET",
    "CAP_SIM", "CAP_ORACLE", "CAP_INT8", "CAP_VPU",
]

CAP_GEMM = "gemm"
CAP_EPILOGUE = "epilogue"
CAP_GRAD = "grad"
CAP_TILED = "tiled"
CAP_INTERPRET = "interpret"
CAP_SIM = "sim"
CAP_ORACLE = "oracle"
CAP_INT8 = "int8"
CAP_VPU = "vpu"


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Calibrated engine rates — the shared planning oracle.

    ``macs_per_s``   sustained MAC rate on tile jobs.
    ``dispatch_s``   per-job dispatch overhead (the paper's ReconOS
                     delegate-thread round trip; 0 for on-die engines).
    ``bytes_per_s``  copy/stream bandwidth (im2col, layout transforms).
    ``ops_per_s``    non-MAC elementwise rate (pool/act/norm stages).
    """

    macs_per_s: float
    dispatch_s: float = 0.0
    bytes_per_s: float = math.inf
    ops_per_s: float = math.inf

    def job_time(self, job_macs: int, job_bytes: int = 0) -> float:
        """Seconds for ONE tile job: roofline max of compute and traffic,
        plus the dispatch overhead."""
        compute = job_macs / self.macs_per_s
        memory = job_bytes / self.bytes_per_s if job_bytes else 0.0
        return max(compute, memory) + self.dispatch_s

    def estimate(self, jobset) -> float:
        """Seconds to run every job of one GEMM's JobSet on this engine.
        All jobs of a JobSet are identical fixed-size tiles (§3.2.1), so
        this is num_jobs * per-job time."""
        if jobset.num_jobs == 0:   # degenerate GEMM (e.g. empty prompt)
            return 0.0
        job = next(jobset.jobs())
        return jobset.num_jobs * self.job_time(job.macs, job.bytes_moved)

    def scaled(self, factor: float) -> "CostModel":
        """A view of this model at ``factor``x the MAC rate (heterogeneous
        pool members expressed relative to a base engine)."""
        return dataclasses.replace(self, macs_per_s=self.macs_per_s * factor)


@dataclasses.dataclass
class Telemetry:
    """Per-engine dispatch AND runtime counters.

    ``busy_s`` is the cost-model estimate of seconds of engine time routed
    here (recorded at trace/dispatch time — the same accounting basis the
    discrete-event simulator and the roofline use).  The runtime counters
    are fed by :class:`repro.soc.SynergyRuntime` workers: ``steals`` is the
    number of jobs this engine executed that it took from ANOTHER engine's
    queue, ``wall_busy_s``/``idle_s`` are measured worker-thread seconds
    executing jobs / waiting for work.  Updates are locked: ThreadedPipeline
    stages and runtime workers write from concurrent threads."""

    gemms: int = 0
    jobs: int = 0
    busy_s: float = 0.0
    bytes_moved: int = 0
    steals: int = 0
    wall_busy_s: float = 0.0
    idle_s: float = 0.0
    #: times this engine was quarantined by a self-healing pool
    quarantines: int = 0
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    def record(self, jobset, est_s: float) -> None:
        n_bytes = 0
        if jobset.num_jobs:
            n_bytes = jobset.num_jobs * next(jobset.jobs()).bytes_moved
        self.record_jobs(jobset.num_jobs, est_s, n_bytes, gemms=1)

    def record_jobs(self, n_jobs: int, est_s: float, n_bytes: int = 0, *,
                    gemms: int = 0, steals: int = 0) -> None:
        """Fine-grained accounting for PARTIAL jobsets — the runtime books
        each engine's actual share of a split GEMM here."""
        with self._lock:
            self.gemms += gemms
            self.jobs += n_jobs
            self.busy_s += est_s
            self.bytes_moved += n_bytes
            self.steals += steals

    def record_runtime(self, *, wall_busy_s: float = 0.0,
                       idle_s: float = 0.0, quarantines: int = 0) -> None:
        """Measured worker-thread time + health events (live runtime
        only)."""
        with self._lock:
            self.wall_busy_s += wall_busy_s
            self.idle_s += idle_s
            self.quarantines += quarantines

    @property
    def busy_fraction(self) -> float:
        """Measured busy / (busy + idle) of this engine's runtime worker
        (the live analog of the simulator's Table-6 utilization).  Reads
        both fields under the lock: a concurrent ``record_runtime`` /
        ``merge`` must not tear the ratio (busy from one window, idle
        from another).  Note the worker books an idle window only AFTER
        its ``cond.wait`` returns, so a mid-window snapshot UNDERCOUNTS
        idle — it can never double-count it (regression-tested)."""
        with self._lock:
            denom = self.wall_busy_s + self.idle_s
            return self.wall_busy_s / denom if denom > 0 else 0.0

    def merge(self, other: "Telemetry") -> None:
        snap = other.snapshot()
        with self._lock:
            self.gemms += snap.gemms
            self.jobs += snap.jobs
            self.busy_s += snap.busy_s
            self.bytes_moved += snap.bytes_moved
            self.steals += snap.steals
            self.wall_busy_s += snap.wall_busy_s
            self.idle_s += snap.idle_s
            self.quarantines += snap.quarantines

    def snapshot(self) -> "Telemetry":
        with self._lock:
            return Telemetry(self.gemms, self.jobs, self.busy_s,
                             self.bytes_moved, self.steals,
                             self.wall_busy_s, self.idle_s,
                             self.quarantines)

    def reset(self) -> None:
        with self._lock:
            self.gemms = 0
            self.jobs = 0
            self.busy_s = 0.0
            self.bytes_moved = 0
            self.steals = 0
            self.wall_busy_s = 0.0
            self.idle_s = 0.0
            self.quarantines = 0


class Engine(abc.ABC):
    """One compute backend behind the unified dispatch surface.

    Subclasses implement :meth:`execute` (a 2-D GEMM with fused epilogue)
    and either pass a :class:`CostModel` to ``__init__`` or override
    :attr:`cost` for backend-dependent rates."""

    def __init__(self, name: str, capabilities: frozenset[str] | set[str],
                 cost: Optional[CostModel] = None):
        self.name = name
        self.capabilities = frozenset(capabilities)
        self._cost = cost
        self.telemetry = Telemetry()

    # ---- planning interface ---------------------------------------------
    @property
    def cost(self) -> CostModel:
        if self._cost is None:
            raise NotImplementedError(f"engine {self.name!r} has no cost model")
        return self._cost

    def estimate(self, jobset) -> float:
        """Seconds to run this JobSet here — the dispatcher's ranking key."""
        return self.cost.estimate(jobset)

    def available(self) -> bool:
        """Whether the engine can run on the current backend right now."""
        return True

    def recalibrate(self, observed_macs_per_s: float,
                    alpha: float = 0.5) -> float:
        """EMA-blend a measured MAC rate into this engine's cost model
        (steal-aware recalibration: the runtime feeds measured
        ``wall_busy_s`` back so LPT seeding adapts to observed speed).
        The blend starts from the CURRENT effective model (stored or
        backend-computed) and persists in ``_cost``; builtin engines with
        dynamic cost properties honor the stored model once set.  Returns
        the rate now in effect."""
        if observed_macs_per_s <= 0:
            return self.cost.macs_per_s
        current = self.cost
        blended = ((1.0 - alpha) * current.macs_per_s
                   + alpha * observed_macs_per_s)
        self._cost = dataclasses.replace(current, macs_per_s=blended)
        return blended

    def supports(self, required) -> bool:
        return frozenset(required) <= self.capabilities

    # ---- execution interface --------------------------------------------
    @abc.abstractmethod
    def execute(self, a, b, *, bias=None, activation: Callable | None = None,
                tile=(256, 256, 256), out_dtype=None, precision=None):
        """C = act(A @ B + bias) for 2-D ``a (m, k)`` and ``b (k, n)``."""

    def __repr__(self) -> str:
        caps = ",".join(sorted(self.capabilities))
        return f"<{type(self).__name__} {self.name!r} [{caps}]>"
