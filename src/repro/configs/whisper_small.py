"""whisper-small — enc-dec, conv audio frontend (STUB) [arXiv:2212.04356; unverified].

12L refers to the decoder stack; whisper-small pairs it with a 12-layer
encoder.  input_specs() supplies precomputed 1500-frame embeddings in place
of the conv frontend."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51865,
    encoder_layers=12, encoder_len=1500,
    act="gelu", frontend="audio",
    source="arXiv:2212.04356; unverified")
