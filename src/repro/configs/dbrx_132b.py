"""dbrx-132b — 16-expert top-4 fine-grained MoE [hf:databricks/dbrx-base; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab_size=100352,
    n_experts=16, top_k=4,
    param_dtype="bfloat16", optimizer="adafactor", fsdp=True,
    source="hf:databricks/dbrx-base; unverified")
