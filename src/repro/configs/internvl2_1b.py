"""internvl2-1b — InternViT frontend (STUB) + InternLM2/Qwen2-class LM backbone
[arXiv:2404.16821; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab_size=151655,
    frontend="vision",                  # input_specs() supplies patch embeddings
    source="arXiv:2404.16821; hf")
