"""The paper's seven benchmark CNNs (Table 2), modeled from their
Darknet/Caffe training configs.  Per-frame op counts match the paper's
reported GOPS-at-fps (Table 4) to within ~10-20%:

  MNIST  ~23 MOP/frame (paper: 2.15 GOPS @ 96.2 fps -> 22.3 MOP)
  CIFAR_full ~25 MOP/frame (paper: 1.67 GOPS @ 63.5 fps -> 26.3 MOP)
"""

from __future__ import annotations

from repro.models.cnn import CNNConfig

# ("conv", cout, k, stride, pad) | ("pool", size) | ("fc", n)

MNIST = CNNConfig(
    name="MNIST", input_hw=28, cin=1, layers=(
        ("conv", 32, 5, 1, 2), ("pool", 2),
        ("conv", 64, 5, 1, 2), ("pool", 2),
        ("fc", 256), ("fc", 10),
    ))

CIFAR_FULL = CNNConfig(
    name="CIFAR_full", input_hw=32, cin=3, layers=(
        ("conv", 32, 5, 1, 2), ("pool", 2),
        ("conv", 32, 5, 1, 2), ("pool", 2),
        ("conv", 64, 5, 1, 2), ("pool", 2),
        ("fc", 10),
    ))

CIFAR_ALEX = CNNConfig(
    name="CIFAR_Alex", input_hw=32, cin=3, layers=(
        ("conv", 32, 5, 1, 2), ("pool", 2),
        ("conv", 32, 5, 1, 2), ("pool", 2),
        ("conv", 64, 5, 1, 2), ("pool", 2),
        ("fc", 64), ("fc", 10),
    ))

CIFAR_ALEX_PLUS = CNNConfig(
    name="CIFAR_Alex+", input_hw=32, cin=3, layers=(
        ("conv", 64, 5, 1, 2), ("pool", 2),
        ("conv", 64, 5, 1, 2), ("pool", 2),
        ("conv", 128, 5, 1, 2), ("pool", 2),
        ("fc", 128), ("fc", 10),
    ))

CIFAR_DARKNET = CNNConfig(
    name="CIFAR_Darknet", input_hw=32, cin=3, layers=(
        ("conv", 32, 3, 1, 1), ("pool", 2),
        ("conv", 64, 3, 1, 1), ("pool", 2),
        ("conv", 128, 3, 1, 1),
        ("conv", 128, 3, 1, 1), ("pool", 2),
        ("fc", 10),
    ))

SVHN = CNNConfig(
    name="SVHN", input_hw=32, cin=3, layers=(
        ("conv", 32, 5, 1, 2), ("pool", 2),
        ("conv", 32, 5, 1, 2), ("pool", 2),
        ("conv", 64, 5, 1, 2), ("pool", 2),
        ("fc", 128), ("fc", 10),
    ))

MPCNN = CNNConfig(
    name="MPCNN", input_hw=32, cin=1, layers=(
        ("conv", 16, 5, 1, 2), ("pool", 2),
        ("conv", 32, 5, 1, 2), ("pool", 2),
        ("conv", 64, 5, 1, 2), ("pool", 2),
        ("fc", 64), ("fc", 10),
    ))

PAPER_CNNS = {c.name: c for c in (
    CIFAR_DARKNET, CIFAR_ALEX, CIFAR_ALEX_PLUS, CIFAR_FULL,
    MNIST, SVHN, MPCNN)}
