"""zamba2-2.7b — Mamba2 backbone + shared attention block [arXiv:2411.15242; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    attn_every=6,                      # one shared attn+MLP block per 6 mamba blocks
    act="gelu",
    source="arXiv:2411.15242; hf (hybrid: Mamba2 + shared attn blocks)")
