"""ArchConfig: the declarative architecture description (the Synergy
"network configuration file" of Fig 1/8, adapted to LM families), plus the
assigned input-shape set."""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

__all__ = ["ArchConfig", "ShapeCell", "SHAPES", "reduced"]

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    act: str = "silu"            # 'silu' (SwiGLU) | 'gelu' (GeGLU)
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    # hybrid (zamba2): one SHARED attention+MLP block applied every k layers
    attn_every: int = 0
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_len: int = 1500      # whisper 30 s @ 50 Hz after conv frontend
    # modality frontend stub: 'none' | 'audio' | 'vision'
    frontend: str = "none"
    # numerics / memory policy (per-arch defaults; launch can override)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    cache_dtype: str = ""        # KV-cache storage ('' -> compute dtype;
                                 # 'int8' for quantized decode, §Perf B2)
    optimizer: str = "adamw"     # 'adamw' | 'adafactor' (giant archs)
    fsdp: bool = False           # shard params/opt over the data axis
    remat: bool = True
    source: str = ""             # provenance note

    # ---- derived ----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k? (SSM/hybrid only — §DESIGN)"""
        return self.family in ("ssm", "hybrid")

    @property
    def takes_embeddings(self) -> bool:
        """Modality-frontend archs consume precomputed embeddings (stub)."""
        return self.frontend != "none"

    @property
    def param_jdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def compute_jdtype(self):
        return jnp.dtype(self.compute_dtype)

    def n_params(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6·N·D)."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        mlp_dense = 3 * d * self.d_ff
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        if self.family == "moe":
            mlp = self.n_experts * mlp_dense + d * self.n_experts  # + router
            per_layer = attn + mlp
            n = self.n_layers * per_layer
        elif self.family == "ssm":
            per_layer = self._ssm_block_params()
            n = self.n_layers * per_layer
        elif self.family == "hybrid":
            n_groups = self.n_layers // max(1, self.attn_every)
            shared = attn + mlp_dense
            n = self.n_layers * self._ssm_block_params() + shared
        elif self.family == "audio":
            dec = self.n_layers * (attn * 2 + mlp_dense)  # self+cross attn
            enc = self.encoder_layers * (attn + mlp_dense)
            n = dec + enc
        else:
            n = self.n_layers * (attn + mlp_dense)
        return n + emb + self.n_layers * 2 * d  # + norms

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        hd = self.resolved_head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        mlp_active = 3 * d * self.d_ff * self.top_k + d * self.n_experts
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + mlp_active + 2 * d) + emb

    def _ssm_block_params(self) -> int:
        d, di, n = self.d_model, self.d_inner, self.ssm_state
        h = self.ssm_heads
        in_proj = d * (2 * di + 2 * n + h)
        return in_proj + di * d + h + di  # + out_proj + A + D


# ---------------------------------------------------------------------------
# Input-shape cells (assigned set; identical across the 10 archs)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ArchConfig, *, n_layers: int = 2, d_model: int = 64,
            n_heads: int = 4, d_ff: int = 128, vocab: int = 512,
            n_experts: int | None = None) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    kv = max(1, min(cfg.n_kv_heads, n_heads) if cfg.n_kv_heads else n_heads)
    while n_heads % kv:
        kv -= 1
    ne = n_experts if n_experts is not None else (4 if cfg.n_experts else 0)
    attn_every = 2 if cfg.attn_every else 0
    return dataclasses.replace(
        cfg, n_layers=n_layers, d_model=d_model, n_heads=n_heads,
        n_kv_heads=kv, d_ff=d_ff, vocab_size=vocab, head_dim=0,
        n_experts=ne, top_k=min(cfg.top_k, ne) if ne else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64, ssm_chunk=16,
        attn_every=attn_every,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_len=24 if cfg.encoder_layers else 1500,
        param_dtype="float32", compute_dtype="float32", fsdp=False)
