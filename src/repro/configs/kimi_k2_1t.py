"""kimi-k2-1t-a32b — trillion-param MoE, 384 experts top-8 [arXiv:2501.kimi2; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab_size=163840,
    n_experts=384, top_k=8,
    param_dtype="bfloat16", optimizer="adafactor", fsdp=True,
    source="arXiv:2501.kimi2 paper-table; unverified")
