"""Architecture registry: the 10 assigned archs + the paper's 7 CNNs."""

from .base import ArchConfig, ShapeCell, SHAPES, reduced
from .zamba2_2p7b import CONFIG as ZAMBA2_2P7B
from .dbrx_132b import CONFIG as DBRX_132B
from .kimi_k2_1t import CONFIG as KIMI_K2_1T
from .internvl2_1b import CONFIG as INTERNVL2_1B
from .internlm2_20b import CONFIG as INTERNLM2_20B
from .granite_3_2b import CONFIG as GRANITE_3_2B
from .phi3_medium_14b import CONFIG as PHI3_MEDIUM_14B
from .gemma_7b import CONFIG as GEMMA_7B
from .mamba2_130m import CONFIG as MAMBA2_130M
from .whisper_small import CONFIG as WHISPER_SMALL
from .paper_cnns import PAPER_CNNS

ARCHS: dict[str, ArchConfig] = {c.name: c for c in (
    ZAMBA2_2P7B, DBRX_132B, KIMI_K2_1T, INTERNVL2_1B, INTERNLM2_20B,
    GRANITE_3_2B, PHI3_MEDIUM_14B, GEMMA_7B, MAMBA2_130M, WHISPER_SMALL,
)}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]
