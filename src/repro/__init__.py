"""repro: Synergy (HW/SW co-designed high-throughput CNN inference, 2018)
reproduced and scaled as a multi-pod JAX training/serving framework.

Core idea preserved: decompose all heavy compute into uniform tile JOBS
behind fixed network-agnostic engines (Pallas kernels), balance jobs across
heterogeneous compute groups at runtime (work stealing -> between-step
rebalancing), and pipeline frames/requests for throughput."""

__version__ = "1.0.0"
