"""Span tracer: a lock-cheap, ring-buffered event recorder.

Every execution layer (work-stealing runtime, graph scheduler, serving
loop, virtual-time sim) emits the SAME small vocabulary of typed events
(:data:`EVENT_KINDS`) onto named *tracks* — one track per engine worker
plus ``manager`` / ``serving`` / ``admission`` / ``graph`` tracks — so a
live trace and a :class:`~repro.soc.simrt.SimRuntime` trace are directly
diffable.

Hot-path design: ``emit()`` appends to a *thread-local* list (no lock);
cells are flushed into the shared bounded ring under one lock every
``flush_every`` events and on ``events()`` / export.  A disabled tracer
is simply ``None`` at the instrumentation site — the guard is one
attribute load, so tracing off costs nothing and cannot perturb
scheduling.

Export is Chrome/Perfetto ``trace_event`` JSON: ``panel_start`` /
``panel_end`` pairs become ``"X"`` complete events with durations, every
other kind becomes an ``"i"`` instant, and ``"M"`` metadata events name
the per-track rows so the file loads directly in ``chrome://tracing`` or
https://ui.perfetto.dev.
"""

from __future__ import annotations

import itertools
import json
import threading
from contextlib import contextmanager
from time import perf_counter

#: the closed event vocabulary shared by live runtime, graph scheduler,
#: serving loop, and the virtual-time sim twin
EVENT_KINDS = frozenset({
    "panel_start", "panel_end",          # one engine executing one panel
    "steal", "seed", "enqueue", "dequeue",
    "graph_node_ready", "graph_node_done", "graph_node_cancelled",
    "graph_node_retry",
    "admission", "shed",
    "quarantine", "readmit",
    "deadline_hit", "deadline_miss",
    "dispatch",
    "fault_injected", "panel_retry",     # fault-injection + recovery layer
    "worker_death", "orphan_reseed",
    "journal", "snapshot", "restore",    # durable-serving layer
    "drain",
})

#: kinds exported as paired "X" complete events (the rest are instants)
_SPAN_STARTS = {"panel_start"}
_SPAN_ENDS = {"panel_end"}

_seq = itertools.count()        # CPython-atomic global ordering tiebreak


class TraceEvent:
    """One recorded event: ``(ts, kind, track, dur, tags)``.

    ``ts`` is seconds on the tracer's clock (``time.perf_counter`` for
    live runs, virtual seconds for sim runs); ``dur`` is only set on
    span-shaped events; ``tags`` is a small dict of identifying context
    (jobset, rid, tenant, priority, victim, ...).
    """

    __slots__ = ("ts", "kind", "track", "dur", "tags", "seq")

    def __init__(self, ts, kind, track, dur=None, tags=None, seq=None):
        self.ts = ts
        self.kind = kind
        self.track = track
        self.dur = dur
        self.tags = tags or {}
        self.seq = next(_seq) if seq is None else seq

    def to_dict(self) -> dict:
        d = {"ts": self.ts, "kind": self.kind, "track": self.track}
        if self.dur is not None:
            d["dur"] = self.dur
        if self.tags:
            d["tags"] = self.tags
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TraceEvent":
        return cls(d["ts"], d["kind"], d["track"], d.get("dur"),
                   dict(d.get("tags", {})))

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"TraceEvent({self.ts:.6f}, {self.kind!r}, {self.track!r},"
                f" dur={self.dur}, tags={self.tags})")


class Tracer:
    """Bounded in-memory event recorder with thread-local write buffers.

    >>> tr = Tracer(capacity=4096)
    >>> tr.emit("steal", "F-PE", victim="S-PE", jobset="step0")
    >>> tr.export_chrome_trace("results/run.json")
    """

    def __init__(self, capacity: int = 65536, *, clock=perf_counter,
                 flush_every: int = 128):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.clock = clock
        self.flush_every = max(1, int(flush_every))
        self._lock = threading.Lock()
        self._ring: list[TraceEvent] = []       # bounded under _lock
        self._dropped = 0
        self._tls = threading.local()
        self._cells: list[list[TraceEvent]] = []    # every live TLS cell

    # ------------------------------------------------------------ write
    def now(self) -> float:
        return self.clock()

    def emit(self, kind: str, track: str, *, ts: float | None = None,
             dur: float | None = None, **tags) -> None:
        """Record one event.  Lock-free except every ``flush_every``-th
        call on each thread (and first call, which registers the cell)."""
        ev = TraceEvent(self.clock() if ts is None else ts,
                        kind, track, dur, tags)
        cell = getattr(self._tls, "cell", None)
        if cell is None:
            cell = self._tls.cell = []
            with self._lock:
                self._cells.append(cell)
        cell.append(ev)
        if len(cell) >= self.flush_every:
            with self._lock:
                self._absorb_locked(cell)

    def span(self, base: str, track: str, ts: float, dur: float,
             **tags) -> None:
        """Emit a ``{base}_start`` / ``{base}_end`` pair with explicit
        stamps (both carry the same tags; the start carries ``dur``)."""
        self.emit(f"{base}_start", track, ts=ts, dur=dur, **tags)
        self.emit(f"{base}_end", track, ts=ts + dur, **tags)

    def _absorb_locked(self, cell: list) -> None:
        self._ring.extend(cell)
        del cell[:]
        excess = len(self._ring) - self.capacity
        if excess > 0:                      # ring semantics: keep newest
            del self._ring[:excess]
            self._dropped += excess

    # ------------------------------------------------------------- read
    def events(self) -> list[TraceEvent]:
        """Flush all thread-local cells and return the ring, oldest
        first, ordered by (ts, seq) so multi-thread output is stable."""
        with self._lock:
            for cell in self._cells:
                if cell:
                    self._absorb_locked(cell)
            out = list(self._ring)
        out.sort(key=lambda e: (e.ts, e.seq))
        return out

    @property
    def dropped(self) -> int:
        with self._lock:
            return self._dropped

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring) + sum(len(c) for c in self._cells)

    def clear(self) -> None:
        with self._lock:
            for cell in self._cells:
                del cell[:]
            self._ring.clear()
            self._dropped = 0

    def counts(self) -> dict[str, int]:
        """{kind: n} histogram of recorded events (flushes first)."""
        out: dict[str, int] = {}
        for ev in self.events():
            out[ev.kind] = out.get(ev.kind, 0) + 1
        return out

    # ----------------------------------------------------------- export
    def export_chrome_trace(self, path: str) -> int:
        """Write Chrome ``trace_event`` JSON; returns #trace events."""
        data = chrome_trace(self.events())
        with open(path, "w") as f:
            json.dump(data, f)
        return len(data["traceEvents"])


# --------------------------------------------------------------- export

def chrome_trace(events: list[TraceEvent]) -> dict:
    """Convert events to a Chrome ``trace_event`` dict.

    ``panel_start``/``panel_end`` pairs on one track fold into ``"X"``
    complete events; other kinds become ``"i"`` instants on their track;
    ``"M"`` metadata rows name each track.  Timestamps are microseconds
    from the earliest event (Chrome's epoch is arbitrary).
    """
    tids: dict[str, int] = {}
    out: list[dict] = []
    t0 = min((e.ts for e in events), default=0.0)

    def tid_of(track: str) -> int:
        tid = tids.get(track)
        if tid is None:
            tid = tids[track] = len(tids) + 1
        return tid

    open_spans: dict[tuple, list[TraceEvent]] = {}
    for ev in events:
        us = (ev.ts - t0) * 1e6
        tid = tid_of(ev.track)
        if ev.kind in _SPAN_STARTS:
            open_spans.setdefault((ev.track, ev.kind), []).append(ev)
            continue
        if ev.kind in _SPAN_ENDS:
            base = ev.kind[:-len("_end")]
            stack = open_spans.get((ev.track, base + "_start"))
            if stack:
                start = stack.pop()
                name = start.tags.get("jobset") or base
                out.append({
                    "name": str(name), "cat": base, "ph": "X",
                    "ts": (start.ts - t0) * 1e6,
                    "dur": max(ev.ts - start.ts, 0.0) * 1e6,
                    "pid": 0, "tid": tid,
                    "args": dict(start.tags, kind=base),
                })
            else:                               # eviction split the pair
                out.append({"name": base, "cat": base, "ph": "E",
                            "ts": us, "pid": 0, "tid": tid,
                            "args": dict(ev.tags, kind=ev.kind)})
            continue
        out.append({
            "name": ev.kind, "cat": ev.kind, "ph": "i", "s": "t",
            "ts": us, "pid": 0, "tid": tid,
            "args": dict(ev.tags, kind=ev.kind),
        })
    # unmatched starts (still running / end evicted) -> "B" begin events
    for (track, _kind), stack in open_spans.items():
        for start in stack:
            out.append({
                "name": str(start.tags.get("jobset") or "panel"),
                "cat": "panel", "ph": "B",
                "ts": (start.ts - t0) * 1e6, "pid": 0,
                "tid": tid_of(track),
                "args": dict(start.tags, kind=start.kind),
            })
    meta = [{"name": "process_name", "ph": "M", "pid": 0,
             "args": {"name": "repro-synergy"}}]
    for track, tid in sorted(tids.items(), key=lambda kv: kv[1]):
        meta.append({"name": "thread_name", "ph": "M", "pid": 0,
                     "tid": tid, "args": {"name": track}})
    out.sort(key=lambda d: d["ts"])
    return {"traceEvents": meta + out, "displayTimeUnit": "ms",
            "otherData": {"source": "repro.obs.trace"}}


def load_chrome_trace(path: str) -> list[TraceEvent]:
    """Parse an exported Chrome trace back into :class:`TraceEvent`s.

    ``"X"`` complete events unfold into a ``panel_start``/``panel_end``
    pair; instants map back to their recorded kind.  Timestamps come
    back in seconds relative to the export epoch — fine for replay
    invariants, not for diffing against the original absolute stamps.
    """
    with open(path) as f:
        data = json.load(f)
    names: dict[int, str] = {}
    for d in data["traceEvents"]:
        if d.get("ph") == "M" and d.get("name") == "thread_name":
            names[d["tid"]] = d["args"]["name"]
    out: list[TraceEvent] = []
    for d in data["traceEvents"]:
        ph = d.get("ph")
        if ph == "M":
            continue
        track = names.get(d.get("tid"), str(d.get("tid")))
        ts = d["ts"] / 1e6
        tags = {k: v for k, v in d.get("args", {}).items() if k != "kind"}
        if ph == "X":
            dur = d.get("dur", 0.0) / 1e6
            base = d.get("cat", "panel")
            out.append(TraceEvent(ts, base + "_start", track, dur, tags))
            out.append(TraceEvent(ts + dur, base + "_end", track, None,
                                  dict(tags)))
        elif ph in ("i", "I"):
            kind = d.get("args", {}).get("kind", d.get("name"))
            out.append(TraceEvent(ts, kind, track, None, tags))
        elif ph == "B":
            out.append(TraceEvent(ts, d["args"].get("kind", "panel_start"),
                                  track, None, tags))
        elif ph == "E":
            out.append(TraceEvent(ts, d["args"].get("kind", "panel_end"),
                                  track, None, tags))
    out.sort(key=lambda e: (e.ts, e.seq))
    return out


def validate_events(events: list[TraceEvent], *,
                    engines: set[str] | None = None) -> list[str]:
    """Replay-invariant checks; returns a list of violations (empty =
    valid).  Checked: every kind is in :data:`EVENT_KINDS`; every
    ``panel_start`` has a matching ``panel_end`` on the SAME track (and
    vice versa); ``steal`` events name a real victim engine distinct
    from the thief's track."""
    errs: list[str] = []
    open_panels: dict[str, int] = {}
    for ev in events:
        if ev.kind not in EVENT_KINDS:
            errs.append(f"unknown event kind {ev.kind!r} on {ev.track!r}")
        if ev.kind == "panel_start":
            open_panels[ev.track] = open_panels.get(ev.track, 0) + 1
        elif ev.kind == "panel_end":
            n = open_panels.get(ev.track, 0)
            if n <= 0:
                errs.append(f"panel_end without panel_start on "
                            f"track {ev.track!r} at ts={ev.ts:.6f}")
            else:
                open_panels[ev.track] = n - 1
        elif ev.kind == "steal":
            victim = ev.tags.get("victim")
            if not victim:
                errs.append(f"steal without victim tag at ts={ev.ts:.6f}")
            elif victim == ev.track:
                errs.append(f"steal from self on track {ev.track!r}")
            elif engines is not None and victim not in engines:
                errs.append(f"steal victim {victim!r} is not a known "
                            f"engine (have {sorted(engines)})")
            if engines is not None and ev.track not in engines:
                errs.append(f"steal on non-engine track {ev.track!r}")
    for track, n in open_panels.items():
        if n:
            errs.append(f"{n} unmatched panel_start on track {track!r}")
    return errs


# ------------------------------------------------------- default tracer
#: process-global default: `SynergyRuntime` falls back to this when no
#: tracer is passed, so `benchmarks/run.py --trace` can capture runtimes
#: constructed deep inside benchmark bodies.  ``None`` = tracing off.
_default: Tracer | None = None


def set_default_tracer(tracer: Tracer | None) -> None:
    global _default
    _default = tracer


def get_default_tracer() -> Tracer | None:
    return _default


@contextmanager
def trace_scope(tracer: Tracer):
    """Install ``tracer`` as the process default for the ``with`` body."""
    prev = _default
    set_default_tracer(tracer)
    try:
        yield tracer
    finally:
        set_default_tracer(prev)
