"""Metrics: counters / gauges / fixed-bucket histograms + Prometheus text.

Design rule (ISSUE 8): the runtime and server do NOT maintain parallel
counters for the registry.  ``Telemetry`` / ``ServeStats`` /
``TenantStats`` / ``rt.stats()`` stay the single source of truth and the
registry is populated from those *views* at collect time
(:func:`collect_runtime` / :func:`collect_server` /
:func:`collect_calibrator`, all invoked by :func:`render_prometheus`).
The only per-observation instrument is the per-tenant queue-wait
histogram, which the server feeds behind a single attribute check — its
``observe()`` is allocation-free (fixed bucket list, bisect index).

>>> from repro.obs.metrics import render_prometheus
>>> print(render_prometheus(runtime=rt, server=srv))
"""

from __future__ import annotations

import math
import re
import threading
from bisect import bisect_left

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

#: default latency buckets (seconds) — tuned for queue waits that span
#: sub-millisecond sim stamps up to multi-second overload backlogs
DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
                   1.0, 2.5, 5.0, 10.0)


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _fmt_labels(names, values) -> str:
    if not names:
        return ""
    pairs = ",".join(
        f'{k}="{_escape(str(v))}"' for k, v in zip(names, values))
    return "{" + pairs + "}"


def _escape(s: str) -> str:
    return s.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


class _Child:
    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Counter(_Child):
    """Monotonic count.  ``set_total`` exists for view-fed collection
    (the authoritative count lives in Telemetry/ServeStats)."""

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._value += v

    def set_total(self, v: float) -> None:
        with self._lock:
            self._value = max(self._value, float(v))


class Gauge(_Child):
    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, v: float = 1.0) -> None:
        with self._lock:
            self._value += v

    def dec(self, v: float = 1.0) -> None:
        with self._lock:
            self._value -= v


class Histogram:
    """Fixed-bucket cumulative histogram.  ``observe`` touches a
    preallocated count list via one bisect — no allocation, one lock."""

    __slots__ = ("_lock", "buckets", "_counts", "_sum", "_count")

    def __init__(self, buckets=DEFAULT_BUCKETS):
        b = sorted(float(x) for x in buckets)
        if not b:
            raise ValueError("need at least one bucket bound")
        self.buckets = tuple(b)
        self._lock = threading.Lock()
        self._counts = [0] * (len(b) + 1)       # +1 for the +Inf bucket
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        i = bisect_left(self.buckets, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    def snapshot(self) -> tuple[list[int], float, int]:
        with self._lock:
            return list(self._counts), self._sum, self._count

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum


class _Family:
    """One named metric family; holds labeled children."""

    def __init__(self, name: str, help: str, kind: str, labelnames=(),
                 buckets=DEFAULT_BUCKETS):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for ln in labelnames:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r}")
        self.name = name
        self.help = help
        self.kind = kind
        self.labelnames = tuple(labelnames)
        self._buckets = buckets
        self._lock = threading.Lock()
        self._children: dict[tuple, object] = {}

    def _make_child(self):
        if self.kind == "counter":
            return Counter()
        if self.kind == "gauge":
            return Gauge()
        return Histogram(self._buckets)

    def labels(self, *values, **kv):
        if kv:
            values = tuple(kv[ln] for ln in self.labelnames)
        values = tuple(str(v) for v in values)
        if len(values) != len(self.labelnames):
            raise ValueError(f"{self.name}: expected labels "
                             f"{self.labelnames}, got {values}")
        child = self._children.get(values)
        if child is None:
            with self._lock:
                child = self._children.setdefault(values,
                                                  self._make_child())
        return child

    def _default_child(self):
        return self.labels()

    # unlabeled convenience passthroughs
    def inc(self, v: float = 1.0):
        self._default_child().inc(v)

    def set(self, v: float):
        self._default_child().set(v)

    def set_total(self, v: float):
        self._default_child().set_total(v)

    def observe(self, v: float):
        self._default_child().observe(v)

    @property
    def value(self) -> float:
        return self._default_child().value

    def render(self) -> list[str]:
        lines = [f"# HELP {self.name} {self.help or self.name}",
                 f"# TYPE {self.name} {self.kind}"]
        with self._lock:
            items = sorted(self._children.items())
        for values, child in items:
            if self.kind == "histogram":
                counts, total, n = child.snapshot()
                cum = 0
                for bound, c in zip(child.buckets + (math.inf,), counts):
                    cum += c
                    lines.append(
                        f"{self.name}_bucket"
                        f"{_fmt_labels(self.labelnames + ('le',), values + (_fmt(bound),))}"
                        f" {cum}")
                lines.append(f"{self.name}_sum"
                             f"{_fmt_labels(self.labelnames, values)}"
                             f" {_fmt(total)}")
                lines.append(f"{self.name}_count"
                             f"{_fmt_labels(self.labelnames, values)}"
                             f" {n}")
            else:
                lines.append(f"{self.name}"
                             f"{_fmt_labels(self.labelnames, values)}"
                             f" {_fmt(child.value)}")
        return lines


class MetricsRegistry:
    """Named families, rendered in registration order."""

    def __init__(self):
        self._lock = threading.Lock()
        self._families: dict[str, _Family] = {}

    def _get(self, name, help, kind, labelnames, buckets=DEFAULT_BUCKETS):
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = _Family(name, help, kind, labelnames, buckets)
                self._families[name] = fam
            elif fam.kind != kind or fam.labelnames != tuple(labelnames):
                raise ValueError(
                    f"metric {name!r} re-registered with different "
                    f"type/labels ({fam.kind}{fam.labelnames} vs "
                    f"{kind}{tuple(labelnames)})")
            return fam

    def counter(self, name, help="", labelnames=()):
        return self._get(name, help, "counter", labelnames)

    def gauge(self, name, help="", labelnames=()):
        return self._get(name, help, "gauge", labelnames)

    def histogram(self, name, help="", labelnames=(),
                  buckets=DEFAULT_BUCKETS):
        return self._get(name, help, "histogram", labelnames, buckets)

    def render(self) -> str:
        with self._lock:
            fams = list(self._families.values())
        lines: list[str] = []
        for fam in fams:
            lines.extend(fam.render())
        return "\n".join(lines) + "\n" if lines else ""

    def clear(self) -> None:
        with self._lock:
            self._families.clear()


#: process-global registry used by `render_prometheus()` by default
REGISTRY = MetricsRegistry()


# ----------------------------------------------------------- collectors

def collect_runtime(rt, registry: MetricsRegistry = REGISTRY) -> None:
    """Feed the registry from ``rt.stats()`` + per-engine Telemetry
    (views only — nothing here is bookkept twice)."""
    st = rt.stats()
    eng = registry.gauge("repro_engine_queue_depth",
                         "queued panels per engine worker", ("engine",))
    jobs = registry.counter("repro_engine_jobs_total",
                            "panels executed per engine", ("engine",))
    steals = registry.counter("repro_engine_steals_total",
                              "panels stolen BY this engine", ("engine",))
    busy = registry.gauge("repro_engine_busy_fraction",
                          "wall busy fraction per engine", ("engine",))
    health = registry.gauge("repro_engine_health",
                            "EMA health score (1.0 = nominal)", ("engine",))
    quar = registry.gauge("repro_engine_quarantined",
                          "1 if the engine is quarantined", ("engine",))
    for name, es in st["engines"].items():
        eng.labels(name).set(es["queued"])
        jobs.labels(name).set_total(es["jobs"])
        steals.labels(name).set_total(es["steals"])
        busy.labels(name).set(es["busy_fraction"])
        if es.get("health") is not None:
            health.labels(name).set(es["health"])
        quar.labels(name).set(1.0 if es.get("quarantined") else 0.0)
    registry.gauge("repro_runtime_steal_rate",
                   "fraction of executed panels that were stolen").set(
        st["total_steals"] / st["total_jobs"] if st["total_jobs"] else 0.0)
    registry.counter("repro_runtime_submissions_total",
                     "jobset submissions").set_total(st["submissions"])
    registry.counter("repro_runtime_rebalances_total",
                     "hotplug/quarantine queue rebalances").set_total(
        st["rebalances"])
    registry.counter("repro_runtime_quarantines_total",
                     "self-healing quarantine trips").set_total(
        st["quarantines"])
    registry.counter("repro_runtime_retries_total",
                     "panel re-executions under the RetryPolicy").set_total(
        st.get("retries", 0))
    registry.counter("repro_runtime_worker_deaths_total",
                     "engine workers declared dead by the heartbeat "
                     "monitor").set_total(st.get("worker_deaths", 0))
    registry.counter("repro_runtime_orphan_reseeds_total",
                     "orphaned panels re-seeded after a worker "
                     "death").set_total(st.get("orphan_reseeds", 0))


def collect_server(srv, registry: MetricsRegistry = REGISTRY) -> None:
    """Feed the registry from ``ServeStats`` / ``TenantStats`` views and
    live queue/in-flight occupancy."""
    s = srv.stats
    registry.counter("repro_serve_tokens_total",
                     "decode tokens produced").set_total(s.tokens_out)
    registry.counter("repro_serve_prefills_total",
                     "prefills completed").set_total(s.prefills)
    registry.counter("repro_serve_decode_steps_total",
                     "decode steps executed").set_total(s.decode_steps)
    registry.counter("repro_serve_rejected_total",
                     "admission rejections").set_total(s.admission_rejects)
    registry.counter("repro_serve_shed_engagements_total",
                     "load-shed ladder engagements").set_total(
        s.shed_engagements)
    registry.counter("repro_serve_replayed_tokens_total",
                     "tokens recomputed from the journal on restore "
                     "(already delivered; not throughput)").set_total(
        getattr(s, "replayed_tokens", 0))
    registry.counter("repro_serve_snapshots_total",
                     "crash-consistent snapshots taken").set_total(
        getattr(s, "snapshots", 0))
    registry.counter("repro_serve_restores_total",
                     "successful snapshot+journal restores").set_total(
        getattr(s, "restores", 0))
    registry.gauge("repro_serve_shed_level",
                   "current shed ladder level").set(
        getattr(srv, "_shed_level", 0))
    registry.gauge("repro_serve_inflight",
                   "async in-flight window occupancy").set(
        len(getattr(srv, "_inflight", ()) or ()))
    registry.gauge("repro_serve_inflight_peak",
                   "peak in-flight window occupancy").set(s.inflight_peak)
    registry.gauge("repro_serve_pending",
                   "requests queued behind admission").set(
        len(srv.pending))
    tn = s.tenants or {}
    if tn:
        tok = registry.counter("repro_tenant_tokens_total",
                               "tokens per tenant", ("tenant",))
        adm = registry.counter("repro_tenant_admitted_total",
                               "admissions per tenant", ("tenant",))
        rej = registry.counter("repro_tenant_rejected_total",
                               "rejections per tenant", ("tenant",))
        wait = registry.counter("repro_tenant_queue_wait_seconds_total",
                                "cumulative admission queue wait",
                                ("tenant",))
        att = registry.gauge("repro_tenant_deadline_attainment",
                             "deadline hits / (hits+misses)", ("tenant",))
        for name, ts in sorted(tn.items()):
            tok.labels(name).set_total(ts.tokens_out)
            adm.labels(name).set_total(ts.admitted)
            rej.labels(name).set_total(ts.rejected)
            wait.labels(name).set_total(ts.queue_wait_s)
            if ts.deadline_hits + ts.deadline_misses:
                att.labels(name).set(ts.deadline_attainment)


def collect_calibrator(engine, registry: MetricsRegistry = REGISTRY) -> None:
    """Publish-count view over an engine's ``ActCalibrator.state()``:
    a shape is *published* once it has ``>= min_updates`` observations
    (i.e. ``scale_for`` starts returning a scale)."""
    cal = getattr(engine, "calibrator", None)
    if cal is None:
        return
    state = cal.state()
    published = sum(1 for sc in state.values()
                    if sc.updates >= cal.min_updates)
    registry.gauge("repro_calibrator_tracked_shapes",
                   "activation shapes under calibration",
                   ("engine",)).labels(engine.name).set(len(state))
    registry.gauge("repro_calibrator_published_shapes",
                   "shapes whose act scale is published",
                   ("engine",)).labels(engine.name).set(published)


def render_prometheus(*, runtime=None, server=None, engines=(),
                      registry: MetricsRegistry = REGISTRY) -> str:
    """Collect from the given views (if any) and render the registry in
    Prometheus text exposition format."""
    if runtime is not None:
        collect_runtime(runtime, registry)
        for eng in getattr(runtime, "engines", ()):
            collect_calibrator(eng, registry)
    if server is not None:
        collect_server(server, registry)
        if runtime is None and getattr(server, "runtime", None) is not None:
            collect_runtime(server.runtime, registry)
    for eng in engines:
        collect_calibrator(eng, registry)
    return registry.render()


# -------------------------------------------------------------- parsing

def parse_prometheus(text: str) -> dict[str, list[tuple[dict, float]]]:
    """Minimal exposition-format parser (used by tests + acceptance):
    ``{metric_name: [({label: value}, sample_value), ...]}``.  Raises
    ``ValueError`` on malformed lines."""
    out: dict[str, list[tuple[dict, float]]] = {}
    sample_re = re.compile(
        r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^}]*\})?\s+(\S+)$")
    label_re = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = sample_re.match(line)
        if not m:
            raise ValueError(f"malformed exposition line: {line!r}")
        name, labelblob, raw = m.groups()
        labels = {}
        if labelblob:
            labels = {k: v.replace('\\"', '"').replace("\\\\", "\\")
                      for k, v in label_re.findall(labelblob)}
        value = math.inf if raw == "+Inf" else float(raw)
        out.setdefault(name, []).append((labels, value))
    return out
