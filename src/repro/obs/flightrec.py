"""Flight recorder: post-mortem dumps without a re-run.

On a serving timeout (``ServeTimeoutError``), an admission rejection
(``AdmissionRejected``), a self-healing quarantine, a panel exhausting
its :class:`~repro.soc.faults.RetryPolicy` (reason ``retry_exhausted``:
the failed panel's jobset, attempt history and the engines it failed
on), or a worker declared dead by the heartbeat monitor (reason
``worker_death``: the dead engine plus its orphaned panel counts), the
recorder snapshots the tracer's last ``last_n`` events plus whatever
``stats()`` views the caller hands it into a timestamped JSON file under
``results/flightrec-*.json``.  Dumps are best-effort (a full disk must
never take down serving) and rate-capped (``max_dumps``) so a
quarantine storm can't fill the results directory.
"""

from __future__ import annotations

import itertools
import json
import os
import threading
import time


def _jsonable(obj):
    """Best-effort conversion of stats snapshots to JSON-safe values."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple, set, frozenset)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    if hasattr(obj, "__dataclass_fields__"):
        return {k: _jsonable(getattr(obj, k))
                for k in obj.__dataclass_fields__}
    return repr(obj)


class FlightRecorder:
    """Dump ``(reason, last-N events, stats snapshot)`` to JSON.

    >>> rec = FlightRecorder(tracer, dir="results", last_n=512)
    >>> rec.dump("serve_timeout", stats=rt.stats(), context={"rid": 3})
    'results/flightrec-20260808-120000-0-serve_timeout.json'
    """

    def __init__(self, tracer=None, *, dir: str = "results",
                 last_n: int = 512, max_dumps: int = 16,
                 prefix: str = "flightrec"):
        self.tracer = tracer
        self.dir = dir
        self.last_n = int(last_n)
        self.max_dumps = int(max_dumps)
        self.prefix = prefix
        self.dumps: list[str] = []      # paths written, oldest first
        self.suppressed = 0             # dumps skipped past the cap
        self._lock = threading.Lock()
        self._seq = itertools.count()

    def dump(self, reason: str, *, stats=None, context=None) -> str | None:
        """Write one dump; returns the path or ``None`` (capped/failed).
        Never raises — the recorder must not add failure modes to the
        paths it observes."""
        with self._lock:
            if len(self.dumps) >= self.max_dumps:
                self.suppressed += 1
                return None
            seq = next(self._seq)
        try:
            events = self.tracer.events() if self.tracer is not None else []
            payload = {
                "reason": reason,
                "stamp_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                           time.gmtime()),
                "context": _jsonable(context or {}),
                "stats": _jsonable(stats or {}),
                "n_events": min(len(events), self.last_n),
                "dropped_events": (self.tracer.dropped
                                   if self.tracer is not None else 0),
                "events": [e.to_dict() for e in events[-self.last_n:]],
            }
            safe = "".join(c if c.isalnum() or c in "-_" else "_"
                           for c in reason)[:40]
            stamp = time.strftime("%Y%m%d-%H%M%S")
            path = os.path.join(
                self.dir, f"{self.prefix}-{stamp}-{seq}-{safe}.json")
            os.makedirs(self.dir, exist_ok=True)
            with open(path, "w") as f:
                json.dump(payload, f)
        except OSError:
            return None
        with self._lock:
            self.dumps.append(path)
        return path
