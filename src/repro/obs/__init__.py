"""repro.obs — observability for the heterogeneous runtime.

Three pillars (ISSUE 8):

* :mod:`repro.obs.trace` — a lock-cheap, ring-buffered span tracer with
  typed events and Chrome/Perfetto ``trace_event`` export.
* :mod:`repro.obs.metrics` — counters / gauges / fixed-bucket histograms
  with Prometheus text exposition, fed at collect time from the existing
  ``Telemetry`` / ``ServeStats`` / ``TenantStats`` views (no double
  bookkeeping on the hot path).
* :mod:`repro.obs.flightrec` — a flight recorder that dumps the last N
  events + a runtime ``stats()`` snapshot to ``results/flightrec-*.json``
  on timeouts, admission rejections, and quarantines.

The package deliberately imports nothing from ``repro.soc`` /
``repro.core`` / ``repro.engines`` so every execution layer can import
it without cycles.
"""

from repro.obs.flightrec import FlightRecorder
from repro.obs.metrics import (MetricsRegistry, REGISTRY, parse_prometheus,
                               render_prometheus)
from repro.obs.trace import (EVENT_KINDS, TraceEvent, Tracer,
                             get_default_tracer, load_chrome_trace,
                             set_default_tracer, trace_scope,
                             validate_events)

__all__ = [
    "EVENT_KINDS", "FlightRecorder", "MetricsRegistry", "REGISTRY",
    "TraceEvent", "Tracer", "get_default_tracer", "load_chrome_trace",
    "parse_prometheus", "render_prometheus", "set_default_tracer",
    "trace_scope", "validate_events",
]
