"""Deterministic synthetic data pipeline: sharded token / embedding /
frame streams with background prefetch.

Real-cluster posture: each host materializes ONLY its addressable shard of
the global batch (via jax.make_array_from_callback), the stream is
reproducible from (seed, step) — so a restarted / re-meshed job replays the
exact same data order (fault-tolerance invariant tested in
tests/test_checkpoint.py) — and an N-deep prefetch thread overlaps host
data generation with device compute.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeCell

__all__ = ["synthetic_batches", "prefetch", "make_batch"]


def _rng_for(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def make_batch(cfg: ArchConfig, cell: ShapeCell, seed: int, step: int,
               shardings: dict | None = None) -> dict[str, Any]:
    """One global batch, deterministic in (seed, step)."""
    rng = _rng_for(seed, step)
    b, s = cell.global_batch, cell.seq_len
    batch: dict[str, Any] = {}

    def sharded(name: str, arr: np.ndarray):
        if shardings and name in shardings:
            shd = shardings[name]
            return jax.make_array_from_callback(
                arr.shape, shd, lambda idx: arr[idx])
        return jnp.asarray(arr)

    # a deterministic LM-able stream: token t+1 derived from t (so the loss
    # is learnable, used by examples/train_lm.py)
    toks = rng.integers(0, cfg.vocab_size, size=(b, s + 1), dtype=np.int32)
    toks[:, 1:] = (toks[:, :-1] * 31 + 7) % max(2, cfg.vocab_size // 4)
    if cfg.takes_embeddings:
        emb = rng.standard_normal((b, s, cfg.d_model), dtype=np.float32)
        batch["embeds"] = sharded("embeds", emb.astype(np.float32))
    else:
        batch["tokens"] = sharded("tokens", toks[:, :-1])
    if cfg.family == "audio":
        enc = rng.standard_normal((b, cfg.encoder_len, cfg.d_model),
                                  dtype=np.float32)
        batch["enc_embeds"] = sharded("enc_embeds", enc)
    batch["labels"] = sharded("labels", toks[:, 1:].astype(np.int32))
    return batch


def synthetic_batches(cfg: ArchConfig, cell: ShapeCell, *, seed: int = 0,
                      start_step: int = 0,
                      shardings: dict | None = None) -> Iterator[dict]:
    step = start_step
    while True:
        yield make_batch(cfg, cell, seed, step, shardings)
        step += 1


def prefetch(it: Iterator, depth: int = 2) -> Iterator:
    """Background-thread prefetch (overlap host datagen with device step)."""
    q: queue.Queue = queue.Queue(maxsize=depth)
    _END = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(_END)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is _END:
            return
        yield item
