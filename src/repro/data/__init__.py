from .pipeline import synthetic_batches, prefetch, make_batch
