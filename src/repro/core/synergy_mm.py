"""``synergy_mm`` — the composable tiled-MM operator (paper C1/C2).

Every dense GEMM in the framework is routed through :func:`synergy_matmul`.
It does three things:

  1. Registers the GEMM's :class:`~repro.core.job.JobSet` with the active
     :class:`SynergyTrace` (trace-time metadata: the job decomposition the
     schedulers, cost model, and roofline analysis operate on).
  2. Executes: under an active :func:`repro.soc.runtime_scope` the JobSet's
     tile jobs are SPLIT across the live engine pool and merged (work
     stealing balances the split; an ``engine=`` pin is demoted to a
     queue-affinity hint).  Otherwise it asks the
     :class:`~repro.engines.Dispatcher` for the best-capable registered
     :class:`~repro.engines.Engine` (XLA dot on CPU dry-runs, the Pallas
     ``tiled_mm`` kernel on TPU, or whatever the user registered) and runs
     the whole GEMM there.  The old ``impl='auto'|'xla'|'pallas'`` strings
     survive only as a deprecation shim over the engine lookup.
  3. Records per-engine telemetry (jobs, estimated busy seconds, bytes
     moved) on both the engine and the active trace.

The job abstraction is exactly the paper's: one job == one output tile of C,
zero-padded at borders so a single fixed-size engine serves every layer of
every network ("network-agnostic accelerators").

Telemetry semantics: ``synergy_matmul`` runs at JAX trace time, so counters
advance once per traced GEMM (per compilation), mirroring what
``SynergyTrace`` has always recorded — the static job decomposition, not
per-step execution counts.
"""

from __future__ import annotations

import contextlib
import dataclasses
import sys
import threading
import warnings
from typing import Callable, Optional, Union

import jax

from repro.engines import (CAP_GRAD, Engine, Telemetry, current_scope_engine,
                           dispatch_gemm)

from .job import JobSet

__all__ = ["SynergyTrace", "synergy_matmul", "current_trace", "DEFAULT_TILE"]

# MXU-aligned default tile for the TPU target; the paper-faithful TS=32
# baseline is exercised in benchmarks/EXPERIMENTS §Perf.
DEFAULT_TILE = (256, 256, 256)

_state = threading.local()

#: deprecation shim: legacy ``impl`` strings -> registered engine names
_IMPL_TO_ENGINE = {"auto": None, "xla": "xla", "pallas": "pallas"}


@dataclasses.dataclass
class SynergyTrace:
    """Collects the JobSets of every GEMM traced under this context, plus
    the per-engine telemetry of where the dispatcher routed them."""

    jobsets: list[JobSet] = dataclasses.field(default_factory=list)
    engine_stats: dict[str, Telemetry] = dataclasses.field(
        default_factory=dict)
    _next_layer_id: int = 0

    def add(self, m: int, n: int, k: int, tile, name: str) -> JobSet:
        js = JobSet.for_gemm(self._next_layer_id, m, n, k, tile, name=name)
        self._next_layer_id += 1
        self.jobsets.append(js)
        return js

    def record_engine(self, engine_name: str, js: JobSet,
                      est_s: float) -> None:
        self.engine_stats.setdefault(engine_name, Telemetry()).record(js,
                                                                      est_s)

    def record_runtime(self, accounting: dict) -> None:
        """Book a SynergyRuntime submission's per-engine shares: the split
        GEMM's jobs land on every engine that actually executed part of it
        (stolen jobs included), on the same cost-model busy basis.  The
        gemm itself counts ONCE, credited to the dominant executor, so
        ``sum(gemms) == len(jobsets)`` holds on both dispatch paths."""
        dominant = (max(accounting, key=lambda n: accounting[n]["jobs"])
                    if accounting else None)
        for name, acct in accounting.items():
            t = self.engine_stats.setdefault(name, Telemetry())
            t.record_jobs(acct["jobs"], acct["est_s"], acct["bytes"],
                          gemms=int(name == dominant),
                          steals=acct["steals"])

    @property
    def total_flops(self) -> int:
        return sum(js.total_flops for js in self.jobsets)

    @property
    def num_jobs(self) -> int:
        return sum(js.num_jobs for js in self.jobsets)

    @contextlib.contextmanager
    def activate(self):
        prev = getattr(_state, "trace", None)
        _state.trace = self
        try:
            yield self
        finally:
            _state.trace = prev


def current_trace() -> Optional[SynergyTrace]:
    return getattr(_state, "trace", None)


#: jax's forward-mode AD entry points: every differentiation API
#: (grad/vjp/jvp/linearize) funnels the callee's trace through one of
#: these frames in jax/_src/interpreters/ad.py
_AD_FRAME_NAMES = frozenset({"jvpfun", "jvp_subtrace", "linearize", "jvp"})


def _ad_machinery_on_stack() -> bool:
    """The pjit-jvp detection: ``grad(jit(f))`` differentiates the
    *jaxpr* of ``f``, so inside ``f`` only jit tracers are visible — the
    tracer walk in :func:`_under_grad_trace` cannot see the outer JVP
    trace.  But the TRACING of ``f`` still happens while the ad
    machinery's Python frames are live (pjit traces its callee from
    inside ``ad.jvpfun`` when the caller is differentiating), so walking
    the interpreter stack for those frames closes the gap.  Only runs at
    trace time (operands already known to be Tracers), so the walk costs
    nothing per executed step.

    Remaining limitation: a jaxpr traced OUTSIDE any grad context and
    later differentiated (``g = jit(f); g(x); grad(g)(x)`` reuses the
    cached trace) is routed before differentiation is known — such call
    sites should still pass ``job_class='train'``."""
    fr = sys._getframe(1)
    while fr is not None:
        code = fr.f_code
        if (code.co_name in _AD_FRAME_NAMES
                and code.co_filename.endswith("interpreters/ad.py")):
            return True
        fr = fr.f_back
    return False


def _under_grad_trace(*arrays) -> bool:
    """True when any operand is being traced for differentiation (JVP
    tracers — ``jax.grad``/``vjp``/``jvp``/``linearize`` all route through
    forward mode), or when a jit trace is being built FOR differentiation
    (``grad(jit(f))`` — see :func:`_ad_machinery_on_stack`).  This is the
    dispatch-level guard that keeps CAP_GRAD-free engines (int8
    quantized: round/clip kill the weight gradient; Pallas kernels
    without a VJP rule) off differentiated GEMMs even when no call site
    asked for grad-safety explicitly."""
    traced = False
    pending = [x for x in arrays if x is not None]
    while pending:
        x = pending.pop()
        if not isinstance(x, jax.core.Tracer):
            continue
        traced = True
        names = (type(x).__name__, type(getattr(x, "_trace", x)).__name__)
        if any("jvp" in n.lower() for n in names):
            return True
        # descend through wrapping tracers: JVP carries primal/tangent,
        # vmap's BatchTracer wraps its inner (possibly JVP) tracer in .val
        for attr in ("primal", "tangent", "val"):
            sub = getattr(x, attr, None)
            if sub is not None:
                pending.append(sub)
    return traced and _ad_machinery_on_stack()


def _resolve_impl_shim(impl: Optional[str],
                       engine: Union[str, Engine, None]):
    """Translate the legacy ``impl`` string into an engine lookup."""
    if impl is None:
        return engine
    warnings.warn(
        "synergy_matmul(impl=...) is deprecated; use engine=<registered "
        "engine name> or let the dispatcher pick (repro.engines)",
        DeprecationWarning, stacklevel=3)
    if engine is not None:
        return engine          # explicit engine wins over the legacy string
    try:
        return _IMPL_TO_ENGINE[impl]
    except KeyError:
        return impl            # maybe a registered engine name already


def synergy_matmul(a: jax.Array, b: jax.Array, *,
                   bias: jax.Array | None = None,
                   activation: Callable | None = None,
                   tile: tuple[int, int, int] | int = DEFAULT_TILE,
                   name: str = "",
                   engine: Union[str, Engine, None] = None,
                   impl: str | None = None,
                   job_class: str | None = None,
                   out_dtype=None,
                   precision=None) -> jax.Array:
    """C = act(A @ B + bias) through the Synergy tile-job abstraction.

    a: (..., m, k); b: (k, n).  ``engine``: a registered engine name (or
    instance); None lets the dispatcher rank capable engines by cost model.
    ``job_class``: one of :data:`repro.engines.JOB_CLASSES` ("decode",
    "prefill", "train") applying the precision-routing policy — decode
    prefers registered ``int8`` engines, prefill/train require grad-safe
    full-precision paths.  ``impl`` is the deprecated string spelling of
    the engine choice.
    """
    *lead, m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {a.shape} @ {b.shape}"
    engine = _resolve_impl_shim(impl, engine)
    if engine is None:
        engine = current_scope_engine()   # engine_scope() pin, if any

    # grad guard: a GEMM being differentiated may only land on CAP_GRAD
    # engines, whatever the job class said (an int8 pin under jax.grad is
    # a hard error, not a silent zero-gradient).
    require = (CAP_GRAD,) if _under_grad_trace(a, b, bias) else ()

    batch = 1
    for d in lead:
        batch *= d
    tr = current_trace()
    if tr is not None:
        js = tr.add(batch * m, n, k, tile, name=name or "gemm")
    else:
        js = JobSet.for_gemm(0, batch * m, n, k, tile, name=name or "gemm")

    # Runtime scope: split this GEMM's tile jobs across the live engine
    # pool and merge partials (work stealing balances the split).  An
    # engine pin becomes a queue-affinity HINT, not a hard route.  Under a
    # jit trace the arrays are Tracers the worker threads cannot touch, so
    # traced call sites keep single-engine dispatch.
    from repro.soc.runtime import current_runtime, is_concrete
    rt = current_runtime()
    if rt is not None and is_concrete(a, b, bias):
        # precision routing under a runtime scope happens INSIDE the
        # split (per-job int8 eligibility + LPT over the pool), so no
        # dispatcher ranking pass is needed here — only an explicit
        # engine pin survives as a queue-affinity hint
        affinity = engine.name if isinstance(engine, Engine) else engine
        a2 = a.reshape(-1, k)
        y, accounting = rt.run_matmul(
            js, a2, b, bias=bias, activation=activation,
            tile=tile if isinstance(tile, tuple) else (tile,) * 3,
            out_dtype=out_dtype, precision=precision, affinity=affinity,
            job_class=job_class)
        if tr is not None:
            tr.record_runtime(accounting)
        return y.reshape(*lead, m, n)

    eng = dispatch_gemm(js, engine=engine, require=require,
                        job_class=job_class)
    est_s = eng.estimate(js)
    eng.telemetry.record(js, est_s)
    if tr is not None:
        tr.record_engine(eng.name, js, est_s)

    a2 = a.reshape(-1, k)
    y = eng.execute(a2, b, bias=bias, activation=activation, tile=tile,
                    out_dtype=out_dtype, precision=precision)
    return y.reshape(*lead, m, n)
