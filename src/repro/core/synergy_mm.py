"""``synergy_mm`` — the composable tiled-MM operator (paper C1/C2).

Every dense GEMM in the framework is routed through :func:`synergy_matmul`.
It does three things:

  1. Registers the GEMM's :class:`~repro.core.job.JobSet` with the active
     :class:`SynergyTrace` (trace-time metadata: the job decomposition the
     schedulers, cost model, and roofline analysis operate on).
  2. Picks the execution engine: the Pallas ``tiled_mm`` kernel (TPU target;
     validated in interpret mode on CPU) or the XLA dot (CPU dry-run path —
     keeps the 512-device dry-run HLO clean and lets ``cost_analysis`` see
     canonical dots).
  3. Applies the fused epilogue (bias/activation) — a beyond-paper
     optimization (the paper's PEs write raw C tiles; fusing the epilogue
     removes one HBM round trip per GEMM).

The job abstraction is exactly the paper's: one job == one output tile of C,
zero-padded at borders so a single fixed-size engine serves every layer of
every network ("network-agnostic accelerators").
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .job import JobSet

__all__ = ["SynergyTrace", "synergy_matmul", "current_trace", "DEFAULT_TILE"]

# MXU-aligned default tile for the TPU target; the paper-faithful TS=32
# baseline is exercised in benchmarks/EXPERIMENTS §Perf.
DEFAULT_TILE = (256, 256, 256)

_state = threading.local()


@dataclasses.dataclass
class SynergyTrace:
    """Collects the JobSets of every GEMM traced under this context."""

    jobsets: list[JobSet] = dataclasses.field(default_factory=list)
    _next_layer_id: int = 0

    def add(self, m: int, n: int, k: int, tile, name: str) -> JobSet:
        js = JobSet.for_gemm(self._next_layer_id, m, n, k, tile, name=name)
        self._next_layer_id += 1
        self.jobsets.append(js)
        return js

    @property
    def total_flops(self) -> int:
        return sum(js.total_flops for js in self.jobsets)

    @property
    def num_jobs(self) -> int:
        return sum(js.num_jobs for js in self.jobsets)

    @contextlib.contextmanager
    def activate(self):
        prev = getattr(_state, "trace", None)
        _state.trace = self
        try:
            yield self
        finally:
            _state.trace = prev


def current_trace() -> Optional[SynergyTrace]:
    return getattr(_state, "trace", None)


def _epilogue(y: jax.Array, bias, activation) -> jax.Array:
    if bias is not None:
        y = y + bias
    if activation is not None:
        y = activation(y)
    return y


def synergy_matmul(a: jax.Array, b: jax.Array, *,
                   bias: jax.Array | None = None,
                   activation: Callable | None = None,
                   tile: tuple[int, int, int] | int = DEFAULT_TILE,
                   name: str = "",
                   impl: str = "auto",
                   out_dtype=None,
                   precision=None) -> jax.Array:
    """C = act(A @ B + bias) through the Synergy tile-job abstraction.

    a: (..., m, k); b: (k, n).  ``impl``: 'auto' | 'xla' | 'pallas'.
    """
    *lead, m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {a.shape} @ {b.shape}"
    tr = current_trace()
    if tr is not None:
        batch = 1
        for d in lead:
            batch *= d
        tr.add(batch * m, n, k, tile, name=name or "gemm")

    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "pallas":
        from repro.kernels.tiled_mm import ops as tiled_ops
        a2 = a.reshape(-1, k)
        y = tiled_ops.tiled_matmul(a2, b, tile=tile, bias=bias,
                                   activation=activation,
                                   out_dtype=out_dtype)
        return y.reshape(*lead, m, n)
    if b.dtype != a.dtype:
        # storage dtype != compute dtype (e.g. int8 weight-only quant for
        # decode, §Perf B1): dequant-on-read, accumulate in f32
        b = b.astype(a.dtype)
    y = jax.lax.dot_general(
        a, b, (((a.ndim - 1,), (0,)), ((), ())),
        precision=precision,
        preferred_element_type=jnp.float32)
    y = _epilogue(y, bias, activation)
    return y.astype(out_dtype or a.dtype)
