"""Synergy core: tile-job decomposition, heterogeneous clusters,
work-stealing scheduling, and inter-frame pipelining.

All dense compute dispatches through the engine registry in
:mod:`repro.engines`; the clusters/scheduler below are views over the same
registered cost models."""

from .job import Job, JobSet, ceil_div
from .clusters import (Accelerator, Cluster, F_PE, S_PE, NEON, arm_cost,
                       default_synergy_clusters, make_accelerators)
from .scheduler import (SimLayer, SimNet, SimResult, simulate,
                        single_thread_latency, sf_layer_map, search_sc,
                        lpt_plan, rebalance)
from .synergy_mm import SynergyTrace, synergy_matmul, current_trace
from .pipeline import (EngineStage, ThreadedPipeline, gpipe_reference,
                       gpipe_spmd)
from .im2col import im2col, conv2d_gemm, conv_out_shape
