"""Continuous-batching serving engine — Synergy's scheduler at request
granularity.

The paper's heterogeneous job mix maps directly onto LLM serving: PREFILL
requests are large compute-bound tile-job sets, DECODE steps are small
memory-bound jobs.  Both are expressed as engine job classes
(:class:`PrefillJob` / :class:`DecodeJob`) whose :class:`JobSet` views feed
the same :class:`~repro.engines.Dispatcher` every other GEMM in the
framework uses, so per-step engine routing and busy-time accounting come
from the shared registry cost models.

The engine keeps a fixed-slot decode batch (the "cluster") and, like the
thief thread, fills idle capacity from the pending-request queue: when
slots are free it runs a prefill (admits a request), otherwise it advances
the whole batch one decode step.  The slot batch keeps shapes static
(jit-friendly); finished requests free their slot immediately (inter-frame
pipelining at token granularity).

Cache discipline (continuous batching): every step passes PER-SLOT
positions to ``decode_step`` — a slot's K/V rows are written only at that
slot's own position, and slots marked ``-1`` (idle, or bystanders during
another request's prefill) are never written at all.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.engines import CAP_INT8, Dispatcher, Engine, find_engine

from .job import JobSet

__all__ = ["Request", "PrefillJob", "DecodeJob", "ServeStats",
           "SynergyServer"]

#: tile for the serving-side job accounting (decode GEMMs are tiny; the
#: paper-faithful TS=32 keeps their jobsets non-degenerate)
_SERVE_TILE = 32


@dataclasses.dataclass
class Request:
    rid: int
    tokens: jax.Array          # (prompt_len,) int32
    max_new_tokens: int
    out: list = dataclasses.field(default_factory=list)


# ---------------------------------------------------------------------------
# Engine job classes: the prefill/decode split, dispatcher-visible
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PrefillJob:
    """Admit one request into a slot: a compute-bound tile-job set (the
    prompt's full-sequence GEMMs)."""

    rid: int
    slot: int
    n_tokens: int
    d_model: int
    n_layers: int

    kind = "prefill"

    def jobset(self) -> JobSet:
        # per-request proxy GEMM: (prompt tokens x d_model) @ (d_model x
        # ~4*d_model) per layer, folded into one JobSet (m scales with
        # layers so estimates stay comparable across models)
        return JobSet.for_gemm(self.rid, self.n_tokens * self.n_layers,
                               4 * self.d_model, self.d_model, _SERVE_TILE,
                               name=f"prefill/r{self.rid}")


@dataclasses.dataclass(frozen=True)
class DecodeJob:
    """Advance every live slot one token: a small memory-bound job set."""

    step: int
    slots: tuple[int, ...]     # live slot indices this step serves
    d_model: int
    n_layers: int

    kind = "decode"

    def jobset(self) -> JobSet:
        return JobSet.for_gemm(self.step, len(self.slots) * self.n_layers,
                               4 * self.d_model, self.d_model, _SERVE_TILE,
                               name=f"decode/s{self.step}")


@dataclasses.dataclass
class ServeStats:
    engine_steps: int = 0
    prefills: int = 0
    decode_steps: int = 0
    tokens_out: int = 0
    #: dispatcher accounting per job class: estimated engine-busy seconds
    job_busy_s: dict = dataclasses.field(
        default_factory=lambda: {"prefill": 0.0, "decode": 0.0})
    #: job class -> engine name the dispatcher (or the runtime's dominant
    #: executor) last routed it to
    job_engine: dict = dataclasses.field(default_factory=dict)
    #: tile jobs per PRECISION class of the engine that executed them
    #: (int8 = CAP_INT8 quantized engines; fp32 = everything else) — the
    #: serving-visible face of the precision-routing policy
    precision_jobs: dict = dataclasses.field(
        default_factory=lambda: {"int8": 0, "fp32": 0})
    #: runtime mode only: tile jobs executed / stolen across the pool
    runtime_jobs: int = 0
    runtime_steals: int = 0

    @property
    def slot_efficiency(self) -> float:
        return self.tokens_out / max(1, self.decode_steps)


class SynergyServer:
    """cfg: reduced/real ArchConfig; params: model params.

    slots: decode batch size (static); max_len: cache depth."""

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 64,
                 prefill_len: int = 16,
                 dispatcher: Optional[Dispatcher] = None,
                 runtime=None):
        from repro.models import decode_step, init_cache
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.prefill_len = prefill_len
        self.cache = init_cache(cfg, slots, max_len)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.slot_pos = [0] * slots
        self.pending: list[Request] = []
        self.stats = ServeStats()
        self.dispatcher = dispatcher or Dispatcher()
        #: optional repro.soc.SynergyRuntime — prefill/decode jobsets become
        #: runtime submissions (tile jobs spread by stealing: decode steps
        #: soak up capacity an idle prefill engine leaves on the table)
        self.runtime = runtime
        if runtime is not None:
            runtime.start()

        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))

    # ------------------------------------------------------------- requests
    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    # --------------------------------------------------------------- engine
    def step(self) -> bool:
        """One engine step: prefill-if-capacity else decode.  Returns True
        if any work was done."""
        self.stats.engine_steps += 1
        slot = self._free_slot()
        if self.pending and slot is not None:
            self._do_prefill(self.pending.pop(0), slot)
            return True
        if any(r is not None for r in self.slot_req):
            self._do_decode()
            return True
        return False

    def run(self, until_drained: bool = True, max_steps: int = 10_000):
        while max_steps > 0:
            if not self.step():
                break
            max_steps -= 1
        return self.stats

    # ------------------------------------------------------------ internals
    @staticmethod
    def _precision_class(engine: Optional[Engine]) -> str:
        return ("int8" if engine is not None
                and CAP_INT8 in engine.capabilities else "fp32")

    def _account(self, job) -> Optional[Engine]:
        """Route the job class' JobSet: through the runtime (tile jobs
        submitted, stolen, booked per executing engine) when one is
        attached, else whole to the dispatcher's pick.  Either way the
        precision-routing policy applies — ``job.kind`` is the dispatcher
        job class, so DECODE steps land on registered int8 engines while
        prefill stays on grad-safe full-precision paths — and per-precision
        job counts land in ``ServeStats.precision_jobs``.  Returns the
        policy-selected engine (the runtime path returns the seed-hint
        engine) so decode can feed its activation calibrator."""
        js = job.jobset()
        if self.runtime is not None:
            # queue-affinity hint: seed on the policy's choice (int8 for
            # decode when one is registered), let idle engines steal tiles
            try:
                hint_eng = self.dispatcher.select(js, job_class=job.kind)
                hint = hint_eng.name
            except RuntimeError:
                hint_eng, hint = None, None
            fut = self.runtime.submit(js, affinity=hint)
            fut.result(timeout=60.0)
            acct = fut.accounting
            total = sum(a["est_s"] for a in acct.values())
            self.stats.job_busy_s[job.kind] += total
            if acct:
                dominant = max(acct, key=lambda n: acct[n]["jobs"])
                self.stats.job_engine[job.kind] = dominant
            for name, a in acct.items():
                # pool engines need not be registry entries: resolve from
                # the runtime's live pool first, the registry second
                eng = self.runtime.find_engine(name) or find_engine(name)
                self.stats.precision_jobs[self._precision_class(eng)] \
                    += a["jobs"]
            self.stats.runtime_jobs += sum(a["jobs"] for a in acct.values())
            self.stats.runtime_steals += sum(a["steals"]
                                             for a in acct.values())
            return hint_eng
        eng = self.dispatcher.select(js, job_class=job.kind)
        est = eng.estimate(js)
        eng.telemetry.record(js, est)
        self.stats.job_busy_s[job.kind] += est
        self.stats.job_engine[job.kind] = eng.name
        self.stats.precision_jobs[self._precision_class(eng)] += js.num_jobs
        return eng

    def _slot_positions(self) -> jnp.ndarray:
        """(slots,) int32 of per-slot cache positions; -1 for empty slots."""
        return jnp.array(
            [self.slot_pos[i] if r is not None else -1
             for i, r in enumerate(self.slot_req)], jnp.int32)

    def _do_prefill(self, req: Request, slot: int) -> None:
        # The prompt replays through the decode path one token at a time
        # (single jitted program keeps this example simple; a production
        # prefill writes the cache in one pass).  Positions are per-slot:
        # ONLY the target slot's position is set, so live requests in other
        # slots keep their KV cache entries untouched.
        toks = req.tokens[: self.prefill_len]
        if toks.shape[0] == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        self._account(PrefillJob(req.rid, slot, int(toks.shape[0]),
                                 self.cfg.d_model, self.cfg.n_layers))
        # slot reuse: zero the slot's cache rows (every cache tensor —
        # K/V and SSM states alike — carries batch at axis 1).  Attention
        # masks stale K/V anyway; recurrent SSM state NEEDS the reset or a
        # reused slot would continue the previous request's recurrence.
        self.cache = jax.tree.map(
            lambda a: a.at[:, slot].set(jnp.zeros_like(a[:, slot])),
            self.cache)
        logits = None
        for i in range(toks.shape[0]):
            tok = (jnp.zeros((self.slots, 1), jnp.int32)
                   .at[slot, 0].set(toks[i].astype(jnp.int32)))
            pos = jnp.full((self.slots,), -1, jnp.int32).at[slot].set(i)
            logits, self.cache = self._decode(
                self.params, self.cache, tok, pos)
        # the prompt's last-token logits seed the first generated token
        first = int(jnp.argmax(logits[slot, -1]))
        req.out.append(first)
        self.slot_req[slot] = req
        self.slot_pos[slot] = int(toks.shape[0])
        self.stats.prefills += 1

    def _feed_act_calibrator(self, eng: Optional[Engine],
                             toks: jnp.ndarray,
                             live: tuple[int, ...]) -> None:
        """Decode feeds the activation calibrator: the step's LIVE-slot
        token embeddings are the activation panel of the decode GEMMs,
        so observing them per step converges the quantized engine's
        per-shape EMA online (keyed by the serving proxy GEMM's (k, n) =
        (d_model, 4*d_model), the same key the runtime's int8 split
        consults).  Empty slots are excluded — their padding token-0
        embeddings are not traffic, and a large embed[0] row would
        inflate the max|a| EMA and waste int8 resolution on an artifact.
        A plain fp32 engine has no calibrator — no-op."""
        if eng is None or not hasattr(eng, "observe_activations") or not live:
            return
        embed = (self.params.get("embed")
                 if isinstance(self.params, dict) else None)
        if embed is None:
            return
        acts = embed[toks[jnp.array(live), 0]]
        eng.observe_activations(acts, self.cfg.d_model, 4 * self.cfg.d_model)

    def _do_decode(self) -> None:
        live = tuple(i for i, r in enumerate(self.slot_req) if r is not None)
        toks = jnp.zeros((self.slots, 1), jnp.int32)
        for i, r in enumerate(self.slot_req):
            if r is not None and r.out:
                toks = toks.at[i, 0].set(r.out[-1])
        eng = self._account(DecodeJob(self.stats.decode_steps, live,
                                      self.cfg.d_model, self.cfg.n_layers))
        self._feed_act_calibrator(eng, toks, live)
        # per-slot positions: each live slot reads/writes at ITS OWN index
        # (a shared max(pos) would smear late-arriving requests' tokens
        # into earlier requests' cache rows); empty slots are masked (-1)
        pos = self._slot_positions()
        logits, self.cache = self._decode(self.params, self.cache, toks, pos)
        self.stats.decode_steps += 1
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            nxt = int(jnp.argmax(logits[i, -1]))
            r.out.append(nxt)
            self.slot_pos[i] += 1
            self.stats.tokens_out += 1
            done = (len(r.out) >= r.max_new_tokens
                    or self.slot_pos[i] >= self.max_len - 1)
            if done:
                self.slot_req[i] = None   # free the slot (continuous batching)
