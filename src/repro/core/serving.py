"""Continuous-batching serving engine — Synergy's scheduler at request
granularity.

The paper's heterogeneous job mix maps directly onto LLM serving: PREFILL
requests are large compute-bound tile-job sets, DECODE steps are small
memory-bound jobs.  The engine keeps a fixed-slot decode batch (the
"cluster") and, like the thief thread, fills idle capacity from the
pending-request queue: when slots are free it runs a prefill (admits a
request), otherwise it advances the whole batch one decode step.  The
slot batch keeps shapes static (jit-friendly); finished requests free
their slot immediately (inter-frame pipelining at token granularity).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["Request", "ServeStats", "SynergyServer"]


@dataclasses.dataclass
class Request:
    rid: int
    tokens: jax.Array          # (prompt_len,) int32
    max_new_tokens: int
    out: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ServeStats:
    engine_steps: int = 0
    prefills: int = 0
    decode_steps: int = 0
    tokens_out: int = 0

    @property
    def slot_efficiency(self) -> float:
        return self.tokens_out / max(1, self.decode_steps)


class SynergyServer:
    """cfg: reduced/real ArchConfig; params: model params.

    slots: decode batch size (static); max_len: cache depth."""

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 64,
                 prefill_len: int = 16):
        from repro.models import decode_step, init_cache, prefill
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.prefill_len = prefill_len
        self.cache = init_cache(cfg, slots, max_len)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.slot_pos = [0] * slots
        self.pending: list[Request] = []
        self.stats = ServeStats()

        self._prefill = jax.jit(lambda p, t: prefill(cfg, p, tokens=t))
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))

    # ------------------------------------------------------------- requests
    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    # --------------------------------------------------------------- engine
    def step(self) -> bool:
        """One engine step: prefill-if-capacity else decode.  Returns True
        if any work was done."""
        self.stats.engine_steps += 1
        slot = self._free_slot()
        if self.pending and slot is not None:
            self._do_prefill(self.pending.pop(0), slot)
            return True
        if any(r is not None for r in self.slot_req):
            self._do_decode()
            return True
        return False

    def run(self, until_drained: bool = True, max_steps: int = 10_000):
        while max_steps > 0:
            if not self.step():
                break
            max_steps -= 1
        return self.stats

    # ------------------------------------------------------------ internals
    def _do_prefill(self, req: Request, slot: int) -> None:
        # the prompt's last-token logits seed the first generated token;
        # its K/V enter the slot's cache region by replaying through the
        # decode path (single jitted program per token keeps this example
        # simple; a production prefill writes the cache in one pass)
        toks = req.tokens[: self.prefill_len]
        for i in range(toks.shape[0]):
            tok = jnp.broadcast_to(toks[i], (self.slots, 1)).astype(jnp.int32)
            logits, self.cache = self._decode(
                self.params, self.cache, tok, jnp.int32(i))
        first = int(jnp.argmax(logits[slot, -1]))
        req.out.append(first)
        self.slot_req[slot] = req
        self.slot_pos[slot] = toks.shape[0]
        self.stats.prefills += 1

    def _do_decode(self) -> None:
        toks = jnp.zeros((self.slots, 1), jnp.int32)
        for i, r in enumerate(self.slot_req):
            if r is not None and r.out:
                toks = toks.at[i, 0].set(r.out[-1])
        pos = max(p for r, p in zip(self.slot_req, self.slot_pos)
                  if r is not None)
        logits, self.cache = self._decode(self.params, self.cache, toks,
                                          jnp.int32(pos))
        self.stats.decode_steps += 1
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            nxt = int(jnp.argmax(logits[i, -1]))
            r.out.append(nxt)
            self.slot_pos[i] += 1
            self.stats.tokens_out += 1
            done = (len(r.out) >= r.max_new_tokens
                    or self.slot_pos[i] >= self.max_len - 1)
            if done:
                self.slot_req[i] = None   # free the slot (continuous batching)
