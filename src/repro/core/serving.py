"""Continuous-batching serving engine — Synergy's scheduler at request
granularity.

The paper's heterogeneous job mix maps directly onto LLM serving: PREFILL
requests are large compute-bound conv-as-GEMM job sets (the CNN front-end
of the SoC — every prompt token becomes one frame through a
:mod:`repro.configs.paper_cnns` network, lowered to im2col + GEMM exactly
like §3.1.1), DECODE steps are small memory-bound jobs.  Both are
expressed as engine job classes (:class:`PrefillJob` / :class:`DecodeJob`)
whose :class:`JobSet` views feed the same
:class:`~repro.engines.Dispatcher` every other GEMM in the framework uses,
so per-step engine routing and busy-time accounting come from the shared
registry cost models.

Batching and asynchrony (ISSUE 5):

* **Admission waves** — ``step()`` admits *every* pending request up to the
  free slots (``min(pending, free)``) in ONE wave: one batched LM replay
  for the whole wave (per-slot masked positions keep bystanders
  untouched), one stacked frame batch through the conv front-end, ONE
  im2col gather per conv layer (:func:`repro.core.im2col.im2col_wave`).
* **Coalesced decode** — the per-step decode folds every live slot's
  per-layer FFN GEMM into ONE runtime submission whose row-panel split
  amortizes dispatch overhead; when the model params expose stacked FFN
  weights (``blocks.mlp.wi``), the REAL per-layer ``wi`` matrices are
  stacked along n into one ``(d_model, n_layers·2·d_ff)`` weight — the
  decode GEMM computes every layer's actual up-projection on the live
  embeddings (a proxy weight remains the fallback for families without a
  dense FFN stack).  ``decode_mode="per-slot"`` keeps the sequential
  per-slot loop as the measured baseline (bitwise-identical output — the
  int32-partial int8 path is exact integer math, and fp32 row reductions
  are row-independent).
* **In-flight window** — runtime submissions are reaped through a bounded
  FIFO (``max_inflight``), so submissions of step *t* overlap compute of
  step *t−1*; completion is reaped in submission order (ordered per slot),
  and the activation calibrator is fed at REAP time from a device-side
  ``max|a|`` launched at submit (no host sync on the hot path).

Dataflow-graph prefill (ISSUE 6): the wave's conv front-end is ONE
:meth:`~repro.soc.SynergyRuntime.submit_graph` DAG — layer *l+1*'s
host-side im2col gather is a graph node gated on layer *l*'s GEMM, so the
gather overlaps the *next* wave of GEMM panels instead of serializing at
every reap.  With ``prefill_chunk_macs`` set, the wave's graph is split
into bounded-cost chunks and the LM prompt replay into bounded token
quanta, and ``step()`` interleaves one chunk with the coalesced decode
GEMM — live decoders never stall behind a large admission
(``ServeStats.prefill_chunks`` / ``decode_stall_steps`` expose the
difference).

Cache discipline (continuous batching): every step passes PER-SLOT
positions to ``decode_step`` — a slot's K/V rows are written only at that
slot's own position, and slots marked ``-1`` (idle, or bystanders during
another request's prefill) are never written at all.  Chunked prefill
preserves this bitwise: replay quanta touch only the admitted wave's
slots, decode steps touch only live slots, and the two sets are disjoint
until the replay finalizes.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.engines import CAP_INT8, Dispatcher, Engine, find_engine
from repro.obs.flightrec import FlightRecorder
from repro.obs.trace import get_default_tracer
from repro.soc.durable import (CrashPlan, Durability, RequestJournal,
                               RestoreMismatch, SimulatedCrash,
                               array_to_meta, load_snapshot, meta_to_array,
                               register_server)
from repro.soc.qos import AdmissionRejected, Tenant
from repro.soc.qos_policy import PREFILL_PRIORITY_OFFSET, FairShare, QosTag

from .im2col import conv_out_shape, im2col_wave
from .job import JobSet, chunk_by_macs

__all__ = ["Request", "PrefillJob", "DecodeJob", "ServeStats",
           "TenantStats", "ServeTimeoutError", "SynergyServer"]

#: tile for the serving-side job accounting (decode GEMMs are tiny; the
#: paper-faithful TS=32 keeps their jobsets non-degenerate)
_SERVE_TILE = 32


class ServeTimeoutError(RuntimeError):
    """A runtime submission missed the server's ``submit_timeout``.

    Carries the jobset name, the per-engine accounting booked so far, and
    the affected request/tenant identity (``rids``/``tenants``) — so the
    operator sees WHICH submission stalled, how much of it each engine
    had already executed, and WHOSE traffic it was — not a bare futures
    error."""

    def __init__(self, jobset_name: str, timeout: float, accounting: dict,
                 rids: Sequence[int] = (), tenants: Sequence[str] = ()):
        self.jobset_name = jobset_name
        self.timeout = timeout
        self.accounting = dict(accounting)
        self.rids = tuple(rids)
        self.tenants = tuple(t for t in tenants if t)
        done = {name: a.get("jobs", 0) for name, a in self.accounting.items()}
        who = ""
        if self.rids:
            who = f" [rids={list(self.rids)}"
            who += (f" tenants={sorted(set(self.tenants))}]"
                    if self.tenants else "]")
        super().__init__(
            f"serving submission {jobset_name!r} not done in {timeout}s "
            f"(per-engine jobs completed so far: {done or 'none'}){who}")


@dataclasses.dataclass
class Request:
    rid: int
    tokens: jax.Array          # (prompt_len,) int32
    max_new_tokens: int
    out: list = dataclasses.field(default_factory=list)
    #: tenant name (required on a tenanted server; ignored otherwise)
    tenant: Optional[str] = None
    #: per-request SLO deadline in seconds from submission (overrides the
    #: tenant class default; None = the class default / no deadline)
    deadline_s: Optional[float] = None
    #: stamped by the server: monotonic submit instant, resolved absolute
    #: deadline, and the instant the last token was emitted — always
    #: recorded (QoS or not) so attainment is computable on ANY server
    submitted_at: float = 0.0
    deadline_at: float = math.inf
    done_at: Optional[float] = None


# ---------------------------------------------------------------------------
# Engine job classes: the prefill/decode split, dispatcher-visible
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PrefillJob:
    """Admit one WAVE of requests: the wave's frames through the conv
    front-end, as real conv-as-GEMM JobSets (one per CONV layer, batched
    over every frame of every admitted request — no proxy GEMM)."""

    wave: int
    rids: tuple[int, ...]
    slots: tuple[int, ...]
    n_frames: int
    cnn: object                # repro.models.cnn.CNNConfig

    kind = "prefill"

    def jobsets(self) -> list[JobSet]:
        """The wave's per-CONV-layer im2col GEMM JobSets — the same
        shapes :func:`repro.models.cnn.build_simnet` exports to the DES,
        so server prefill busy-seconds and simulator busy-seconds read
        one cost model over one job decomposition."""
        from repro.models.cnn import conv_jobsets
        return [js for _, js in
                conv_jobsets(self.cnn, self.n_frames,
                             name_prefix=f"prefill/w{self.wave}/")]


@dataclasses.dataclass(frozen=True)
class DecodeJob:
    """Advance every live slot one token: ONE coalesced memory-bound job
    set covering the whole live batch.  With real stacked FFN weights the
    GEMM is ``(live, d_model) @ (d_model, n_layers·ffn_cols)`` (per-layer
    ``wi`` stacked along n); the proxy fallback stacks per-layer GEMMs
    along m (``ffn_cols is None``)."""

    step: int
    slots: tuple[int, ...]     # live slot indices this step serves
    d_model: int
    n_layers: int
    ffn_cols: Optional[int] = None   # per-layer FFN width (2·d_ff) | None

    kind = "decode"

    def jobset(self) -> JobSet:
        if self.ffn_cols is not None:
            return JobSet.for_gemm(
                self.step, len(self.slots), self.n_layers * self.ffn_cols,
                self.d_model, _SERVE_TILE, name=f"decode/s{self.step}")
        return JobSet.for_gemm(self.step, len(self.slots) * self.n_layers,
                               4 * self.d_model, self.d_model, _SERVE_TILE,
                               name=f"decode/s{self.step}")


@dataclasses.dataclass
class TenantStats:
    """Per-tenant serving counters (``ServeStats.tenants[name]``) — the
    attribution surface for QoS failures: whose tokens, whose queue-wait,
    whose deadlines."""

    admitted: int = 0
    rejected: int = 0
    prefills: int = 0
    tokens_out: int = 0
    queue_wait_s: float = 0.0
    max_queue_wait_s: float = 0.0
    deadline_hits: int = 0
    deadline_misses: int = 0
    #: decode steps this tenant's slots ran int8-degraded (shed ladder)
    degraded_steps: int = 0

    @property
    def deadline_attainment(self) -> float:
        n = self.deadline_hits + self.deadline_misses
        return self.deadline_hits / n if n else 1.0


@dataclasses.dataclass
class ServeStats:
    engine_steps: int = 0
    prefills: int = 0
    #: admission waves executed (prefills / prefill_waves = mean wave size)
    prefill_waves: int = 0
    decode_steps: int = 0
    tokens_out: int = 0
    #: deepest the async in-flight window got (0 = fully synchronous)
    inflight_peak: int = 0
    #: bounded-cost prefill chunks executed (conv graph chunks + LM replay
    #: quanta) — 0 in legacy blocking-admission mode
    prefill_chunks: int = 0
    #: engine steps where live decoders sat idle behind a blocking
    #: admission wave — chunked prefill drives this to 0
    decode_stall_steps: int = 0
    #: dispatcher accounting per job class: estimated engine-busy seconds
    job_busy_s: dict = dataclasses.field(
        default_factory=lambda: {"prefill": 0.0, "decode": 0.0})
    #: job class -> engine name the dispatcher (or the runtime's dominant
    #: executor) last routed it to
    job_engine: dict = dataclasses.field(default_factory=dict)
    #: tile jobs per PRECISION class of the engine that executed them
    #: (int8 = CAP_INT8 quantized engines; fp32 = everything else) — the
    #: serving-visible face of the precision-routing policy
    precision_jobs: dict = dataclasses.field(
        default_factory=lambda: {"int8": 0, "fp32": 0})
    #: runtime mode only: tile jobs executed / stolen across the pool
    runtime_jobs: int = 0
    runtime_steals: int = 0
    #: runtime mode only: panel re-executions absorbed by the pool's
    #: RetryPolicy (injected faults, worker deaths) — the serving-visible
    #: proof that a crash mid-wave cost retries, not requests
    runtime_retries: int = 0
    #: tenant name -> :class:`TenantStats` (tenanted servers only)
    tenants: dict = dataclasses.field(default_factory=dict)
    #: requests refused admission (queue bound hit after the shed ladder)
    admission_rejects: int = 0
    #: times the shed ladder ENGAGED (occupancy crossed the watermark)
    shed_engagements: int = 0
    #: decode steps that ran with at least one int8-degraded slot group
    shed_degraded_steps: int = 0
    #: tokens recomputed from the journal during a restore's replay —
    #: already delivered by the crashed process, NOT fresh throughput
    #: (the no-double-count invariant: restored ``tokens_out`` +
    #: ``replayed_tokens`` equals the uninterrupted run's ``tokens_out``)
    replayed_tokens: int = 0
    #: runtime/dispatcher tile jobs executed under replay accounting
    replayed_jobs: int = 0
    #: crash-consistent snapshots taken (cadence + close())
    snapshots: int = 0
    #: successful snapshot+journal restores this ServeStats survived
    restores: int = 0

    @property
    def slot_efficiency(self) -> float:
        return self.tokens_out / max(1, self.decode_steps)


@dataclasses.dataclass
class _Inflight:
    """One outstanding serving submission in the reap window."""

    kind: str                       # "prefill" | "decode"
    futures: list
    graph: object = None            # GraphFuture (real conv prefill DAG)
    cal_engine: object = None       # engine whose calibrator reap feeds
    amax: object = None             # device-side max|acts| (decode)
    cal_key: Optional[tuple] = None  # (k, n) batch-shape key
    layout: Optional[tuple] = None   # (live, n_layers) result stitching
    wide: bool = False               # real-FFN n-stacked decode layout
    #: request/tenant identity for timeout attribution
    rids: tuple = ()
    tenant_names: tuple = ()
    #: shed-ladder row partition: (normal_rows, degraded_rows) index lists
    #: into the live layout when decode split into two class submissions
    groups: Optional[tuple] = None


@dataclasses.dataclass
class _ConvProgress:
    """The chunked conv front-end of one admission wave: remaining
    ``(steps, jobsets)`` chunks plus the carry between them (chunk *c+1*'s
    first gather reshapes chunk *c*'s flat GEMM output)."""

    wave: int
    chunks: list                    # remaining [(steps, jobsets), ...]
    x: jax.Array                    # carry: frames | previous flat output
    in_shape: Optional[tuple]       # (N, H, W, C) restore for the carry
    n_frames: int
    hint: Optional[str]
    total: int = 0                  # chunks at construction (for naming)
    idx: int = 0                    # next chunk index
    fut: object = None              # outstanding GraphFuture
    qos: Optional[QosTag] = None    # the wave's prefill-class tag
    rids: tuple = ()                # timeout attribution
    tenant_names: tuple = ()

    @property
    def done(self) -> bool:
        return self.fut is None and not self.chunks


@dataclasses.dataclass
class _PrefillProgress:
    """One admission wave in flight under chunked prefill: the staged LM
    replay arrays plus the conv-chunk chain.  ``step()`` advances one
    bounded quantum per call and runs decode in the same step."""

    wave: list                      # [(req, slot, toks), ...]
    lens: list
    span: int
    tok_np: np.ndarray
    pos_np: np.ndarray
    conv: Optional[_ConvProgress]
    last_row: dict = dataclasses.field(default_factory=dict)
    tok_i: int = 0
    finalized: bool = False


class SynergyServer:
    """cfg: reduced/real ArchConfig; params: model params.

    slots: decode batch size (static); max_len: cache depth;
    prefill_cnn: the :class:`~repro.models.cnn.CNNConfig` whose CONV
    layers are the prefill front-end (default: the paper's MNIST net);
    admission: ``"wave"`` admits min(pending, free slots) per step,
    ``"single"`` keeps the legacy one-request-per-step baseline;
    decode_mode: ``"batched"`` coalesces the live slots into one runtime
    GEMM, ``"per-slot"`` submits one GEMM per slot (the baseline);
    max_inflight: bound of the async submit/reap window (0 = synchronous);
    submit_timeout: seconds a runtime submission may stay outstanding
    before :class:`ServeTimeoutError`;
    prefill_chunk_macs: when set, split each admission wave's conv graph
    and LM replay into chunks of roughly this many MACs and interleave
    them with decode — ``None`` keeps the legacy blocking admission;
    keep_decode_outputs: retain each step's reaped decode-GEMM output in
    ``decode_gemm_outputs`` (canonical (live, n_layers, n_cols) layout
    in BOTH decode modes — how the bitwise-identity tests compare them);
    tenants: :class:`repro.soc.qos.Tenant` list — enables multi-tenant
    QoS: per-tenant bounded queues, weighted fair admission
    (:class:`~repro.soc.qos_policy.FairShare`), QoS tags on every
    runtime submission (decode at class priority, prefill one notch
    below — see ``PREFILL_PRIORITY_OFFSET``), the load-shedding ladder,
    and per-tenant :class:`TenantStats`; ``None`` keeps the untenanted
    FIFO server, decision-for-decision identical to before;
    max_pending: pending-queue bound — server-wide without tenants,
    per-tenant default (each tenant's own ``max_pending`` overrides)
    with them; overflow raises :class:`~repro.soc.qos.AdmissionRejected`
    with a cost-model retry-after (``None`` = unbounded, the legacy
    behavior);
    durable: :class:`~repro.soc.durable.Durability` — write-ahead journal
    every accepted request and emitted token, snapshot server state
    through :class:`~repro.checkpoint.Checkpointer` every
    ``snapshot_every`` steps, and enable :meth:`restore` /
    :meth:`close` / SIGTERM drain; ``None`` keeps the ephemeral server;
    crash_plan: :class:`~repro.soc.durable.CrashPlan` — deterministic
    test harness: raise :class:`~repro.soc.durable.SimulatedCrash` at
    the start of the given engine step.
    """

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 64,
                 prefill_len: int = 16,
                 dispatcher: Optional[Dispatcher] = None,
                 runtime=None,
                 prefill_cnn=None,
                 admission: str = "wave",
                 decode_mode: str = "batched",
                 max_inflight: int = 2,
                 submit_timeout: float = 60.0,
                 prefill_chunk_macs: Optional[int] = None,
                 keep_decode_outputs: bool = False,
                 tenants: Optional[Sequence[Tenant]] = None,
                 max_pending: Optional[int] = None,
                 tracer=None, flight_recorder=None, metrics=None,
                 durable: Optional[Durability] = None,
                 crash_plan: Optional[CrashPlan] = None):
        from repro.models import decode_step, init_cache
        from repro.models.cnn import init_cnn
        if admission not in ("wave", "single"):
            raise ValueError(f"admission must be 'wave'|'single': {admission!r}")
        if decode_mode not in ("batched", "per-slot"):
            raise ValueError(
                f"decode_mode must be 'batched'|'per-slot': {decode_mode!r}")
        if max_inflight < 0:
            raise ValueError(f"max_inflight must be >= 0: {max_inflight!r}")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.prefill_len = prefill_len
        self.admission = admission
        self.decode_mode = decode_mode
        self.max_inflight = max_inflight
        self.submit_timeout = submit_timeout
        self.prefill_chunk_macs = prefill_chunk_macs
        self.keep_decode_outputs = keep_decode_outputs
        self.cache = init_cache(cfg, slots, max_len)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.slot_pos = [0] * slots
        self.max_pending = max_pending
        self._qos_enabled = tenants is not None
        if self._qos_enabled:
            if not tenants:
                raise ValueError("tenants=[] — pass None for an "
                                 "untenanted server")
            names = [t.name for t in tenants]
            if len(set(names)) != len(names):
                raise ValueError(f"duplicate tenant names: {names}")
            self.tenants = {t.name: t for t in tenants}
        else:
            self.tenants = {"default": Tenant("default")}
        self._queues: dict[str, list[Request]] = {
            name: [] for name in self.tenants}
        self._fair = FairShare()
        self._shed_level = 0
        self.stats = ServeStats()
        self.dispatcher = dispatcher or Dispatcher()
        #: optional repro.soc.SynergyRuntime — prefill/decode jobsets become
        #: runtime submissions (tile jobs spread by stealing: decode steps
        #: soak up capacity an idle prefill engine leaves on the table)
        self.runtime = runtime
        if runtime is not None:
            runtime.start()
        # observability: share the runtime's tracer/flight recorder so one
        # tracer covers engine, graph, serving, and admission tracks; with
        # no tracer anywhere every emit site is one attribute check
        if tracer is None:
            tracer = getattr(runtime, "_tracer", None)
            if tracer is None:
                tracer = get_default_tracer()
        self._tracer = tracer
        if flight_recorder is None:
            flight_recorder = getattr(runtime, "_flight", None)
            if flight_recorder is None and tracer is not None:
                flight_recorder = FlightRecorder(tracer)
        self._flight = flight_recorder
        #: optional MetricsRegistry: the ONLY per-observation instrument
        #: (per-tenant queue-wait histogram) — everything else is view-fed
        self._metrics = metrics
        self._qwait_hist = (metrics.histogram(
            "repro_tenant_queue_wait_seconds",
            "admission queue wait per tenant", ("tenant",))
            if metrics is not None else None)
        if prefill_cnn is None:
            from repro.configs.paper_cnns import MNIST
            prefill_cnn = MNIST
        self.prefill_cnn = prefill_cnn
        self._cnn_params = init_cnn(prefill_cnn, jax.random.key(0))
        self._decode_w = self._build_decode_weight(cfg, params)
        #: slots reserved by an in-flight chunked admission: not live yet
        #: (decode skips them) and not free (admission skips them)
        self._prefilling: set[int] = set()
        self._progress: Optional[_PrefillProgress] = None
        self._inflight: collections.deque[_Inflight] = collections.deque()
        self.decode_gemm_outputs: list = []

        # durability: journal + checkpointer + replay/drain flags.  The
        # flags exist on EVERY server (one attribute check per site);
        # only a Durability allocates the journal and checkpointer.
        self.durable = durable
        self._crash_plan = crash_plan
        self._journal: Optional[RequestJournal] = None
        self._ck: Optional[Checkpointer] = None
        self._replaying = False
        self._replay_q: Optional[collections.deque] = None
        self._closing = False
        self._drain_requested = False
        #: rid -> Request rebuilt by restore() (snapshot + journal) — the
        #: restored analog of the caller-held Request objects, since the
        #: crashed process's objects died with it
        self.restored_requests: dict[int, Request] = {}
        if durable is not None:
            self._journal = RequestJournal(durable.journal_path,
                                           fsync=durable.fsync)
            self._ck = Checkpointer(durable.snapshot_dir,
                                    keep=durable.keep,
                                    async_write=durable.async_snapshots)
            register_server(self)

        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))

    # ------------------------------------------------------------- requests
    @property
    def pending(self) -> list[Request]:
        """Untenanted servers expose the REAL pending list (mutable, the
        legacy surface); tenanted servers return a flattened snapshot of
        every tenant queue — mutate through submit()/admission there."""
        if not self._qos_enabled:
            return self._queues["default"]
        return [r for q in self._queues.values() for r in q]

    def _tstats(self, name: str) -> TenantStats:
        return self.stats.tenants.setdefault(name, TenantStats())

    def submit(self, req: Request) -> None:
        """Admit one request into its tenant's pending queue.

        Stamps ``submitted_at`` and resolves the absolute ``deadline_at``
        (request ``deadline_s`` overrides the tenant class default) on
        EVERY server, so attainment is computable against an untenanted
        FIFO baseline too.  Tenanted servers enforce the per-tenant bound
        (``Tenant.max_pending`` falling back to the server's
        ``max_pending``) and raise :class:`~repro.soc.qos.
        AdmissionRejected` with a cost-model retry-after when it is hit —
        AFTER the shed ladder has already engaged at the occupancy
        watermark.  An unknown tenant raises ``KeyError``."""
        if self._closing:
            name = req.tenant or "default"
            raise AdmissionRejected(name, self._retry_after(name),
                                    "server closing")
        now = time.monotonic()
        req.submitted_at = now
        if not self._qos_enabled:
            dl = req.deadline_s
            req.deadline_at = now + dl if dl is not None else math.inf
            q = self._queues["default"]
            if (self.max_pending is not None
                    and len(q) >= self.max_pending):
                raise self._reject("default", req)
            self._journal_submit(req)
            q.append(req)
            return
        if req.tenant not in self.tenants:
            raise KeyError(f"unknown tenant {req.tenant!r}; known: "
                           f"{sorted(self.tenants)}")
        t = self.tenants[req.tenant]
        dl = (req.deadline_s if req.deadline_s is not None
              else t.qos.deadline_s)
        req.deadline_at = now + dl if dl is not None else math.inf
        self._update_shed()
        q = self._queues[t.name]
        bound = (t.max_pending if t.max_pending is not None
                 else self.max_pending)
        if bound is not None and len(q) >= bound:
            self._tstats(t.name).rejected += 1
            raise self._reject(t.name, req)
        self._journal_submit(req)
        q.append(req)

    def _reject(self, tname: str, req: Request) -> AdmissionRejected:
        """Book + trace + flight-record one admission rejection and
        return the exception for the caller to raise."""
        self.stats.admission_rejects += 1
        retry = self._retry_after(tname)
        tr = self._tracer
        if tr is not None:
            tr.emit("admission", "admission", outcome="rejected",
                    tenant=tname, rid=req.rid, retry_after_s=retry)
        if self._flight is not None:
            self._flight.dump(
                "admission_rejected", stats=self.stats,
                context={"tenant": tname, "rid": req.rid,
                         "retry_after_s": retry,
                         "queued": len(self._queues.get(tname, ()))})
        return AdmissionRejected(tname, retry)

    def _retry_after(self, tname: str) -> float:
        """Cost-model estimate of when this tenant's queue frees a spot:
        the queued requests' remaining tokens through the dispatcher's
        decode estimate, over the slot parallelism."""
        q = self._queues.get(tname, [])
        js = DecodeJob(0, (0,), self.cfg.d_model, self.cfg.n_layers,
                       self._decode_ffn_cols).jobset()
        try:
            eng = self.dispatcher.select(js, job_class="decode")
            per_tok = eng.estimate(js)
        except RuntimeError:
            per_tok = 1e-3
        toks = sum(r.max_new_tokens for r in q) or 1
        return per_tok * toks / max(1, self.slots)

    def _update_shed(self) -> None:
        """The load-shedding ladder's occupancy trigger, with hysteresis:
        ENGAGE level 1 (sheddable tenants' decode degrades to int8-only
        via the ``decode_degraded`` job class) when bounded queues reach
        80% of capacity; disengage below 40%.  Unbounded tenancy never
        sheds — there is no overload signal to act on."""
        if not self._qos_enabled:
            return
        cap = tot = 0
        for name, t in self.tenants.items():
            bound = (t.max_pending if t.max_pending is not None
                     else self.max_pending)
            if bound is None:
                continue
            cap += bound
            tot += len(self._queues[name])
        if cap == 0:
            self._shed_level = 0
            return
        occ = tot / cap
        if self._shed_level == 0 and occ >= 0.8:
            self._shed_level = 1
            self.stats.shed_engagements += 1
            tr = self._tracer
            if tr is not None:
                tr.emit("shed", "admission", level=1, occupancy=occ)
        elif self._shed_level == 1 and occ < 0.4:
            self._shed_level = 0
            tr = self._tracer
            if tr is not None:
                tr.emit("shed", "admission", level=0, occupancy=occ)

    def reset_stats(self) -> None:
        """Fresh counters (benchmark repetitions reuse a warmed server)."""
        self.stats = ServeStats()
        self.decode_gemm_outputs = []

    # --------------------------------------------------------------- engine
    def step(self) -> bool:
        """One engine step.  Legacy mode (``prefill_chunk_macs=None``):
        admit a prefill WAVE if there is capacity, else advance the whole
        decode batch one token.  Chunked mode: advance the in-flight
        admission by one bounded chunk AND decode the live batch in the
        SAME step.  Returns True if any work was done (in-flight
        submissions may still be outstanding — ``run()``/``drain()``
        reap them).  Durable servers: fires a due :class:`CrashPlan`
        BEFORE any work (the boundary a between-steps SIGKILL lands on),
        engages a requested drain, and snapshots on the
        ``snapshot_every`` cadence after the step's work."""
        if (self._crash_plan is not None and not self._replaying
                and self._crash_plan.due(self.stats.engine_steps)):
            plan, self._crash_plan = self._crash_plan, None
            raise SimulatedCrash(f"CrashPlan(at_step={plan.at_step})")
        if self._drain_requested:
            self._closing = True
        worked = self._step_inner()
        every = self.durable.snapshot_every if self.durable else 0
        if (self._ck is not None and not self._replaying and every
                and self.stats.engine_steps % every == 0):
            self.snapshot()
        return worked

    def _step_inner(self) -> bool:
        self.stats.engine_steps += 1
        if self.prefill_chunk_macs is None:
            live = any(r is not None for r in self.slot_req)
            if self._admit_wave():
                if live:
                    self.stats.decode_stall_steps += 1
                return True
            if live:
                self._do_decode()
                return True
            return False
        worked = False
        if (self._progress is not None and self._replaying
                and self._replay_next_is_admit()):
            # replay alignment: the recorded run's chunk chain had already
            # completed (conv graph timing is wall-clock, token values are
            # not) and the next journaled event is an admission — force the
            # chain to the same boundary so the wave slots free up now
            self._force_finish_progress()
            worked = True
        if self._progress is not None:
            worked = self._advance_prefill(self._progress) or worked
        elif self._admit_wave():
            worked = True
        if any(r is not None for r in self.slot_req):
            self._do_decode()
            worked = True
        return worked

    def run(self, until_drained: bool = True, max_steps: int = 10_000):
        while max_steps > 0:
            if self._drain_requested:
                # SIGTERM (or request_drain) landed: graceful close —
                # finish live generations, snapshot, release the pool
                self.close()
                return self.stats
            if not self.step():
                break
            max_steps -= 1
        self.drain()
        return self.stats

    def drain(self) -> ServeStats:
        """Finish any in-flight chunked admission (replay remainder plus
        the conv chunk chain, blocking under ``submit_timeout``), then
        reap every outstanding in-flight submission."""
        prog = self._progress
        if prog is not None:
            if prog.tok_i < prog.span:
                self._replay_span(prog, prog.tok_i, prog.span)
                prog.tok_i = prog.span
                self.stats.prefill_chunks += 1
            if not prog.finalized:
                self._finalize_replay(prog)
            conv = prog.conv
            while conv is not None and not conv.done:
                self._harvest_conv_blocking(conv)
            self._progress = None
        while self._inflight:
            self._reap_one()
        return self.stats

    # ------------------------------------------------------------ admission
    def _pick_requests(self, n: int) -> list[tuple[str, Request]]:
        """Weighted fair admission: up to ``n`` ``(tenant, request)``
        pairs, chosen head-of-queue by :class:`~repro.soc.qos_policy.
        FairShare` (priority first, then stride virtual time, deadline as
        the tie-break).  Peeks only — the caller validates the whole wave
        before committing the pops, preserving the legacy
        nothing-dropped-on-error invariant (an aborted wave leaves a
        little virtual-time drift, never a lost request)."""
        taken = {name: 0 for name in self._queues}
        picked: list[tuple[str, Request]] = []
        while len(picked) < n:
            cands = []
            for name, q in self._queues.items():
                i = taken[name]
                if i < len(q):
                    t = self.tenants[name]
                    cands.append((name, t.qos.priority, q[i].deadline_at,
                                  t.qos.weight))
            if not cands:
                break
            name = self._fair.pick(cands)
            picked.append((name, self._queues[name][taken[name]]))
            taken[name] += 1
            self._fair.charge(name, self.tenants[name].qos.weight)
        return picked

    def _admit_wave(self) -> int:
        """Admit ``min(pending, free slots)`` requests in ONE wave (one
        batched LM replay + one conv-front-end batch); ``"single"``
        admission caps the wave at 1 (the legacy baseline).  Tenanted
        servers pick wave members by weighted fair share instead of
        global FIFO; untenanted admission is byte-identical to before."""
        if self._closing:
            return 0
        if self._replaying:
            return self._replay_admit()
        free = [i for i, r in enumerate(self.slot_req)
                if r is None and i not in self._prefilling]
        if not self._qos_enabled:
            q = self._queues["default"]
            n = min(len(q), len(free))
            if self.admission == "single":
                n = min(n, 1)
            if n == 0:
                return 0
            # validate BEFORE popping: a bad request mid-wave must not
            # drop the wave members already taken off the pending queue
            wave = []
            for j, slot in enumerate(free[:n]):
                req = q[j]
                toks = req.tokens[: self.prefill_len]
                if toks.shape[0] == 0:
                    raise ValueError(f"request {req.rid}: empty prompt")
                wave.append((req, slot, toks))
            del q[:n]
            self._journal_admit(wave)
            tr = self._tracer
            if tr is not None:
                tr.emit("admission", "admission", outcome="admitted",
                        n=n, rids=[r.rid for r, _, _ in wave])
            self._do_prefill_wave(wave)
            return n
        navail = len(free)
        if self.admission == "single":
            navail = min(navail, 1)
        if navail == 0:
            return 0
        picked = self._pick_requests(navail)
        if not picked:
            return 0
        wave = []
        for (tname, req), slot in zip(picked, free):
            toks = req.tokens[: self.prefill_len]
            if toks.shape[0] == 0:
                raise ValueError(f"request {req.rid}: empty prompt")
            wave.append((req, slot, toks))
        now = time.monotonic()
        for tname, req in picked:
            self._queues[tname].remove(req)
            ts = self._tstats(tname)
            ts.admitted += 1
            wait = max(0.0, now - req.submitted_at)
            ts.queue_wait_s += wait
            ts.max_queue_wait_s = max(ts.max_queue_wait_s, wait)
            if self._qwait_hist is not None:
                self._qwait_hist.labels(tname).observe(wait)
        self._update_shed()
        self._journal_admit(wave)
        tr = self._tracer
        if tr is not None:
            tr.emit("admission", "admission", outcome="admitted",
                    n=len(wave), rids=[r.rid for _, r in picked],
                    tenants=[t for t, _ in picked])
        self._do_prefill_wave(wave)
        return len(wave)

    # ----------------------------------------------------------- durability
    def _journal_submit(self, req: Request) -> None:
        """WAL the accepted request BEFORE it enters its queue — after
        every admission check, so the journal holds exactly the accepted
        set (a rejected request must not be replayed)."""
        if self._journal is None or self._replaying:
            return
        self._journal.append({
            "t": "submit", "rid": int(req.rid),
            "tok": np.asarray(req.tokens, np.int64).tolist(),
            "new": int(req.max_new_tokens),
            "tenant": req.tenant, "dl": req.deadline_s})

    def _journal_admit(self, wave: list) -> None:
        """WAL one committed admission wave (rid -> slot assignment) —
        live admission timing is wall-clock-dependent (conv completion,
        submission interleave), so replay FORCES these assignments
        instead of re-running the scheduler."""
        if self._journal is None or self._replaying:
            return
        self._journal.append({
            "t": "admit",
            "wave": [[int(r.rid), int(slot)] for r, slot, _ in wave]})

    def _journal_emit(self, kind: str, emits: list) -> bool:
        """WAL one token-emission batch, or — during replay — verify the
        recomputation bitwise against the journaled record.  Returns True
        when the emission was a replay (already delivered; callers must
        not re-book throughput).  An exhausted replay queue mid-step
        means the crash interrupted that step: the events from here on
        were never delivered, so they journal (and book) fresh."""
        if self._journal is None:
            return False
        rec = {"t": kind, "e": emits}
        if self._replaying and self._replay_q:
            exp = self._replay_q.popleft()
            if exp.get("t") != kind or exp.get("e") != emits:
                self._restore_mismatch(exp, rec)
            return True
        self._journal.append(rec)
        return False

    def _restore_mismatch(self, expected, got) -> None:
        if self._flight is not None:
            self._flight.dump("restore_mismatch", stats=self.stats,
                              context={"expected": expected, "got": got})
        raise RestoreMismatch(expected, got)

    def _replay_next_is_admit(self) -> bool:
        return (bool(self._replay_q)
                and self._replay_q[0].get("t") == "admit")

    def _take_queued(self, rid: int):
        """Remove and return the pending request with ``rid`` (journal
        replay admits by identity, not queue position)."""
        for name, q in self._queues.items():
            for i, r in enumerate(q):
                if r.rid == rid:
                    del q[i]
                    return r, name
        return None, None

    def _replay_admit(self) -> int:
        """Force the next journaled admission wave: pop each recorded rid
        from its queue into its recorded slot.  FairShare is charged in
        the recorded wave order (identical virtual times afterwards), but
        ``pick`` never runs — the journal IS the schedule.  Per-tenant
        throughput stats are NOT re-booked (replay recomputes state, it
        does not re-serve)."""
        q = self._replay_q
        if not q or q[0].get("t") != "admit":
            return 0
        rec = q.popleft()
        if self._qos_enabled:
            # the recorded pick entered every then-pending tenant at the
            # vt floor — apply the same rule BEFORE popping wave members
            self._fair.join(name for name, pq in self._queues.items()
                            if pq)
        wave = []
        for rid, slot in rec["wave"]:
            rid, slot = int(rid), int(slot)
            req, tname = self._take_queued(rid)
            if (req is None or self.slot_req[slot] is not None
                    or slot in self._prefilling):
                self._restore_mismatch(
                    rec, {"rid": rid, "slot": slot,
                          "queued": req is not None,
                          "slot_busy": self.slot_req[slot] is not None})
            wave.append((req, slot, req.tokens[: self.prefill_len]))
            if (self._qos_enabled and tname is not None
                    and tname in self.tenants):
                self._fair.charge(tname, self.tenants[tname].qos.weight)
        if self._qos_enabled:
            self._update_shed()
        self._do_prefill_wave(wave)
        return len(wave)

    def _resubmit(self, rec: dict) -> None:
        """Replay one journaled submit: rebuild the Request and queue it
        directly — the crashed process already ran the admission checks,
        so bounds are bypassed (replay must never reject)."""
        req = Request(rid=int(rec["rid"]),
                      tokens=jnp.asarray(np.array(rec["tok"], np.int32)),
                      max_new_tokens=int(rec["new"]),
                      tenant=rec.get("tenant"),
                      deadline_s=rec.get("dl"))
        self._stamp_restored(req)
        name = (req.tenant if self._qos_enabled and req.tenant
                else "default")
        self._queues.setdefault(name, []).append(req)
        if self._qos_enabled:
            self._update_shed()
        self.restored_requests[req.rid] = req

    def _stamp_restored(self, req: Request) -> None:
        """Fresh submit/deadline stamps for a restored request — monotonic
        instants do not survive a process boundary, so SLO clocks restart
        at the restore (documented restore semantics: the crash pauses
        deadlines, it does not consume them)."""
        now = time.monotonic()
        req.submitted_at = now
        dl = req.deadline_s
        if (dl is None and self._qos_enabled
                and req.tenant in self.tenants):
            dl = self.tenants[req.tenant].qos.deadline_s
        req.deadline_at = now + dl if dl is not None else math.inf

    def _force_finish_progress(self) -> None:
        """Complete the in-flight chunked admission NOW (blocking): drain
        the remaining replay quanta and conv chunk chain.  Used by replay
        alignment and the snapshot-time quiesce path via ``drain()``."""
        prog = self._progress
        if prog is None:
            return
        if prog.tok_i < prog.span:
            self._replay_span(prog, prog.tok_i, prog.span)
            prog.tok_i = prog.span
            self.stats.prefill_chunks += 1
        if not prog.finalized:
            self._finalize_replay(prog)
        conv = prog.conv
        while conv is not None and not conv.done:
            self._harvest_conv_blocking(conv)
        self._progress = None

    # ----------------------------------------------- snapshots and restore
    @staticmethod
    def _req_state(req: Request) -> dict:
        return {"rid": int(req.rid),
                "tok": np.asarray(req.tokens, np.int64).tolist(),
                "new": int(req.max_new_tokens),
                "out": [int(x) for x in req.out],
                "tenant": req.tenant, "dl": req.deadline_s}

    def _req_from_state(self, st: dict) -> Request:
        req = Request(rid=int(st["rid"]),
                      tokens=jnp.asarray(np.array(st["tok"], np.int32)),
                      max_new_tokens=int(st["new"]),
                      out=[int(x) for x in st["out"]],
                      tenant=st.get("tenant"),
                      deadline_s=st.get("dl"))
        self._stamp_restored(req)
        self.restored_requests[req.rid] = req
        return req

    def _snapshot_state(self) -> dict:
        """The server as a FLAT ``{key: array}`` Checkpointer tree: cache
        leaves, in-flight prefill arrays, and one uint8 "meta" leaf
        holding every scalar/structural field as JSON (scalars survive a
        JSON round-trip bitwise; real arrays go as .npy leaves)."""
        leaves, _ = jax.tree_util.tree_flatten(self.cache)
        state = {f"cache_{i:04d}": leaf for i, leaf in enumerate(leaves)}
        meta: dict = {
            "version": 1,
            "journal_off": self._journal.offset(),
            "stats": dataclasses.asdict(self.stats),
            "slot_pos": [int(p) for p in self.slot_pos],
            "slots": [self._req_state(r) if r is not None else None
                      for r in self.slot_req],
            "queues": {name: [self._req_state(r) for r in q]
                       for name, q in self._queues.items()},
            "prefilling": sorted(self._prefilling),
            "fair": self._fair.snapshot(),
            "shed_level": self._shed_level,
            "calibrator": None, "runtime": None, "progress": None,
        }
        cal = self._calibration_engine()
        if cal is not None and hasattr(cal, "calibrator"):
            meta["calibrator"] = cal.calibrator.export_state()
        if self.runtime is not None:
            meta["runtime"] = self.runtime.state_snapshot()
        prog = self._progress
        if prog is not None:
            pmeta = {
                "wave": [[self._req_state(r), int(slot)]
                         for r, slot, _ in prog.wave],
                "span": int(prog.span), "tok_i": int(prog.tok_i),
                "finalized": bool(prog.finalized),
                "row_slots": sorted(prog.last_row), "conv": None,
            }
            state["prog_tok"] = prog.tok_np
            state["prog_pos"] = prog.pos_np
            for slot, row in prog.last_row.items():
                state[f"prog_row_{int(slot):04d}"] = np.asarray(row)
            conv = prog.conv
            if conv is not None and not conv.done:
                pmeta["conv"] = {
                    "wave_no": int(conv.wave), "idx": int(conv.idx),
                    "total": int(conv.total),
                    "n_frames": int(conv.n_frames),
                    "in_shape": (list(conv.in_shape)
                                 if conv.in_shape else None),
                    "rids": [int(r) for r in conv.rids],
                    "tenant_names": list(conv.tenant_names)}
                state["conv_x"] = np.asarray(conv.x)
            meta["progress"] = pmeta
        state["meta"] = meta_to_array(meta)
        return state

    def snapshot(self) -> int:
        """Take one crash-consistent snapshot at a quiescent boundary:
        reap the async window, harvest (without advancing) an outstanding
        conv chunk graph, quiesce the pool, save through the
        Checkpointer.  Returns the snapshot's step id."""
        if self._ck is None:
            raise RuntimeError("snapshot() needs durable=Durability(...)")
        while self._inflight:
            self._reap_one()
        prog = self._progress
        if (prog is not None and prog.conv is not None
                and prog.conv.fut is not None):
            # land the outstanding chunk so the carry is concrete, but do
            # NOT submit the next one: the snapshot captures the chain at
            # a chunk boundary and the next step resumes it
            conv = prog.conv
            vals = self._graph_result(conv.fut, conv.rids,
                                      conv.tenant_names)
            self._book_runtime("prefill", conv.fut.accounting, conv.fut)
            conv.x = vals[-1]
            conv.fut = None
        if self.runtime is not None:
            self.runtime.quiesce(self.submit_timeout)
        step = self.stats.engine_steps
        self._ck.save(step, self._snapshot_state(),
                      block=not self.durable.async_snapshots)
        self.stats.snapshots += 1
        tr = self._tracer
        if tr is not None:
            tr.emit("snapshot", "serving", step=step,
                    journal_off=self._journal.offset())
        return step

    def _apply_snapshot(self, flat: dict) -> dict:
        meta = array_to_meta(flat["meta"])
        st = dict(meta["stats"])
        tstats = st.pop("tenants", {})
        self.stats = ServeStats(**st)
        self.stats.tenants = {k: TenantStats(**v)
                              for k, v in tstats.items()}
        leaves, treedef = jax.tree_util.tree_flatten(self.cache)
        self.cache = jax.tree_util.tree_unflatten(
            treedef, [jnp.asarray(flat[f"cache_{i:04d}"])
                      for i in range(len(leaves))])
        self.slot_pos = [int(p) for p in meta["slot_pos"]]
        self.slot_req = [self._req_from_state(s) if s is not None else None
                         for s in meta["slots"]]
        queues: dict[str, list[Request]] = {n: [] for n in self.tenants}
        for name, q in meta["queues"].items():
            queues[name] = [self._req_from_state(s) for s in q]
        self._queues = queues
        self._prefilling = {int(s) for s in meta["prefilling"]}
        self._fair.restore(meta["fair"])
        self._shed_level = int(meta["shed_level"])
        if meta.get("calibrator") is not None:
            cal = self._calibration_engine()
            if cal is not None and hasattr(cal, "calibrator"):
                cal.calibrator.import_state(meta["calibrator"])
        if meta.get("runtime") is not None and self.runtime is not None:
            self.runtime.restore_state(meta["runtime"])
        if meta.get("progress") is not None:
            self._progress = self._rebuild_progress(meta["progress"], flat)
        return meta

    def _rebuild_progress(self, pmeta: dict, flat: dict) -> _PrefillProgress:
        wave = []
        for st, slot in pmeta["wave"]:
            req = self._req_from_state(st)
            wave.append((req, int(slot), req.tokens[: self.prefill_len]))
        lens = [int(t.shape[0]) for _, _, t in wave]
        prog = _PrefillProgress(
            wave, lens, int(pmeta["span"]),
            np.asarray(flat["prog_tok"], np.int32),
            np.asarray(flat["prog_pos"], np.int32), None,
            tok_i=int(pmeta["tok_i"]),
            finalized=bool(pmeta["finalized"]))
        for slot in pmeta["row_slots"]:
            prog.last_row[int(slot)] = jnp.asarray(
                flat[f"prog_row_{int(slot):04d}"])
        if pmeta.get("conv") is not None:
            prog.conv = self._rebuild_conv(pmeta["conv"], flat, wave)
        return prog

    def _rebuild_conv(self, cmeta: dict, flat: dict,
                      wave: list) -> _ConvProgress:
        """Reconstruct the chunk chain: jobsets/steps/groups are pure
        functions of (cnn, n_frames, wave_no, chunk_macs) — recomputed,
        not stored; only the carry array and the cursor come from disk."""
        from repro.models.cnn import conv_graph_steps
        wave_no, idx = int(cmeta["wave_no"]), int(cmeta["idx"])
        n_frames = int(cmeta["n_frames"])
        job = PrefillJob(wave_no, tuple(int(r) for r in cmeta["rids"]),
                         tuple(slot for _, slot, _ in wave),
                         n_frames=n_frames, cnn=self.prefill_cnn)
        jobsets = job.jobsets()
        steps = conv_graph_steps(self.prefill_cnn)
        groups = chunk_by_macs(jobsets, self.prefill_chunk_macs)
        hint_eng = (self._affinity_hint(jobsets[0], "prefill")
                    if jobsets else None)
        in_shape = (tuple(cmeta["in_shape"])
                    if cmeta.get("in_shape") else None)
        return _ConvProgress(
            wave_no,
            [([steps[i] for i in g], [jobsets[i] for i in g])
             for g in groups[idx:]],
            jnp.asarray(flat["conv_x"]), in_shape, n_frames,
            hint_eng.name if hint_eng is not None else None,
            total=int(cmeta["total"]), idx=idx,
            qos=self._prefill_qos(wave), rids=job.rids,
            tenant_names=tuple(cmeta["tenant_names"]))

    @classmethod
    def restore(cls, cfg, params, *, durable: Durability, **kwargs):
        """Reconstruct a durable server from ``durable.directory``: load
        the latest snapshot, then RE-EXECUTE the journal suffix —
        submits requeue, admissions are forced into their recorded
        slots, and every recomputed token is verified bitwise against
        its journal record (:class:`~repro.soc.durable.RestoreMismatch`
        + flight dump on divergence).  Replayed tokens book into
        ``replayed_tokens``; the returned server resumes serving with
        nothing lost and nothing double-served.  ``kwargs`` are the
        constructor's (the pool/config must match the crashed server's)."""
        srv = cls(cfg, params, durable=durable, **kwargs)
        off = 0
        if srv._ck.latest_step() is not None:
            _, flat = load_snapshot(srv._ck)
            meta = srv._apply_snapshot(flat)
            off = int(meta["journal_off"])
        records, _, _ = RequestJournal.scan(durable.journal_path,
                                            start=off)
        srv._replaying = True
        srv._replay_q = collections.deque(records)
        try:
            while srv._replay_q:
                while (srv._replay_q
                       and srv._replay_q[0].get("t") == "submit"):
                    srv._resubmit(srv._replay_q.popleft())
                if not srv._replay_q:
                    break
                if not srv.step():
                    srv._restore_mismatch(
                        srv._replay_q[0],
                        {"reason": "replay stalled: no work to run"})
            # replay-phase runtime work reaps under replay accounting;
            # an outstanding conv chunk lands but the chain stays at its
            # boundary for the live steps to resume
            while srv._inflight:
                srv._reap_one()
            prog = srv._progress
            if (prog is not None and prog.conv is not None
                    and prog.conv.fut is not None):
                conv = prog.conv
                vals = srv._graph_result(conv.fut, conv.rids,
                                         conv.tenant_names)
                srv._book_runtime("prefill", conv.fut.accounting, conv.fut)
                conv.x = vals[-1]
                conv.fut = None
        finally:
            srv._replaying = False
            srv._replay_q = None
        srv.stats.restores += 1
        tr = srv._tracer
        if tr is not None:
            tr.emit("restore", "serving", journal_off=off,
                    records=len(records),
                    replayed_tokens=srv.stats.replayed_tokens)
            if srv._journal.truncated_bytes:
                tr.emit("journal", "serving", outcome="torn_tail",
                        truncated_bytes=srv._journal.truncated_bytes)
        return srv

    # ------------------------------------------------------ graceful drain
    def request_drain(self) -> None:
        """Flag a graceful drain (async-signal-safe: sets a bool; the
        serving loop engages it at its next step and ``run()`` closes)."""
        self._drain_requested = True

    def close(self, deadline_s: float = 30.0, *,
              release_pool: bool = True) -> ServeStats:
        """Graceful shutdown: stop admission, run live generations to
        completion while ``deadline_s`` allows, drain in-flight work,
        snapshot (durable servers — pending requests survive into the
        snapshot for the next ``restore()``), close the journal, and
        release the pool."""
        self._closing = True
        t0 = time.monotonic()
        while (any(r is not None for r in self.slot_req)
               or self._progress is not None):
            if time.monotonic() - t0 >= deadline_s:
                break
            if not self.step():
                break
        self.drain()
        if self._ck is not None:
            self.snapshot()
            self._ck.wait()
            self._journal.close()
        tr = self._tracer
        if tr is not None:
            tr.emit("drain", "serving", deadline_s=deadline_s,
                    live=sum(r is not None for r in self.slot_req),
                    pending=len(self.pending))
        if release_pool and self.runtime is not None:
            self.runtime.shutdown()
        return self.stats

    # ------------------------------------------------------------ internals
    @staticmethod
    def _precision_class(engine: Optional[Engine]) -> str:
        return ("int8" if engine is not None
                and CAP_INT8 in engine.capabilities else "fp32")

    def _affinity_hint(self, js: JobSet, kind: str) -> Optional[Engine]:
        """The dispatcher's policy pick for this job class — the runtime
        queue-affinity hint (int8 for decode when one is registered)."""
        try:
            return self.dispatcher.select(js, job_class=kind)
        except RuntimeError:
            return None

    def _account_dispatch(self, kind: str, js: JobSet) -> Engine:
        """No-runtime path: route the JobSet whole to the dispatcher's
        pick and book its cost-model estimate."""
        eng = self.dispatcher.select(js, job_class=kind)
        if self._replaying:
            self.stats.replayed_jobs += js.num_jobs
            return eng
        est = eng.estimate(js)
        eng.telemetry.record(js, est)
        self.stats.job_busy_s[kind] += est
        self.stats.job_engine[kind] = eng.name
        self.stats.precision_jobs[self._precision_class(eng)] += js.num_jobs
        return eng

    def _book_runtime(self, kind: str, acct: dict, src=None) -> None:
        """Book one reaped runtime submission's per-engine accounting.
        ``src`` is the reaped future/graph itself, when available — its
        ``retries`` count (panels re-executed by the pool's RetryPolicy)
        rolls into ``stats.runtime_retries``."""
        if self._replaying:
            # replay recomputes state, it does not re-serve: the work is
            # real but its throughput was already delivered once
            self.stats.replayed_jobs += sum(
                a["jobs"] for a in acct.values())
            return
        if src is not None:
            self.stats.runtime_retries += getattr(src, "retries", 0)
        self.stats.job_busy_s[kind] += sum(a["est_s"] for a in acct.values())
        if acct:
            dominant = max(acct, key=lambda n: acct[n]["jobs"])
            self.stats.job_engine[kind] = dominant
        for name, a in acct.items():
            # pool engines need not be registry entries: resolve from
            # the runtime's live pool first, the registry second
            eng = self.runtime.find_engine(name) or find_engine(name)
            self.stats.precision_jobs[self._precision_class(eng)] \
                += a["jobs"]
        self.stats.runtime_jobs += sum(a["jobs"] for a in acct.values())
        self.stats.runtime_steals += sum(a["steals"] for a in acct.values())

    def _dump_timeout(self, name: str, rids, tenants) -> None:
        """Flight-record a serving timeout: event tail + runtime stats so
        the post-mortem shows WHERE the stuck submission's panels sat."""
        if self._flight is None:
            return
        rt_stats = self.runtime.stats() if self.runtime is not None else {}
        self._flight.dump(
            "serve_timeout",
            stats={"runtime": rt_stats, "serve": self.stats},
            context={"jobset": name, "rids": list(rids),
                     "tenants": list(tenants),
                     "timeout_s": self.submit_timeout})

    def _fut_result(self, fut, rids: tuple = (), tenants: tuple = ()):
        try:
            return fut.result(timeout=self.submit_timeout)
        except TimeoutError:
            self._dump_timeout(fut.jobset.name, rids, tenants)
            raise ServeTimeoutError(fut.jobset.name, self.submit_timeout,
                                    fut.accounting, rids, tenants) from None

    def _graph_result(self, gf, rids: tuple = (), tenants: tuple = ()):
        """Block on one prefill graph; a timeout CANCELS the graph —
        not-yet-started downstream nodes never launch and queued panels
        are drained — before surfacing :class:`ServeTimeoutError`."""
        try:
            return gf.result(timeout=self.submit_timeout)
        except TimeoutError:
            gf.cancel("serving submit_timeout")
            self._dump_timeout(gf.name, rids, tenants)
            raise ServeTimeoutError(gf.name, self.submit_timeout,
                                    gf.accounting, rids, tenants) from None

    # ----------------------------------------------------------- QoS tags
    def _req_tenant(self, req: Optional[Request]) -> Optional[Tenant]:
        if req is None or not self._qos_enabled:
            return None
        return self.tenants.get(req.tenant)

    def _decode_qos(self, slots: Sequence[int]) -> Optional[QosTag]:
        """The coalesced decode submission's tag: the MOST urgent live
        member wins — max priority, earliest absolute deadline."""
        if not self._qos_enabled:
            return None
        prio, dl = None, math.inf
        for s in slots:
            t = self._req_tenant(self.slot_req[s])
            if t is None:
                continue
            prio = (t.qos.priority if prio is None
                    else max(prio, t.qos.priority))
            dl = min(dl, self.slot_req[s].deadline_at)
        return None if prio is None else QosTag(prio, dl)

    def _prefill_qos(self, wave: list) -> Optional[QosTag]:
        """The wave's prefill tag: its most urgent member's class, one
        priority notch below decode (``PREFILL_PRIORITY_OFFSET``) so
        decode-class panels preempt bulk prefill at chunk boundaries."""
        if not self._qos_enabled:
            return None
        prio, dl = None, math.inf
        for req, _, _ in wave:
            t = self._req_tenant(req)
            if t is None:
                continue
            prio = (t.qos.priority if prio is None
                    else max(prio, t.qos.priority))
            dl = min(dl, req.deadline_at)
        return (None if prio is None
                else QosTag(prio + PREFILL_PRIORITY_OFFSET, dl))

    # ------------------------------------------------------ in-flight window
    def _push_inflight(self, inf: _Inflight) -> None:
        self._inflight.append(inf)
        while len(self._inflight) > self.max_inflight:
            self._reap_one()
        # peak is measured AFTER eviction: what stays outstanding past
        # the step (0 = fully synchronous, matching the field docs)
        self.stats.inflight_peak = max(self.stats.inflight_peak,
                                       len(self._inflight))

    def _reap_one(self) -> None:
        """Reap the OLDEST in-flight submission (FIFO — completions are
        booked in submission order, so per-slot accounting stays ordered),
        book its accounting, and feed the activation calibrator from the
        device-side ``max|a|`` launched at submit."""
        inf = self._inflight.popleft()
        if inf.graph is not None:
            self._graph_result(inf.graph, inf.rids, inf.tenant_names)
            self._book_runtime(inf.kind, inf.graph.accounting, inf.graph)
        results = [self._fut_result(f, inf.rids, inf.tenant_names)
                   for f in inf.futures]
        for fut in inf.futures:
            self._book_runtime(inf.kind, fut.accounting, fut)
        if inf.kind == "decode" and inf.layout is not None:
            live, nl = inf.layout
            n_cols = inf.cal_key[1]
            if inf.wide:
                # real-FFN n-stacked layout: rows are slots already
                n_per = n_cols // nl
                if inf.groups is not None:
                    # shed-ladder split: stitch the class groups' rows
                    # back into live-slot order
                    rows: list = [None] * live
                    for g, res in zip(inf.groups, results):
                        r3 = res.reshape(len(g), nl, n_per)
                        for k, j in enumerate(g):
                            rows[j] = r3[k]
                    y = jnp.stack(rows, 0)
                elif len(results) == 1:  # batched: (live, nl·n_per)
                    y = results[0].reshape(live, nl, n_per)
                else:                  # per-slot: one (1, nl·n_per) each
                    y = jnp.stack([r.reshape(nl, n_per) for r in results], 0)
            elif len(results) == 1:    # proxy batched: (nl·live, 4d)
                y = results[0].reshape(nl, live, n_cols).transpose(1, 0, 2)
            else:                      # proxy per-slot: one (nl, 4d) each
                y = jnp.stack(results, 0)
            if self.keep_decode_outputs:
                self.decode_gemm_outputs.append(y)
            eng = inf.cal_engine
            if (eng is not None and inf.amax is not None
                    and hasattr(eng, "observe_amax")):
                eng.observe_amax(float(inf.amax), *inf.cal_key)

    def _calibration_engine(self) -> Optional[Engine]:
        """The live pool's quantized engine (whose calibrator gates the
        runtime's int8 split), if any."""
        if self.runtime is None:
            return None
        for name in self.runtime.engine_names:
            eng = self.runtime.find_engine(name)
            if eng is not None and hasattr(eng, "observe_amax"):
                return eng
        return None

    def _has_fp32_engine(self) -> bool:
        """Whether the pool can execute grad-safe (non-int8) prefill
        panels — real conv compute needs one; otherwise prefill books
        accounting jobsets only."""
        for name in self.runtime.engine_names:
            eng = self.runtime.find_engine(name)
            if eng is not None and CAP_INT8 not in eng.capabilities:
                return True
        return False

    def _has_int8_engine(self) -> bool:
        """Whether the pool has an int8 engine — the shed ladder's
        degraded decode tier requires one (``decode_degraded`` is a hard
        int8 job class; without the engine shedding stays at rejection
        only)."""
        if self.runtime is None:
            return False
        for name in self.runtime.engine_names:
            eng = self.runtime.find_engine(name)
            if eng is not None and CAP_INT8 in eng.capabilities:
                return True
        return False

    def _degraded_rows(self, live: Sequence[int]) -> list[int]:
        """Row indices (into ``live``) whose slot belongs to a SHEDDABLE
        tenant while the load-shed ladder is engaged — their decode steps
        are routed through the int8-only ``decode_degraded`` class so the
        fp32 pool stays free for interactive traffic."""
        self._update_shed()
        if (not self._qos_enabled or self._shed_level == 0
                or not self._has_int8_engine()):
            return []
        out = []
        for j, slot in enumerate(live):
            req = self.slot_req[slot]
            t = self.tenants.get(req.tenant) if req is not None else None
            if t is not None and t.qos.sheddable:
                out.append(j)
        return out

    # -------------------------------------------------------------- prefill
    def _wave_frames(self, toks: jax.Array) -> Optional[jax.Array]:
        """The wave's conv-front-end input: each prompt token becomes one
        (H, W, Cin) frame by tiling its embedding row — the vision-encoder
        analog (deterministic, so prefill numerics are reproducible).
        None when the params carry no embedding table (accounting-only
        prefill)."""
        embed = (self.params.get("embed")
                 if isinstance(self.params, dict) else None)
        if embed is None:
            return None
        c = self.prefill_cnn
        hwc = c.input_hw * c.input_hw * c.cin
        vecs = embed[toks].astype(jnp.float32)            # (N, d_model)
        reps = -(-hwc // vecs.shape[1])
        flat = jnp.tile(vecs, (1, reps))[:, :hwc]
        return flat.reshape(vecs.shape[0], c.input_hw, c.input_hw, c.cin)

    def _im2col(self, x, kh, kw, stride, pad):
        """Wave gather indirection: resolves ``im2col_wave`` through THIS
        module's globals at call time, so instrumentation (tests count
        one gather per conv layer) hooks the serving module as before."""
        return im2col_wave(x, kh, kw, stride, pad)

    def _submit_prefill(self, job: PrefillJob, frames: Optional[jax.Array],
                        qos: Optional[QosTag] = None,
                        tenant_names: tuple = ()) -> Optional[_ConvProgress]:
        """Route the wave's conv JobSets: a REAL im2col+GEMM dataflow
        graph through the runtime when the pool can run grad-safe panels
        (chunked into a :class:`_ConvProgress` chain when
        ``prefill_chunk_macs`` is set, else one graph reaped through the
        in-flight window), a single batched accounting submission
        (``submit_many``) otherwise, and plain dispatcher estimates
        without a runtime.  ``qos`` tags every panel with the wave's
        prefill class.  Returns the in-flight chunk chain, if any."""
        jobsets = job.jobsets()
        if not jobsets:
            return None
        if self.runtime is None:
            for js in jobsets:
                self._account_dispatch("prefill", js)
            return None
        hint_eng = self._affinity_hint(jobsets[0], "prefill")
        hint = hint_eng.name if hint_eng is not None else None
        if frames is not None and self._has_fp32_engine():
            from repro.models.cnn import conv_graph_steps
            steps = conv_graph_steps(self.prefill_cnn)
            groups = chunk_by_macs(jobsets, self.prefill_chunk_macs)
            conv = _ConvProgress(
                job.wave,
                [([steps[i] for i in g], [jobsets[i] for i in g])
                 for g in groups],
                frames, None, job.n_frames, hint, total=len(groups),
                qos=qos, rids=job.rids, tenant_names=tenant_names)
            self._submit_conv_chunk(conv)
            if self.prefill_chunk_macs is None:
                # legacy: ONE graph for the whole wave, reaped (and
                # cancelled on timeout) through the in-flight window
                self._push_inflight(_Inflight(
                    "prefill", [], graph=conv.fut, rids=job.rids,
                    tenant_names=tenant_names))
                return None
            return conv
        futs = self.runtime.submit_many(jobsets, affinity=hint, qos=qos)
        self._push_inflight(_Inflight("prefill", futs, rids=job.rids,
                                      tenant_names=tenant_names))
        return None

    def _submit_conv_chunk(self, conv: _ConvProgress) -> None:
        """Build and submit the next chunk's dataflow graph (gather and
        GEMM nodes per conv layer, gathers gated on the previous layer's
        GEMM so they overlap its panel execution)."""
        from repro.models.cnn import conv_wave_graph
        steps, jss = conv.chunks.pop(0)
        nodes, edges = conv_wave_graph(
            self.prefill_cnn, self._cnn_params, conv.x, steps, jss,
            conv.n_frames, in_shape=conv.in_shape, affinity=conv.hint,
            im2col_fn=self._im2col, qos=conv.qos)
        name = (f"prefill/w{conv.wave}" if conv.total == 1
                else f"prefill/w{conv.wave}/c{conv.idx}")
        conv.fut = self.runtime.submit_graph(nodes, edges,
                                             affinity=conv.hint, name=name,
                                             qos=conv.qos)
        # the next chunk's first gather reshapes this chunk's flat output
        oh, ow, cout = steps[-1][3]
        conv.in_shape = (conv.n_frames, oh, ow, cout)
        conv.idx += 1
        if self.prefill_chunk_macs is not None:
            self.stats.prefill_chunks += 1

    def _advance_conv(self, conv: Optional[_ConvProgress]) -> bool:
        """Non-blocking chunk-chain progression: harvest a finished chunk
        graph (book accounting, take the carry) and submit the next."""
        if conv is None or conv.done:
            return False
        if conv.fut is not None:
            if not conv.fut.done():
                return False
            vals = conv.fut.result(0)
            self._book_runtime("prefill", conv.fut.accounting, conv.fut)
            conv.x = vals[-1]
            conv.fut = None
        if conv.chunks:
            self._submit_conv_chunk(conv)
        return True

    def _harvest_conv_blocking(self, conv: _ConvProgress) -> None:
        """Drain-path chunk harvest: block under ``submit_timeout``."""
        if conv.fut is not None:
            vals = self._graph_result(conv.fut, conv.rids,
                                      conv.tenant_names)
            self._book_runtime("prefill", conv.fut.accounting, conv.fut)
            conv.x = vals[-1]
            conv.fut = None
        if conv.chunks:
            self._submit_conv_chunk(conv)

    def _do_prefill_wave(self, wave: list) -> None:
        lens = [int(toks.shape[0]) for _, _, toks in wave]
        slots = [slot for _, slot, _ in wave]
        self.stats.prefill_waves += 1
        # conv front-end FIRST: workers crunch the wave's first conv layer
        # while the host replays the LM prompt below (ARM-side /
        # accelerator-side overlap, §4.3)
        job = PrefillJob(self.stats.prefill_waves,
                         tuple(r.rid for r, _, _ in wave), tuple(slots),
                         n_frames=sum(lens), cnn=self.prefill_cnn)
        frames = self._wave_frames(
            jnp.concatenate([toks for _, _, toks in wave]))
        conv = self._submit_prefill(
            job, frames, qos=self._prefill_qos(wave),
            tenant_names=tuple(r.tenant for r, _, _ in wave
                               if r.tenant))

        # slot reuse: zero the admitted slots' cache rows (every cache
        # tensor — K/V and SSM states alike — carries batch at axis 1).
        # Attention masks stale K/V anyway; recurrent SSM state NEEDS the
        # reset or a reused slot would continue the previous recurrence.
        sl = jnp.array(slots)
        self.cache = jax.tree.map(
            lambda a: a.at[:, sl].set(jnp.zeros_like(a[:, sl])), self.cache)

        # batched LM replay: ONE jitted decode call per token index covers
        # the WHOLE wave (each admitted slot at its own position; slots
        # not being admitted — live decoders included — stay masked -1, so
        # their K/V and SSM state are never written).
        span = max(lens)
        tok_np = np.zeros((span, self.slots, 1), np.int32)
        pos_np = np.full((span, self.slots), -1, np.int32)
        for (req, slot, toks), ln in zip(wave, lens):
            tok_np[:ln, slot, 0] = np.asarray(toks[:ln], np.int32)
            pos_np[:ln, slot] = np.arange(ln)
        prog = _PrefillProgress(wave, lens, span, tok_np, pos_np, conv)
        if self.prefill_chunk_macs is None:
            self._replay_span(prog, 0, span)
            self._finalize_replay(prog)
            return
        # chunked: reserve the slots and advance one quantum now; decode
        # runs in the SAME engine step (the disjoint-slot masking above
        # makes the interleave bitwise-invisible to live decoders)
        self._prefilling.update(slots)
        self._progress = prog
        self._advance_prefill(prog)

    def _replay_quantum(self, n_wave: int) -> int:
        """Token indices one replay chunk may cover: the MAC budget over
        the wave's per-token LM cost (~n_layers · 4·d_model² per slot)."""
        per_tok = max(1, n_wave * self.cfg.n_layers
                      * 4 * self.cfg.d_model * self.cfg.d_model)
        return max(1, int(self.prefill_chunk_macs) // per_tok)

    def _replay_span(self, prog: _PrefillProgress, i0: int, i1: int) -> None:
        for i in range(i0, i1):
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(prog.tok_np[i]),
                jnp.asarray(prog.pos_np[i]))
            for (req, slot, toks), ln in zip(prog.wave, prog.lens):
                if i == ln - 1:    # the prompt's last-token logits
                    prog.last_row[slot] = logits[slot, -1]

    def _finalize_replay(self, prog: _PrefillProgress) -> None:
        firsts = np.asarray(jnp.argmax(
            jnp.stack([prog.last_row[slot] for _, slot, _ in prog.wave]),
            axis=-1))
        replayed = False
        if self._journal is not None:
            emits = [[int(req.rid), int(slot), int(firsts[j])]
                     for j, (req, slot, _) in enumerate(prog.wave)]
            replayed = self._journal_emit("first", emits)
        for j, ((req, slot, toks), ln) in enumerate(zip(prog.wave,
                                                        prog.lens)):
            req.out.append(int(firsts[j]))
            self.slot_req[slot] = req
            self.slot_pos[slot] = ln
            if not replayed:
                self.stats.prefills += 1
                if self._qos_enabled and req.tenant in self.tenants:
                    self._tstats(req.tenant).prefills += 1
            self._prefilling.discard(slot)
        prog.finalized = True

    def _advance_prefill(self, prog: _PrefillProgress) -> bool:
        """One bounded chunk of the in-flight admission: harvest/submit a
        conv chunk if one completed, replay one LM token quantum.  Clears
        ``self._progress`` once replay AND conv chain are done."""
        worked = self._advance_conv(prog.conv)
        if prog.tok_i < prog.span:
            i1 = min(prog.span, prog.tok_i + self._replay_quantum(
                len(prog.wave)))
            self._replay_span(prog, prog.tok_i, i1)
            prog.tok_i = i1
            self.stats.prefill_chunks += 1
            worked = True
            if prog.tok_i >= prog.span:
                self._finalize_replay(prog)
        if prog.finalized and (prog.conv is None or prog.conv.done):
            self._progress = None
        return worked

    # --------------------------------------------------------------- decode
    def _build_decode_weight(self, cfg, params) -> jax.Array:
        """The coalesced decode GEMM's weight.  When the params expose the
        stacked per-layer FFN up-projection (``blocks.mlp.wi`` of shape
        (n_layers, d_model, 2·d_ff) — dense/vlm families), stack it along
        n into ``(d_model, n_layers·2·d_ff)`` so the decode GEMM computes
        every layer's REAL wi on the live embeddings.  Families without a
        dense FFN stack (moe experts, ssm/hybrid mamba blocks) fall back
        to the seeded proxy ``(d_model, 4·d_model)`` weight."""
        wi = None
        if isinstance(params, dict):
            blocks = params.get("blocks")
            if isinstance(blocks, dict):
                mlp = blocks.get("mlp")
                if isinstance(mlp, dict):
                    wi = mlp.get("wi")
        if (wi is not None and getattr(wi, "ndim", 0) == 3
                and wi.shape[0] == cfg.n_layers
                and wi.shape[1] == cfg.d_model):
            self._decode_ffn_cols = int(wi.shape[2])
            return jnp.transpose(wi, (1, 0, 2)).reshape(
                cfg.d_model,
                cfg.n_layers * self._decode_ffn_cols).astype(jnp.float32)
        self._decode_ffn_cols = None
        return (jax.random.normal(
            jax.random.key(0xD0), (cfg.d_model, 4 * cfg.d_model))
            * 0.05).astype(jnp.float32)

    def _slot_positions(self) -> jnp.ndarray:
        """(slots,) int32 of per-slot cache positions; -1 for empty slots."""
        return jnp.array(
            [self.slot_pos[i] if r is not None else -1
             for i, r in enumerate(self.slot_req)], jnp.int32)

    def _live_embeddings(self, toks: jnp.ndarray,
                         live: tuple[int, ...]) -> Optional[jax.Array]:
        """The step's LIVE-slot token embeddings — the activation panel of
        the decode GEMMs.  Empty slots are excluded: their padding
        token-0 embeddings are not traffic, and a large embed[0] row would
        inflate the max|a| EMA and waste int8 resolution on an artifact."""
        embed = (self.params.get("embed")
                 if isinstance(self.params, dict) else None)
        if embed is None or not live:
            return None
        return embed[toks[jnp.array(live), 0]].astype(jnp.float32)

    def _submit_decode(self, job: DecodeJob,
                       acts: Optional[jax.Array]) -> None:
        js = job.jobset()
        hint_eng = self._affinity_hint(js, "decode")
        hint = hint_eng.name if hint_eng is not None else None
        qos = self._decode_qos(job.slots)
        rids = tuple(self.slot_req[s].rid for s in job.slots
                     if self.slot_req[s] is not None)
        tnames = tuple(self.slot_req[s].tenant for s in job.slots
                       if self.slot_req[s] is not None
                       and self.slot_req[s].tenant)
        if acts is None:
            # no embedding table: accounting-only coalesced submission
            fut = self.runtime.submit(js, affinity=hint, qos=qos)
            self._push_inflight(_Inflight("decode", [fut], rids=rids,
                                          tenant_names=tnames))
            return
        d, nl = self.cfg.d_model, self.cfg.n_layers
        w = self._decode_w
        n_cols = int(w.shape[1])
        wide = self._decode_ffn_cols is not None
        deg = self._degraded_rows(job.slots)
        degraded_applied = False
        cal = self._calibration_engine()
        if cal is None and hasattr(hint_eng, "observe_amax"):
            cal = hint_eng
        # device-side max|a| launched NOW, folded into the EMA at reap —
        # skipped entirely when nothing will consume it (fp32-only pool)
        amax = jnp.max(jnp.abs(acts)) if cal is not None else None
        groups = None
        if self.decode_mode == "batched":
            # ONE coalesced submission: real-FFN mode stacks every
            # layer's wi along n (rows = live slots); the proxy stacks
            # the per-layer GEMM along m — either way, one row-panel
            # split amortizes dispatch
            if wide and deg and len(deg) < len(job.slots):
                # shed ladder engaged on a mixed wave: split the row
                # panel so sheddable tenants' rows run through the
                # int8-only degraded class while the rest keep the full
                # decode class (stitched back by row index at reap)
                norm = tuple(j for j in range(len(job.slots))
                             if j not in set(deg))
                groups = (norm, tuple(deg))
                degraded_applied = True
                futs = []
                for g, jc in zip(groups, ("decode", "decode_degraded")):
                    js_g = JobSet.for_gemm(
                        job.step, len(g), n_cols, d, _SERVE_TILE,
                        name=f"decode/s{job.step}/{jc}")
                    h_eng = self._affinity_hint(js_g, jc)
                    futs.append(self.runtime.submit_gemm(
                        acts[jnp.array(g)], w, jobset=js_g,
                        tile=(_SERVE_TILE,) * 3, job_class=jc,
                        affinity=h_eng.name if h_eng is not None else None,
                        qos=self._decode_qos([job.slots[j] for j in g]),
                        observe_acts=False))
            else:
                jc = "decode"
                if wide and deg and len(deg) == len(job.slots):
                    jc = "decode_degraded"
                    degraded_applied = True
                a = acts if wide else jnp.tile(acts, (nl, 1))
                futs = [self.runtime.submit_gemm(
                    a, w, jobset=js, tile=(_SERVE_TILE,) * 3,
                    job_class=jc, affinity=hint, qos=qos,
                    observe_acts=False)]
        else:
            # the sequential per-slot baseline (one submission per slot)
            futs = []
            degset = set(deg)
            for j, slot in enumerate(job.slots):
                m_j = 1 if wide else nl
                jc = "decode_degraded" if j in degset else "decode"
                degraded_applied = degraded_applied or jc != "decode"
                js_j = JobSet.for_gemm(
                    job.step, m_j, n_cols, d, _SERVE_TILE,
                    name=f"decode/s{job.step}/slot{slot}")
                a_j = (acts[j:j + 1] if wide
                       else jnp.tile(acts[j:j + 1], (nl, 1)))
                futs.append(self.runtime.submit_gemm(
                    a_j, w, jobset=js_j, tile=(_SERVE_TILE,) * 3,
                    job_class=jc, affinity=hint, qos=qos,
                    observe_acts=False))
        if degraded_applied:
            self.stats.shed_degraded_steps += 1
            for j in deg:
                req = self.slot_req[job.slots[j]]
                if req is not None and req.tenant in self.tenants:
                    self._tstats(req.tenant).degraded_steps += 1
        self._push_inflight(_Inflight(
            "decode", futs, cal_engine=cal, amax=amax, cal_key=(d, n_cols),
            layout=(len(job.slots), nl), wide=wide, groups=groups,
            rids=rids, tenant_names=tnames))

    def _do_decode(self) -> None:
        live = tuple(i for i, r in enumerate(self.slot_req) if r is not None)
        # ONE host->device transfer for the step's token batch (per-slot
        # .at[] updates would dispatch an eager op per live slot per step)
        toks_np = np.zeros((self.slots, 1), np.int32)
        for i, r in enumerate(self.slot_req):
            if r is not None and r.out:
                toks_np[i, 0] = r.out[-1]
        toks = jnp.asarray(toks_np)
        job = DecodeJob(self.stats.decode_steps, live, self.cfg.d_model,
                        self.cfg.n_layers, self._decode_ffn_cols)
        acts = self._live_embeddings(toks, live)
        if self.runtime is not None:
            self._submit_decode(job, acts)
        else:
            eng = self._account_dispatch("decode", job.jobset())
            if acts is not None and hasattr(eng, "observe_activations"):
                eng.observe_activations(acts, self.cfg.d_model,
                                        int(self._decode_w.shape[1]))
        # per-slot positions: each live slot reads/writes at ITS OWN index
        # (a shared max(pos) would smear late-arriving requests' tokens
        # into earlier requests' cache rows); empty slots are masked (-1)
        pos = self._slot_positions()
        logits, self.cache = self._decode(self.params, self.cache, toks, pos)
        self.stats.decode_steps += 1
        # ONE device argmax + ONE host sync for the whole batch (a
        # per-slot int(jnp.argmax(...)) costs an eager op + sync per slot)
        nxt_all = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
        replayed = False
        if self._journal is not None:
            # WAL the step's emissions BEFORE appending to the visible
            # streams (during replay: verify bitwise instead)
            emits = [[int(r.rid), i, int(nxt_all[i])]
                     for i, r in enumerate(self.slot_req) if r is not None]
            replayed = self._journal_emit("tok", emits)
        now = time.monotonic()
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            nxt = int(nxt_all[i])
            r.out.append(nxt)
            self.slot_pos[i] += 1
            if replayed:
                self.stats.replayed_tokens += 1
            else:
                self.stats.tokens_out += 1
                if self._qos_enabled and r.tenant in self.tenants:
                    self._tstats(r.tenant).tokens_out += 1
            done = (len(r.out) >= r.max_new_tokens
                    or self.slot_pos[i] >= self.max_len - 1)
            if done:
                # stamped on EVERY server so attainment is computable
                # post-hoc even without tenancy
                r.done_at = now
                if (not replayed and self._qos_enabled
                        and r.tenant in self.tenants
                        and math.isfinite(r.deadline_at)):
                    ts = self._tstats(r.tenant)
                    hit = now <= r.deadline_at
                    if hit:
                        ts.deadline_hits += 1
                    else:
                        ts.deadline_misses += 1
                    tr = self._tracer
                    if tr is not None:
                        tr.emit("deadline_hit" if hit else "deadline_miss",
                                "serving", rid=r.rid, tenant=r.tenant,
                                margin_s=r.deadline_at - now)
                self.slot_req[i] = None   # free the slot (continuous batching)
