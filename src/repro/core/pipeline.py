"""Inter-frame pipelining (paper C4) at two scales.

1. :class:`ThreadedPipeline` — the faithful reproduction of the paper's
   HW/SW multi-threaded pipeline: one thread per layer/stage, mailbox
   (bounded synchronized FIFO) between stages, multiple frames in flight.
   Used by the CNN inference example and the utilization benchmarks
   (paper Table 6).

2. :func:`gpipe_spmd` — the pod-scale adaptation: GPipe-style microbatch
   pipeline across a mesh axis inside ``shard_map``.  Stages map to pods;
   activations move with ``jax.lax.ppermute`` (point-to-point on the slow
   inter-pod ICI links — the same communication-pattern argument the paper
   makes for pipelining across heterogeneous fabric).  ``gpipe_reference``
   is the pure-software oracle used by the tests.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp

__all__ = ["ThreadedPipeline", "EngineStage", "StageStats",
           "PipelineStageError", "gpipe_reference", "gpipe_spmd"]


# ---------------------------------------------------------------------------
# 1. Faithful: threaded layer pipeline with mailboxes
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StageStats:
    name: str
    busy_s: float = 0.0
    frames: int = 0
    engine: Optional[str] = None


@dataclasses.dataclass
class EngineStage:
    """A pipeline stage bound to the engine registry.

    ``fn`` processes one frame's payload; ``engine`` (optional) pins the
    stage's GEMMs to a registered engine — the worker runs ``fn`` under
    ``repro.engines.engine_scope``, so every ``synergy_matmul`` traced
    inside routes there (already-jitted fns keep the routing of their
    first trace), and the stage is attributed in the run stats.
    :meth:`gemm` builds the common case — a stage that IS one dense GEMM —
    directly on ``synergy_matmul``, so stage compute flows through the
    same dispatch surface as everything else."""

    name: str
    fn: Callable[[Any], Any]
    engine: Optional[str] = None

    @classmethod
    def gemm(cls, name: str, w, *, bias=None, activation=None,
             tile=None, engine: Optional[str] = None) -> "EngineStage":
        from .synergy_mm import DEFAULT_TILE, synergy_matmul
        tile = tile if tile is not None else DEFAULT_TILE

        def fn(a):
            return synergy_matmul(a, w, bias=bias, activation=activation,
                                  tile=tile, name=name, engine=engine)
        return cls(name, fn, engine)

    def __call__(self, payload):
        return self.fn(payload)


def _as_stage(spec: Union["EngineStage", tuple]) -> EngineStage:
    if isinstance(spec, EngineStage):
        return spec
    name, fn = spec
    return EngineStage(name, fn)


_STOP = object()


@dataclasses.dataclass
class _Failure:
    """A stage exception, traveling the pipe in place of the frame so every
    downstream mailbox keeps draining (no deadlock)."""

    stage: str
    error: BaseException


class PipelineStageError(RuntimeError):
    """Raised by :meth:`ThreadedPipeline.run` when a stage raised; the
    original exception is chained as ``__cause__``."""


class ThreadedPipeline:
    """Producer/consumer layer pipeline (paper §3.1, Figure 2).

    stages: list of :class:`EngineStage` or (name, fn) tuples — fn
    processes one frame's payload.  mailbox_capacity bounds frames in
    flight between adjacent stages.

    ``runtime``: an optional :class:`repro.soc.SynergyRuntime` — stage
    workers run under its :func:`~repro.soc.runtime_scope`, so stage GEMMs
    split across the engine pool and an ``EngineStage.engine`` pin becomes
    a queue-affinity hint rather than a hard route.  When None, a runtime
    scope active in the caller's thread at :meth:`run` time is inherited.

    A raising stage does NOT deadlock the pipe: the exception travels
    downstream as a poison frame, every worker keeps draining its inbox,
    and :meth:`run` re-raises :class:`PipelineStageError` after joining.
    """

    def __init__(self,
                 stages: Sequence[Union[EngineStage,
                                        tuple[str, Callable[[Any], Any]]]],
                 mailbox_capacity: int = 4,
                 runtime: Optional[Any] = None):
        self.stages = [_as_stage(s) for s in stages]
        self.mailboxes = [queue.Queue(maxsize=mailbox_capacity)
                          for _ in range(len(self.stages) + 1)]
        self.stats = [StageStats(s.name, engine=s.engine)
                      for s in self.stages]
        self.runtime = runtime

    def _worker(self, idx: int, runtime) -> None:
        import contextlib

        from repro.engines import engine_scope
        stage = self.stages[idx]
        fn = stage.fn
        if stage.engine is not None:
            raw = fn

            def fn(item):
                with engine_scope(stage.engine):
                    return raw(item)
        inbox, outbox = self.mailboxes[idx], self.mailboxes[idx + 1]
        st = self.stats[idx]
        if runtime is not None:
            from repro.soc import runtime_scope
            scope = runtime_scope(runtime)
        else:
            scope = contextlib.nullcontext()
        with scope:
            while True:
                item = inbox.get()
                if item is _STOP:
                    outbox.put(_STOP)
                    return
                if isinstance(item, _Failure):   # pass the poison through
                    outbox.put(item)
                    continue
                t0 = time.perf_counter()
                try:
                    out = fn(item)
                except BaseException as e:
                    out = _Failure(stage.name, e)
                st.busy_s += time.perf_counter() - t0
                st.frames += 1
                outbox.put(out)

    def run(self, frames: Sequence[Any]) -> tuple[list[Any], dict]:
        runtime = self.runtime
        if runtime is None:
            from repro.soc import current_runtime
            runtime = current_runtime()
        threads = [threading.Thread(target=self._worker, args=(i, runtime),
                                    daemon=True)
                   for i in range(len(self.stages))]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        feeder = threading.Thread(
            target=lambda: ([self.mailboxes[0].put(f) for f in frames],
                            self.mailboxes[0].put(_STOP)),
            daemon=True)
        feeder.start()
        outputs = []
        failure: Optional[_Failure] = None
        while True:
            item = self.mailboxes[-1].get()
            if item is _STOP:
                break
            if isinstance(item, _Failure):
                failure = failure or item       # keep draining to _STOP
                continue
            outputs.append(item)
        wall = time.perf_counter() - t0
        for t in threads:
            t.join()
        feeder.join()
        if failure is not None:
            raise PipelineStageError(
                f"stage {failure.stage!r} raised "
                f"{type(failure.error).__name__}: {failure.error}"
            ) from failure.error
        util = {s.name: (s.busy_s / wall if wall > 0 else 0.0) for s in self.stats}
        return outputs, {
            "wall_s": wall,
            "fps": len(outputs) / wall if wall > 0 else 0.0,
            "stage_utilization": util,
            "stage_engines": {s.name: s.engine for s in self.stats
                              if s.engine is not None},
            "runtime": runtime.stats() if runtime is not None else None,
        }


# ---------------------------------------------------------------------------
# 2. Pod-scale: GPipe microbatch pipeline under shard_map
# ---------------------------------------------------------------------------

def gpipe_reference(stage_fn: Callable[[Any, jax.Array], jax.Array],
                    stage_params: Sequence[Any],
                    microbatches: jax.Array) -> jax.Array:
    """Oracle: apply stages sequentially to each microbatch.

    stage_params: length-S list of per-stage params; microbatches: (M, ...).
    """
    def per_mb(x):
        for p in stage_params:
            x = stage_fn(p, x)
        return x
    return jax.vmap(per_mb)(microbatches)


def gpipe_spmd(stage_fn: Callable[[Any, jax.Array], jax.Array],
               my_params: Any,
               microbatches: jax.Array,
               *,
               axis_name: str,
               num_stages: int) -> jax.Array:
    """GPipe forward pipeline, called INSIDE shard_map.

    Each device along ``axis_name`` holds one stage's params (``my_params``)
    and the full microbatch stream (M, ...) enters at stage 0.  The schedule
    runs M + S - 1 ticks; at each tick every stage processes its current
    microbatch and ppermutes the activation to the next stage, overlapping
    per-tick compute with the point-to-point transfer (XLA schedules the
    ppermute async against the next tick's compute).

    Returns the (M, ...) outputs, valid on the LAST stage (stage < S-1
    devices return zeros) — callers typically ppermute/psum the result back.
    """
    stage = jax.lax.axis_index(axis_name)
    m = microbatches.shape[0]
    ticks = m + num_stages - 1
    x_shape = microbatches.shape[1:]

    def tick(carry, t):
        state, outputs = carry      # state: activation entering this stage
        # stage 0 injects microbatch t (if within range)
        inject = jnp.where(t < m, t, m - 1)
        x0 = microbatches[inject]
        x_in = jnp.where(stage == 0, x0, state)
        y = stage_fn(my_params, x_in)
        # collect finished microbatch on the last stage (masked write — a
        # lax.cond here would give the branches different varying-axis
        # types under shard_map)
        out_idx = t - (num_stages - 1)
        valid = (stage == num_stages - 1) & (out_idx >= 0) & (out_idx < m)
        updated = outputs.at[jnp.clip(out_idx, 0, m - 1)].set(y)
        outputs = jnp.where(valid, updated, outputs)
        # shift activations stage i -> i+1 (ring permute; last->first unused)
        perm = [(i, (i + 1) % num_stages) for i in range(num_stages)]
        state = jax.lax.ppermute(y, axis_name, perm)
        return (state, outputs), None

    # stages must preserve activation shape (residual-block property), so the
    # output stream has the input microbatch shape.
    outputs0 = jnp.zeros((m,) + x_shape, dtype=microbatches.dtype)
    state0 = jnp.zeros(x_shape, dtype=microbatches.dtype)
    # the loop body makes the carry vary over the stage axis (ppermute /
    # axis_index); mark the initial carry varying so scan types match
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is not None:
        state0 = pcast(state0, (axis_name,), to="varying")
        outputs0 = pcast(outputs0, (axis_name,), to="varying")
    (_, outputs), _ = jax.lax.scan(tick, (state0, outputs0),
                                   jnp.arange(ticks))
    return outputs
