"""im2col: data-layout transformation turning convolution into GEMM (§3.1.1).

The paper follows Caffe/Darknet: flatten each (kh, kw, cin) receptive field
into a row, so ``conv(x, w)`` becomes ``A[m, k] @ B[k, n]`` with

    m = out_h * out_w          (per image)
    k = kh * kw * cin
    n = cout

We keep NHWC layout (TPU-native) rather than Darknet's NCHW.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.lru_cache(maxsize=256)
def _patch_index_grids(oh: int, ow: int, kh: int, kw: int,
                       stride: int) -> tuple[np.ndarray, np.ndarray]:
    """(OH, KH) row and (OW, KW) col gather indices, memoized: a CNN
    forward pass calls im2col once per conv layer per step with the same
    handful of geometries, and rebuilding the grids costs numpy work on
    every call of what is otherwise a pure-JAX hot path.  Treat the
    returned arrays as read-only (they are shared across calls)."""
    i0 = np.arange(oh) * stride
    j0 = np.arange(ow) * stride
    rows = i0[:, None] + np.arange(kh)[None, :]
    cols = j0[:, None] + np.arange(kw)[None, :]
    return rows, cols


def im2col(x: jax.Array, kh: int, kw: int, stride: int = 1,
           padding: int = 0) -> jax.Array:
    """x: (N, H, W, C) -> patches (N, OH*OW, KH*KW*C)."""
    n, h, w, c = x.shape
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (w + 2 * padding - kw) // stride + 1
    # extract_patches via gather of strided slices; vectorized with reshape
    # trick: index grids are static per (geometry) — memoized above.
    rows, cols = _patch_index_grids(oh, ow, kh, kw, stride)
    # gather -> (N, OH, KH, W', C) -> (N, OH, KH, OW, KW, C)
    patches = x[:, rows, :, :]           # (N, OH, KH, W+2p, C)
    patches = patches[:, :, :, cols, :]  # (N, OH, KH, OW, KW, C)
    patches = patches.transpose(0, 1, 3, 2, 4, 5)  # (N, OH, OW, KH, KW, C)
    return patches.reshape(n, oh * ow, kh * kw * c)


def im2col_wave(x: jax.Array, kh: int, kw: int, stride: int = 1,
                padding: int = 0) -> jax.Array:
    """Batched multi-image im2col for a serving admission wave.

    x: (N, H, W, C) — ALL frames of the wave stacked along the batch axis
    (every admitted request's frames together) — returns the flattened
    (N*OH*OW, KH*KW*C) GEMM activation panel in one call.  The point is
    the amortization: ONE gather (and one memoized index-grid lookup, see
    :func:`_patch_index_grids`) covers the whole wave, instead of one
    gather per request; the panel feeds a single batched conv GEMM whose
    row-panel split the runtime then spreads across the pool."""
    n = x.shape[0]
    patches = im2col(x, kh, kw, stride, padding)
    return patches.reshape(n * patches.shape[1], patches.shape[2])


def conv_out_shape(h: int, w: int, kh: int, kw: int, stride: int,
                   padding: int) -> tuple[int, int]:
    return ((h + 2 * padding - kh) // stride + 1,
            (w + 2 * padding - kw) // stride + 1)


def conv2d_gemm(x: jax.Array, w: jax.Array, stride: int = 1, padding: int = 0,
                matmul=None) -> jax.Array:
    """Convolution via im2col + GEMM (the Synergy CONV path).

    x: (N, H, W, Cin); w: (KH, KW, Cin, Cout) -> (N, OH, OW, Cout).
    ``matmul`` lets callers route the GEMM through ``synergy_mm`` (tile jobs);
    defaults to jnp.matmul.
    """
    kh, kw, cin, cout = w.shape
    n, h, wd, _ = x.shape
    oh, ow = conv_out_shape(h, wd, kh, kw, stride, padding)
    a = im2col(x, kh, kw, stride, padding)          # (N, OH*OW, K)
    b = w.reshape(kh * kw * cin, cout)              # (K, Cout)
    mm = matmul if matmul is not None else jnp.matmul
    out = mm(a.reshape(n * oh * ow, -1), b)         # (N*OH*OW, Cout)
    return out.reshape(n, oh, ow, cout)
